"""Refresh benchmarks/baselines.json from a BENCH_matrix.json run.

  PYTHONPATH=src python tools/update_baseline.py BENCH_matrix.json \
      [--baselines benchmarks/baselines.json] [--cells id1,id2,...]
      [--enforce-timing] [--dry-run]

For every declared cell in the report, writes/updates one baseline entry:

* timing cells  -> ``{median_s, sigma_s, n, config_hash, enforce}``
* exact cells   -> ``{hash, config_hash, enforce}``

The ``config_hash`` makes the entry self-invalidating: when a cell's
declarative config changes, the gates treat the old entry as *stale* and
fall back to in-run-reference-only — never a silent pass against a
meaningless number (see repro.bench.gates.baseline_entry).

Enforcement policy on merge:

* an EXISTING entry keeps its ``enforce`` flag (curation survives
  refreshes);
* a NEW timing entry defaults to ``enforce: false`` — advisory — because
  CI hosts are not the curator's host; flip it by hand (or pass
  ``--enforce-timing``) only for cells you trust cross-machine;
* a NEW exact (value-hash) entry defaults to ``enforce: true`` — the
  figure cells are deterministic model outputs, so any hash drift is a
  real reproducibility break.

Baselines from a *smoke* run are refused unless ``--allow-smoke``: smoke
cells run fewer repeats/requests, and curating them would quietly loosen
the full-run gates.
"""

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.bench.gates import BASELINE_SCHEMA, SCHEMA, validate_report


def baseline_from_cell(cell: dict, old: dict | None,
                       enforce_timing: bool) -> dict | None:
    kind = cell.get("kind")
    if cell.get("missing") or not cell.get("declared", True):
        return None
    if kind == "timing" and cell.get("timing"):
        t = cell["timing"]
        return {
            "kind": "timing",
            "median_s": t["median_s"],
            "sigma_s": t["sigma_s"],
            "n": t["n"],
            "config_hash": cell["config_hash"],
            "enforce": old["enforce"] if old and "enforce" in old
            else enforce_timing,
        }
    if kind == "exact" and cell.get("hash"):
        return {
            "kind": "exact",
            "hash": cell["hash"],
            "config_hash": cell["config_hash"],
            "enforce": old["enforce"] if old and "enforce" in old else True,
        }
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Merge a BENCH_matrix.json run into the checked-in "
                    "baselines (see docs/benchmarks.md)")
    ap.add_argument("report", help="BENCH_matrix.json from a full run")
    ap.add_argument("--baselines", default="benchmarks/baselines.json")
    ap.add_argument("--cells", default="",
                    help="comma-separated cell ids to update (default: all)")
    ap.add_argument("--enforce-timing", action="store_true",
                    help="NEW timing entries get enforce:true (default "
                         "advisory)")
    ap.add_argument("--allow-smoke", action="store_true",
                    help="accept a smoke-run report (normally refused)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the merged baselines, write nothing")
    args = ap.parse_args(argv)

    report = json.loads(pathlib.Path(args.report).read_text())
    errs = validate_report(report)
    if errs:
        print(f"refusing invalid report ({len(errs)} schema error(s)):",
              file=sys.stderr)
        for e in errs[:10]:
            print(f"  {e}", file=sys.stderr)
        return 1
    assert report.get("schema") == SCHEMA
    if report.get("smoke") and not args.allow_smoke:
        print("refusing a --smoke report: smoke cells run fewer repeats; "
              "curate baselines from a full run (or pass --allow-smoke)",
              file=sys.stderr)
        return 1

    bpath = pathlib.Path(args.baselines)
    baselines = (json.loads(bpath.read_text()) if bpath.exists()
                 else {"schema": BASELINE_SCHEMA, "cells": {}})
    assert baselines.get("schema") == BASELINE_SCHEMA

    only = {c.strip() for c in args.cells.split(",") if c.strip()}
    updated, skipped = [], []
    for cid, cell in report.get("cells", {}).items():
        if only and cid not in only:
            continue
        entry = baseline_from_cell(cell, baselines["cells"].get(cid),
                                   args.enforce_timing)
        if entry is None:
            skipped.append(cid)
            continue
        baselines["cells"][cid] = entry
        updated.append(cid)

    baselines["source"] = {
        "matrix_config_hash": report.get("matrix_config_hash"),
        "smoke": bool(report.get("smoke")),
    }
    text = json.dumps(baselines, indent=1, sort_keys=True) + "\n"
    if args.dry_run:
        print(text)
    else:
        bpath.write_text(text)
    enforced = sum(1 for e in baselines["cells"].values() if e.get("enforce"))
    print(f"{'would update' if args.dry_run else 'updated'} {len(updated)} "
          f"entr{'y' if len(updated) == 1 else 'ies'} in {bpath} "
          f"({enforced}/{len(baselines['cells'])} enforced); "
          f"skipped {len(skipped)} contract/missing cell(s)",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
