"""Docs checker: execute every ```python snippet and validate intra-doc
links (the CI docs job — .github/workflows/ci.yml).

Usage:
  PYTHONPATH=src python tools/check_docs.py [files...]

Defaults to README.md + docs/*.md.  Rules:

* every fenced ```python block must run to completion in a fresh
  subprocess with PYTHONPATH=src (snippets are self-contained by
  convention; put `<!-- notest -->` on the line directly above a fence to
  skip one, e.g. for deliberately-failing or accelerator-only examples);
* every relative markdown link target must exist on disk (external
  http(s)/mailto links are not fetched).

Exit code 0 iff everything passes; failures print the file, the snippet
index or link, and the captured stderr.
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

FENCE = re.compile(r"^```(\w*)\s*$")
LINK = re.compile(r"\[[^\]]*\]\(([^)\s#]+)(#[^)]*)?\)")
SKIP_MARK = "<!-- notest -->"


def extract_snippets(text: str) -> list[tuple[int, str, bool]]:
    """(start_line, code, skip) for each fenced ```python block."""
    out = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m and m.group(1) == "python":
            skip = i > 0 and SKIP_MARK in lines[i - 1]
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            out.append((start, "\n".join(body), skip))
        i += 1
    return out


def run_snippet(code: str, cwd: pathlib.Path) -> tuple[bool, str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900, cwd=str(cwd), env=env,
    )
    return proc.returncode == 0, proc.stderr[-3000:]


def check_links(path: pathlib.Path, text: str) -> list[str]:
    errors = []
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files = [pathlib.Path(a).resolve() for a in argv] or [
        ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    failures: list[str] = []
    n_snips = 0
    for path in files:
        text = path.read_text()
        failures += check_links(path, text)
        for line, code, skip in extract_snippets(text):
            rel = path.relative_to(ROOT) if path.is_relative_to(ROOT) \
                else path
            if skip:
                print(f"SKIP {rel}:{line} (notest)")
                continue
            n_snips += 1
            ok, err = run_snippet(code, ROOT)
            status = "ok" if ok else "FAIL"
            print(f"{status:4} {rel}:{line}")
            if not ok:
                failures.append(f"{rel}:{line} snippet failed:\n{err}")
    for f in failures:
        print(f, file=sys.stderr)
    print(f"{n_snips} snippets run, {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
