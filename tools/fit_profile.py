"""Measured-bandwidth profile calibration (ROADMAP: close the loop from
census to table).

``benchmarks/comm_bench.py`` emits, per gather policy, a ``fit_inputs``
ledger: the measured wall time per step plus the analytical per-stage
(tier, α-events, wire bytes) census of that policy's collectives.  Each
policy routes different byte/event mixes over the two link tiers, so the
set of policies over-determines the α-β model

    t_measured ≈ t0 + Σ_stages  alpha_events · α(tier) + wire_bytes / β(tier)

(``t0`` absorbs the policy-independent compute).  This tool least-squares
that system per tier and emits a ready-to-paste
``repro.core.linkmodel.custom_profile(...)`` snippet, turning a measured
``BENCH_comm.json`` from real hardware into a registered link table the
autotuner can rank policies over.

The ``host`` tier (device<->host DMA, core/linkmodel.py) joins the same
solve: the bench's offload cells ledger their d2h/h2d stream as
``tier='host'`` stages (one α-event per transfer, point-to-point bytes),
so a ledger that exercises ``carry_offload='host'`` or ``offload_opt``
constrains the host (α, β) alongside the network tiers.  Ledgers without
offload cells leave it unconstrained — the snippet then omits
``host_bw`` and the profile falls back to ``DEFAULT_HOST_LINK``.

Usage:
  PYTHONPATH=src python tools/fit_profile.py artifacts/benchmarks/BENCH_comm.json \
      [--name fitted-cluster] [--node-size 8]

Caveats: on the CPU host meshes the "measured" times are compute-bound, so
the fitted bandwidths describe the host, not a network — the tool's value
is the mechanism, exercised on synthetic ledgers by
``tests/test_fit_profile.py`` and on real ledgers by running the bench on
a cluster.  Tiers that no observation exercises are reported as
unconstrained and filled from the ``--fallback`` profile.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

TIERS = ("intra", "inter", "host")

# Fit floors: α ≥ 0 s, bandwidth ≤ 10 TB/s (inv_bw floor).  Compute-bound
# ledgers can drive either coefficient negative; clamping keeps the emitted
# profile physical (and flags the clamp in the diagnostics).
ALPHA_FLOOR = 0.0
INV_BW_FLOOR = 1e-13


@dataclasses.dataclass(frozen=True)
class Observation:
    """One measured step: seconds + the per-stage ledger behind it."""

    label: str
    t_measured_s: float
    # stage label -> {"tier", "alpha_events", "wire_bytes"}
    stages: dict


@dataclasses.dataclass(frozen=True)
class TierFit:
    alpha: float                 # seconds per hop
    bandwidth: float             # bytes/second
    constrained: bool            # any observation exercised this tier
    clamped: bool                # fit hit a physical floor


@dataclasses.dataclass(frozen=True)
class FitResult:
    tiers: dict                  # tier name -> TierFit
    t0: float                    # policy-independent offset (seconds)
    residual_rms_s: float
    n_observations: int

    def describe(self) -> dict:
        return {
            "tiers": {k: dataclasses.asdict(v) for k, v in self.tiers.items()},
            "t0_s": self.t0,
            "residual_rms_s": self.residual_rms_s,
            "n_observations": self.n_observations,
        }


def observations_from_bench(bench: dict) -> list[Observation]:
    """Extract the fit ledger from a BENCH_comm.json ``policies`` section."""
    out = []
    for label, entry in bench.get("policies", {}).items():
        fi = entry.get("fit_inputs")
        if not fi:
            continue
        out.append(Observation(label=label,
                               t_measured_s=float(fi["t_measured_s"]),
                               stages=dict(fi["stages"])))
    return out


def _design(observations: list[Observation]):
    """Rows: one per observation.  Columns: [α per tier..., inv_bw per
    tier..., t0] in ``TIERS`` order (intra, inter, host)."""
    a = np.zeros((len(observations), 2 * len(TIERS) + 1))
    y = np.zeros(len(observations))
    for i, obs in enumerate(observations):
        y[i] = obs.t_measured_s
        a[i, -1] = 1.0
        for stage in obs.stages.values():
            j = TIERS.index(stage["tier"])
            a[i, j] += float(stage["alpha_events"])
            a[i, len(TIERS) + j] += float(stage["wire_bytes"])
    return a, y


def fit_tiers(observations: list[Observation]) -> FitResult:
    """Least-squares (α, β) per tier from measured step times.

    Columns no observation exercises are dropped from the solve (their tier
    is reported unconstrained); a rank check rejects underdetermined
    systems (fewer independent observations than exercised coefficients)
    instead of emitting an arbitrary min-norm answer; coefficients below
    the physical floors are clamped and refit is skipped — the residual
    then reports the clamp's cost honestly.
    """
    if len(observations) < 2:
        raise ValueError(
            f"need >= 2 observations to separate t0 from link terms, got "
            f"{len(observations)}")
    a, y = _design(observations)
    used = [j for j in range(a.shape[1])
            if j == a.shape[1] - 1 or np.any(a[:, j] != 0.0)]
    rank = np.linalg.matrix_rank(a[:, used])
    if rank < len(used):
        raise ValueError(
            f"underdetermined fit: {len(observations)} observations span "
            f"rank {rank} but {len(used)} coefficients are exercised — a "
            f"min-norm lstsq answer would be arbitrary.  Add policies with "
            f"different tier byte/event mixes to the bench ledger.")
    coef = np.zeros(a.shape[1])
    sol, *_ = np.linalg.lstsq(a[:, used], y, rcond=None)
    coef[used] = sol

    exercised = [
        bool(np.any(a[:, j] != 0.0) or np.any(a[:, len(TIERS) + j] != 0.0))
        for j in range(len(TIERS))
    ]
    clamped = [False] * len(TIERS)
    for j in range(len(TIERS)):
        if not exercised[j]:
            continue  # unconstrained, not degenerate — no floors to hit
        if coef[j] < ALPHA_FLOOR:
            coef[j], clamped[j] = ALPHA_FLOOR, True
        if coef[len(TIERS) + j] < INV_BW_FLOOR:
            coef[len(TIERS) + j], clamped[j] = INV_BW_FLOOR, True

    resid = y - a @ coef
    tiers = {}
    for j, name in enumerate(TIERS):
        constrained = exercised[j]
        inv = coef[len(TIERS) + j]
        tiers[name] = TierFit(
            alpha=float(coef[j]),
            bandwidth=float(1.0 / inv) if inv > 0 else float("inf"),
            constrained=bool(constrained),
            clamped=clamped[j],
        )
    return FitResult(
        tiers=tiers,
        t0=float(coef[-1]),
        residual_rms_s=float(np.sqrt(np.mean(resid ** 2))),
        n_observations=len(observations),
    )


def emit_snippet(fit: FitResult, *, name: str, node_size: int,
                 fallback: str = "v5e") -> str:
    """A ready-to-paste ``custom_profile(...)`` call for the fitted table.

    Unconstrained *network* tiers fall back to the named profile's values
    (flagged in the comment) so the snippet always constructs a valid
    LinkProfile; an unconstrained host tier is simply omitted —
    ``custom_profile`` then leaves ``host=None`` and the profile falls back
    to ``DEFAULT_HOST_LINK``.
    """
    from repro.core.linkmodel import get_profile

    fb = get_profile(fallback)
    vals = {}
    notes = []
    for tier in ("intra", "inter"):
        tf = fit.tiers[tier]
        if tf.constrained:
            vals[f"{tier}_bw"] = tf.bandwidth
            vals[f"alpha_{tier}"] = tf.alpha
            if tf.clamped:
                notes.append(f"{tier} tier hit a fit floor (clamped)")
        else:
            link = fb.link(tier)
            vals[f"{tier}_bw"] = link.bandwidth
            vals[f"alpha_{tier}"] = link.alpha
            notes.append(f"{tier} tier unconstrained; copied from "
                         f"{fallback!r}")
    host = fit.tiers["host"]
    if host.constrained:
        vals["host_bw"] = host.bandwidth
        vals["alpha_host"] = host.alpha
        if host.clamped:
            notes.append("host tier hit a fit floor (clamped)")
    else:
        notes.append("host tier unconstrained; DEFAULT_HOST_LINK applies")
    note = ("\n# NOTE: " + "; ".join(notes)) if notes else ""
    lines = [
        f"    {name!r},",
        f"    intra_bw={vals['intra_bw']:.6g},",
        f"    inter_bw={vals['inter_bw']:.6g},",
        f"    node_size={node_size},",
        f"    alpha_intra={vals['alpha_intra']:.6g},",
        f"    alpha_inter={vals['alpha_inter']:.6g},",
    ]
    if "host_bw" in vals:
        lines += [f"    host_bw={vals['host_bw']:.6g},",
                  f"    alpha_host={vals['alpha_host']:.6g},"]
    lines += ["    description='fitted from BENCH_comm.json',",
              "    register=True,"]
    body = "\n".join(lines)
    return (
        f"# fitted from {fit.n_observations} measured policies, "
        f"residual rms {fit.residual_rms_s:.3e} s{note}\n"
        f"from repro.core.linkmodel import custom_profile\n\n"
        f"profile = custom_profile(\n{body}\n)\n"
    )


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", help="path to BENCH_comm.json")
    ap.add_argument("--name", default="fitted",
                    help="name for the emitted custom_profile")
    ap.add_argument("--node-size", type=int, default=8,
                    help="fast-tier island size of the measured cluster")
    ap.add_argument("--fallback", default="v5e",
                    help="profile supplying values for unconstrained tiers")
    args = ap.parse_args(argv)

    bench = json.loads(open(args.bench).read())
    obs = observations_from_bench(bench)
    if not obs:
        print("no fit_inputs ledgers in this BENCH_comm.json — re-run "
              "benchmarks/comm_bench.py", file=sys.stderr)
        return 1
    fit = fit_tiers(obs)
    print(json.dumps(fit.describe(), indent=1), file=sys.stderr)
    print(emit_snippet(fit, name=args.name, node_size=args.node_size,
                       fallback=args.fallback))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
