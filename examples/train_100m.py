"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps with the full production loop (checkpointing, fault
tolerance, CommEngine-owned collectives with double-buffered gather
prefetch).

    PYTHONPATH=src python examples/train_100m.py --steps 300

On this CPU host a step takes seconds; on a real pod the identical script
scales by swapping `make_host_mesh()` for `make_mics_topology(...)` (see
repro/launch/train.py) — and `MiCSConfig(policy="auto", link_profile=...)`
re-tunes the gather policies for that pod's link table.
"""

import argparse
import dataclasses
import logging

from repro.configs import get_config
from repro.core.mics import MiCSConfig
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.data.pipeline import DataConfig
from repro.models.build import build_model, exact_param_count
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import LoopConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--global-batch", type=int, default=8)
ap.add_argument("--checkpoint-dir", default="checkpoints/train_100m")
args = ap.parse_args()

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

# ~100M-parameter llama3-family configuration
cfg = dataclasses.replace(
    get_config("llama3.2-1b"),
    name="llama-100m", n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
    head_dim=64, d_ff=2048, vocab=32_000, max_seq=args.seq,
)
print(f"params: {exact_param_count(cfg)/1e6:.1f}M")

topo = MiCSTopology(make_host_mesh())
model = build_model(cfg, tp=topo.model_size)
stats = train(
    model, topo,
    MiCSConfig(micro_steps=2),
    OptConfig(lr_max=6e-4, total_steps=args.steps,
              warmup_steps=max(args.steps // 20, 1)),
    DataConfig(vocab=cfg.vocab, seq=args.seq,
               global_batch=args.global_batch, micro_steps=2),
    LoopConfig(total_steps=args.steps, checkpoint_every=100,
               checkpoint_dir=args.checkpoint_dir, log_every=20),
)
print(f"loss {stats.losses[0]:.3f} -> {stats.losses[-1]:.3f} "
      f"({len(stats.losses)} steps, {sum(stats.step_times):.0f}s)")
