"""Quickstart: train a reduced llama-family model with MiCS on this host.

    PYTHONPATH=src python examples/quickstart.py

Everything below is the public API surface: pick a config, build the model,
build the MiCS train step for a topology, feed batches.  Every collective
in the step (staged parameter gathers with double-buffered prefetch, 2-hop
gradient sync) is owned by the CommEngine built from ``MiCSConfig`` —
see docs/comm-engine.md; ``MiCSConfig(policy="auto",
link_profile="efa-100g")`` would let the link-model autotuner pick the
gather topology/wire dtype instead (docs/autotuning.md).
"""

import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.mics import MiCSConfig, build_train_step, init_state
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.build import build_model
from repro.optim.adamw import OptConfig

cfg = smoke_variant(get_config("llama3.2-1b"))
topo = MiCSTopology(make_host_mesh())          # 1 device; axes generalize
model = build_model(cfg, tp=topo.model_size)

mcfg = MiCSConfig(micro_steps=2)   # 2-hop sync, staged prefetched gathers
state = init_state(model, topo, seed=0)
step = build_train_step(model, topo, mcfg,
                        OptConfig(lr_max=3e-3, total_steps=20, warmup_steps=2))

data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=64, global_batch=8,
                              micro_steps=2))
for i in range(20):
    batch = {k: jnp.asarray(v) for k, v in data.global_step_batch(i).items()}
    state, metrics = step(state, batch)
    if i % 5 == 0 or i == 19:
        print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
              f"|g| {float(metrics['grad_norm']):.3f}")
print("done — the loss curve is heading down; run longer for more")
