"""Elastic-scaling demo: train on one topology, lose half the "cluster", and
resume from the checkpoint on a different mesh — partition groups, TP degree
and data parallelism all change; the flat model states reshard untouched.

Runs on 8 virtual CPU devices (set before jax import, like the dry-run).

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, smoke_variant
from repro.core.mics import MiCSConfig, build_train_step, init_state
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import elastic_restart

cfg = smoke_variant(get_config("llama3.2-1b"))
dc = DataConfig(vocab=cfg.vocab, seq=32, global_batch=8, micro_steps=2)
data = SyntheticLM(dc)
oc = OptConfig(lr_max=1e-3, total_steps=40, warmup_steps=0)
ckpt_dir = "checkpoints/elastic_demo"

# --- phase 1: "8-chip cluster": pod=2, p=2, tp=2 ---------------------------
topo8 = MiCSTopology(make_host_mesh(2, 1, 2, 2),
                     partition_axes=("shard",),
                     replication_axes=("pod", "repl"))
model8 = build_model(cfg, tp=2)
state = init_state(model8, topo8, seed=0)
step8 = build_train_step(model8, topo8, MiCSConfig(micro_steps=2), oc)
for i in range(6):
    batch = {k: jnp.asarray(v) for k, v in data.global_step_batch(i).items()}
    state, metrics = step8(state, batch)
    print(f"[8 devices, p=2, tp=2] step {i} loss {float(metrics['loss']):.4f}")

ck = Checkpointer(ckpt_dir)
ck.save(state, step=6, topo=topo8, data_cursor=6)
print("checkpoint written; simulating loss of one pod ...")

# --- phase 2: resume on the surviving pod (4 chips): p=2, no replication ---
# TP degree is fixed across restores (flat layouts are TP-local); pods,
# partition groups and replication degree all reshard freely.
topo4 = MiCSTopology(make_host_mesh(1, 1, 2, 2),
                     partition_axes=("shard",),
                     replication_axes=())
model4, state4, step4, meta = elastic_restart(
    ckpt_dir, cfg, topo4, MiCSConfig(micro_steps=2), oc)
cursor = meta["data_cursor"]
for i in range(cursor, cursor + 6):
    batch = {k: jnp.asarray(v) for k, v in data.global_step_batch(i).items()}
    state4, metrics = step4(state4, batch)
    print(f"[4 devices, p=2, tp=2] step {i} loss {float(metrics['loss']):.4f}")
print("resumed seamlessly on the degraded mesh — loss curve continues")
