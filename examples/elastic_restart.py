"""Elastic-scaling demo: survive a mid-run pod preemption IN the train loop.

An 8-virtual-device "cluster" (pod=2, p=2, tp=2) trains under a scripted
fault timeline (core/faults.FaultPlan): at step 8 one pod (4 devices) is
lost abruptly — no preemption notice.  The elastic loop rolls back to the
newest complete checkpoint, re-picks the partition-group size for the
survivors (autotune.resolve_world), rebuilds the mesh + step function, and
keeps training on 4 devices; at step 16 the capacity returns and the loop
grows back to 8.  The world-change ledger and a cold cross-topology
restore close the demo.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json

from repro.configs import get_config, smoke_variant
from repro.core.faults import FaultPlan
from repro.core.mics import MiCSConfig
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.data.pipeline import DataConfig
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import (
    ElasticConfig, LoopConfig, elastic_restart, resize_for_world, train,
)

cfg = smoke_variant(get_config("llama3.2-1b"))
dc = DataConfig(vocab=cfg.vocab, seq=32, global_batch=8, micro_steps=2)
mcfg = MiCSConfig(micro_steps=2)
oc = OptConfig(lr_max=1e-3, total_steps=40, warmup_steps=0)
lc = LoopConfig(total_steps=24, checkpoint_every=4, log_every=4,
                checkpoint_dir="checkpoints/elastic_demo")

# the scripted failure timeline: abrupt pod loss, later grow-back
plan = (FaultPlan()
        .preempt(8, devices=4, notice=False)   # pod dies, no warning
        .grow(16, devices=4))                  # capacity comes back

topo8 = MiCSTopology(make_host_mesh(2, 1, 2, 2))   # pod=2, p=2, tp=2
model = build_model(cfg, tp=2)

print("training on 8 devices with a scripted pod loss at step 8 ...")
stats = train(model, topo8, mcfg, oc, dc, lc,
              fault_injector=plan, elastic=ElasticConfig())

print(f"\nsurvived {len(stats.world_changes)} world change(s), "
      f"{stats.restarts} restart(s); ledger:")
print(json.dumps(stats.world_changes, indent=1))
print(f"final loss {stats.losses[-1]:.4f} after {len(stats.losses)} "
      f"computed steps (includes the recomputed rollback span)")

# a cold restart resumes the same checkpoint through the same rebuild path
# the loop used (resize_for_world), on whatever world is available now:
topo4, mcfg4, info = resize_for_world(model, mcfg, 4, tp=2, partition_size=2)
_, state, step_fn, meta = elastic_restart(
    lc.checkpoint_dir, cfg, topo4, mcfg4, oc)
print(f"\ncold restore onto 4 devices: step {meta['step']}, "
      f"data cursor {meta['data_cursor']}, p={info['partition_size']} "
      f"({info['rule']} rule) — trajectory would continue bitwise")
