"""Serving example: prefill a batch of prompts and greedy-decode
continuations with the MiCS-sharded serving runtime (per-layer weight
gathers through the same CommEngine as training, per-rank KV cache).

    PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma-2b

Decode re-gathers every layer's weights each step, so the gather policy is
the binding knob here: ``--prefetch 0`` falls back to the serial schedule,
``--quant-gather`` stores int8 weights and halves the wire bytes, and
``--policy auto --link-profile efa-100g`` lets the autotuner choose
(docs/autotuning.md).
"""


from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    import sys

    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "recurrentgemma-2b"]
    if "--smoke" not in sys.argv:
        sys.argv += ["--smoke"]
    serve_main()
