"""Serving example: prefill a batch of prompts and greedy-decode
continuations with the MiCS-sharded serving runtime (ZeRO-3-style parameter
gathering, per-rank KV cache).

    PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma-2b
"""

import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    import sys

    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "recurrentgemma-2b"]
    if "--smoke" not in sys.argv:
        sys.argv += ["--smoke"]
    serve_main()
