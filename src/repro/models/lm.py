"""Model assembly: pools of stacked layers + embedding/head, with all
parameter-gather collectives routed through the MiCS ``CommEngine``.

A ``Pool`` is a stack of identical superblocks whose parameters live in one
flat buffer per layer (``[stack, tp, flat_len]`` globally).  The forward pass
scans over the stack; each layer's flat shard is gathered across the
partition group (one collective per layer — the paper's coalesced gather),
unflattened, and applied under ``jax.checkpoint`` so the backward pass
re-gathers (ZeRO-3 semantics + activation checkpointing).

Two schedules exist (``CommEngine.prefetch`` selects):

* **serial** — gather layer i, compute layer i (the seed behaviour; every
  gather blocks compute).
* **double-buffered prefetch** — the scan carries layer i's gathered flat
  buffer while its body *issues layer i+1's all-gather before running layer
  i's compute*.  The gather has no data dependency on the current layer's
  math, so XLA's scheduler can overlap it with the matmuls — the ZeRO-3
  style prefetch MiCS assumes.  Loss is bitwise identical to the serial
  schedule (same gathers, same compute, same order of adds).

The prefetch schedule's backward residual is selected by
``GatherPolicy.prefetch_carry``:

* ``'stored'`` (the seed behaviour) — the carried gathered buffer becomes a
  per-layer scan residual, so the backward never re-gathers; costs
  O(layers x flat_len) HBM per scanned pool (DESIGN.md §4).
* ``'remat'`` — the whole pool scan runs under a custom VJP
  (:func:`_apply_pool_prefetch_remat`): the forward is the *identical*
  double-buffered scan (bitwise-equal losses), but only the layer-input
  activations and the parameter shards are kept; the backward re-issues
  each layer's all-gather (through the same CommEngine gather and its
  exact adjoint) and re-linearizes the layer on the fly.  Costs one extra
  all-gather per layer per micro-step and only O(layers x shard) HBM —
  the memory planner's first mitigation knob (core/memplan.py).

A third residency for the stored carry is ``GatherPolicy.carry_offload =
'host'`` (:func:`_apply_pool_prefetch_offload`): the forward streams each
layer's gathered buffer down to host memory (core/hostoffload.py) as soon
as the next layer's gather is in flight, and the backward streams it back
right before that layer's recompute — no re-gather (unlike remat), no
O(layers x flat_len) HBM residual (unlike stored), at the price of
2 x layers x flat_len bytes over the host link per micro-step (priced as
the ``host`` tier of the link model, core/linkmodel.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.flat_param import FlatLayout
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class Pool:
    name: str
    layout: FlatLayout
    stack: int
    # apply(tensors, x, ctx, cache) -> ((x, aux), new_cache)
    apply: Callable
    # make_cache(batch, cache_len) -> cache pytree for ONE stacked row
    make_cache: Callable | None = None


@dataclasses.dataclass(frozen=True)
class ModelDef:
    cfg: ArchConfig
    tp: int
    pools: tuple[Pool, ...]
    embed: Pool
    head: Pool
    vocab_padded: int

    def pool(self, name: str) -> Pool:
        for p in (*self.pools, self.embed, self.head):
            if p.name == name:
                return p
        raise KeyError(name)

    def all_pools(self) -> tuple[Pool, ...]:
        return (self.embed, *self.pools, self.head)

    def global_flat_shapes(self) -> dict[str, tuple[int, int, int]]:
        return {
            p.name: (p.stack, self.tp, p.layout.flat_len) for p in self.all_pools()
        }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _row(x, idx=(0,)):
    """Index every leaf (flat pools may be {'q','s'} dicts when quantized)."""
    return jax.tree.map(lambda a: a[idx], x)


def _apply_pool(
    pool: Pool, flat_rows, x: jax.Array, ctx: L.Ctx,
    comm, caches=None,
):
    """Scan a pool over its stack.  flat_rows: [stack, 1, S_local] leaves.

    ``comm`` is the CommEngine owning every gather collective; its
    ``prefetch`` policy selects the serial or double-buffered schedule, and
    ``prefetch_carry`` the stored-vs-remat backward residual of the latter.
    """
    if getattr(comm, "prefetch", False) and pool.stack > 1:
        if (getattr(comm, "carry_offload", "none") == "host"
                and caches is None and ctx.enc_out is None
                and not isinstance(flat_rows, dict)):
            # Host-offloaded stored carry: same custom-VJP restrictions as
            # remat (no serving caches, no encoder output), plus a plain
            # fp32 shard layout (quantized {'q','s'} pools keep the
            # in-HBM carry — their gathered buffer is already compact).
            return _apply_pool_prefetch_offload(pool, flat_rows, x, ctx, comm)
        if (getattr(comm, "prefetch_carry", "stored") == "remat"
                and caches is None and ctx.enc_out is None):
            # remat needs a backward pass to pay off and a custom VJP to
            # run; the cached (serving) path has no backward, and a
            # cross-attended encoder output may not be closed over by a
            # custom VJP (it carries gradient) — both fall back to stored.
            return _apply_pool_prefetch_remat(pool, flat_rows, x, ctx, comm)
        return _apply_pool_prefetch(pool, flat_rows, x, ctx, comm, caches)
    return _apply_pool_serial(pool, flat_rows, x, ctx, comm, caches)


def _apply_pool_serial(pool, flat_rows, x, ctx, comm, caches):
    """Reference schedule: gather layer i, then compute layer i."""

    def inner(x, row, cache):
        tensors = comm.gather(pool, _row(row), seed=ctx.step_seed)
        (x, aux), new_cache = pool.apply(tensors, x, ctx, cache)
        return x, aux, new_cache

    inner = jax.checkpoint(inner)

    if caches is None:

        def body(carry, row):
            x, aux_tot = carry
            x, aux, _ = inner(x, row, None)
            return (x, aux_tot + aux), None

        (x, aux), _ = lax.scan(body, (x, jnp.float32(0.0)), flat_rows)
        return x, aux, None

    def body(carry, xs):
        x, aux_tot = carry
        row, cache = xs
        x, aux, new_cache = inner(x, row, cache)
        return (x, aux_tot + aux), new_cache

    (x, aux), new_caches = lax.scan(body, (x, jnp.float32(0.0)), (flat_rows, caches))
    return x, aux, new_caches


def _apply_pool_prefetch(pool, flat_rows, x, ctx, comm, caches):
    """Double-buffered schedule: the carry holds layer i's gathered flat
    buffer; the body issues layer i+1's all-gather *before* layer i's
    compute, so the collective overlaps the matmuls.  The scanned inputs are
    the rows rotated one slot left (iteration i sees row i+1); the prologue
    gathers row 0.  The final iteration's wrap-around gather of row 0 is the
    one redundant collective of the schedule (its result is discarded).

    Bitwise equivalence to the serial schedule: the same gather policy runs
    on the same shards, unflatten/compute run in the same order, and the
    aux accumulation order is unchanged.  ``jax.checkpoint`` wraps the body,
    so the backward pass recomputes unflatten+compute from the carried
    buffer (and the lookahead gather) instead of storing activations.
    """
    nxt_rows = jax.tree.map(lambda a: jnp.roll(a, -1, axis=0), flat_rows)
    cur0 = comm.gather_flat(_row(flat_rows, (0, 0)), seed=ctx.step_seed)

    def inner(x, cur_full, nxt_row, cache):
        nxt_full = comm.gather_flat(
            _row(nxt_row), seed=ctx.step_seed)      # layer i+1, issued first
        tensors = comm.unflatten(pool, cur_full)     # layer i, from the carry
        (x, aux), new_cache = pool.apply(tensors, x, ctx, cache)
        return x, aux, nxt_full, new_cache

    inner = jax.checkpoint(inner)

    if caches is None:

        def body(carry, nxt_row):
            x, aux_tot, cur = carry
            x, aux, nxt, _ = inner(x, cur, nxt_row, None)
            return (x, aux_tot + aux, nxt), None

        (x, aux, _), _ = lax.scan(body, (x, jnp.float32(0.0), cur0), nxt_rows)
        return x, aux, None

    def body(carry, xs):
        x, aux_tot, cur = carry
        nxt_row, cache = xs
        x, aux, nxt, new_cache = inner(x, cur, nxt_row, cache)
        return (x, aux_tot + aux, nxt), new_cache

    (x, aux, _), new_caches = lax.scan(
        body, (x, jnp.float32(0.0), cur0), (nxt_rows, caches))
    return x, aux, new_caches


def _apply_pool_prefetch_remat(pool, flat_rows, x, ctx, comm):
    """Double-buffered prefetch with a rematerialized backward residual
    (``GatherPolicy.prefetch_carry='remat'``).

    The forward is the *same* double-buffered scan as
    :func:`_apply_pool_prefetch` — same gathers on the same shards in the
    same order, so losses are bitwise identical to the stored schedule.
    The difference is what survives for the backward pass: the whole scan
    runs under a ``jax.custom_vjp`` whose residuals are only the parameter
    shards (``flat_rows``, which already live in HBM — O(layers x shard))
    and the stacked per-layer input activations (the activation checkpoint
    any schedule keeps).  The carried gathered buffer is *not* a residual.
    The backward is a hand-rolled reverse scan that re-issues each layer's
    all-gather (``comm.gather_flat`` — the CommEngine's custom-VJP gather,
    so the row cotangent is still the exact staged hop-1 reduce-scatter)
    and linearizes the layer on the fly, exactly what ``jax.checkpoint``
    would recompute, minus the stored carry.  Cost: one extra all-gather
    per layer per micro-step (the re-gather); saving: the O(layers x
    flat_len) carry residual (DESIGN.md §4, core/memplan.py).

    Cache-carrying (serving) and encoder-output-consuming pools never take
    this path (:func:`_apply_pool` falls back): serving has no backward,
    and ``ctx.enc_out`` carries gradient that a custom VJP closure would
    silently drop.
    """
    seed = ctx.step_seed

    @jax.checkpoint
    def layer(row, x_in):
        """One layer from its shard: gather -> unflatten -> apply.

        Checkpointed so its VJP is the same recompute-then-transpose the
        stored schedule's ``jax.checkpoint(inner)`` runs — gradients stay
        bitwise identical between the two carries, not just losses.
        """
        full = comm.gather_flat(_row(row), seed=seed)
        tensors = comm.unflatten(pool, full)
        (x_out, aux), _ = pool.apply(tensors, x_in, ctx, None)
        return x_out, aux

    def fwd_scan(x, flat_rows):
        """The double-buffered forward; also stacks per-layer inputs."""
        nxt_rows = jax.tree.map(lambda a: jnp.roll(a, -1, axis=0), flat_rows)
        cur0 = comm.gather_flat(_row(flat_rows, (0, 0)), seed=seed)

        def body(carry, nxt_row):
            xc, aux_tot, cur = carry
            nxt = comm.gather_flat(_row(nxt_row), seed=seed)  # layer i+1
            tensors = comm.unflatten(pool, cur)
            (x_out, aux), _ = pool.apply(tensors, xc, ctx, None)
            return (x_out, aux_tot + aux, nxt), xc            # stash input

        (x_out, aux, _), x_ins = lax.scan(
            body, (x, jnp.float32(0.0), cur0), nxt_rows)
        return (x_out, aux), x_ins

    @jax.custom_vjp
    def scan_fn(x, flat_rows):
        return fwd_scan(x, flat_rows)[0]

    def scan_fwd(x, flat_rows):
        out, x_ins = fwd_scan(x, flat_rows)
        return out, (flat_rows, x_ins)

    def scan_bwd(res, cts):
        flat_rows, x_ins = res
        ct_x, ct_aux = cts

        def body(ct_x, xs):
            row, x_in = xs
            _, vjp = jax.vjp(layer, row, x_in)   # re-gathers the layer
            d_row, d_x = vjp((ct_x, ct_aux))
            return d_x, d_row

        ct_x, d_rows = lax.scan(body, ct_x, (flat_rows, x_ins),
                                reverse=True)
        return ct_x, d_rows

    scan_fn.defvjp(scan_fwd, scan_bwd)
    x, aux = scan_fn(x, flat_rows)
    return x, aux, None


def _apply_pool_prefetch_offload(pool, flat_rows, x, ctx, comm):
    """Double-buffered prefetch whose stored carry lives in HOST memory
    (``GatherPolicy.carry_offload='host'``).

    The forward is the *same* double-buffered scan as
    :func:`_apply_pool_prefetch` — same gathers on the same shards in the
    same order, bitwise-identical losses — but each layer's carried
    gathered buffer is streamed down to the host stash
    (core/hostoffload.py) right after the next layer's gather is issued,
    so the backward residual kept on device is only the stacked layer
    inputs (the activation checkpoint every schedule keeps).  The backward
    is a hand-rolled reverse scan that streams each buffer back up
    (h2d), re-linearizes the layer under ``jax.checkpoint`` from the
    *identical* bytes the forward computed, and pushes the full-buffer
    cotangent through :meth:`CommEngine.gather_flat_adjoint` — the exact
    same staged hop-1 reduce-scatter adjoint the stored schedule's VJP
    runs, so gradients too are bitwise identical to ``'stored'``.

    Versus the alternatives: no re-gather per layer (unlike ``'remat'``),
    no O(layers x flat_len) HBM residual (unlike ``'stored'``); the cost
    is 2 x layers x flat_len bytes over the host link per micro-step,
    priced by the autotuner as the link model's ``host`` tier.
    """
    seed = ctx.step_seed
    stash = comm.host_stash
    tag = comm.carry_tag(pool.name)
    s_local = jax.tree.leaves(flat_rows)[0].shape[-1]
    full_len = s_local * comm.partition_size
    full_dtype = comm.gather_out_dtype()

    @jax.checkpoint
    def layer_from_full(full, x_in):
        """One layer from its restored gathered buffer (no collective)."""
        tensors = comm.unflatten(pool, full)
        (x_out, aux), _ = pool.apply(tensors, x_in, ctx, None)
        return x_out, aux

    def fwd_scan(x, flat_rows, store):
        nxt_rows = jax.tree.map(lambda a: jnp.roll(a, -1, axis=0), flat_rows)
        cur0 = comm.gather_flat(_row(flat_rows, (0, 0)), seed=seed)

        def body(carry, xs):
            i, nxt_row = xs
            xc, aux_tot, cur, tok = carry
            nxt = comm.gather_flat(_row(nxt_row), seed=seed)  # layer i+1
            if store:
                tok = tok + stash.put(tag, i, cur)            # d2h stream
            tensors = comm.unflatten(pool, cur)
            (x_out, aux), _ = pool.apply(tensors, xc, ctx, None)
            return (x_out, aux_tot + aux, nxt, tok), xc       # stash input

        (x_out, aux, _, tok), x_ins = lax.scan(
            body, (x, jnp.float32(0.0), cur0, jnp.int32(0)),
            (jnp.arange(pool.stack), nxt_rows))
        return (x_out, aux), tok, x_ins

    @jax.custom_vjp
    def scan_fn(x, flat_rows):
        # Primal-only calls never populate the stash (store=False): with no
        # backward pass there is no consumer to pop the buffers.
        return fwd_scan(x, flat_rows, store=False)[0]

    def scan_fwd(x, flat_rows):
        # The summed put token MUST ride the residuals and feed the
        # backward's gets: custom_vjp's partial-eval DCEs even ordered
        # io_callbacks whose outputs escape nowhere (observed on the CPU
        # backend), so an unthreaded token means no d2h puts at all.
        out, tok, x_ins = fwd_scan(x, flat_rows, store=True)
        return out, (tok, x_ins)

    def scan_bwd(res, cts):
        tok, x_ins = res
        ct_x, ct_aux = cts

        def body(ct_x, xs):
            i, x_in = xs
            full = stash.get(tag, i + 0 * tok,
                             (full_len,), full_dtype)          # h2d stream
            _, vjp = jax.vjp(layer_from_full, full, x_in)
            d_full, d_x = vjp((ct_x, ct_aux))
            d_row = comm.gather_flat_adjoint(d_full, seed=seed)
            return d_x, d_row[None, :]

        ct_x, d_rows = lax.scan(
            body, ct_x, (jnp.arange(pool.stack), x_ins), reverse=True)
        return ct_x, d_rows

    scan_fn.defvjp(scan_fwd, scan_bwd)
    x, aux = scan_fn(x, flat_rows)
    return x, aux, None


def embed_tokens(model: ModelDef, t_embed, tokens, ctx: L.Ctx, *, pos=None):
    cfg = model.cfg
    x = L.embed_lookup(t_embed["emb.table"], tokens, ctx)
    if "emb.pos" in t_embed:
        if pos is None:
            positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        elif getattr(pos, "ndim", 0) == 1:
            # per-request positions [b] (continuous batching)
            positions = pos[:, None] + jnp.arange(tokens.shape[1])[None, :]
        else:
            positions = jnp.broadcast_to(pos, tokens.shape)
        pe = L.embed_lookup(t_embed["emb.pos"], positions, ctx)
        x = x + pe
    return x.astype(ctx.compute_dtype)


def encode_audio(model: ModelDef, t_embed, audio, ctx: L.Ctx):
    """Whisper stub frontend: precomputed frame embeddings + learned pos."""
    frames = audio.shape[1]
    positions = jnp.broadcast_to(jnp.arange(frames), audio.shape[:2])
    pe = L.embed_lookup(t_embed["emb.audio_pos"], positions, ctx)
    return (audio + pe).astype(ctx.compute_dtype)


def lm_logits(model: ModelDef, t_head, x, ctx: L.Ctx):
    cfg = model.cfg
    if cfg.norm == "ln":
        x = L.layer_norm(x, t_head["final.scale"], t_head["final.bias"])
    else:
        x = L.rms_norm(x, t_head["final.scale"])
    return x @ t_head["head.w"]


def forward(
    model: ModelDef,
    flat: dict[str, jax.Array],
    comm,
    ctx: L.Ctx,
    batch: dict[str, jax.Array],
    caches: dict | None = None,
):
    """Run embedding -> pools -> final hidden states.

    ``comm`` is the CommEngine (core/comm.py) that owns every gather.
    Returns (hidden, aux_loss, new_caches, t_head).
    """
    cfg = model.cfg
    t_embed = comm.gather(model.embed, _row(flat["embed"], (0, 0)),
                          seed=ctx.step_seed)
    aux_total = jnp.float32(0.0)
    new_caches: dict[str, Any] = {}

    if cfg.family == "encdec" and ctx.mode != "decode":
        enc_x = encode_audio(model, t_embed, batch["audio"], ctx)
        enc_ctx = dataclasses.replace(ctx, mode="train", pos=None)
        for pool in model.pools:
            if not pool.name.startswith("enc"):
                continue
            enc_x, aux, _ = _apply_pool(
                pool, flat[pool.name], enc_x, enc_ctx, comm, None)
            aux_total = aux_total + aux
        ctx = dataclasses.replace(ctx, enc_out=enc_x)
    if cfg.family == "vlm" and ctx.mode != "decode":
        ctx = dataclasses.replace(
            ctx, vision=batch["vision"].astype(ctx.compute_dtype))

    x = embed_tokens(model, t_embed, batch["tokens"], ctx, pos=ctx.pos)
    for pool in model.pools:
        if cfg.family == "encdec" and pool.name.startswith("enc"):
            continue
        pool_cache = caches.get(pool.name) if caches is not None else None
        x, aux, nc = _apply_pool(
            pool, flat[pool.name], x, ctx, comm, pool_cache)
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[pool.name] = nc

    t_head = comm.gather(model.head, _row(flat["head"], (0, 0)),
                         seed=ctx.step_seed)
    return x, aux_total, new_caches, t_head


def loss_fn(
    model: ModelDef,
    flat: dict[str, jax.Array],
    comm,
    ctx: L.Ctx,
    batch: dict[str, jax.Array],
):
    """Token cross-entropy + MoE aux.  batch: tokens/targets/mask [b, T]."""
    hidden, aux, _, t_head = forward(model, flat, comm, ctx, batch)
    logits = lm_logits(model, t_head, hidden, ctx)
    ce = L.tp_cross_entropy(
        logits, batch["targets"], batch["mask"].astype(jnp.float32),
        vocab_real=model.cfg.vocab, vocab_padded=model.vocab_padded, ctx=ctx,
    )
    loss = ce + model.cfg.router_aux_weight * aux
    return loss, {"loss": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------

def prefill(
    model: ModelDef,
    flat: dict[str, jax.Array],
    comm,
    ctx: L.Ctx,
    batch: dict[str, jax.Array],
):
    """Forward over the prompt, returning per-pool caches + last logits."""
    ctx = dataclasses.replace(ctx, mode="prefill")
    caches = init_caches(model, batch["tokens"].shape[0], ctx.cache_len, prefill=True)
    hidden, _, new_caches, t_head = forward(
        model, flat, comm, ctx, batch, caches)
    logits = lm_logits(model, t_head, hidden[:, -1:], ctx)
    return logits, new_caches


def decode_step(
    model: ModelDef,
    flat: dict[str, jax.Array],
    comm,
    ctx: L.Ctx,
    tokens: jax.Array,          # [b, tq] current token ids (tq=1 rectangular)
    pos: jax.Array,             # scalar absolute position, or [b] per-request
    caches: dict,
    *,
    pages=None,                 # runtime/paged.PageState for paged KV caches
):
    ctx = dataclasses.replace(ctx, mode="decode", pos=pos, pages=pages)
    batch = {"tokens": tokens}
    hidden, _, new_caches, t_head = forward(
        model, flat, comm, ctx, batch, caches)
    logits = lm_logits(model, t_head, hidden, ctx)
    return logits, new_caches


def init_caches(model: ModelDef, batch: int, cache_len: int, *, prefill: bool = False):
    """Zero caches for every pool (stacked along the pool's stack dim).

    In prefill mode the scan still needs cache *inputs* with the right
    structure; their values are ignored and replaced by the computed caches.
    """
    caches = {}
    for pool in model.pools:
        if pool.make_cache is None:
            continue
        one = pool.make_cache(batch, cache_len)
        caches[pool.name] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (pool.stack, *a.shape)), one)
    return caches


def greedy_sample(logits_local: jax.Array, ctx: L.Ctx, vocab_real: int) -> jax.Array:
    """Argmax over the vocab-parallel logits."""
    vl = logits_local.shape[-1]
    lg = logits_local.astype(jnp.float32)
    start = ctx.tp_index() * vl
    col = start + jnp.arange(vl)
    lg = jnp.where(col[None, None, :] < vocab_real, lg, L.NEG_INF)
    local_max = jnp.max(lg, axis=-1)
    local_arg = jnp.argmax(lg, axis=-1) + start
    if ctx.tp == 1:
        return local_arg
    gmax = lax.pmax(local_max, ctx.tp_axis)
    cand = jnp.where(local_max >= gmax, local_arg, jnp.iinfo(jnp.int32).max)
    return lax.pmin(cand, ctx.tp_axis)


def sample_tokens(
    logits_local: jax.Array,    # [b, V/tp] vocab-parallel logits
    ctx: L.Ctx,
    vocab_real: int,
    *,
    seed: jax.Array,            # [b] int32 per-request seeds
    pos: jax.Array,             # [b] int32 position of the sampled token
    temperature: jax.Array,     # [b] f32; 0.0 = greedy
    top_k: int = 0,             # static; 0 = full vocab
) -> jax.Array:
    """Seeded categorical sampler over vocab-parallel logits -> [b] ids.

    Exact Gumbel-max: argmax(logits/T + G) with G ~ Gumbel(0, 1) drawn from
    a key folded over (request seed, token position, tp shard index) — the
    same step-varying fold-in discipline as the qgZ dither seed, so decoding
    is reproducible per (seed, position) and distinct across both.  Rows
    with ``temperature == 0`` take the noiseless argmax (== greedy_sample).
    Under tp > 1 each shard draws noise for its own vocab columns and the
    global argmax uses the pmax/pmin index trick; ``top_k`` is applied
    per shard, i.e. the union of per-shard top-k — a superset of the true
    top-k (exact when tp == 1).
    """
    b, vl = logits_local.shape
    lg = logits_local.astype(jnp.float32)
    start = ctx.tp_index() * vl
    col = start + jnp.arange(vl)
    lg = jnp.where(col[None, :] < vocab_real, lg, L.NEG_INF)
    if top_k:
        thr = lax.top_k(lg, min(top_k, vl))[0][:, -1]
        lg = jnp.where(lg < thr[:, None], L.NEG_INF, lg)

    tpi = ctx.tp_index()

    def noise_row(s, p):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.fold_in(
                jax.random.PRNGKey(0), s), p), tpi)
        return jax.random.gumbel(key, (vl,), jnp.float32)

    g = jax.vmap(noise_row)(seed.astype(jnp.int32), pos.astype(jnp.int32))
    t = jnp.maximum(temperature, 1e-6)[:, None]
    # masked lanes stay masked: NEG_INF/T + G is still < any real score
    scores = jnp.where(temperature[:, None] > 0.0, lg / t + g, lg)

    local_max = jnp.max(scores, axis=-1)
    local_arg = jnp.argmax(scores, axis=-1).astype(jnp.int32) + start
    if ctx.tp == 1:
        return local_arg
    gmax = lax.pmax(local_max, ctx.tp_axis)
    cand = jnp.where(local_max >= gmax, local_arg, jnp.iinfo(jnp.int32).max)
    return lax.pmin(cand, ctx.tp_axis)
