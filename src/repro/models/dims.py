"""Tensor-parallel dimension bookkeeping: head padding, KV gather groups.

The model axis has a fixed size (16 in production, 1 in smoke tests).  Head
counts in the assigned architectures are not always divisible by it
(recurrentgemma: 10 Q heads; whisper: 20), and GQA KV head counts are often
smaller than it.  Policy (see DESIGN.md §3):

* Q heads are padded up to a multiple of ``tp``.  Padded heads are masked
  after attention (before the output projection), so their weights receive
  zero gradient and the model is mathematically identical to the unpadded
  architecture — only FLOPs are wasted, which the roofline accounts for.
* KV projections are stored sharded over the flattened (kv_heads × head_dim)
  dimension.  If ``kv_heads_pad < tp``, each rank holds a slice of one KV
  head's dims, and the full head is re-assembled with an all-gather over the
  contiguous model-axis sub-group of ``tp // kv_heads_pad`` ranks that share
  that head (``Segment.model_gather``).  No parameter is stored replicated,
  so gradients need no fix-ups.
"""

from __future__ import annotations

import dataclasses


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class AttnDims:
    tp: int
    d_model: int
    head_dim: int
    hq: int              # true Q head count
    hq_pad: int          # padded to multiple of tp
    hq_local: int        # per model rank
    hkv: int             # true KV head count
    hkv_pad: int         # padded (to divisor or multiple of tp)
    kv_gather: int       # model-axis sub-group size reassembling one KV head
    hkv_local: int       # KV heads materialized per rank after gathering
    q_per_kv_local: int  # local Q heads per local KV head

    @property
    def q_cols_local(self) -> int:
        return self.hq_pad * self.head_dim // self.tp

    @property
    def kv_cols_stored(self) -> int:
        """Stored (pre-gather) KV projection columns per rank."""
        return self.hkv_pad * self.head_dim // self.tp


def attn_dims(d_model: int, n_heads: int, n_kv_heads: int, head_dim: int, tp: int) -> AttnDims:
    hq_pad = _round_up(n_heads, tp)
    if n_kv_heads >= tp:
        hkv_pad = _round_up(n_kv_heads, tp)
        kv_gather = 1
        hkv_local = hkv_pad // tp
    else:
        # pad kv heads to a power-of-two divisor of tp
        hkv_pad = 1
        while hkv_pad < n_kv_heads:
            hkv_pad *= 2
        while tp % hkv_pad != 0:
            hkv_pad *= 2
        kv_gather = tp // hkv_pad
        hkv_local = 1
    hq_local = hq_pad // tp
    # every local KV head serves an equal number of local Q heads
    if hq_local % hkv_local != 0:
        raise ValueError(
            f"local Q heads {hq_local} not divisible by local KV heads {hkv_local}"
        )
    return AttnDims(
        tp=tp,
        d_model=d_model,
        head_dim=head_dim,
        hq=n_heads,
        hq_pad=hq_pad,
        hq_local=hq_local,
        hkv=n_kv_heads,
        hkv_pad=hkv_pad,
        kv_gather=kv_gather,
        hkv_local=hkv_local,
        q_per_kv_local=hq_local // hkv_local,
    )


def shard_dim(total: int, tp: int, name: str = "") -> int:
    if total % tp != 0:
        raise ValueError(f"dim {name}={total} not divisible by tp={tp}")
    return total // tp


def pad_to_tp(total: int, tp: int) -> int:
    return _round_up(total, tp)
