"""Transformer layer types: dense (llama/qwen/granite/yi), cross-attention
(llama-3.2-vision), encoder/decoder (whisper), and MoE (deepseek, dbrx).

Each layer type provides
  * ``*_layout(cfg, tp, b)``   — appends its segments to a LayoutBuilder
  * ``*_apply(t, x, ctx, ...)``— pure function over unflattened tensors
  * cache constructors for decode.

Weights are stored TP-local (see models/dims.py for the KV-gather scheme);
activations are full ``d_model`` per rank, with a ``psum('model')`` after the
attention output and MLP down projections (Megatron TP).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core import quant as Q
from repro.core.flat_param import LayoutBuilder
from repro.models import layers as L
from repro.models.dims import AttnDims, attn_dims, shard_dim


# ---------------------------------------------------------------------------
# attention sub-block
# ---------------------------------------------------------------------------

def attn_layout(
    cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = "attn.",
    *, bias: bool = False, kv_input_dim: int | None = None,
):
    ad = attn_dims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, tp)
    d = cfg.d_model
    kvd = kv_input_dim or d
    std = 1.0 / math.sqrt(d)
    out_std = 1.0 / math.sqrt(ad.hq_pad * ad.head_dim) / math.sqrt(2 * cfg.n_layers)
    b.add(prefix + "wq", (d, ad.q_cols_local), std=std)
    b.add(prefix + "wk", (kvd, ad.kv_cols_stored), std=std,
          model_gather=ad.kv_gather, model_gather_dim=1)
    b.add(prefix + "wv", (kvd, ad.kv_cols_stored), std=std,
          model_gather=ad.kv_gather, model_gather_dim=1)
    b.add(prefix + "wo", (ad.q_cols_local, d), std=out_std)
    if bias:
        b.add(prefix + "bq", (ad.q_cols_local,), init="zeros", decay=False)
        b.add(prefix + "bk", (ad.kv_cols_stored,), init="zeros", decay=False,
              model_gather=ad.kv_gather, model_gather_dim=0)
        b.add(prefix + "bv", (ad.kv_cols_stored,), init="zeros", decay=False,
              model_gather=ad.kv_gather, model_gather_dim=0)
        b.add(prefix + "bo", (shard_dim(d, tp),), init="zeros", decay=False,
              model_gather=tp, model_gather_dim=0)
    return ad


def attn_qkv(t, x, kv_x, ad: AttnDims, ctx: L.Ctx, prefix: str, *, bias: bool):
    """Project to q [b,t,hkv_local,g,dh], k/v [b,t,hkv_local,dh]."""
    bsz, tq, _ = x.shape
    tk = kv_x.shape[1]
    q = x @ t[prefix + "wq"]
    k = kv_x @ t[prefix + "wk"]
    v = kv_x @ t[prefix + "wv"]
    if bias:
        q = q + t[prefix + "bq"].astype(q.dtype)
        k = k + t[prefix + "bk"].astype(k.dtype)
        v = v + t[prefix + "bv"].astype(v.dtype)
    q = q.reshape(bsz, tq, ad.hkv_local, ad.q_per_kv_local, ad.head_dim)
    k = k.reshape(bsz, tk, ad.hkv_local, ad.head_dim)
    v = v.reshape(bsz, tk, ad.hkv_local, ad.head_dim)
    return q, k, v


def attn_out(t, attn: jax.Array, ad: AttnDims, ctx: L.Ctx, prefix: str, *, bias: bool):
    """attn [b,t,hkv_local,g,dh] -> [b,t,d] (full, post-psum)."""
    bsz, tq = attn.shape[:2]
    hmask = L.local_head_mask(ad.hq, ad.hq_pad, ad.hq_local, ctx)
    attn = attn * hmask.reshape(1, 1, ad.hkv_local, ad.q_per_kv_local, 1).astype(attn.dtype)
    out = attn.reshape(bsz, tq, ad.q_cols_local) @ t[prefix + "wo"]
    out = L.tp_psum(out, ctx)
    if bias:
        out = out + t[prefix + "bo"].astype(out.dtype)
    return out


def _paged_kv_write(cache, pages, k, v, absp, valid_tok):
    """Scatter this tick's k/v token rows into the paged block pool.

    cache: {"k","v"[,"ks","vs"]} with k/v [n_blocks, block_size, h, dh]
    (int8 pools add f32 scale pages [n_blocks, block_size, n_scale]);
    k/v [b, tq, h, dh]; absp [b, tq] absolute positions; valid_tok [b, tq].
    Padding rows are redirected out of range and dropped (``mode="drop"``),
    so a chunk never corrupts blocks it does not own.  Int8 pools quantize
    each token row against its own per-128-block absmax (the qgZ scheme) —
    blocks are only ever written incrementally, never re-quantized.
    """
    nb, bs_blk = cache["k"].shape[:2]
    bidx = jnp.arange(absp.shape[0])[:, None]
    blk = pages.block_tables[bidx, absp // bs_blk]
    blk = jnp.where(valid_tok, blk, nb)  # out-of-range -> dropped
    off = absp % bs_blk
    new = dict(cache)
    if "ks" in cache:
        # Scales are per (token, head, 128-block of head_dim) so the scale
        # pages shard over the model axis exactly like the k/v pages.
        qk, sk = Q.quantize_flat(k.astype(jnp.float32))
        qv, sv = Q.quantize_flat(v.astype(jnp.float32))
        new["k"] = cache["k"].at[blk, off].set(qk, mode="drop")
        new["v"] = cache["v"].at[blk, off].set(qv, mode="drop")
        new["ks"] = cache["ks"].at[blk, off].set(sk, mode="drop")
        new["vs"] = cache["vs"].at[blk, off].set(sv, mode="drop")
    else:
        new["k"] = cache["k"].at[blk, off].set(k.astype(cache["k"].dtype), mode="drop")
        new["v"] = cache["v"].at[blk, off].set(v.astype(cache["v"].dtype), mode="drop")
    return new


def _paged_kv_read(cache, pages, compute_dtype):
    """Gather the block pool into a contiguous [b, max_blocks*bs, h, dh] view.

    The view has the same key-axis length as a contiguous cache of capacity
    ``max_blocks * block_size``, and unwritten tail entries are masked by
    ``kv_valid_len`` — masked lanes underflow to exactly 0.0 in the fp32
    softmax, which is what makes paged decode bitwise-equal to the
    contiguous reference.
    """
    tables = pages.block_tables
    b, mb = tables.shape
    nb, bs_blk, h, dh = cache["k"].shape

    def view(name):
        pagev = cache[name][tables]  # [b, mb, bs, ...]
        return pagev.reshape(b, mb * bs_blk, *pagev.shape[3:])

    k, v = view("k"), view("v")
    if "ks" in cache:
        k = Q.dequantize_flat(k, view("ks"), dtype=compute_dtype)
        v = Q.dequantize_flat(v, view("vs"), dtype=compute_dtype)
    return k, v


def self_attention(
    t, x, ctx: L.Ctx, ad: AttnDims, cfg: ArchConfig, *,
    prefix: str = "attn.", causal: bool = True, window: int = 0,
    use_rope: bool = True, bias: bool = False, cache=None,
):
    """Self attention in train/prefill/decode modes.

    cache: None (train) or dict(k, v[, pos]) for prefill-fill / decode.
    Returns (out, new_cache).
    """
    bsz, tq, _ = x.shape
    q, k, v = attn_qkv(t, x, x, ad, ctx, prefix, bias=bias)

    if ctx.mode == "decode" and (ctx.pages is not None or getattr(ctx.pos, "ndim", 0)):
        # Continuous batching: per-request positions [b] (ragged batch),
        # optionally over a paged block pool.  tq > 1 means a chunk of
        # tokens per slot (chunked prefill interleaved with decode); rows
        # at or beyond a slot's n_new are padding whose writes are dropped
        # and whose outputs the scheduler ignores.
        if window:
            raise NotImplementedError("paged/vector-position decode needs window == 0")
        pos, pages = ctx.pos, ctx.pages
        absp = pos[:, None] + jnp.arange(tq)[None, :]  # [b, tq]
        if use_rope:
            q = _rope5(q, absp, cfg.rope_theta)
            k = L.rotary(k, absp, cfg.rope_theta)
        n_new = getattr(pages, "n_new", None) if pages is not None else None
        valid_tok = (jnp.arange(tq)[None, :] < n_new[:, None]) if n_new is not None \
            else jnp.ones((bsz, tq), bool)
        if pages is not None:
            new_cache = _paged_kv_write(cache, pages, k, v, absp, valid_tok)
            k_all, v_all = _paged_kv_read(new_cache, pages, ctx.compute_dtype)
        else:
            cap = cache["k"].shape[1]
            bidx = jnp.arange(bsz)[:, None]
            slot = jnp.where(valid_tok, absp, cap)  # out-of-range -> dropped
            k_all = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype), mode="drop")
            v_all = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": k_all, "v": v_all}
        out = L.attention(
            q, k_all, v_all, causal=False, window=0,
            kv_valid_len=absp + 1, scores_dtype=ctx.scores_dtype,
        )
        # a cache dtype wider than the compute dtype (fp32 KV under bf16
        # compute) must not leak into the residual stream's scan carry
        out = out.astype(x.dtype)
        return attn_out(t, out, ad, ctx, prefix, bias=bias), new_cache

    if ctx.mode == "decode":
        pos = ctx.pos
        positions = jnp.broadcast_to(pos, (bsz, tq))
        if use_rope:
            q = _rope5(q, positions, cfg.rope_theta)
            k = L.rotary(k, positions, cfg.rope_theta)
        cap = cache["k"].shape[1]
        slot = pos % cap if window else pos
        k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        valid = jnp.minimum(pos + 1, cap)
        out = L.attention(
            q, k_cache, v_cache, causal=False, window=0,
            kv_valid_len=valid, scores_dtype=ctx.scores_dtype,
        )
        new_cache = {"k": k_cache, "v": v_cache}
        return attn_out(t, out, ad, ctx, prefix, bias=bias), new_cache

    positions = jnp.broadcast_to(jnp.arange(tq), (bsz, tq))
    if use_rope:
        q = _rope5(q, positions, cfg.rope_theta)
        k = L.rotary(k, positions, cfg.rope_theta)
    out = L.attention(q, k, v, causal=causal, window=window,
                      scores_dtype=ctx.scores_dtype)
    new_cache = None
    if ctx.mode == "prefill":
        cap = ctx.cache_len if not window else min(window, ctx.cache_len)
        if tq >= cap:
            # slot of absolute position a is a % cap (matches decode writes)
            k_keep = jnp.roll(k[:, tq - cap:], tq % cap, axis=1)
            v_keep = jnp.roll(v[:, tq - cap:], tq % cap, axis=1)
        else:
            pad = cap - tq
            k_keep = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_keep = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        new_cache = {"k": k_keep.astype(ctx.compute_dtype),
                     "v": v_keep.astype(ctx.compute_dtype)}
    return attn_out(t, out, ad, ctx, prefix, bias=bias), new_cache


def cross_attention(
    t, x, kv_src, ctx: L.Ctx, ad: AttnDims, cfg: ArchConfig, *,
    prefix: str = "xattn.", bias: bool = False, cache=None,
):
    """Cross attention against a precomputed source (vision / encoder).

    During decode the projected source KV comes from the cache (computed at
    prefill) to keep the per-token cost O(1) in projections.
    """
    bsz, tq, _ = x.shape
    if ctx.mode == "decode" and cache is not None:
        q = x @ t[prefix + "wq"]
        if bias:
            q = q + t[prefix + "bq"].astype(q.dtype)
        q = q.reshape(bsz, tq, ad.hkv_local, ad.q_per_kv_local, ad.head_dim)
        k, v = cache["k"], cache["v"]
        out = L.attention(q, k, v, causal=False, scores_dtype=ctx.scores_dtype)
        return attn_out(t, out, ad, ctx, prefix, bias=bias), cache
    q, k, v = attn_qkv(t, x, kv_src, ad, ctx, prefix, bias=bias)
    out = L.attention(q, k, v, causal=False, scores_dtype=ctx.scores_dtype)
    new_cache = None
    if ctx.mode == "prefill":
        new_cache = {"k": k.astype(ctx.compute_dtype), "v": v.astype(ctx.compute_dtype)}
    return attn_out(t, out, ad, ctx, prefix, bias=bias), new_cache


def _rope5(q: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary over [b, t, hkv, g, dh] (fold grouped head dims)."""
    b, tq, hkv, g, dh = q.shape
    out = L.rotary(q.reshape(b, tq, hkv * g, dh), positions, theta)
    return out.reshape(b, tq, hkv, g, dh)


def make_kv_cache(cfg: ArchConfig, tp: int, batch: int, cache_len: int, *, window: int = 0):
    ad = attn_dims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, tp)
    cap = min(window, cache_len) if window else cache_len
    shape = (batch, cap, ad.hkv_local, ad.head_dim)
    return {"k": jnp.zeros(shape, jnp.bfloat16), "v": jnp.zeros(shape, jnp.bfloat16)}


def make_cross_cache(cfg: ArchConfig, tp: int, batch: int, src_len: int):
    ad = attn_dims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, tp)
    shape = (batch, src_len, ad.hkv_local, ad.head_dim)
    return {"k": jnp.zeros(shape, jnp.bfloat16), "v": jnp.zeros(shape, jnp.bfloat16)}


# ---------------------------------------------------------------------------
# norms + MLP sub-blocks
# ---------------------------------------------------------------------------

def norm_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, name: str):
    d_local = shard_dim(cfg.d_model, tp)
    b.add(name + ".scale", (d_local,), init="zeros", decay=False,
          model_gather=tp, model_gather_dim=0)
    if cfg.norm == "ln":
        b.add(name + ".bias", (d_local,), init="zeros", decay=False,
              model_gather=tp, model_gather_dim=0)


def apply_norm(cfg: ArchConfig, t, x, name: str):
    if cfg.norm == "ln":
        return L.layer_norm(x, t[name + ".scale"], t[name + ".bias"])
    return L.rms_norm(x, t[name + ".scale"])


def mlp_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = "mlp.",
               d_ff: int | None = None):
    d = cfg.d_model
    f_local = shard_dim(d_ff or cfg.d_ff, tp, "d_ff")
    std = 1.0 / math.sqrt(d)
    dstd = 1.0 / math.sqrt((d_ff or cfg.d_ff)) / math.sqrt(2 * cfg.n_layers)
    if cfg.mlp in ("swiglu", "geglu"):
        b.add(prefix + "wg", (d, f_local), std=std)
        b.add(prefix + "wu", (d, f_local), std=std)
        b.add(prefix + "wd", (f_local, d), std=dstd)
    else:  # gelu (whisper)
        b.add(prefix + "w1", (d, f_local), std=std)
        b.add(prefix + "b1", (f_local,), init="zeros", decay=False)
        b.add(prefix + "wd", (f_local, d), std=dstd)
        b.add(prefix + "b2", (shard_dim(d, tp),), init="zeros", decay=False,
              model_gather=tp, model_gather_dim=0)


def mlp_apply(cfg: ArchConfig, t, x, ctx: L.Ctx, prefix: str = "mlp."):
    if cfg.mlp == "swiglu":
        out = L.mlp_swiglu(x, t[prefix + "wg"], t[prefix + "wu"], t[prefix + "wd"])
    elif cfg.mlp == "geglu":
        out = L.mlp_geglu(x, t[prefix + "wg"], t[prefix + "wu"], t[prefix + "wd"])
    else:
        out = L.mlp_gelu(x, t[prefix + "w1"], t[prefix + "b1"], t[prefix + "wd"])
    out = L.tp_psum(out, ctx)
    if cfg.mlp == "gelu":
        out = out + t[prefix + "b2"].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# dense decoder layer (llama / qwen / granite / yi family)
# ---------------------------------------------------------------------------

def dense_layer_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = ""):
    pb = LayoutBuilder(prefix)
    norm_layout(cfg, tp, pb, "ln1")
    attn_layout(cfg, tp, pb, "attn.", bias=cfg.qkv_bias)
    norm_layout(cfg, tp, pb, "ln2")
    mlp_layout(cfg, tp, pb, "mlp.")
    b.extend(pb)


def dense_layer_apply(cfg: ArchConfig, ad: AttnDims, t, x, ctx: L.Ctx,
                      cache=None, prefix: str = "", *, window: int = 0,
                      causal: bool = True):
    tt = {name[len(prefix):]: v for name, v in t.items()} if prefix else t
    h = apply_norm(cfg, tt, x, "ln1")
    a, new_cache = self_attention(
        tt, h, ctx, ad, cfg, prefix="attn.", causal=causal, window=window,
        use_rope=cfg.use_rope, bias=cfg.qkv_bias,
        cache=cache,
    )
    x = x + a
    h = apply_norm(cfg, tt, x, "ln2")
    x = x + mlp_apply(cfg, tt, h, ctx, "mlp.")
    return x, new_cache


# ---------------------------------------------------------------------------
# gated cross-attention layer (llama-3.2-vision)
# ---------------------------------------------------------------------------

def cross_layer_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = ""):
    pb = LayoutBuilder(prefix)
    norm_layout(cfg, tp, pb, "ln1")
    attn_layout(cfg, tp, pb, "xattn.")
    pb.add("gate_attn", (1,), init="zeros", decay=False)
    norm_layout(cfg, tp, pb, "ln2")
    mlp_layout(cfg, tp, pb, "mlp.")
    pb.add("gate_mlp", (1,), init="zeros", decay=False)
    b.extend(pb)


def cross_layer_apply(cfg: ArchConfig, ad: AttnDims, t, x, ctx: L.Ctx,
                      cache=None, prefix: str = ""):
    tt = {name[len(prefix):]: v for name, v in t.items()} if prefix else t
    h = apply_norm(cfg, tt, x, "ln1")
    a, new_cache = cross_attention(
        tt, h, ctx.vision if ctx.vision is not None else ctx.enc_out,
        ctx, ad, cfg, prefix="xattn.", cache=cache)
    x = x + jnp.tanh(tt["gate_attn"].astype(jnp.float32)).astype(x.dtype) * a
    h = apply_norm(cfg, tt, x, "ln2")
    m = mlp_apply(cfg, tt, h, ctx, "mlp.")
    x = x + jnp.tanh(tt["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * m
    return x, new_cache


# ---------------------------------------------------------------------------
# whisper encoder / decoder layers
# ---------------------------------------------------------------------------

def encdec_dec_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = ""):
    pb = LayoutBuilder(prefix)
    norm_layout(cfg, tp, pb, "ln1")
    attn_layout(cfg, tp, pb, "attn.", bias=True)
    norm_layout(cfg, tp, pb, "lnx")
    attn_layout(cfg, tp, pb, "xattn.", bias=True)
    norm_layout(cfg, tp, pb, "ln2")
    mlp_layout(cfg, tp, pb, "mlp.")
    b.extend(pb)


def encdec_dec_apply(cfg: ArchConfig, ad: AttnDims, t, x, ctx: L.Ctx,
                     cache=None, prefix: str = ""):
    tt = {name[len(prefix):]: v for name, v in t.items()} if prefix else t
    self_cache = cache.get("self") if cache else None
    cross_cache = cache.get("cross") if cache else None
    h = apply_norm(cfg, tt, x, "ln1")
    a, nc_self = self_attention(
        tt, h, ctx, ad, cfg, prefix="attn.", causal=True,
        use_rope=False, bias=True, cache=self_cache)
    x = x + a
    h = apply_norm(cfg, tt, x, "lnx")
    a, nc_cross = cross_attention(
        tt, h, ctx.enc_out, ctx, ad, cfg, prefix="xattn.", bias=True,
        cache=cross_cache)
    x = x + a
    h = apply_norm(cfg, tt, x, "ln2")
    x = x + mlp_apply(cfg, tt, h, ctx, "mlp.")
    new_cache = None
    if nc_self is not None or nc_cross is not None:
        new_cache = {"self": nc_self, "cross": nc_cross}
    return x, new_cache


# ---------------------------------------------------------------------------
# MoE layer (deepseek-moe / dbrx)
# ---------------------------------------------------------------------------

def moe_layer_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = ""):
    pb = LayoutBuilder(prefix)
    norm_layout(cfg, tp, pb, "ln1")
    attn_layout(cfg, tp, pb, "attn.", bias=cfg.qkv_bias)
    norm_layout(cfg, tp, pb, "ln2")
    d, f = cfg.d_model, cfg.d_ff
    e_local = shard_dim(cfg.n_experts, tp, "n_experts")
    std = 1.0 / math.sqrt(d)
    dstd = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    pb.add("router.w", (d, e_local), std=std, model_gather=tp, model_gather_dim=1)
    pb.add("moe.wg", (e_local, d, f), std=std)
    pb.add("moe.wu", (e_local, d, f), std=std)
    pb.add("moe.wd", (e_local, f, d), std=dstd)
    if cfg.n_shared_experts:
        mlp_layout(cfg, tp, pb, "shared.", d_ff=cfg.n_shared_experts * f)
    b.extend(pb)


def _moe_dispatch_tokens(x2d, t, cfg: ArchConfig, ctx: L.Ctx):
    """GShard-style capacity dispatch with expert parallelism over 'model'.

    x2d: [n, d] tokens.  Returns (out [n, d], aux_loss scalar).
    """
    n, d = x2d.shape
    e = cfg.n_experts
    k = cfg.top_k
    cap = int(math.ceil(n * k / e * cfg.capacity_factor))
    cap = max(4, ((cap + 3) // 4) * 4)

    logits = (x2d @ t["router.w"]).astype(jnp.float32)       # [n, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, k)                # [n, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    flat_e = gate_idx.reshape(-1)                            # [n*k], token-major
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # [n*k, E]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(n * k), flat_e]
    keep = (pos_in_e < cap).astype(x2d.dtype)                # capacity drop

    # scatter tokens into [E, cap, d]
    tok = jnp.repeat(x2d, k, axis=0) * keep[:, None]
    buf = jnp.zeros((e, cap, d), x2d.dtype)
    buf = buf.at[flat_e, jnp.clip(pos_in_e, 0, cap - 1)].add(tok)

    # expert parallelism: ship expert slabs to their owner ranks
    if ctx.tp > 1:
        buf = lax.all_to_all(buf, ctx.tp_axis, split_axis=0, concat_axis=1, tiled=True)
    # buf: [E_local, tp*cap, d]
    h = jnp.einsum("ecd,edf->ecf", buf, t["moe.wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, t["moe.wu"])
    h = jax.nn.silu(h) * u
    out = jnp.einsum("ecf,efd->ecd", h, t["moe.wd"])
    if ctx.tp > 1:
        out = lax.all_to_all(out, ctx.tp_axis, split_axis=1, concat_axis=0, tiled=True)

    # combine: gather each assignment's expert output, weight by gate
    picked = out[flat_e, jnp.clip(pos_in_e, 0, cap - 1)]     # [n*k, d]
    w = (gate_vals.reshape(-1) * keep).astype(picked.dtype)
    y = jnp.sum((picked * w[:, None]).reshape(n, k, d), axis=1)

    # switch-style load-balance loss
    me = jnp.mean(probs, axis=0)                             # [E]
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return y, aux


def moe_ffn(t, x, cfg: ArchConfig, ctx: L.Ctx):
    """Token-parallel MoE: activations are replicated across the model axis,
    so each rank routes only its 1/tp slice of the tokens (otherwise every
    rank would redundantly dispatch identical copies — 16x wasted expert
    FLOPs).  Outputs are re-assembled with an all-gather whose adjoint is a
    reduce-scatter, keeping gradients exact.  Tiny token counts (decode)
    fall back to the replicated path."""
    b, s, d = x.shape
    n = b * s
    tp = ctx.tp
    x2d = x.reshape(n, d)

    shard_tokens = tp > 1 and n % tp == 0 and n >= tp
    if shard_tokens:
        n_local = n // tp
        start = ctx.tp_index() * n_local
        x2d = lax.dynamic_slice_in_dim(x2d, start, n_local, axis=0)
        n = n_local

    chunk = n
    for cand in (4096, 2048, 1024):
        if n > cand and n % cand == 0:
            chunk = cand
            break
    x2 = x2d.reshape(n // chunk, chunk, d)

    def body(aux, xc):
        y, a = _moe_dispatch_tokens(xc, t, cfg, ctx)
        return aux + a, y

    aux, y = lax.scan(body, jnp.float32(0.0), x2)
    aux = aux * (chunk / n)
    y = y.reshape(n, d)
    if shard_tokens:
        y = lax.all_gather(y, ctx.tp_axis, axis=0, tiled=True)
        aux = lax.pmean(aux, ctx.tp_axis)
    out = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + mlp_apply(cfg, t, x, ctx, "shared.")
    return out, aux * (chunk / n)


def moe_layer_apply(cfg: ArchConfig, ad: AttnDims, t, x, ctx: L.Ctx,
                    cache=None, prefix: str = ""):
    tt = {name[len(prefix):]: v for name, v in t.items()} if prefix else t
    h = apply_norm(cfg, tt, x, "ln1")
    a, new_cache = self_attention(
        tt, h, ctx, ad, cfg, prefix="attn.", causal=True,
        use_rope=cfg.use_rope, bias=cfg.qkv_bias, cache=cache,
    )
    x = x + a
    h = apply_norm(cfg, tt, x, "ln2")
    y, aux = moe_ffn(tt, h, cfg, ctx)
    x = x + y
    return (x, aux), new_cache
