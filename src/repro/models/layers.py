"""Numeric building blocks shared by every architecture.

Everything here is a pure function over explicit tensors; tensor-parallel
collectives use named mesh axes (the functions are always called inside
``shard_map`` — on a single device the axes simply have size 1).

The chunked online-softmax attention is the pure-jnp oracle for the Pallas
flash-attention kernel in ``repro.kernels.flash_attention``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# execution context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ctx:
    """Static + dynamic context threaded through block applications."""

    mode: str = "train"            # train | prefill | decode
    tp_axis: str = "model"
    tp: int = 1
    pos: Any = None                # decode: current position scalar (traced)
    cache_len: int = 0             # decode: static KV-cache capacity
    window: int = 0                # local-attention window override
    vision: Any = None             # [b, n_img, d] stub patch embeddings
    enc_out: Any = None            # [b, n_frames, d] encoder output
    compute_dtype: Any = jnp.bfloat16
    scores_bf16: bool = False      # bf16 attention scores (§Perf)
    mlstm_chunk: int = 0           # chunkwise-parallel mLSTM (§Perf; 0=scan)
    step_seed: Any = None          # traced step counter (qgZ dither seed)
    pages: Any = None              # paged-KV state (runtime/paged.PageState):
    #                                block_tables [b, max_blocks] + block_size;
    #                                None = contiguous cache (the default)

    @property
    def scores_dtype(self):
        return jnp.bfloat16 if self.scores_bf16 else jnp.float32

    def tp_index(self):
        if self.tp == 1:
            return jnp.int32(0)
        return lax.axis_index(self.tp_axis)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [b, t, h, dh]; positions: [b, t] absolute token positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [b, t, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _gqa_scores(q: jax.Array, k: jax.Array, dtype=jnp.float32) -> jax.Array:
    """q [b, tq, hkv, g, dh] x k [b, tk, hkv, dh] -> [b, hkv, g, tq, tk]."""
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=dtype
    )


def _masked_softmax(s: jax.Array, bias: jax.Array) -> jax.Array:
    """Numerically-stable softmax in the score dtype; the row-max and the
    normalizer are kept in fp32 (flash-kernel-style) so bf16 scores only
    halve the HBM traffic of the [tq, tk] tensors, not the statistics.

    ``bias`` is [tq, tk] (shared across the batch) or [b, tq, tk]
    (per-request masks for continuous batching)."""
    if bias.ndim == 3:
        s = s + bias[:, None, None].astype(s.dtype)
    else:
        s = s + bias[None, None, None].astype(s.dtype)
    m = lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m)
    denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
    return p / jnp.maximum(denom, 1e-30).astype(p.dtype)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p [b, hkv, g, tq, tk] x v [b, tk, hkv, dh] -> [b, tq, hkv, g, dh]."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _mask_bias(
    q_pos: jax.Array, k_pos: jax.Array, *, causal: bool, window: int,
    kv_valid_len: jax.Array | None = None,
) -> jax.Array:
    """[tq, tk] additive mask (0 allowed, NEG_INF blocked)."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    diff = q_pos[:, None] - k_pos[None, :]
    if causal:
        m = jnp.where(diff < 0, NEG_INF, m)
    if window:
        m = jnp.where(diff >= window, NEG_INF, m)
    if kv_valid_len is not None:
        m = jnp.where(k_pos[None, :] >= kv_valid_len, NEG_INF, m)
    return m


def attention(
    q: jax.Array,                 # [b, tq, hkv, g, dh]
    k: jax.Array,                 # [b, tk, hkv, dh]
    v: jax.Array,                 # [b, tk, hkv, dh]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: Any = 0,            # absolute position of q[0]
    k_offset: Any = 0,
    kv_valid_len: Any = None,     # decode: number of valid cache entries
    chunk_q: int = 512,
    scores_dtype=jnp.float32,
) -> jax.Array:
    """Scaled-dot-product GQA attention, chunked over queries.

    Direct path for short query lengths; otherwise a `lax.scan` over query
    chunks (scores are [chunk_q, tk] — memory-bounded for 32k prefill).
    Local-window attention slices the KV to a static-length window per query
    chunk, so HLO FLOPs reflect the sub-quadratic cost.
    scores_dtype=bf16 halves the HBM traffic of the score/probability
    tensors (fp32 statistics retained) — beyond-paper optimization, §Perf.
    """
    b, tq, hkv, g, dh = q.shape
    tk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    qs = (q.astype(jnp.float32) * scale).astype(q.dtype)

    # Per-request valid lengths ([b] or [b, tq]) produce a [b, tq, tk] bias;
    # a scalar kv_valid_len keeps the exact legacy [tq, tk] code path.
    batched_valid = kv_valid_len is not None and getattr(kv_valid_len, "ndim", 0) >= 1

    def direct(qc, q_pos):
        bias = _mask_bias(
            q_pos, k_offset + jnp.arange(tk), causal=causal, window=window,
            kv_valid_len=None if batched_valid else kv_valid_len,
        )
        if batched_valid:
            kvl = kv_valid_len if kv_valid_len.ndim == 2 else kv_valid_len[:, None]
            invalid = (k_offset + jnp.arange(tk))[None, None, :] >= kvl[:, :, None]
            bias = bias[None] + jnp.where(invalid, NEG_INF, 0.0)
        p = _masked_softmax(_gqa_scores(qc, k, scores_dtype), bias)
        return _gqa_out(p, v)

    if batched_valid or tq <= max(chunk_q, 1) or tq % chunk_q != 0:
        return direct(qs, q_offset + jnp.arange(tq))

    nq = tq // chunk_q

    if window and window + chunk_q < tk:
        # local attention: static-length KV slab per query chunk
        slab = window + chunk_q

        def body(_, i):
            q_lo = i * chunk_q
            qc = lax.dynamic_slice_in_dim(qs, q_lo, chunk_q, axis=1)
            k_lo = jnp.clip(q_lo + chunk_q - slab, 0, tk - slab)
            kc = lax.dynamic_slice_in_dim(k, k_lo, slab, axis=1)
            vc = lax.dynamic_slice_in_dim(v, k_lo, slab, axis=1)
            bias = _mask_bias(
                q_offset + q_lo + jnp.arange(chunk_q),
                k_offset + k_lo + jnp.arange(slab),
                causal=causal, window=window, kv_valid_len=kv_valid_len,
            )
            p = _masked_softmax(_gqa_scores(qc, kc, scores_dtype), bias)
            return None, _gqa_out(p, vc)

        _, chunks = lax.scan(body, None, jnp.arange(nq))
    else:

        def body(_, i):
            q_lo = i * chunk_q
            qc = lax.dynamic_slice_in_dim(qs, q_lo, chunk_q, axis=1)
            out = direct(qc, q_offset + q_lo + jnp.arange(chunk_q))
            return None, out

        _, chunks = lax.scan(body, None, jnp.arange(nq))

    # chunks: [nq, b, chunk_q, hkv, g, dh] -> [b, tq, hkv, g, dh]
    return jnp.moveaxis(chunks, 0, 1).reshape(b, tq, hkv, g, dh)


# ---------------------------------------------------------------------------
# MLPs (TP-sharded hidden dim; caller psums after the down projection)
# ---------------------------------------------------------------------------

def mlp_swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


def mlp_geglu(x, wg, wu, wd):
    h = jax.nn.gelu(x @ wg, approximate=True) * (x @ wu)
    return h @ wd


def mlp_gelu(x, w1, b1, w2):
    h = jax.nn.gelu(x @ w1 + b1.astype(x.dtype), approximate=True)
    return h @ w2


# ---------------------------------------------------------------------------
# embeddings + vocab-parallel loss
# ---------------------------------------------------------------------------

def embed_lookup(table_local: jax.Array, ids: jax.Array, ctx: Ctx) -> jax.Array:
    """table_local: [vocab, d/tp] (d sharded over model) -> [b, t, d] full."""
    emb_local = jnp.take(table_local, ids, axis=0)
    if ctx.tp == 1:
        return emb_local
    return lax.all_gather(emb_local, ctx.tp_axis, axis=-1, tiled=True)


def tp_cross_entropy(
    logits_local: jax.Array,   # [b, t, V/tp] (vocab sharded over model)
    targets: jax.Array,        # [b, t] int32 global vocab ids
    mask: jax.Array,           # [b, t] 1.0 valid token
    *,
    vocab_real: int,
    vocab_padded: int,
    ctx: Ctx,
) -> jax.Array:
    """Vocab-parallel softmax cross-entropy (Megatron-style), fp32."""
    vl = logits_local.shape[-1]
    lg = logits_local.astype(jnp.float32)
    start = ctx.tp_index() * vl
    col = start + jnp.arange(vl)
    lg = jnp.where(col[None, None, :] < vocab_real, lg, NEG_INF)

    # the stabilizer max carries no gradient (softmax is shift-invariant)
    m_local = lax.stop_gradient(jnp.max(lg, axis=-1))
    m = lax.pmax(m_local, ctx.tp_axis) if ctx.tp > 1 else m_local
    e = jnp.exp(lg - m[..., None])
    denom_local = jnp.sum(e, axis=-1)
    denom = lax.psum(denom_local, ctx.tp_axis) if ctx.tp > 1 else denom_local

    tgt_local = targets - start
    in_range = (tgt_local >= 0) & (tgt_local < vl)
    tgt_logit_local = jnp.take_along_axis(
        lg, jnp.clip(tgt_local, 0, vl - 1)[..., None], axis=-1
    )[..., 0]
    tgt_logit_local = jnp.where(in_range, tgt_logit_local, 0.0)
    tgt_logit = lax.psum(tgt_logit_local, ctx.tp_axis) if ctx.tp > 1 else tgt_logit_local

    nll = jnp.log(denom) + m - tgt_logit
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def tp_psum(x: jax.Array, ctx: Ctx) -> jax.Array:
    return lax.psum(x, ctx.tp_axis) if ctx.tp > 1 else x


def local_head_mask(hq: int, hq_pad: int, hq_local: int, ctx: Ctx) -> jax.Array:
    """1.0 for real Q heads, 0.0 for padded heads, per model rank."""
    if hq == hq_pad:
        return jnp.ones((hq_local,), jnp.float32)
    base = ctx.tp_index() * hq_local if ctx.tp > 1 else 0
    return ((base + jnp.arange(hq_local)) < hq).astype(jnp.float32)
