"""Recurrent blocks: Griffin RG-LRU (recurrentgemma) and xLSTM (m/sLSTM).

TPU adaptation notes (DESIGN.md §2):
* RG-LRU is a per-channel diagonal linear recurrence — it shards trivially
  over the model axis and runs as a `lax.associative_scan` (parallel prefix)
  over time; the Pallas kernel in kernels/rglru implements the same scan with
  explicit VMEM tiling.  Gate projections use diagonal weights (documented
  simplification of Griffin's block-diagonal maps; keeps TP exact).
* xLSTM-125m is far too small to shard over a 16-wide model axis; its weights
  are stored model-sharded (no replication) but gathered fully at use and the
  cell computed replicated per rank.  sLSTM's dense recurrent coupling makes
  per-step sharding a collective-per-timestep — a degenerate port we reject.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.core.flat_param import LayoutBuilder
from repro.models import layers as L
from repro.models.blocks import (
    apply_norm, mlp_apply, mlp_layout, norm_layout,
)
from repro.models.dims import shard_dim

LRU_C = 8.0


# ---------------------------------------------------------------------------
# Griffin recurrent residual block
# ---------------------------------------------------------------------------

def griffin_rec_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = ""):
    pb = LayoutBuilder(prefix)
    d = cfg.d_model
    r = cfg.lru_width or d
    rl = shard_dim(r, tp, "lru_width")
    std = 1.0 / math.sqrt(d)
    norm_layout(cfg, tp, pb, "ln1")
    pb.add("rec.wx", (d, rl), std=std)
    pb.add("rec.wy", (d, rl), std=std)
    pb.add("rec.conv_w", (cfg.conv_width, rl), std=0.5)
    pb.add("rec.conv_b", (rl,), init="zeros", decay=False)
    pb.add("rec.wi", (rl,), std=0.02, decay=False)
    pb.add("rec.bi", (rl,), init="zeros", decay=False)
    pb.add("rec.wr", (rl,), std=0.02, decay=False)
    pb.add("rec.br", (rl,), init="zeros", decay=False)
    pb.add("rec.lam", (rl,), init="lru", decay=False)
    pb.add("rec.wo", (rl, d), std=1.0 / math.sqrt(r) / math.sqrt(2 * cfg.n_layers))
    norm_layout(cfg, tp, pb, "ln2")
    mlp_layout(cfg, tp, pb, "mlp.")
    b.extend(pb)


def _causal_conv1d(x, w, bias, state=None):
    """Depthwise causal conv; x [b, t, c], w [cw, c].

    state: [b, cw-1, c] previous inputs (decode); returns (y, new_state).
    """
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
        ext = jnp.concatenate([pad, x], axis=1)
    else:
        ext = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(ext[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(cw))
    y = y + bias.astype(x.dtype)
    new_state = ext[:, -(cw - 1):] if cw > 1 else None
    return y, new_state


def _rglru_coeffs(t, x, prefix):
    """Per-channel gates -> (a, b) of the recurrence h = a*h_prev + b."""
    xf = x.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(xf * t[prefix + "wr"].astype(jnp.float32)
                            + t[prefix + "br"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(xf * t[prefix + "wi"].astype(jnp.float32)
                            + t[prefix + "bi"].astype(jnp.float32))
    # log a_base = -softplus(-lam)  (= log sigmoid(lam), stable)
    log_a_base = -jax.nn.softplus(-t[prefix + "lam"].astype(jnp.float32))
    log_a = LRU_C * r_gate * log_a_base
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-6, 1.0)) * (i_gate * xf)
    return a, b


def rglru_scan(t, x, prefix: str = "rec."):
    """RG-LRU over a sequence via associative scan.  x [b, T, rl] -> same."""
    a, b = _rglru_coeffs(t, x, prefix)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype)


def rglru_step(t, x1, h_prev, prefix: str = "rec."):
    """One decode step; x1 [b, rl], h_prev [b, rl] fp32 state."""
    a, b = _rglru_coeffs(t, x1[:, None, :], prefix)
    h = a[:, 0] * h_prev + b[:, 0]
    return h.astype(x1.dtype), h


def griffin_rec_apply(cfg: ArchConfig, t, x, ctx: L.Ctx, cache=None, prefix: str = ""):
    tt = {name[len(prefix):]: v for name, v in t.items()} if prefix else t
    h = apply_norm(cfg, tt, x, "ln1")
    xa = h @ tt["rec.wx"]
    xb = jax.nn.gelu(h @ tt["rec.wy"], approximate=True)
    if ctx.mode == "decode":
        conv_state = cache["conv"]
        xa, conv_state = _causal_conv1d(xa, tt["rec.conv_w"], tt["rec.conv_b"], conv_state)
        y1, h_state = rglru_step(tt, xa[:, 0], cache["h"])
        rec = y1[:, None, :]
        new_cache = {"conv": conv_state.astype(jnp.bfloat16), "h": h_state}
    else:
        xa, conv_state = _causal_conv1d(xa, tt["rec.conv_w"], tt["rec.conv_b"])
        a, b_ = _rglru_coeffs(tt, xa, "rec.")

        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2

        _, hs = lax.associative_scan(combine, (a, b_), axis=1)
        rec = hs.astype(x.dtype)
        new_cache = None
        if ctx.mode == "prefill":
            new_cache = {
                "conv": conv_state.astype(jnp.bfloat16),
                "h": hs[:, -1].astype(jnp.float32),
            }
    out = (rec * xb) @ tt["rec.wo"]
    x = x + L.tp_psum(out, ctx)
    h = apply_norm(cfg, tt, x, "ln2")
    x = x + mlp_apply(cfg, tt, h, ctx, "mlp.")
    return x, new_cache


def make_rec_cache(cfg: ArchConfig, tp: int, batch: int):
    rl = shard_dim(cfg.lru_width or cfg.d_model, tp)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, rl), jnp.bfloat16),
        "h": jnp.zeros((batch, rl), jnp.float32),
    }


# ---------------------------------------------------------------------------
# xLSTM blocks (model-replicated compute; weights stored sharded)
# ---------------------------------------------------------------------------

def _gathered(shape_full, tp):
    """Stored shape for a fully-model-gathered tensor (dim -1 padded)."""
    *lead, last = shape_full
    pad = ((last + tp - 1) // tp) * tp
    return tuple(lead) + (pad // tp,), pad


def _add_gathered(pb: LayoutBuilder, name, shape_full, tp, **kw):
    stored, pad = _gathered(shape_full, tp)
    pb.add(name, stored, model_gather=tp, model_gather_dim=len(stored) - 1, **kw)
    return pad


def mlstm_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = ""):
    pb = LayoutBuilder(prefix)
    d = cfg.d_model
    inner = int(cfg.expand * d)
    nh = cfg.n_heads
    std = 1.0 / math.sqrt(d)
    istd = 1.0 / math.sqrt(inner)
    norm_layout(cfg, tp, pb, "ln1")
    _add_gathered(pb, "m.wup", (d, 2 * inner), tp, std=std)
    _add_gathered(pb, "m.conv_w", (cfg.conv_width, inner), tp, std=0.5)
    _add_gathered(pb, "m.conv_b", (inner,), tp, init="zeros", decay=False)
    _add_gathered(pb, "m.wq", (inner, inner), tp, std=istd)
    _add_gathered(pb, "m.wk", (inner, inner), tp, std=istd)
    _add_gathered(pb, "m.wv", (inner, inner), tp, std=istd)
    _add_gathered(pb, "m.wif", (inner, 2 * nh), tp, std=istd, decay=False)
    _add_gathered(pb, "m.bif", (2 * nh,), tp, init="zeros", decay=False)
    _add_gathered(pb, "m.hnorm", (inner,), tp, init="zeros", decay=False)
    _add_gathered(pb, "m.wo", (inner, d), tp,
                  std=istd / math.sqrt(2 * cfg.n_layers))
    b.extend(pb)


def mlstm_chunkwise(q, k, v, ilog, flog, chunk: int):
    """Chunkwise-parallel mLSTM (xLSTM appendix / GLA-style form).

    The sequential cell streams a [dk, dv] matrix state through HBM every
    timestep — hopeless on TPU.  This form walks chunks of length ``chunk``:
    within a chunk everything is dense matmuls (MXU food), and the state is
    read/written once per chunk, cutting state HBM traffic by ``chunk``x.
    Matches the sequential cell up to the stabilizer-floor choice (see
    tests/test_recurrent.py tolerances).

    q/k/v: [b, T, nh, dh] (k pre-scaled); ilog/flog: [b, T, nh] fp32.
    Returns h [b, T, nh, dh] fp32 and the final (C, n, m) state.
    """
    b, t, nh, dh = q.shape
    nc = t // chunk
    L = chunk

    def per_chunk(carry, xs):
        C, n, m = carry                       # [b,nh,dk,dv], [b,nh,dk], [b,nh]
        qc, kc, vc, il, fl = xs               # [b,L,nh,*]
        il = il.astype(jnp.float32)
        fl = fl.astype(jnp.float32)
        bcum = jnp.cumsum(fl, axis=1)         # [b,L,nh] inclusive decay sums
        btot = bcum[:, -1]                    # [b,nh]

        # D[j,i] = bcum_j - bcum_i + ilog_i  (contribution of step i at j)
        D = (bcum[:, :, None, :] - bcum[:, None, :, :]
             + il[:, None, :, :])             # [b, j=L, i=L, nh]
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        D = jnp.where(mask, D, -jnp.inf)
        m_loc = jnp.max(D, axis=2)            # [b, L, nh]
        m_new = jnp.maximum(bcum + m[:, None, :], m_loc)
        W = jnp.exp(D - m_new[:, :, None, :])         # [b,L,L,nh]
        a = jnp.exp(bcum + m[:, None, :] - m_new)     # [b,L,nh] inter scale

        qf = qc.astype(jnp.float32)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        s = jnp.einsum("bjhd,bihd->bjih", qf, kf)     # [b,L,L,nh]
        sw = s * W
        h_intra = jnp.einsum("bjih,bihd->bjhd", sw, vf)
        h_inter = jnp.einsum("bjhd,bhdv->bjhv", qf, C) * a[..., None]
        n_intra = jnp.einsum("bjih,bihd->bjhd", W, kf)
        n_all = n_intra + n[:, None] * a[..., None]   # [b,L,nh,dk]
        num = h_intra + h_inter
        qn = jnp.einsum("bjhd,bjhd->bjh", qf, n_all)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
        h = num / denom[..., None]

        # carry to the next chunk
        m_next = jnp.maximum(btot + m,
                             jnp.max(btot[:, None] - bcum + il, axis=1))
        dec = jnp.exp(btot + m - m_next)              # [b,nh]
        wgt = jnp.exp(btot[:, None] - bcum + il - m_next[:, None])  # [b,L,nh]
        C = C * dec[..., None, None] + jnp.einsum(
            "bihd,bihv,bih->bhdv", kf, vf, wgt)
        n = n * dec[..., None] + jnp.einsum("bihd,bih->bhd", kf, wgt)
        return (C, n, m_next), h

    resh = lambda x: jnp.moveaxis(
        x.reshape(b, nc, L, *x.shape[2:]), 1, 0)
    carry = (
        jnp.zeros((b, nh, dh, dh), jnp.float32),
        jnp.zeros((b, nh, dh), jnp.float32),
        jnp.full((b, nh), -1e30, jnp.float32),
    )
    carry, hs = lax.scan(
        per_chunk, carry,
        (resh(q), resh(k), resh(v), resh(ilog), resh(flog)))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, t, nh, dh)
    return h, carry


def _mlstm_cell(q, k, v, ilog, flog, carry):
    """One timestep.  q/k/v [b, nh, dh]; ilog/flog [b, nh]."""
    C, n, m = carry
    m_new = jnp.maximum(flog + m, ilog)
    fp = jnp.exp(flog + m - m_new)[..., None]
    ip = jnp.exp(ilog - m_new)[..., None]
    C = fp[..., None] * C + ip[..., None] * (v[..., None, :] * k[..., :, None])
    n = fp * n + ip * k
    denom = jnp.maximum(jnp.abs(jnp.sum(n * q, axis=-1)), jnp.exp(-m_new))
    h = jnp.einsum("bhkv,bhk->bhv", C, q) / denom[..., None]
    return (C, n, m_new), h


def mlstm_apply(cfg: ArchConfig, t, x, ctx: L.Ctx, cache=None, prefix: str = ""):
    tt = {name[len(prefix):]: v for name, v in t.items()} if prefix else t
    d = cfg.d_model
    inner = int(cfg.expand * d)
    nh = cfg.n_heads
    dh = inner // nh
    bsz, tq, _ = x.shape

    h0 = apply_norm(cfg, tt, x, "ln1")
    up = h0 @ tt["m.wup"][:, : 2 * inner]
    xin, z = up[..., :inner], up[..., inner:]
    conv_state = cache["conv"] if ctx.mode == "decode" else None
    xc, conv_state = _causal_conv1d(
        xin, tt["m.conv_w"][:, :inner], tt["m.conv_b"][:inner], conv_state)
    xc = jax.nn.silu(xc)
    q = (xc @ tt["m.wq"][:, :inner]).reshape(bsz, tq, nh, dh)
    k = (xc @ tt["m.wk"][:, :inner]).reshape(bsz, tq, nh, dh) / math.sqrt(dh)
    v = (xin @ tt["m.wv"][:, :inner]).reshape(bsz, tq, nh, dh)
    iflog = (xc @ tt["m.wif"][:, : 2 * nh] + tt["m.bif"][: 2 * nh]).astype(jnp.float32)
    ilog, flog = iflog[..., :nh], jax.nn.log_sigmoid(iflog[..., nh:])

    if ctx.mode == "decode":
        carry = (cache["C"], cache["n"], cache["m"])
        carry, h = _mlstm_cell(
            q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), ilog[:, 0], flog[:, 0], carry)
        hseq = h[:, None]
        new_cache = {"C": carry[0], "n": carry[1], "m": carry[2],
                     "conv": conv_state.astype(jnp.bfloat16)}
    else:
        chunk = ctx.mlstm_chunk
        if chunk and tq % chunk == 0 and tq > chunk:
            hseq, carry = mlstm_chunkwise(q, k, v, ilog, flog, chunk)
        else:
            carry = (
                jnp.zeros((bsz, nh, dh, dh), jnp.float32),
                jnp.zeros((bsz, nh, dh), jnp.float32),
                jnp.full((bsz, nh), -1e30, jnp.float32),
            )

            def step(c, inp):
                qt, kt, vt, it_, ft = inp
                c, h = _mlstm_cell(qt, kt, vt, it_, ft, c)
                return c, h

            xs = (
                jnp.moveaxis(q, 1, 0).astype(jnp.float32),
                jnp.moveaxis(k, 1, 0).astype(jnp.float32),
                jnp.moveaxis(v, 1, 0).astype(jnp.float32),
                jnp.moveaxis(ilog, 1, 0),
                jnp.moveaxis(flog, 1, 0),
            )
            carry, hs = lax.scan(step, carry, xs)
            hseq = jnp.moveaxis(hs, 0, 1)
        new_cache = None
        if ctx.mode == "prefill":
            new_cache = {"C": carry[0], "n": carry[1], "m": carry[2],
                         "conv": conv_state.astype(jnp.bfloat16)
                         if conv_state is not None else
                         jnp.zeros((bsz, cfg.conv_width - 1, inner), jnp.bfloat16)}

    hflat = hseq.reshape(bsz, tq, inner).astype(x.dtype)
    hflat = L.rms_norm(hflat, tt["m.hnorm"][:inner])
    out = (hflat * jax.nn.silu(z)) @ tt["m.wo"][:, :d]
    return x + out, new_cache


def slstm_layout(cfg: ArchConfig, tp: int, b: LayoutBuilder, prefix: str = ""):
    pb = LayoutBuilder(prefix)
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    std = 1.0 / math.sqrt(d)
    norm_layout(cfg, tp, pb, "ln1")
    for g in ("z", "i", "f", "o"):
        _add_gathered(pb, f"s.w{g}", (d, d), tp, std=std)
        _add_gathered(pb, f"s.r{g}", (nh, dh, dh), tp, std=1.0 / math.sqrt(dh),
                      decay=False)
        _add_gathered(pb, f"s.b{g}", (d,), tp, init="zeros", decay=False)
    _add_gathered(pb, "s.hnorm", (d,), tp, init="zeros", decay=False)
    _add_gathered(pb, "s.wo", (d, d), tp, std=std / math.sqrt(2 * cfg.n_layers))
    norm_layout(cfg, tp, pb, "ln2")
    mlp_layout(cfg, tp, pb, "mlp.", d_ff=4 * d)
    b.extend(pb)


def _slstm_step(tt, xt, carry, nh, dh):
    """xt [b, d] fp32; carry (c, n, h, m) each [b, d]/[b, nh]-shaped."""
    c, n, h, m = carry
    b = xt.shape[0]
    hh = h.reshape(b, nh, dh)

    def gate(g):
        wx = xt @ tt[f"s.w{g}"][:, : nh * dh]
        rh = jnp.einsum("bhd,hde->bhe", hh, tt[f"s.r{g}"]).reshape(b, nh * dh)
        return wx + rh + tt[f"s.b{g}"][: nh * dh]

    z = jnp.tanh(gate("z"))
    ilog = gate("i")
    flog = jax.nn.log_sigmoid(gate("f"))
    o = jax.nn.sigmoid(gate("o"))
    m_new = jnp.maximum(flog + m, ilog)
    fp = jnp.exp(flog + m - m_new)
    ip = jnp.exp(ilog - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h_new = o * (c / jnp.maximum(n, 1e-6))
    return (c, n, h_new, m_new), h_new


def slstm_apply(cfg: ArchConfig, t, x, ctx: L.Ctx, cache=None, prefix: str = ""):
    tt = {name[len(prefix):]: v for name, v in t.items()} if prefix else t
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    bsz, tq, _ = x.shape
    h0 = apply_norm(cfg, tt, x, "ln1").astype(jnp.float32)

    if ctx.mode == "decode":
        carry = (cache["c"], cache["n"], cache["h"], cache["m"])
        carry, hs = _slstm_step(tt, h0[:, 0], carry, nh, dh)
        hseq = hs[:, None]
        new_cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    else:
        carry = tuple(
            jnp.zeros((bsz, d), jnp.float32) for _ in range(3)
        ) + (jnp.full((bsz, d), -1e30, jnp.float32),)

        def step(c, xt):
            return _slstm_step(tt, xt, c, nh, dh)

        carry, hs = lax.scan(step, carry, jnp.moveaxis(h0, 1, 0))
        hseq = jnp.moveaxis(hs, 0, 1)
        new_cache = None
        if ctx.mode == "prefill":
            new_cache = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}

    hseq = L.rms_norm(hseq.astype(x.dtype), tt["s.hnorm"][:d])
    x = x + hseq @ tt["s.wo"][:, :d]
    h = apply_norm(cfg, tt, x, "ln2")
    x = x + mlp_apply(cfg, tt, h, ctx, "mlp.")
    return x, new_cache


def make_mlstm_cache(cfg: ArchConfig, batch: int):
    inner = int(cfg.expand * cfg.d_model)
    nh = cfg.n_heads
    dh = inner // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, inner), jnp.bfloat16),
    }


def make_slstm_cache(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }
