"""Model registry: ArchConfig -> ModelDef (pools, layouts, apply fns)."""

from __future__ import annotations

import functools
import math

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.flat_param import LayoutBuilder
from repro.models import blocks as B
from repro.models import recurrent as R
from repro.models.dims import attn_dims, pad_to_tp, shard_dim
from repro.models.lm import ModelDef, Pool


def _embed_pool(cfg: ArchConfig, tp: int) -> Pool:
    b = LayoutBuilder()
    b.add("emb.table", (cfg.vocab, shard_dim(cfg.d_model, tp)), std=0.02)
    if cfg.family == "encdec":
        b.add("emb.pos", (cfg.max_seq, shard_dim(cfg.d_model, tp)), std=0.02)
        b.add("emb.audio_pos", (cfg.n_audio_frames, shard_dim(cfg.d_model, tp)),
              std=0.02)
    return Pool("embed", b.build(), 1, apply=None)


def _head_pool(cfg: ArchConfig, tp: int, vocab_padded: int) -> Pool:
    b = LayoutBuilder()
    d_local = shard_dim(cfg.d_model, tp)
    b.add("final.scale", (d_local,), init="zeros", decay=False,
          model_gather=tp, model_gather_dim=0)
    if cfg.norm == "ln":
        b.add("final.bias", (d_local,), init="zeros", decay=False,
              model_gather=tp, model_gather_dim=0)
    b.add("head.w", (cfg.d_model, vocab_padded // tp), std=1.0 / math.sqrt(cfg.d_model))
    return Pool("head", b.build(), 1, apply=None)


def _wrap(apply):
    """Normalize sub-layer applies to ((x, aux), cache)."""

    def f(t, x, ctx, cache):
        out, nc = apply(t, x, ctx, cache)
        if isinstance(out, tuple):
            return out, nc
        return (out, jnp.float32(0.0)), nc

    return f


def build_model(cfg: ArchConfig, tp: int) -> ModelDef:
    ad = attn_dims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.resolved_head_dim, tp)
    vocab_padded = pad_to_tp(cfg.vocab, tp)
    pools: list[Pool] = []

    if cfg.family in ("dense",):
        b = LayoutBuilder()
        B.dense_layer_layout(cfg, tp, b)
        apply = _wrap(lambda t, x, ctx, cache: B.dense_layer_apply(
            cfg, ad, t, x, ctx, cache, window=cfg.window))
        pools.append(Pool(
            "layers", b.build(), cfg.n_layers, apply,
            make_cache=lambda bsz, clen: B.make_kv_cache(
                cfg, tp, bsz, clen, window=cfg.window),
        ))

    elif cfg.family == "moe":
        b = LayoutBuilder()
        B.moe_layer_layout(cfg, tp, b)
        apply = _wrap(lambda t, x, ctx, cache: B.moe_layer_apply(
            cfg, ad, t, x, ctx, cache))
        pools.append(Pool(
            "layers", b.build(), cfg.n_layers, apply,
            make_cache=lambda bsz, clen: B.make_kv_cache(cfg, tp, bsz, clen),
        ))

    elif cfg.family == "vlm":
        n_self = cfg.cross_interval
        n_super, rem = divmod(cfg.n_layers, n_self + 1)
        if rem:
            raise ValueError("vlm layer count must divide by (interval+1)")
        b = LayoutBuilder()
        for i in range(n_self):
            B.dense_layer_layout(cfg, tp, b, prefix=f"s{i}.")
        B.cross_layer_layout(cfg, tp, b, prefix="x.")

        def apply(t, x, ctx, cache):
            aux = jnp.float32(0.0)
            nc = {}
            for i in range(n_self):
                sub = cache.get(f"s{i}") if cache else None
                x, c = B.dense_layer_apply(cfg, ad, t, x, ctx, sub, prefix=f"s{i}.")
                nc[f"s{i}"] = c
            sub = cache.get("x") if cache else None
            x, c = B.cross_layer_apply(cfg, ad, t, x, ctx, sub, prefix="x.")
            nc["x"] = c
            if all(v is None for v in nc.values()):
                nc = None
            return (x, aux), nc

        def mk_cache(bsz, clen):
            c = {f"s{i}": B.make_kv_cache(cfg, tp, bsz, clen) for i in range(n_self)}
            c["x"] = B.make_cross_cache(cfg, tp, bsz, cfg.n_vision_tokens)
            return c

        pools.append(Pool("layers", b.build(), n_super, apply, mk_cache))

    elif cfg.family == "encdec":
        be = LayoutBuilder()
        B.dense_layer_layout(cfg, tp, be)  # bidirectional self-attn encoder
        enc_apply = _wrap(lambda t, x, ctx, cache: B.dense_layer_apply(
            cfg, ad, t, x, ctx, cache, causal=False))
        pools.append(Pool("enc", be.build(), cfg.n_encoder_layers, enc_apply))
        bd = LayoutBuilder()
        B.encdec_dec_layout(cfg, tp, bd)
        dec_apply = _wrap(lambda t, x, ctx, cache: B.encdec_dec_apply(
            cfg, ad, t, x, ctx, cache))

        def mk_cache(bsz, clen):
            return {
                "self": B.make_kv_cache(cfg, tp, bsz, clen),
                "cross": B.make_cross_cache(cfg, tp, bsz, cfg.n_audio_frames),
            }

        pools.append(Pool("dec", bd.build(), cfg.n_layers, dec_apply, mk_cache))

    elif cfg.family == "griffin":
        pattern = cfg.pattern or ("rec", "rec", "attn")
        n_super, rem = divmod(cfg.n_layers, len(pattern))
        pools.extend(_griffin_pools(cfg, tp, ad, pattern, n_super, "g"))
        if rem:
            pools.extend(_griffin_pools(cfg, tp, ad, pattern[:rem], 1, "gtail"))

    elif cfg.family == "xlstm":
        every = cfg.slstm_every or 4
        pattern = ("m",) * (every - 1) + ("s",)
        n_super, rem = divmod(cfg.n_layers, len(pattern))
        pools.extend(_xlstm_pools(cfg, tp, pattern, n_super, "x"))
        if rem:
            pools.extend(_xlstm_pools(cfg, tp, ("m",) * rem, 1, "xtail"))

    else:
        raise ValueError(f"unknown family {cfg.family}")

    return ModelDef(
        cfg=cfg, tp=tp, pools=tuple(pools),
        embed=_embed_pool(cfg, tp),
        head=_head_pool(cfg, tp, vocab_padded),
        vocab_padded=vocab_padded,
    )


def _griffin_pools(cfg, tp, ad, pattern, stack, name):
    b = LayoutBuilder()
    kinds = []
    counts = {"rec": 0, "attn": 0}
    for kind in pattern:
        i = counts[kind]
        counts[kind] += 1
        prefix = f"{kind}{i}."
        kinds.append((kind, prefix))
        if kind == "rec":
            R.griffin_rec_layout(cfg, tp, b, prefix=prefix)
        else:
            B.dense_layer_layout(cfg, tp, b, prefix=prefix)

    def apply(t, x, ctx, cache):
        nc = {}
        for kind, prefix in kinds:
            sub = cache.get(prefix) if cache else None
            if kind == "rec":
                x, c = R.griffin_rec_apply(cfg, t, x, ctx, sub, prefix=prefix)
            else:
                x, c = B.dense_layer_apply(
                    cfg, ad, t, x, ctx, sub, prefix=prefix, window=cfg.window)
            nc[prefix] = c
        if all(v is None for v in nc.values()):
            nc = None
        return (x, jnp.float32(0.0)), nc

    def mk_cache(bsz, clen):
        c = {}
        for kind, prefix in kinds:
            if kind == "rec":
                c[prefix] = R.make_rec_cache(cfg, tp, bsz)
            else:
                c[prefix] = B.make_kv_cache(cfg, tp, bsz, clen, window=cfg.window)
        return c

    return [Pool(name, b.build(), stack, apply, mk_cache)]


def _xlstm_pools(cfg, tp, pattern, stack, name):
    b = LayoutBuilder()
    kinds = []
    counts = {"m": 0, "s": 0}
    for kind in pattern:
        i = counts[kind]
        counts[kind] += 1
        prefix = f"{kind}{i}."
        kinds.append((kind, prefix))
        if kind == "m":
            R.mlstm_layout(cfg, tp, b, prefix=prefix)
        else:
            R.slstm_layout(cfg, tp, b, prefix=prefix)

    def apply(t, x, ctx, cache):
        nc = {}
        for kind, prefix in kinds:
            sub = cache.get(prefix) if cache else None
            if kind == "m":
                x, c = R.mlstm_apply(cfg, t, x, ctx, sub, prefix=prefix)
            else:
                x, c = R.slstm_apply(cfg, t, x, ctx, sub, prefix=prefix)
            nc[prefix] = c
        if all(v is None for v in nc.values()):
            nc = None
        return (x, jnp.float32(0.0)), nc

    def mk_cache(bsz, clen):
        c = {}
        for kind, prefix in kinds:
            c[prefix] = (R.make_mlstm_cache(cfg, bsz) if kind == "m"
                         else R.make_slstm_cache(cfg, bsz))
        return c

    return [Pool(name, b.build(), stack, apply, mk_cache)]


# ---------------------------------------------------------------------------
# parameter accounting (for the partition heuristic + MODEL_FLOPS)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _counts(cfg: ArchConfig) -> tuple[int, int]:
    model = build_model(cfg, tp=1)
    total = 0
    active = 0
    for pool in model.all_pools():
        for seg in pool.layout.segments:
            n = seg.size * pool.stack
            total += n
            if seg.name.split(".")[0] == "moe" or seg.name.startswith("moe."):
                active += int(n * cfg.top_k / max(cfg.n_experts, 1))
            else:
                active += n
    return total, active


def exact_param_count(cfg: ArchConfig) -> int:
    return _counts(cfg)[0]


def active_param_count(cfg: ArchConfig) -> int:
    return _counts(cfg)[1]
