"""Shared perf-matrix infrastructure (benchmarks/matrix.py is the CLI).

``measure``   the measure core: warmup-discarding repeat timing, robust
              variance statistics (median + MAD/IQR), config hashing, and
              the shared check registry the subprocess harnesses record
              their verdicts through.
``gates``     variance-aware regression gates: a cell fails only when its
              regression over the in-run reference (or a checked-in
              baseline) exceeds BOTH the threshold and the measured noise
              band.  Also the BENCH_matrix.json schema validator.
``matrixdef`` the declarative matrix: which suites run, which cells each
              must produce, and the gates applied to every cell.
``runner``    executes the matrix (one subprocess per suite), assembles
              the BENCH_matrix.json report, and applies the gates.
"""
