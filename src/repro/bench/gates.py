"""Variance-aware regression gates + the BENCH_matrix.json schema.

The gating rule (the tentpole contract): a timing cell FAILS only when its
regression exceeds BOTH the configured threshold AND the measured noise
band.  With ``m`` the cell median, ``r`` the reference median and
``sigma`` each side's standard error of the median
(:attr:`repro.bench.measure.TimingStats.sigma_s`),

    excess = m - threshold * r
    noise  = z * sqrt(sigma_m^2 + (threshold * sigma_r)^2)
    FAIL  <=>  excess > noise

so a genuine 1.5x slowdown against a 1.2x threshold fails decisively,
while a 1.25x blip inside a wide noise band does not — and a quiet
machine (tiny sigmas) tightens the gate automatically.

References come in two flavours, applied independently:

* ``ratio_vs_ref`` — the *in-run* reference cell (bucketed vs same-run
  serial, paged vs same-run fixed).  Always enforced: machine drift
  cancels because both sides ran seconds apart on the same host.
* ``ratio_vs_baseline`` — the checked-in ``benchmarks/baselines.json``
  entry.  A missing or *stale* entry (config_hash mismatch) downgrades
  this gate to "recorded, not enforced" — it NEVER becomes a
  pass-by-default on the in-run ratio check, which still applies.  An
  entry with ``"enforce": false`` is advisory (CI hosts are not the
  curator's host); ``"enforce": true`` is a hard gate.

``contract`` gates consume a suite-local boolean verdict (bitwise
equality, census match, ledger accounting...); ``exact_vs_baseline``
compares a deterministic cell's value hash with the baseline (the
paper-figure cells — model-derived, so exact reproducibility is the
contract, never timing); ``metric_bound`` gates a scalar metric
(e.g. paged-beats-fixed throughput ratio > 1 at saturation).
"""

from __future__ import annotations

import json
import math
import pathlib
from dataclasses import dataclass, field

SCHEMA = "bench-matrix/v1"
BASELINE_SCHEMA = "bench-baselines/v1"
DEFAULT_Z = 3.0


@dataclass(frozen=True)
class GateSpec:
    """One gate applied to one cell, declared in the matrix config."""

    kind: str                    # ratio_vs_ref | ratio_vs_baseline |
    #                              contract | exact_vs_baseline | metric_bound
    threshold: float | None = None
    reference: str | None = None  # cell id, for ratio_vs_ref
    normalize_by: str | None = None  # metrics key dividing the timing
    #                                  (per-row decode comparisons)
    metric: str | None = None    # metrics key, for metric_bound
    min_value: float | None = None
    max_value: float | None = None
    z: float = DEFAULT_Z
    enforce_smoke: bool = True   # gate counts toward --check in smoke runs
    enforce_full: bool = True    # ... and in full runs

    def to_jsonable(self) -> dict:
        return {k: v for k, v in self.__dict__.items() if v is not None}


@dataclass
class GateResult:
    kind: str
    ok: bool
    enforced: bool
    detail: str = ""
    data: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "ok": self.ok,
                "enforced": self.enforced, "detail": self.detail,
                **self.data}


def _median_sigma(cell: dict, normalize_by: str | None):
    t = cell.get("timing")
    if not t:
        return None, None
    m, s = float(t["median_s"]), float(t["sigma_s"])
    if normalize_by:
        rows = float(cell["metrics"][normalize_by])
        m, s = m / rows, s / rows
    return m, s


def _significant_excess(m, sig_m, base, sig_base, threshold, z):
    """(ratio, excess, noise, fail) for the shared significance rule."""
    ratio = m / base if base else math.inf
    excess = m - threshold * base
    noise = z * math.hypot(sig_m, threshold * sig_base)
    return ratio, excess, noise, excess > noise


def gate_ratio_vs_ref(spec: GateSpec, cell: dict, cells: dict) -> GateResult:
    ref = cells.get(spec.reference)
    if ref is None:
        return GateResult(spec.kind, False, True,
                          f"reference cell {spec.reference!r} missing")
    m, sig_m = _median_sigma(cell, spec.normalize_by)
    r, sig_r = _median_sigma(ref, spec.normalize_by)
    if m is None or r is None:
        return GateResult(spec.kind, False, True,
                          "timing stats missing on cell or reference")
    ratio, excess, noise, fail = _significant_excess(
        m, sig_m, r, sig_r, spec.threshold, spec.z)
    detail = (f"median {m*1e6:.1f}us vs ref {r*1e6:.1f}us "
              f"(x{ratio:.3f}, threshold {spec.threshold}, "
              f"noise band {noise*1e6:.1f}us)")
    return GateResult(spec.kind, not fail, True, detail, {
        "reference": spec.reference, "ratio": ratio,
        "threshold": spec.threshold, "excess_s": excess, "noise_s": noise,
        "significant": fail})


def gate_ratio_vs_baseline(spec: GateSpec, cell: dict,
                           baseline: dict | None) -> GateResult:
    entry, status = baseline_entry(baseline, cell)
    if entry is None:
        return GateResult(
            spec.kind, True, False,
            f"baseline {status}: in-run reference only", {"baseline": status})
    m, sig_m = _median_sigma(cell, spec.normalize_by)
    if m is None:
        return GateResult(spec.kind, False, True, "timing stats missing")
    base = float(entry["median_s"])
    sig_b = float(entry.get("sigma_s", 0.0))
    ratio, excess, noise, fail = _significant_excess(
        m, sig_m, base, sig_b, spec.threshold, spec.z)
    enforced = bool(entry.get("enforce", False))
    detail = (f"median {m*1e6:.1f}us vs baseline {base*1e6:.1f}us "
              f"(x{ratio:.3f}, threshold {spec.threshold}"
              + ("" if enforced else ", advisory") + ")")
    return GateResult(spec.kind, not fail, enforced, detail, {
        "baseline": "enforced" if enforced else "advisory",
        "ratio": ratio, "threshold": spec.threshold,
        "excess_s": excess, "noise_s": noise, "significant": fail})


def gate_contract(spec: GateSpec, cell: dict) -> GateResult:
    ok = cell.get("ok")
    if ok is None:
        return GateResult(spec.kind, False, True, "no verdict recorded")
    return GateResult(spec.kind, bool(ok), True,
                      "" if ok else str(cell.get("detail", "check failed")))


def gate_exact_vs_baseline(spec: GateSpec, cell: dict,
                           baseline: dict | None) -> GateResult:
    entry, status = baseline_entry(baseline, cell)
    got = cell.get("hash")
    if got is None:
        return GateResult(spec.kind, False, True, "cell has no value hash")
    if entry is None:
        return GateResult(
            spec.kind, True, False,
            f"baseline {status}: hash {got} recorded, not compared",
            {"baseline": status, "hash": got})
    want = entry.get("hash")
    ok = got == want
    return GateResult(
        spec.kind, ok, bool(entry.get("enforce", True)),
        "" if ok else f"value hash {got} != baseline {want}",
        {"baseline": "present", "hash": got, "baseline_hash": want})


def gate_metric_bound(spec: GateSpec, cell: dict) -> GateResult:
    v = cell.get("metrics", {}).get(spec.metric)
    if v is None:
        return GateResult(spec.kind, False, True,
                          f"metric {spec.metric!r} missing")
    v = float(v)
    ok = ((spec.min_value is None or v >= spec.min_value)
          and (spec.max_value is None or v <= spec.max_value))
    return GateResult(
        spec.kind, ok, True,
        f"{spec.metric}={v:.4g} (min={spec.min_value}, max={spec.max_value})",
        {"metric": spec.metric, "value": v})


def evaluate_gates(specs, cell: dict, cells: dict, baseline: dict | None,
                   smoke: bool) -> list:
    """All gate records for one cell; smoke/full enforcement applied."""
    out = []
    for spec in specs:
        if spec.kind == "ratio_vs_ref":
            res = gate_ratio_vs_ref(spec, cell, cells)
        elif spec.kind == "ratio_vs_baseline":
            res = gate_ratio_vs_baseline(spec, cell, baseline)
        elif spec.kind == "contract":
            res = gate_contract(spec, cell)
        elif spec.kind == "exact_vs_baseline":
            res = gate_exact_vs_baseline(spec, cell, baseline)
        elif spec.kind == "metric_bound":
            res = gate_metric_bound(spec, cell)
        else:
            res = GateResult(spec.kind, False, True,
                             f"unknown gate kind {spec.kind!r}")
        if smoke and not spec.enforce_smoke:
            res.enforced = False
            res.detail = (res.detail + " [not enforced in smoke]").strip()
        if not smoke and not spec.enforce_full:
            res.enforced = False
        out.append(res)
    return out


# ---------------------------------------------------------------------------
# baselines (benchmarks/baselines.json)
# ---------------------------------------------------------------------------

def load_baselines(path) -> dict | None:
    p = pathlib.Path(path)
    if not p.exists():
        return None
    data = json.loads(p.read_text())
    assert data.get("schema") == BASELINE_SCHEMA, data.get("schema")
    return data


def baseline_entry(baseline: dict | None, cell: dict):
    """(entry, status): entry is None when missing or stale.

    Staleness: the baseline was curated for a different cell config
    (config_hash mismatch), so comparing against it would be meaningless
    — the gate treats it exactly like a missing baseline.
    """
    if baseline is None:
        return None, "missing (no baselines file)"
    entry = baseline.get("cells", {}).get(cell.get("id") or "")
    if entry is None:
        return None, "missing"
    if entry.get("config_hash") not in (None, cell.get("config_hash")):
        return None, (f"stale (config_hash {entry.get('config_hash')} != "
                      f"{cell.get('config_hash')})")
    return entry, "present"


# ---------------------------------------------------------------------------
# report schema
# ---------------------------------------------------------------------------

_CELL_KINDS = ("timing", "contract", "exact", "metric")


def validate_report(report: dict) -> list:
    """Structural check of a BENCH_matrix.json dict; returns error strings
    (empty = valid).  Round-trip safe: validate(json.loads(json.dumps(r)))
    agrees with validate(r)."""
    errs = []
    if report.get("schema") != SCHEMA:
        errs.append(f"schema {report.get('schema')!r} != {SCHEMA!r}")
    for key in ("smoke", "matrix_config_hash", "suites", "cells", "ok"):
        if key not in report:
            errs.append(f"missing top-level key {key!r}")
    for name, s in (report.get("suites") or {}).items():
        if "status" not in s:
            errs.append(f"suite {name}: missing status")
    for cid, cell in (report.get("cells") or {}).items():
        if cell.get("kind") not in _CELL_KINDS:
            errs.append(f"cell {cid}: bad kind {cell.get('kind')!r}")
        if "config_hash" not in cell:
            errs.append(f"cell {cid}: missing config_hash")
        if cell.get("kind") == "timing" and cell.get("timing") is None \
                and cell.get("missing") is not True:
            errs.append(f"cell {cid}: timing cell without timing stats")
        for g in cell.get("gates", []):
            if not isinstance(g.get("ok"), bool):
                errs.append(f"cell {cid}: gate without boolean ok")
    if not isinstance(report.get("failures", []), list):
        errs.append("failures is not a list")
    return errs
