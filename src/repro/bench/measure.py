"""The measure core shared by every bench suite and the matrix runner.

Every timed cell in the repo goes through :class:`TimingStats`: discard
``warmup`` leading samples, keep ``n`` repeats, and summarize them with
*robust* statistics — the median as the location estimate and the MAD
(median absolute deviation) / IQR as the spread.  On the oversubscribed
CI hosts these benches run on, means and minima are hostage to scheduler
noise; the median+MAD pair is what the variance-aware regression gates in
:mod:`repro.bench.gates` reason about ("Is Network the Bottleneck of
Distributed Training?" is the cautionary tale — single-shot timings on
cloud hosts mislead).

Also here, because every suite needs them:

* :func:`config_hash` / :func:`result_hash` — canonical-JSON SHA-256
  prefixes.  A cell's ``config_hash`` is its provenance: baselines carry
  it, and a baseline whose hash no longer matches the cell's current
  config is *stale* and silently ignored by the gates (never compared).
* :func:`timing_cell` / :func:`contract_cell` / :func:`exact_cell` — the
  standard per-cell record constructors; the matrix runner consumes these
  shapes from every suite's JSON output.
* :func:`make_check` / :func:`contract_cells` / :func:`exit_check` — the
  shared verdict registry for the subprocess harnesses (memplan, elastic,
  serve-chaos): each named check records ``{"ok": bool}`` and the
  ``--check`` CLI shim exits nonzero iff any failed.
"""

from __future__ import annotations

import hashlib
import json
import math
import statistics
import sys
import time
import traceback
from dataclasses import dataclass

# MAD -> sigma for a normal distribution, and the standard error of the
# median (1.2533 sigma / sqrt(n)); folded so
#   se(median) ~= MEDIAN_SE_FACTOR * mad / sqrt(n)
MAD_SIGMA = 1.4826
MEDIAN_SE_FACTOR = 1.2533 * MAD_SIGMA


def _jsonable(obj):
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    if hasattr(obj, "tolist"):  # numpy scalars/arrays without importing numpy
        return obj.tolist()
    return str(obj)


def canonical_json(obj) -> str:
    """Deterministic JSON: sorted keys, no whitespace, stable fallbacks."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=_jsonable)


def config_hash(obj) -> str:
    """12-hex-digit provenance hash of a cell's declarative config."""
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:12]


# result payloads get the same treatment; a separate name because the two
# hashes mean different things in a cell record (provenance vs value)
result_hash = config_hash


@dataclass(frozen=True)
class TimingStats:
    """Robust summary of repeated timings (seconds).

    ``samples_s`` are the post-warmup repeats; ``warmup`` records how many
    leading samples were discarded (provenance only — they are gone).
    """

    samples_s: tuple
    warmup: int = 0

    @staticmethod
    def from_samples(samples, warmup: int = 0) -> "TimingStats":
        kept = tuple(float(s) for s in list(samples)[warmup:])
        if not kept:
            raise ValueError("no samples left after warmup discard")
        return TimingStats(samples_s=kept, warmup=warmup)

    @property
    def n(self) -> int:
        return len(self.samples_s)

    @property
    def median_s(self) -> float:
        return float(statistics.median(self.samples_s))

    @property
    def mad_s(self) -> float:
        med = self.median_s
        return float(statistics.median(abs(s - med) for s in self.samples_s))

    @property
    def iqr_s(self) -> float:
        if self.n < 2:
            return 0.0
        xs = sorted(self.samples_s)
        q = statistics.quantiles(xs, n=4, method="inclusive")
        return float(q[2] - q[0])

    @property
    def min_s(self) -> float:
        return float(min(self.samples_s))

    @property
    def sigma_s(self) -> float:
        """Standard error of the median (the gate's noise unit).

        MAD-based; falls back to the IQR when the MAD degenerates to zero
        (e.g. quantized clocks), and to 0.0 only when every sample is
        identical — in which case any excess is genuinely significant.
        """
        spread = self.mad_s
        if spread == 0.0:
            spread = self.iqr_s / (2 * 0.6745 * MAD_SIGMA) if self.iqr_s \
                else 0.0
        return MEDIAN_SE_FACTOR * spread / math.sqrt(self.n)

    def to_dict(self) -> dict:
        return {
            "samples_s": list(self.samples_s),
            "warmup": self.warmup,
            "n": self.n,
            "median_s": self.median_s,
            "mad_s": self.mad_s,
            "iqr_s": self.iqr_s,
            "min_s": self.min_s,
            "sigma_s": self.sigma_s,
            "median_us": self.median_s * 1e6,
        }

    @staticmethod
    def from_dict(d: dict) -> "TimingStats":
        return TimingStats(samples_s=tuple(d["samples_s"]),
                           warmup=int(d.get("warmup", 0)))


def measure(fn, *, warmup: int = 1, repeats: int = 5) -> TimingStats:
    """Time ``fn()`` ``warmup + repeats`` times, discarding the warmups.

    ``fn`` must block until its work is done (callers wrap device work
    with ``jax.block_until_ready`` or an equivalent host sync).
    """
    samples = []
    for _ in range(warmup + repeats):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return TimingStats.from_samples(samples, warmup=warmup)


# ---------------------------------------------------------------------------
# standard per-cell records (what every suite emits under out["cells"])
# ---------------------------------------------------------------------------

def _cell(kind: str, config: dict, *, timing=None, metrics=None, ok=None,
          detail=None, value_hash=None) -> dict:
    rec = {
        "kind": kind,
        "config": config,
        "config_hash": config_hash(config),
        "timing": timing.to_dict() if isinstance(timing, TimingStats)
        else timing,
        "metrics": metrics or {},
        "ok": ok,
    }
    if detail is not None:
        rec["detail"] = detail
    if value_hash is not None:
        rec["hash"] = value_hash
    return rec


def timing_cell(config: dict, timing: TimingStats, *, metrics=None,
                ok=None, detail=None) -> dict:
    """A measured cell: gated on time ratios (and optionally a verdict)."""
    return _cell("timing", config, timing=timing, metrics=metrics, ok=ok,
                 detail=detail)


def contract_cell(config: dict, ok: bool, *, metrics=None,
                  detail=None) -> dict:
    """A correctness cell: gated on its boolean verdict only."""
    return _cell("contract", config, metrics=metrics, ok=bool(ok),
                 detail=detail)


def exact_cell(config: dict, value_hash: str, *, metrics=None, ok=None,
               detail=None) -> dict:
    """A deterministic-output cell: gated on exact value-hash equality
    with the checked-in baseline (model-derived figures — never timing)."""
    return _cell("exact", config, metrics=metrics, ok=ok, detail=detail,
                 value_hash=value_hash)


# ---------------------------------------------------------------------------
# the shared harness verdict registry (memplan/elastic/serve-chaos pattern)
# ---------------------------------------------------------------------------

def make_check(results: dict):
    """The subprocess harnesses' ``@check(name)`` decorator: run the body
    immediately, record ``{"ok": bool}`` (plus error + traceback tail on
    failure) into ``results`` — one registry shared by all harnesses."""
    def check(name):
        def deco(fn):
            try:
                fn()
                results[name] = {"ok": True}
            except Exception as e:  # noqa: BLE001
                results[name] = {
                    "ok": False,
                    "err": f"{type(e).__name__}: {e}",
                    "tb": traceback.format_exc()[-2000:],
                }
            return fn
        return deco
    return check


def failed_checks(results: dict) -> list:
    """Names of recorded checks whose verdict is ``ok: False``."""
    return [k for k, v in results.items()
            if isinstance(v, dict) and v.get("ok") is False]


def contract_cells(suite: str, results: dict, base_config: dict) -> dict:
    """Standard cell records for every named check in a harness registry.

    Cell ids are ``<suite>/<check>``; each carries the harness's shared
    config (mesh/model/...) plus the check name, hashed for provenance.
    """
    cells = {}
    for name, verdict in results.items():
        if not (isinstance(verdict, dict) and "ok" in verdict):
            continue
        cfg = dict(base_config, suite=suite, check=name)
        cells[f"{suite}/{name}"] = contract_cell(
            cfg, verdict["ok"],
            detail=verdict.get("err"))
    return cells


def exit_check(results: dict, gate_name: str) -> None:
    """The harnesses' ``--check`` tail: exit 1 iff any check failed."""
    bad = failed_checks(results)
    if bad:
        print(f"{gate_name} FAILED: {bad}", file=sys.stderr)
        sys.exit(1)
