"""The declarative perf matrix: suites, cells, and the gates on each.

This is the single place the repo's bench surface is enumerated.  The
matrix runner (:mod:`repro.bench.runner`, CLI ``benchmarks/matrix.py``)
executes it end to end; each suite's thin ``--check`` shim evaluates just
its own slice through :func:`repro.bench.runner.check_suite` — so a
standalone ``benchmarks/comm_bench.py --smoke --check`` applies exactly
the gates declared here, and CI's single matrix invocation reproduces
every historical per-script gate.

Shared axis constants (policy labels, serve rates, figure names) live
here too: the suites import them, so a drift between "what the matrix
expects" and "what a suite emits" is a hard cell-missing failure, not a
silent coverage gap.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.bench.gates import GateSpec
from repro.bench.measure import config_hash


@dataclass(frozen=True)
class SuiteSpec:
    """One bench subprocess: script + per-mode args + wall-clock bound."""

    name: str
    script: str                   # repo-relative
    args: tuple = ()              # full-run extra argv
    smoke_args: tuple = ()        # smoke-run extra argv
    timeout_s: int = 1800

    def argv(self, smoke: bool) -> list:
        return list(self.smoke_args if smoke else self.args)

    def to_jsonable(self) -> dict:
        return {"script": self.script, "args": list(self.args),
                "smoke_args": list(self.smoke_args),
                "timeout_s": self.timeout_s}


@dataclass(frozen=True)
class CellSpec:
    """One declared matrix cell: the suite that must emit it + its gates."""

    id: str
    suite: str
    gates: tuple = ()

    def to_jsonable(self) -> dict:
        return {"suite": self.suite,
                "gates": [g.to_jsonable() for g in self.gates]}


@dataclass(frozen=True)
class MatrixSpec:
    suites: dict
    cells: dict
    smoke: bool

    def to_jsonable(self) -> dict:
        return {
            "smoke": self.smoke,
            "suites": {k: v.to_jsonable() for k, v in self.suites.items()},
            "cells": {k: v.to_jsonable() for k, v in self.cells.items()},
        }

    @property
    def config_hash(self) -> str:
        return config_hash(self.to_jsonable())


# ---------------------------------------------------------------------------
# shared axis constants (imported by the suites — drift becomes a hard
# cell-missing gate failure instead of silent coverage loss)
# ---------------------------------------------------------------------------

COMM_POLICY_LABELS = (
    "flat@bf16", "inner_first@bf16", "outer_first@bf16", "inner_first@int8",
    "inner_first@bf16+qgZ", "inner_first@int8+qgZ", "inner_first@bf16+host",
    "inner_first@fp32+host",
)
COMM_BOUNDARY_CELLS = ("serial", "bucketed", "bucketed_approx",
                       "bucketed_offload")
# step-time thresholds vs the same-run serial reference (the offload cell
# pays the documented CPU io_callback round-trip a real DMA engine avoids;
# the approx-clip cell's extra global-norm estimate can serialize badly
# when the 8-virtual-device host is contended — observed up to ~1.5x on
# an otherwise-passing host — so its bound is loose enough to only catch
# overlap actually breaking)
COMM_BOUNDARY_THRESHOLDS = {"bucketed": 1.2, "bucketed_approx": 1.8,
                            "bucketed_offload": 3.0}

SERVE_RATES_FULL = ("0.25", "0.5", "1.0", "2.0", "inf")
SERVE_RATES_SMOKE = ("0.5", "inf")
SERVE_STEP_KINDS = ("fixed_decode", "paged_decode", "paged_chunk",
                    "fixed_prefill")
SERVE_PER_ROW_THRESHOLD = 1.2

MEMPLAN_CHECKS = ("footprint_match", "footprint_degenerate",
                  "remat_lowers_peak", "census_match_remat",
                  "carried_buffer_census", "offload_lowers_peak")
ELASTIC_CHECKS = ("kill_pod_resume_bitwise", "grow_back_resume_bitwise",
                  "repick_keep_rule_bitwise", "resolve_scale_repick",
                  "data_continuity", "straggler_flagged", "crash_mid_save",
                  "reshard_roundtrip", "offload_cross_topology")
CHAOS_CHECKS = ("preempt_replay_bitwise", "grow_back_readmission",
                "straggler_evict", "crash_retry", "shed_under_burst")

# model-derived paper-figure cells: deterministic pure-model outputs, so
# the gate is EXACT value-hash reproducibility vs the baseline, not timing
FIGURE_CELLS = ("fig2", "fig7_8", "fig9", "fig10", "case_study_100b",
                "fig11", "fig12", "fig13", "fig14", "table1")
# full-run extras: real (CPU-training / model-building) cells whose floats
# are jax-version dependent — contract-gated on their internal asserts only
FIGURE_CELLS_FULL = ("fig15", "fig16")

# advisory ceiling for the checked-in-baseline timing comparison (only a
# hard gate on cells whose baseline entry sets "enforce": true)
BASELINE_TIMING_THRESHOLD = 1.5

_CONTRACT = (GateSpec(kind="contract"),)


def _timing_gates(reference: str, threshold: float,
                  normalize_by: str | None = None,
                  contract: bool = True) -> tuple:
    gates = [
        GateSpec(kind="ratio_vs_ref", reference=reference,
                 threshold=threshold, normalize_by=normalize_by),
        GateSpec(kind="ratio_vs_baseline",
                 threshold=BASELINE_TIMING_THRESHOLD,
                 normalize_by=normalize_by),
    ]
    if contract:
        gates.insert(0, GateSpec(kind="contract"))
    return tuple(gates)


def build_matrix(smoke: bool) -> MatrixSpec:
    """The full declarative matrix for one run mode."""
    suites = {
        "comm": SuiteSpec(
            "comm", "benchmarks/comm_bench.py",
            args=("--steps", "8"), smoke_args=("--smoke", "--steps", "5")),
        "serve": SuiteSpec(
            "serve", "benchmarks/serve_bench.py", smoke_args=("--smoke",)),
        "memplan": SuiteSpec("memplan", "tests/memplan_harness.py",
                             timeout_s=1500),
        "elastic": SuiteSpec("elastic", "tests/elastic_harness.py",
                             timeout_s=1500),
        "chaos": SuiteSpec("chaos", "tests/serve_chaos_harness.py",
                           timeout_s=1500),
        "figures": SuiteSpec(
            "figures", "benchmarks/run.py",
            args=("--matrix-cells", "--full"),
            smoke_args=("--matrix-cells",), timeout_s=900),
    }

    cells = {}

    def add(cid, suite, gates):
        cells[cid] = CellSpec(id=cid, suite=suite, gates=tuple(gates))

    # --- comm: gather schedules, policy ledger, boundary grid -------------
    add("comm/gather/serial", "comm", ())
    add("comm/gather/prefetch", "comm", _CONTRACT)   # loss bitwise equal
    for label in COMM_POLICY_LABELS:                 # census byte match
        add(f"comm/policy/{label}", "comm", _CONTRACT)
    for label in COMM_BOUNDARY_CELLS:
        if label == "serial":
            add("comm/boundary/serial", "comm", ())  # the in-run reference
        else:
            add(f"comm/boundary/{label}", "comm",
                _timing_gates("comm/boundary/serial",
                              COMM_BOUNDARY_THRESHOLDS[label]))
    add("comm/contract/predicted_exposed", "comm", _CONTRACT)
    add("comm/contract/host_fit_stage", "comm", _CONTRACT)

    # --- serve: interleaved step prices, closed-loop sweep, overload ------
    add("serve/step/fixed_decode", "serve", ())      # the in-run reference
    add("serve/step/paged_decode", "serve",
        _timing_gates("serve/step/fixed_decode", SERVE_PER_ROW_THRESHOLD,
                      normalize_by="rows", contract=False))
    add("serve/step/paged_chunk", "serve", ())
    add("serve/step/fixed_prefill", "serve", ())
    add("serve/equivalence", "serve", _CONTRACT)     # paged bitwise
    rates = SERVE_RATES_SMOKE if smoke else SERVE_RATES_FULL
    for rate in rates:
        gates = list(_CONTRACT)
        if rate == "inf":
            # paged beats the static baseline at saturation — a real
            # throughput claim, only trustworthy at full request counts
            gates.append(GateSpec(kind="metric_bound",
                                  metric="normalized_ratio", min_value=1.0,
                                  enforce_smoke=False))
        add(f"serve/rate/{rate}", "serve", gates)
    add("serve/overload", "serve", _CONTRACT)

    # --- the subprocess harnesses: contract matrices ----------------------
    for name in MEMPLAN_CHECKS:
        add(f"memplan/{name}", "memplan", _CONTRACT)
    for name in ELASTIC_CHECKS:
        add(f"elastic/{name}", "elastic", _CONTRACT)
    for name in CHAOS_CHECKS:
        add(f"chaos/{name}", "chaos", _CONTRACT)

    # --- paper figures: exact reproducibility, never timing ---------------
    for name in FIGURE_CELLS:
        add(f"figures/{name}", "figures",
            (GateSpec(kind="contract"),
             GateSpec(kind="exact_vs_baseline")))
    if not smoke:
        for name in FIGURE_CELLS_FULL:
            add(f"figures/{name}", "figures", _CONTRACT)

    return MatrixSpec(suites=suites, cells=cells, smoke=smoke)
