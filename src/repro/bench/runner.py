"""Executes the declarative perf matrix and assembles BENCH_matrix.json.

One subprocess per suite (each suite pins its own virtual-device count,
exactly as the historical per-script CI steps did), then one central gate
pass: every declared cell is looked up in its suite's emitted ``cells``
section, its gates (in-run reference ratio, baseline ratio, contract,
exact-hash, metric bound) are evaluated by :mod:`repro.bench.gates`, and
the whole run lands in a single trajectory-friendly report:

* ``suites``  — per-suite status, wall time, script + argv provenance;
* ``cells``   — per-cell records: declarative config + config_hash,
  timing samples/median/MAD/IQR, metrics (wire bytes,
  predicted-vs-measured ratios, ...), gate verdicts;
* ``failures``— every *enforced* gate that failed (a declared cell a
  suite failed to emit is itself a failure — coverage can only shrink
  loudly).

``main`` is the CLI behind ``benchmarks/matrix.py``.  The report is
always written/printed before a failing exit so the artifact survives
gate failures (CI uploads it with ``if: always()``).
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

from repro.bench import gates as G
from repro.bench import matrixdef as MD

DEFAULT_BASELINES = "benchmarks/baselines.json"


def repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3]


def _suite_env() -> dict:
    # same minimal-but-sufficient child env as tests/harness_util.py, plus
    # the repo root on PYTHONPATH so `from benchmarks import ...` resolves
    return {
        "PYTHONPATH": "src" + os.pathsep + ".",
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": str(pathlib.Path.home()),
        "JAX_PLATFORMS": "cpu",
    }


def run_suite(suite: MD.SuiteSpec, smoke: bool,
              root: pathlib.Path | None = None) -> dict:
    """Run one suite subprocess; parse its JSON; never raise."""
    root = root or repo_root()
    argv = [sys.executable, str(root / suite.script), *suite.argv(smoke)]
    t0 = time.perf_counter()
    status = {"script": suite.script, "argv": argv[1:], "status": "ok"}
    try:
        proc = subprocess.run(
            argv, capture_output=True, text=True, cwd=str(root),
            env=_suite_env(), timeout=suite.timeout_s)
    except subprocess.TimeoutExpired:
        status.update(status="timeout", wall_s=time.perf_counter() - t0)
        return {"status": status, "out": {}}
    status["wall_s"] = round(time.perf_counter() - t0, 2)
    status["returncode"] = proc.returncode
    out = {}
    try:
        stdout = proc.stdout
        out = json.loads(stdout[stdout.index("{"):])
    except (ValueError, json.JSONDecodeError):
        status["status"] = "no-json"
    if proc.returncode != 0:
        status["status"] = "error"
    if status["status"] != "ok":
        status["stderr_tail"] = proc.stderr[-3000:]
    return {"status": status, "out": out}


def _missing_cell(spec: MD.CellSpec, reason: str) -> dict:
    return {"kind": "contract", "config": {"id": spec.id}, "missing": True,
            "config_hash": "", "metrics": {}, "ok": False, "detail": reason,
            "timing": None}


def gate_cells(matrix: MD.MatrixSpec, suite_cells: dict,
               baseline: dict | None, *, suites: set | None = None) -> tuple:
    """Evaluate every declared cell's gates.

    ``suite_cells`` maps suite name -> emitted cells dict.  Returns
    ``(report_cells, failures)``; extra (undeclared) emitted cells are
    carried through ungated for the trajectory.
    """
    report_cells: dict = {}
    failures: list = []
    # declared cells first, so in-run references resolve among them
    emitted_flat: dict = {}
    for sname, cells in suite_cells.items():
        for cid, rec in (cells or {}).items():
            emitted_flat[cid] = rec
    for cid, spec in matrix.cells.items():
        if suites is not None and spec.suite not in suites:
            continue
        rec = emitted_flat.get(cid)
        if rec is None:
            reason = f"declared cell not emitted by suite {spec.suite!r}"
            rec = _missing_cell(spec, reason)
        rec = dict(rec, id=cid, suite=spec.suite, declared=True)
        results = G.evaluate_gates(spec.gates, rec, emitted_flat, baseline,
                                   matrix.smoke)
        if rec.get("missing"):
            results.insert(0, G.GateResult("present", False, True,
                                           rec["detail"]))
        rec["gates"] = [r.to_dict() for r in results]
        rec["ok"] = all(r.ok for r in results if r.enforced)
        report_cells[cid] = rec
        failures += [{"cell": cid, "gate": r.kind, "detail": r.detail}
                     for r in results if r.enforced and not r.ok]
    for cid, rec in emitted_flat.items():
        if cid in report_cells:
            continue
        rec = dict(rec, id=cid, declared=False, gates=[])
        rec["ok"] = rec.get("ok") is not False
        report_cells[cid] = rec
    return report_cells, failures


def assemble_report(matrix: MD.MatrixSpec, suite_runs: dict,
                    baseline: dict | None, baseline_path) -> dict:
    suite_cells = {name: run["out"].get("cells", {})
                   for name, run in suite_runs.items()}
    cells, failures = gate_cells(matrix, suite_cells, baseline,
                                 suites=set(suite_runs))
    suites_out = {}
    for name, run in suite_runs.items():
        suites_out[name] = run["status"]
        if run["status"]["status"] != "ok":
            failures.append({"cell": None, "gate": "suite",
                             "detail": f"suite {name}: "
                                       f"{run['status']['status']}"})
    return {
        "schema": G.SCHEMA,
        "smoke": matrix.smoke,
        "matrix_config_hash": matrix.config_hash,
        "baseline_path": str(baseline_path) if baseline else None,
        "suites": suites_out,
        "cells": cells,
        "failures": failures,
        "ok": not failures,
    }


def check_suite(name: str, out: dict, *, smoke: bool,
                baseline: dict | None = None) -> list:
    """The standalone shims' gate: evaluate ONE suite's slice of the
    declared matrix against its own emitted cells (no baseline by
    default, so baseline gates stay advisory).  Returns failure strings.
    """
    matrix = MD.build_matrix(smoke)
    cells, failures = gate_cells(matrix, {name: out.get("cells", {})},
                                 baseline, suites={name})
    return [f"{f['cell']}: [{f['gate']}] {f['detail']}" for f in failures]


def _summary_lines(report: dict) -> list:
    lines = []
    n_ok = sum(1 for c in report["cells"].values() if c.get("ok"))
    lines.append(f"matrix: {n_ok}/{len(report['cells'])} cells ok, "
                 f"{len(report['failures'])} enforced gate failure(s), "
                 f"smoke={report['smoke']}, "
                 f"config={report['matrix_config_hash']}")
    for name, s in report["suites"].items():
        lines.append(f"  suite {name:8s} {s['status']:7s} "
                     f"{s.get('wall_s', 0.0):8.1f}s  {s['script']}")
    for f in report["failures"]:
        lines.append(f"  FAIL {f['cell'] or '(suite)'} [{f['gate']}]: "
                     f"{f['detail']}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks/matrix.py",
        description="Declarative perf-matrix runner with variance-aware "
                    "regression gates (see docs/benchmarks.md)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer repeats/requests/rates)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when any enforced gate fails")
    ap.add_argument("--suites", default="",
                    help="comma-separated subset (default: all)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINES,
                    help="baselines file (missing entries downgrade the "
                         "baseline gates to advisory)")
    ap.add_argument("--out", default="",
                    help="also write the report JSON here (written before "
                         "a failing exit, so the artifact always survives)")
    ap.add_argument("--list", action="store_true",
                    help="print the declared matrix (cells + gates) and exit")
    args = ap.parse_args(argv)

    matrix = MD.build_matrix(args.smoke)
    if args.list:
        print(json.dumps(matrix.to_jsonable(), indent=1))
        return 0

    selected = [s.strip() for s in args.suites.split(",") if s.strip()] \
        or list(matrix.suites)
    unknown = [s for s in selected if s not in matrix.suites]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; have {list(matrix.suites)}")

    root = repo_root()
    baseline = G.load_baselines(root / args.baseline)
    suite_runs = {}
    for name in selected:
        suite = matrix.suites[name]
        print(f"[matrix] running suite {name} "
              f"({suite.script} {' '.join(suite.argv(args.smoke))})",
              file=sys.stderr, flush=True)
        suite_runs[name] = run_suite(suite, args.smoke, root)
        print(f"[matrix]   -> {suite_runs[name]['status']['status']} in "
              f"{suite_runs[name]['status'].get('wall_s', 0.0):.1f}s",
              file=sys.stderr, flush=True)

    report = assemble_report(matrix, suite_runs, baseline,
                             root / args.baseline)
    text = json.dumps(report, indent=1, default=str)
    if args.out:
        pathlib.Path(args.out).write_text(text + "\n")
    print(text)
    for line in _summary_lines(report):
        print(line, file=sys.stderr)
    if args.check and not report["ok"]:
        return 1
    return 0
