"""Pallas kernels for the compute hot-spots, each shipped as a package of
``kernel.py`` (the Pallas implementation), ``ops.py`` (shape/sharding-aware
wrappers used by the model code) and ``ref.py`` (pure-jnp reference the
tests compare against): ``flash_attention``, ``rmsnorm``, ``rglru``."""
