"""Pallas RG-LRU (Griffin) scan kernel + pure-jnp reference."""

from repro.kernels.rglru.kernel import rglru
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_ref

__all__ = ["rglru", "rglru_scan", "rglru_ref"]
