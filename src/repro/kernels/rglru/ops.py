"""Jitted public wrapper for the RG-LRU kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rglru.kernel import rglru


@functools.partial(jax.jit, static_argnames=("block_t", "block_c", "interpret"))
def rglru_scan(a, b, *, block_t: int = 256, block_c: int = 128,
               interpret: bool = True):
    bt, bc = block_t, block_c
    while a.shape[1] % bt:
        bt //= 2
    while a.shape[2] % bc:
        bc //= 2
    return rglru(a, b, block_t=max(bt, 1), block_c=max(bc, 1),
                 interpret=interpret)
