"""RG-LRU sequence-scan Pallas TPU kernel.

The RG-LRU recurrence h_t = a_t * h_{t-1} + b_t is diagonal per channel, so
the kernel tiles channels across the parallel grid dimension and walks time
chunks sequentially, carrying the running state h in VMEM scratch across the
"arbitrary" time-grid dimension.  Within a time chunk the recurrence is
unrolled as a fori_loop over rows held in VMEM — on TPU this trades the
log-depth associative scan (which materializes 2x[T,C] intermediates in HBM)
for a single streaming pass with O(block_c) state.

Inputs are the precomputed per-step coefficients (a, b) — gate math stays in
XLA where it fuses with the surrounding projections; the kernel owns only the
memory-bound sequential part.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)   # [block_t, block_c]
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h


def rglru(
    a: jax.Array,          # [b, T, c] decay coefficients in (0, 1)
    b: jax.Array,          # [b, T, c] input terms
    *,
    block_t: int = 256,
    block_c: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bsz, t, c = a.shape
    block_t = min(block_t, t)
    block_c = min(block_c, c)
    if t % block_t or c % block_c:
        raise ValueError(f"dims ({t},{c}) must divide blocks ({block_t},{block_c})")
    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(bsz, c // block_c, t // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_t, block_c), lambda i, j, k: (i, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_c), lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, c), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_c,), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
