"""Pure-jnp oracle for the RG-LRU scan (associative scan, same math as
models/recurrent.py)."""

import jax.numpy as jnp
from jax import lax


def rglru_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t over axis 1; h_{-1} = 0."""

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    _, h = lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype)
