"""Jitted public wrapper for the flash-attention kernel (GQA-aware)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret",
                                             "block_q", "block_k"))
def flash_attention_gqa(
    q: jax.Array,   # [b, tq, hkv, g, dh]  (layout used by models/layers.py)
    k: jax.Array,   # [b, tk, hkv, dh]
    v: jax.Array,   # [b, tk, hkv, dh]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, tq, hkv, g, dh = q.shape
    tk = k.shape[1]
    qf = jnp.moveaxis(q, 1, 3).reshape(b * hkv * g, tq, dh)
    kf = jnp.repeat(jnp.moveaxis(k, 1, 2), g, axis=1).reshape(b * hkv * g, tk, dh)
    vf = jnp.repeat(jnp.moveaxis(v, 1, 2), g, axis=1).reshape(b * hkv * g, tk, dh)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return jnp.moveaxis(out.reshape(b, hkv, g, tq, dh), 3, 1)
