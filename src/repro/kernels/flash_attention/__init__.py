"""Pallas flash attention (GQA-aware) + pure-jnp reference."""

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import flash_attention_gqa
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention", "flash_attention_gqa", "attention_ref"]
