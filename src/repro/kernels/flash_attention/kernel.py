"""Fused flash-attention Pallas TPU kernel (causal + optional local window).

TPU adaptation of the IO-aware attention idea: the [T, S] score matrix never
touches HBM — each (batch*head, q-block) grid cell streams K/V blocks through
VMEM, keeping the online-softmax state (m, l, acc) in VMEM scratch.  Block
shapes are MXU-aligned (multiples of 128 on the contraction dims).

Grid: (bh, nq, nk) with the kv dimension innermost ("arbitrary" semantics so
scratch carries across kv steps).  Fully-masked kv blocks are skipped with
pl.when — for causal+window attention the skipped blocks make the kernel's
effective FLOPs sub-quadratic, matching the chunked pure-jnp oracle.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 block_q: int, block_k: int, seq_k: int, causal: bool,
                 window: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * block_q
    k_lo = ki * block_k

    # block-level mask decision (static per grid cell at run time)
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_lo + block_k - 1 >= q_lo - window + 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale      # [bq, d]
        k = k_ref[0].astype(jnp.float32)              # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)       # [bq, bk]

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,        # [bh, Tq, d]
    k: jax.Array,        # [bh, Tk, d]
    v: jax.Array,        # [bh, Tk, d]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, tq, d = q.shape
    tk = k.shape[1]
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    if tq % block_q or tk % block_k:
        raise ValueError(f"seq lens ({tq},{tk}) must divide blocks "
                         f"({block_q},{block_k})")
    nq, nk = tq // block_q, tk // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=tk,
        causal=causal, window=window, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
