"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q/k/v: [bh, T, d] -> [bh, T, d], fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    tq, tk = s.shape[-2:]
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
