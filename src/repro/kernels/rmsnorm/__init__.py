"""Pallas RMSNorm kernel + pure-jnp reference."""

from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ops import rmsnorm_nd
from repro.kernels.rmsnorm.ref import rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_nd", "rmsnorm_ref"]
