"""Fused RMSNorm Pallas TPU kernel.

One HBM round-trip per row block: reads x, computes the fp32 mean-square and
normalized output in VMEM, writes the result.  Row blocks keep the working
set (block_rows x d fp32) inside VMEM; d stays whole because the reduction is
over the feature axis (MXU-free, VPU-friendly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    scale = 1.0 + s_ref[...].astype(jnp.float32)
    o_ref[...] = (y * scale[None, :]).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,          # [n, d]
    scale: jax.Array,      # [d]
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    n, d = x.shape
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"rows {n} must divide block_rows {block_rows}")
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, scale)
