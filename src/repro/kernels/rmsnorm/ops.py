"""Jitted public wrapper for the RMSNorm kernel (any leading shape)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_nd(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
               interpret: bool = True):
    lead = x.shape[:-1]
    n = 1
    for s in lead:
        n *= s
    block = block_rows
    while n % block:
        block //= 2
    out = rmsnorm(x.reshape(n, x.shape[-1]), scale, eps=eps,
                  block_rows=max(block, 1), interpret=interpret)
    return out.reshape(*lead, x.shape[-1])
