"""AdamW over flat parameter shards (ZeRO-sharded optimizer states).

Because model states are flat vectors sharded across the partition group,
the optimizer is purely elementwise on each device's shard — optimizer
states (m, v) are partitioned exactly like parameters (ZeRO-1/2 fall out of
the same layout).  Weight-decay and padding masks are rebuilt per shard from
static segment ranges (see FlatLayout.decay_mask_for_shard).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr_max: float = 3e-4
    lr_min_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(step, oc: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(oc.warmup_steps, 1)
    frac = (step - oc.warmup_steps) / jnp.maximum(
        oc.total_steps - oc.warmup_steps, 1)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = oc.lr_min_ratio + (1 - oc.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return oc.lr_max * jnp.where(step < oc.warmup_steps, warm, cos)


def adamw_shard_update(
    p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
    step, oc: OptConfig, *, decay_mask: jax.Array, pad_mask: jax.Array,
    lr=None, grad_scale=None,
):
    """One AdamW step on a flat shard.  All arrays [*, S_local] fp32.

    ``grad_scale`` folds the gradient-accumulation denominator and the
    global-norm clip factor into this update (``g * grad_scale`` fuses into
    the m/v elementwise pass) — the boundary scheduler (core/schedule.py)
    passes ``clip_scale / denom`` here so neither clipping nor the mean
    costs a standalone full-gradient-tree traversal.
    """
    if grad_scale is not None:
        g = g * grad_scale
    lr = lr_schedule(step, oc) if lr is None else lr
    t = step.astype(jnp.float32) + 1.0
    m = oc.b1 * m + (1 - oc.b1) * g
    v = oc.b2 * v + (1 - oc.b2) * g * g
    mhat = m / (1 - oc.b1 ** t)
    vhat = v / (1 - oc.b2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * decay_mask * p
    p = (p - lr * upd) * pad_mask
    return p, m, v
