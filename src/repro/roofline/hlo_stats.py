"""Trip-count-weighted static analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation once — a
``lax.scan`` over 80 layers or 4 micro-steps contributes its body a single
time, which under-counts FLOPs/bytes/collective traffic by orders of
magnitude for our programs.  This module re-derives the three roofline
inputs from the HLO text itself, walking the computation graph and weighting
``while`` bodies by their ``known_trip_count`` annotation:

  * dot FLOPs       — 2 * batch * Mfree * Nfree * K per dot instruction
                      (elementwise FLOPs are ignored: matmuls are >95% of
                      compute in every cell; noted in EXPERIMENTS.md)
  * HBM bytes       — write-centric estimator: dots count exact operand +
                      result traffic; dynamic-(update-)slice counts the
                      slice (XLA keeps loop carries in place — counting the
                      full stacked buffer would be quadratically wrong);
                      every other op counts 2x its result (read ~= write
                      for elementwise/fusion outputs).  This matches how a
                      bufferized loop actually touches HBM far better than
                      XLA's own full-operand convention.
  * collective wire — ring-algorithm bytes per participant, attributed to
                      the mesh axes spanned by the replica group (decoded
                      from device ids), split ICI vs pod-crossing DCI.

When the caller supplies the MiCS axis roles (``partition_axes`` /
``replication_axes``), every collective is additionally attributed to a
**policy stage** of the CommEngine (core/comm.py): ``param_gather.flat`` /
``.inner`` / ``.outer`` for hop-1 gathers (the inner/outer split decoded
from the replica-group coordinates: contiguous runs along a partition axis
are the fast "intra-node" stage, strided groups the slow inter-node stage),
``grad_rs.*`` for the adjoint reduce-scatters, ``hop2`` for the
replication-group all-reduce, ``model_gather`` for tensor-parallel segment
reassembly.  The quantized gradient wires are attributed the same way:
qgZ's per-stage all-to-alls (int8 payloads + f32 block scales) land in
``grad_rs.{flat,inner,outer}`` by their replica-group coordinates, and the
int8 hop-2's decomposed all-reduce (all-to-all + all-gather over the
replication axes) lands in ``hop2``.  The census also reports **prefetch evidence**: all-gathers
inside ``while`` bodies whose results flow into the loop carry without
passing through any compute (dot) are gathers issued one layer *ahead* of
their consumer — the double-buffered schedule's signature in optimized HLO.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
# The shape alternation accepts tuple shapes with one level of nested
# tuples — e.g. a while carry holding PRNG loop state ``(s32[], ...,
# (s32[], u32[4]{0}, ...), ...)`` as emitted for rolled threefry loops.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\(([^)]*(?:\([^)]*\))?[^)]*)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_GROUPS = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_TRIP = re.compile(r"known_trip_count[^\d]*(\d+)")
_WHILE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_RDIMS = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_LBATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}


def _parse_shape(s: str) -> tuple[int, list[list[int]]]:
    """Returns (total bytes, list of dim lists)."""
    total = 0
    dims_all = []
    for dtype, dims in _SHAPE_ATOM.findall(s):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = math.prod(d) if d else 1
        total += n * _DTYPE_BYTES[dtype]
        dims_all.append(d)
    return total, dims_all


def _ints(s: str) -> list[int]:
    return [int(x) for x in s.split(",") if x.strip()]


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    shape_str: str
    operands: list[str]
    line: str
    root: bool = False


@dataclasses.dataclass
class Comp:
    name: str
    instrs: list
    table: dict      # instr name -> shape_str


def parse_module(text: str) -> tuple[dict, str | None]:
    comps: dict[str, Comp] = {}
    entry = None
    cur: Comp | None = None
    for line in text.splitlines():
        if line.strip() == "}":
            cur = None
            continue
        head = _COMP_HEAD.match(line)
        if head:
            cur = Comp(head.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_str, op, args = m.groups()
        operands = _OPERAND.findall(args)
        ins = Instr(name, op, shape_str, operands, line,
                    root=line.lstrip().startswith("ROOT"))
        cur.instrs.append(ins)
        cur.table[name] = shape_str
    return comps, entry


def _dot_flops(ins: Instr, table: dict) -> float:
    _, res_dims = _parse_shape(ins.shape_str)
    if not ins.operands:
        return 0.0
    lhs_shape = table.get(ins.operands[0], "")
    _, lhs_dims = _parse_shape(lhs_shape)
    if not lhs_dims:
        return 0.0
    ldims = lhs_dims[0]
    cm = _DIMS.search(ins.line)
    contract = _ints(cm.group(1)) if cm else []
    k = math.prod(ldims[i] for i in contract) if contract else 1
    out = math.prod(res_dims[0]) if res_dims else 0
    return 2.0 * out * k


def _group_coords(group: list[int], mesh_shape: dict[str, int]) -> dict[str, list[int]]:
    """Per-axis sorted coordinate sets spanned by one replica group."""
    names = list(mesh_shape)
    sizes = [mesh_shape[n] for n in names]
    coords: dict[str, set] = {n: set() for n in names}
    for dev in group:
        rem = dev
        c = []
        for s in reversed(sizes):
            c.append(rem % s)
            rem //= s
        for name, v in zip(names, reversed(c)):
            coords[name].add(v)
    return {n: sorted(v) for n, v in coords.items()}


def _group_axes(group: list[int], mesh_shape: dict[str, int]) -> tuple[str, ...]:
    coords = _group_coords(group, mesh_shape)
    return tuple(n for n in mesh_shape if len(coords[n]) > 1)


def _stage_label(
    kind: str,
    axes: tuple[str, ...],
    group: list[int],
    mesh_shape: dict[str, int],
    partition_axes: tuple[str, ...],
    replication_axes: tuple[str, ...],
    model_axis: str,
    nbytes: float = 0.0,
) -> str:
    """Attribute one collective to a CommEngine policy stage.

    ``all-to-all`` collectives are the quantized gradient wires: over
    partition axes they are qgZ hop-1 stages (the all-to-all decomposition
    of a block-quantized reduce-scatter, ``grad_rs.*``); over replication
    axes they are the int8 hop-2 reduce-scatter leg, whose matching
    all-gather over the replication axes is the other half of the
    decomposed quantized all-reduce — both land in ``hop2``.
    """
    # size-1 axes never vary inside a replica group; compare against the
    # *effective* partition/replication axes only.
    pset = {a for a in partition_axes if mesh_shape.get(a, 1) > 1}
    rset = {a for a in replication_axes if mesh_shape.get(a, 1) > 1}
    aset = set(axes)
    if not aset:
        return "other"
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        if aset == {model_axis}:
            return "model_gather" if kind == "all-gather" else "model_rs"
        if rset and aset <= rset and kind in ("all-gather", "all-to-all"):
            return "hop2"  # decomposed quantized all-reduce legs
        prefix = "param_gather" if kind == "all-gather" else "grad_rs"
        if not aset <= pset:
            return "other"
        coords = _group_coords(group, mesh_shape)
        partial_axes = [a for a in axes if len(coords[a]) < mesh_shape[a]]
        if not partial_axes and aset == pset:
            return f"{prefix}.flat"  # whole partition group, one collective
        # A staged hop: either a sub-group within one partition axis, or a
        # subset of a multi-axis partition group.  Contiguous coordinate
        # runs are the fast ("inner"/intra-node) stage; strided runs the
        # slow ("outer"/inter-node) stage (paper Fig 5).
        if len(axes) == 1 and partial_axes:
            c = coords[axes[0]]
            contiguous = c == list(range(c[0], c[0] + len(c)))
            return f"{prefix}.inner" if contiguous else f"{prefix}.outer"
        if aset < pset:
            # multi-axis partition group staged one mesh axis at a time:
            # the slowest partition axis is the outer stage.
            slowest = next(a for a in partition_axes if a in pset)
            return f"{prefix}.outer" if slowest in aset else f"{prefix}.inner"
        return f"{prefix}.flat"
    if kind == "all-reduce":
        if aset == {model_axis}:
            return "tp_allreduce"  # tensor-parallel activation reductions
        if aset <= rset:
            return "hop2"
        # The Fig-14 alternative schedule all-reduces the *full gradient*
        # over every data axis; scalar metric/clip reductions over the same
        # axes are told apart by payload size.
        if (pset and pset <= aset and model_axis not in aset
                and rset <= aset and nbytes > 4096):
            return "allreduce_slice"
        return "allreduce.other"
    return "other"


def _parse_groups(line: str):
    m = _GROUPS.search(line)
    if m:
        return [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in re.findall(r"\{([^}]*)\}", m.group(1))
        ]
    m = _GROUPS_IOTA.search(line)
    if m:
        import numpy as np

        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = _ints(m.group(3))
        perm = _ints(m.group(4)) if m.group(4) else list(range(len(dims)))
        ids = np.arange(math.prod(dims)).reshape(dims).transpose(perm)
        ids = ids.reshape(ngroups, gsize)
        return [list(map(int, row)) for row in ids]
    return None


# Ops that merely move/reinterpret data: a value flowing through these into
# the loop-carry tuple has not been consumed by compute.
_CARRY_PASSTHROUGH = {
    "tuple", "get-tuple-element", "bitcast", "reshape", "transpose",
    "convert", "copy", "slice", "concatenate", "optimization-barrier",
    "all-gather-done",
}


_DATA_MOVEMENT_OPS = _CARRY_PASSTHROUGH | _FREE_OPS | {
    "broadcast", "dynamic-slice", "pad", "reverse", "all-gather",
    "all-gather-start",
}


def _is_data_movement(comps: dict, name: str, depth: int = 0) -> bool:
    """True iff the computation only moves/reinterprets values (no math) —
    a value flowing through such a call/fusion has not been consumed."""
    comp = comps.get(name)
    if comp is None or depth > 16:
        return False  # unknown callee: assume compute (conservative)
    for ins in comp.instrs:
        if ins.op in ("call", "fusion"):
            if not all(_is_data_movement(comps, sub, depth + 1)
                       for sub in _CALLS.findall(ins.line)):
                return False
            continue
        if ins.op not in _DATA_MOVEMENT_OPS:
            return False
    return True


def prefetch_census(comps: dict) -> dict:
    """Evidence that parameter gathers are issued one layer ahead.

    In the double-buffered schedule (models/lm.py), a layer scan body
    all-gathers layer i+1's shard and passes the result straight into the
    loop carry; the compute of iteration i never touches it.  In optimized
    HLO that reads as: an ``all-gather`` inside a ``while`` body whose value
    reaches the ROOT tuple through data-movement ops only (no dot, no
    compute fusion).  The serial schedule has zero such gathers — every
    gather's value is consumed by the same iteration's matmuls.

    ``carried_buffer_bytes`` is the summed result size of the carried
    gathers — the per-iteration slice of the prefetch-carry residual the
    memory planner prices (core/memplan.py: the stored carry keeps
    ``stack`` stacked copies of it; the remat carry drops it).
    """
    bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "while":
                wm = _WHILE.search(ins.line)
                if wm:
                    bodies.add(wm.group(2))

    total, carried = 0, 0
    carried_bytes = 0.0
    for bname in sorted(bodies):
        comp = comps.get(bname)
        if comp is None:
            continue
        by_name = {i.name: i for i in comp.instrs}
        gathers = {i.name for i in comp.instrs
                   if i.op in ("all-gather", "all-gather-start")}
        total += len(gathers)
        root = next((i for i in comp.instrs if i.root), None)
        if root is None or not gathers:
            continue
        seen: set[str] = set()
        frontier = list(root.operands)
        while frontier:
            nm = frontier.pop()
            if nm in seen:
                continue
            seen.add(nm)
            ins = by_name.get(nm)
            if ins is None:
                continue
            if ins.op in ("all-gather", "all-gather-start"):
                continue  # terminal: counted via ``seen`` below
            if ins.op in _CARRY_PASSTHROUGH:
                frontier.extend(ins.operands)
            elif ins.op in ("fusion", "call") and all(
                    _is_data_movement(comps, sub)
                    for sub in _CALLS.findall(ins.line)):
                frontier.extend(ins.operands)
        for name in gathers & seen:
            carried += 1
            carried_bytes += _parse_shape(by_name[name].shape_str)[0]
    return {"body_all_gathers": total, "carried_all_gathers": carried,
            "carried_buffer_bytes": carried_bytes}


# Arithmetic ops that count as boundary compute when they sit between two
# hop-2 collectives in program order (converts/copies are wire decompress
# plumbing, not compute).
_ARITH_OPS = {
    "multiply", "add", "subtract", "divide", "rsqrt", "sqrt", "power",
    "maximum", "minimum", "exponential", "negate",
}


def boundary_census(
    comps: dict,
    mesh_shape: dict,
    *,
    partition_axes: tuple = (),
    replication_axes: tuple = (),
    model_axis: str = "model",
) -> dict:
    """Evidence that hop-2 collectives interleave with boundary compute.

    The bucketed boundary scheduler (core/schedule.py) issues bucket *k*'s
    hop-2 all-reduce before bucket *k−1*'s norm/decompress compute, so in
    the optimized HLO the hop-2 collectives of one computation have real
    compute instructions (fusions, reduces, arithmetic — not converts or
    copies) *between* them in program order.  The serial reference issues
    every hop-2 back to back before the first norm reduce touches any
    result.  Under the int8 hop-2 wire there are no hop-2 all-reduces at
    all: each payload runs as a decomposed quantized all-reduce whose int8
    all-to-all (the reduce-scatter leg) is counted as that payload's hop-2
    op instead (the f32 scale traffic and the all-gather leg are not
    double-counted).  Reports, over all computations:

      hop2_ops               hop-2-stage collectives, one per payload
                             (all-reduces, or int8 all-to-all legs)
      hop2_max_operand_bytes largest single hop-2 payload (bucket ceiling)
      compute_between_hop2   compute instructions strictly between the
                             first and last hop-2 of a computation
      interleaved            compute_between_hop2 > 0
    """
    total_ops = 0
    max_bytes = 0.0
    between = 0
    for comp in comps.values():
        positions = []
        for idx, ins in enumerate(comp.instrs):
            if ins.op not in ("all-reduce", "all-reduce-start", "all-to-all"):
                continue
            kind = "all-to-all" if ins.op == "all-to-all" else "all-reduce"
            if kind == "all-to-all" and "s8[" not in ins.shape_str:
                continue  # count only the int8 q leg, once per payload
            groups = _parse_groups(ins.line)
            if groups:
                axes = _group_axes(groups[0], mesh_shape)
                group0 = groups[0]
            else:
                axes = tuple(mesh_shape)
                group0 = list(range(math.prod(mesh_shape.values())))
            ob = 0
            for o in ins.operands:
                if o in comp.table:
                    ob += _parse_shape(comp.table[o])[0]
            stage = _stage_label(
                kind, axes, group0, mesh_shape,
                tuple(partition_axes), tuple(replication_axes), model_axis,
                nbytes=ob)
            if stage != "hop2":
                continue
            # scalar metric reductions (loss/aux pmeans) share the hop-2
            # axes on p=1 topologies; gradient buckets are rank-1 buffers,
            # so rank-0 operands are excluded whatever their byte count
            op_dims = [d for o in ins.operands if o in comp.table
                       for d in _parse_shape(comp.table[o])[1]]
            if op_dims and all(len(d) == 0 for d in op_dims):
                continue
            positions.append(idx)
            max_bytes = max(max_bytes, float(ob))
        total_ops += len(positions)
        if len(positions) < 2:
            continue
        for ins in comp.instrs[positions[0] + 1: positions[-1]]:
            if ins.op in _ARITH_OPS or ins.op == "reduce" or ins.op == "dot":
                between += 1
            elif ins.op in ("fusion", "call") and not all(
                    _is_data_movement(comps, sub)
                    for sub in _CALLS.findall(ins.line)):
                between += 1
    return {
        "hop2_ops": total_ops,
        "hop2_max_operand_bytes": max_bytes,
        "compute_between_hop2": between,
        "interleaved": between > 0,
    }


def analyze(
    text: str,
    mesh_shape: dict[str, int],
    *,
    partition_axes: tuple[str, ...] = (),
    replication_axes: tuple[str, ...] = (),
    model_axis: str = "model",
) -> dict:
    comps, entry = parse_module(text)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].instrs), default=None)

    flops = 0.0
    bytes_hbm = 0.0
    coll = defaultdict(lambda: dict(
        wire_bytes=0.0, result_bytes=0.0, operand_bytes=0.0, count=0.0,
        group_size=0, crosses_pod=False, stage="other"))

    def operand_bytes(ins: Instr, table: dict) -> int:
        total = 0
        for o in ins.operands:
            if o in table:
                b, _ = _parse_shape(table[o])
                total += b
        return total

    def walk(name: str, weight: float, depth: int):
        if name not in comps or depth > 64:
            return
        comp = comps[name]
        nonlocal flops, bytes_hbm
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                tm = _TRIP.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                wm = _WHILE.search(ins.line)
                if wm:
                    walk(wm.group(2), weight * trip, depth + 1)
                    walk(wm.group(1), weight * (trip + 1), depth + 1)
                continue
            if op in ("fusion", "call", "conditional", "custom-call",
                      "reduce", "map", "sort", "scatter", "select-and-scatter"):
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * 2 * rb
                for sub in _CALLS.findall(ins.line):
                    walk_flops_only(sub, weight, depth + 1)
                continue
            if op in _FREE_OPS:
                continue
            if op == "dot":
                flops += weight * _dot_flops(ins, comp.table)
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * (rb + operand_bytes(ins, comp.table))
            elif op == "dynamic-update-slice":
                upd = (_parse_shape(comp.table.get(ins.operands[1], ""))[0]
                       if len(ins.operands) > 1 else 0)
                bytes_hbm += weight * 2 * upd
            elif op == "dynamic-slice":
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * 2 * rb
            elif op not in _COLLECTIVES:
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * 2 * rb
            if op in _COLLECTIVES:
                kind = op.replace("-start", "")
                rb, _ = _parse_shape(ins.shape_str)
                ob = operand_bytes(ins, comp.table) or rb
                groups = _parse_groups(ins.line)
                if groups:
                    gsize = len(groups[0])
                    axes = _group_axes(groups[0], mesh_shape)
                    group0 = groups[0]
                else:
                    gsize = math.prod(mesh_shape.values())
                    axes = tuple(mesh_shape)
                    group0 = list(range(gsize))
                stage = _stage_label(
                    kind, axes, group0, mesh_shape,
                    tuple(partition_axes), tuple(replication_axes), model_axis,
                    nbytes=ob)
                if gsize > 1:
                    frac = (gsize - 1) / gsize
                    if kind == "all-gather":
                        wire = rb * frac
                    elif kind == "reduce-scatter":
                        wire = ob * frac
                    elif kind == "all-reduce":
                        wire = 2 * ob * frac
                    elif kind == "all-to-all":
                        wire = ob * frac
                    else:
                        wire = ob
                    e = coll[(kind, axes, stage)]
                    e["wire_bytes"] += wire * weight
                    e["result_bytes"] += rb * weight
                    e["operand_bytes"] += ob * weight
                    e["count"] += weight
                    e["group_size"] = gsize
                    e["crosses_pod"] = "pod" in axes
                    e["stage"] = stage
                bytes_hbm += weight * (rb + ob)

    def walk_flops_only(name: str, weight: float, depth: int):
        """Inside fusions: count dot FLOPs only (bytes stay at the boundary)."""
        nonlocal flops
        if name not in comps or depth > 64:
            return
        for ins in comps[name].instrs:
            if ins.op == "dot":
                flops += weight * _dot_flops(ins, comps[name].table)
            for sub in _CALLS.findall(ins.line):
                walk_flops_only(sub, weight, depth + 1)

    if entry:
        walk(entry, 1.0, 0)

    total = sum(e["wire_bytes"] for e in coll.values())
    dci = sum(e["wire_bytes"] for e in coll.values() if e["crosses_pod"])
    by_stage: dict[str, dict] = defaultdict(
        lambda: dict(wire_bytes=0.0, count=0.0))
    for (_, _, stage), e in coll.items():
        by_stage[stage]["wire_bytes"] += e["wire_bytes"]
        by_stage[stage]["count"] += e["count"]
    return {
        "dot_flops": flops,
        "hbm_bytes": bytes_hbm,
        "total_wire_bytes": total,
        "dci_wire_bytes": dci,
        "ici_wire_bytes": total - dci,
        "n_collectives": sum(e["count"] for e in coll.values()),
        "by_collective": {
            f"{kind}@{'x'.join(axes) or 'world'}@{stage}": e
            for (kind, axes, stage), e in sorted(
                coll.items(), key=lambda kv: str(kv[0]))
        },
        "by_stage": dict(sorted(by_stage.items())),
        "prefetch": prefetch_census(comps),
        "boundary": boundary_census(
            comps, mesh_shape,
            partition_axes=partition_axes,
            replication_axes=replication_axes,
            model_axis=model_axis),
    }
