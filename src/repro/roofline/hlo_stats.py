"""Trip-count-weighted static analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation once — a
``lax.scan`` over 80 layers or 4 micro-steps contributes its body a single
time, which under-counts FLOPs/bytes/collective traffic by orders of
magnitude for our programs.  This module re-derives the three roofline
inputs from the HLO text itself, walking the computation graph and weighting
``while`` bodies by their ``known_trip_count`` annotation:

  * dot FLOPs       — 2 * batch * Mfree * Nfree * K per dot instruction
                      (elementwise FLOPs are ignored: matmuls are >95% of
                      compute in every cell; noted in EXPERIMENTS.md)
  * HBM bytes       — write-centric estimator: dots count exact operand +
                      result traffic; dynamic-(update-)slice counts the
                      slice (XLA keeps loop carries in place — counting the
                      full stacked buffer would be quadratically wrong);
                      every other op counts 2x its result (read ~= write
                      for elementwise/fusion outputs).  This matches how a
                      bufferized loop actually touches HBM far better than
                      XLA's own full-operand convention.
  * collective wire — ring-algorithm bytes per participant, attributed to
                      the mesh axes spanned by the replica group (decoded
                      from device ids), split ICI vs pod-crossing DCI.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_ATOM = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\(([^)]*(?:\([^)]*\))?[^)]*)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_GROUPS = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_TRIP = re.compile(r"known_trip_count[^\d]*(\d+)")
_WHILE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_DIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_RDIMS = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_LBATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}


def _parse_shape(s: str) -> tuple[int, list[list[int]]]:
    """Returns (total bytes, list of dim lists)."""
    total = 0
    dims_all = []
    for dtype, dims in _SHAPE_ATOM.findall(s):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = math.prod(d) if d else 1
        total += n * _DTYPE_BYTES[dtype]
        dims_all.append(d)
    return total, dims_all


def _ints(s: str) -> list[int]:
    return [int(x) for x in s.split(",") if x.strip()]


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    shape_str: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Comp:
    name: str
    instrs: list
    table: dict      # instr name -> shape_str


def parse_module(text: str) -> tuple[dict, str | None]:
    comps: dict[str, Comp] = {}
    entry = None
    cur: Comp | None = None
    for line in text.splitlines():
        if line.strip() == "}":
            cur = None
            continue
        head = _COMP_HEAD.match(line)
        if head:
            cur = Comp(head.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_str, op, args = m.groups()
        operands = _OPERAND.findall(args)
        ins = Instr(name, op, shape_str, operands, line)
        cur.instrs.append(ins)
        cur.table[name] = shape_str
    return comps, entry


def _dot_flops(ins: Instr, table: dict) -> float:
    _, res_dims = _parse_shape(ins.shape_str)
    if not ins.operands:
        return 0.0
    lhs_shape = table.get(ins.operands[0], "")
    _, lhs_dims = _parse_shape(lhs_shape)
    if not lhs_dims:
        return 0.0
    ldims = lhs_dims[0]
    cm = _DIMS.search(ins.line)
    contract = _ints(cm.group(1)) if cm else []
    k = math.prod(ldims[i] for i in contract) if contract else 1
    out = math.prod(res_dims[0]) if res_dims else 0
    return 2.0 * out * k


def _group_axes(group: list[int], mesh_shape: dict[str, int]) -> tuple[str, ...]:
    names = list(mesh_shape)
    sizes = [mesh_shape[n] for n in names]
    varying = set()
    base = None
    for dev in group:
        c = []
        rem = dev
        for s in reversed(sizes):
            c.append(rem % s)
            rem //= s
        c = tuple(reversed(c))
        base = base or c
        for i, (a, b) in enumerate(zip(c, base)):
            if a != b:
                varying.add(names[i])
    return tuple(n for n in names if n in varying)


def _parse_groups(line: str):
    m = _GROUPS.search(line)
    if m:
        return [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in re.findall(r"\{([^}]*)\}", m.group(1))
        ]
    m = _GROUPS_IOTA.search(line)
    if m:
        import numpy as np

        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = _ints(m.group(3))
        perm = _ints(m.group(4)) if m.group(4) else list(range(len(dims)))
        ids = np.arange(math.prod(dims)).reshape(dims).transpose(perm)
        ids = ids.reshape(ngroups, gsize)
        return [list(map(int, row)) for row in ids]
    return None


def analyze(text: str, mesh_shape: dict[str, int]) -> dict:
    comps, entry = parse_module(text)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].instrs), default=None)

    flops = 0.0
    bytes_hbm = 0.0
    coll = defaultdict(lambda: dict(
        wire_bytes=0.0, result_bytes=0.0, operand_bytes=0.0, count=0.0,
        group_size=0, crosses_pod=False))

    def operand_bytes(ins: Instr, table: dict) -> int:
        total = 0
        for o in ins.operands:
            if o in table:
                b, _ = _parse_shape(table[o])
                total += b
        return total

    def walk(name: str, weight: float, depth: int):
        if name not in comps or depth > 64:
            return
        comp = comps[name]
        nonlocal flops, bytes_hbm
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                tm = _TRIP.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                wm = _WHILE.search(ins.line)
                if wm:
                    walk(wm.group(2), weight * trip, depth + 1)
                    walk(wm.group(1), weight * (trip + 1), depth + 1)
                continue
            if op in ("fusion", "call", "conditional", "custom-call",
                      "reduce", "map", "sort", "scatter", "select-and-scatter"):
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * 2 * rb
                for sub in _CALLS.findall(ins.line):
                    walk_flops_only(sub, weight, depth + 1)
                continue
            if op in _FREE_OPS:
                continue
            if op == "dot":
                flops += weight * _dot_flops(ins, comp.table)
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * (rb + operand_bytes(ins, comp.table))
            elif op == "dynamic-update-slice":
                upd = (_parse_shape(comp.table.get(ins.operands[1], ""))[0]
                       if len(ins.operands) > 1 else 0)
                bytes_hbm += weight * 2 * upd
            elif op == "dynamic-slice":
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * 2 * rb
            elif op not in _COLLECTIVES:
                rb, _ = _parse_shape(ins.shape_str)
                bytes_hbm += weight * 2 * rb
            if op in _COLLECTIVES:
                kind = op.replace("-start", "")
                rb, _ = _parse_shape(ins.shape_str)
                ob = operand_bytes(ins, comp.table) or rb
                groups = _parse_groups(ins.line)
                if groups:
                    gsize = len(groups[0])
                    axes = _group_axes(groups[0], mesh_shape)
                else:
                    gsize = math.prod(mesh_shape.values())
                    axes = tuple(mesh_shape)
                if gsize > 1:
                    frac = (gsize - 1) / gsize
                    if kind == "all-gather":
                        wire = rb * frac
                    elif kind == "reduce-scatter":
                        wire = ob * frac
                    elif kind == "all-reduce":
                        wire = 2 * ob * frac
                    elif kind == "all-to-all":
                        wire = ob * frac
                    else:
                        wire = ob
                    e = coll[(kind, axes)]
                    e["wire_bytes"] += wire * weight
                    e["result_bytes"] += rb * weight
                    e["operand_bytes"] += ob * weight
                    e["count"] += weight
                    e["group_size"] = gsize
                    e["crosses_pod"] = "pod" in axes
                bytes_hbm += weight * (rb + ob)

    def walk_flops_only(name: str, weight: float, depth: int):
        """Inside fusions: count dot FLOPs only (bytes stay at the boundary)."""
        nonlocal flops
        if name not in comps or depth > 64:
            return
        for ins in comps[name].instrs:
            if ins.op == "dot":
                flops += weight * _dot_flops(ins, comps[name].table)
            for sub in _CALLS.findall(ins.line):
                walk_flops_only(sub, weight, depth + 1)

    if entry:
        walk(entry, 1.0, 0)

    total = sum(e["wire_bytes"] for e in coll.values())
    dci = sum(e["wire_bytes"] for e in coll.values() if e["crosses_pod"])
    return {
        "dot_flops": flops,
        "hbm_bytes": bytes_hbm,
        "total_wire_bytes": total,
        "dci_wire_bytes": dci,
        "ici_wire_bytes": total - dci,
        "n_collectives": sum(e["count"] for e in coll.values()),
        "by_collective": {
            f"{kind}@{'x'.join(axes) or 'world'}": e
            for (kind, axes), e in sorted(coll.items(), key=lambda kv: str(kv[0]))
        },
    }
