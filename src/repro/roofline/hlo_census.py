"""Collective census over compiled (optimized, SPMD-partitioned) HLO text.

`compiled.cost_analysis()` has no collective-byte statistic, so we parse the
optimized module: every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute instruction, its shapes, and its replica groups.

Two subtleties make this a real parser rather than a grep:

* collectives inside `lax.scan` loops appear once in the text but execute
  trip-count times — XLA annotates `while` ops with
  ``backend_config={"known_trip_count":{"n":...}}``, so the census walks the
  computation graph (entry -> while bodies -> fusions) multiplying by trip
  counts;
* replica groups are device-id lists; ids are decoded back into
  (pod, repl, shard, model) mesh coordinates so each collective is attributed
  to the mesh axes it spans — in particular whether it crosses the pod
  boundary (DCI) or stays on intra-pod ICI.

Wire-bytes use the standard ring algorithm accounting per participant:
  all-gather (g-1)/g * result;   reduce-scatter (g-1)/g * operand;
  all-reduce 2(g-1)/g * operand; all-to-all (g-1)/g * operand;
  collective-permute: operand.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_KIND_RE = re.compile(r"=\s*(?:\([^=]*?\)|\S+)\s+([\w\-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_groups(line: str) -> list[list[int]] | None:
    m = _GROUPS_RE.search(line)
    if m:
        return [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in re.findall(r"\{([^}]*)\}", m.group(1))
        ]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np

        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        ids = np.arange(math.prod(dims)).reshape(dims).transpose(perm).reshape(
            ngroups, gsize)
        return [list(map(int, row)) for row in ids]
    return None


def _axes_of_group(group: list[int], mesh_shape: dict[str, int]) -> tuple[str, ...]:
    names = list(mesh_shape)
    sizes = [mesh_shape[n] for n in names]
    varying = set()
    base = None
    for dev in group:
        c = []
        rem = dev
        for s in reversed(sizes):
            c.append(rem % s)
            rem //= s
        c = tuple(reversed(c))
        if base is None:
            base = c
        for i, (a, b) in enumerate(zip(c, base)):
            if a != b:
                varying.add(names[i])
    return tuple(n for n in names if n in varying)


@dataclasses.dataclass
class _Comp:
    name: str
    collectives: list  # (kind, result_bytes, operand_bytes, gsize, axes)
    whiles: list       # (body_name, trip)
    calls: list        # sub-computation names (weight 1)


def _split_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        head = _COMP_HEAD_RE.match(line)
        if head and line.rstrip().endswith("{"):
            cur = _Comp(head.group(1), [], [], [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mw = _WHILE_RE.search(line)
        if mw:
            trip_m = _TRIP_RE.search(line)
            trip = int(trip_m.group(1)) if trip_m else 1
            cur.whiles.append((mw.group(2), trip))
            continue
        mc = _COLLECTIVE_RE.match(line)
        if mc:
            shape_str, kind = mc.group(1), mc.group(2)
            result_bytes = _shape_bytes(shape_str)
            paren = line[line.index("("):]
            operand_bytes = _shape_bytes(paren.split("replica_groups")[0])
            groups = _parse_groups(line)
            if groups:
                gsize = len(groups[0])
                axes = groups[0]
            else:
                gsize, axes = 0, []
            cur.collectives.append(
                (kind, result_bytes, operand_bytes or result_bytes, gsize, axes))
            continue
        km = _KIND_RE.search(line)
        if km and km.group(1) in ("fusion", "call", "conditional"):
            for sub in _CALLS_RE.findall(line):
                cur.calls.append(sub)
    return comps


def census(hlo_text: str, mesh_shape: dict[str, int]) -> dict:
    """Trip-count-weighted collective census of an optimized HLO module."""
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(line[len("ENTRY "):].strip()) or \
                _COMP_HEAD_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: computation with most collectives
        entry = max(comps, key=lambda n: len(comps[n].collectives), default=None)

    agg: dict = defaultdict(lambda: dict(
        wire_bytes=0, result_bytes=0, operand_bytes=0, count=0,
        group_size=0, crosses_pod=False))

    def walk(name: str, weight: float, seen: tuple):
        if name not in comps or name in seen:
            return
        comp = comps[name]
        for kind, rb, ob, gsize, group in comp.collectives:
            if gsize <= 1:
                continue
            axes = _axes_of_group(group, mesh_shape)
            frac = (gsize - 1) / gsize
            if kind == "all-gather":
                wire = rb * frac
            elif kind == "reduce-scatter":
                wire = ob * frac
            elif kind == "all-reduce":
                wire = 2 * ob * frac
            elif kind == "all-to-all":
                wire = ob * frac
            else:
                wire = ob
            e = agg[(kind, axes)]
            e["wire_bytes"] += int(wire * weight)
            e["result_bytes"] += int(rb * weight)
            e["operand_bytes"] += int(ob * weight)
            e["count"] += weight
            e["group_size"] = gsize
            e["crosses_pod"] = "pod" in axes
        for body, trip in comp.whiles:
            walk(body, weight * trip, seen + (name,))
        for sub in comp.calls:
            walk(sub, weight, seen + (name,))

    if entry:
        walk(entry, 1.0, ())

    total = sum(e["wire_bytes"] for e in agg.values())
    dci = sum(e["wire_bytes"] for e in agg.values() if e["crosses_pod"])
    return {
        "total_wire_bytes": total,
        "dci_wire_bytes": dci,
        "ici_wire_bytes": total - dci,
        "n_collectives": sum(e["count"] for e in agg.values()),
        "by_collective": {
            f"{kind}@{'x'.join(axes) or 'none'}": e
            for (kind, axes), e in sorted(agg.items(), key=lambda kv: str(kv[0]))
        },
    }
