"""Roofline synthesis: dry-run artifacts -> three-term roofline table.

Terms (per device, per step; constants read from the v5e link profile —
core/linkmodel.py is the single link model of the tree):
  compute    = dot_flops / peak_flops        (bf16 peak, 197e12 on v5e)
  memory     = hbm_bytes / hbm_bw            (819e9 on v5e)
  collective = ici_wire / intra_bw + dci_wire / inter_bw
               (per-link ICI 50e9; DCI modeled at 1/8 ICI per pod-boundary
                link — assumption recorded in the profile and
                EXPERIMENTS.md)

MODEL_FLOPS uses 6·N·D for training (N = active params for MoE) and 2·N·D
for inference shapes, divided across all chips; the ratio MODEL/HLO exposes
remat + padded-head + capacity-factor waste.
"""

from __future__ import annotations

import json
import pathlib

from repro.core.linkmodel import V5E

PEAK_BF16 = V5E.peak_flops
HBM_BW = V5E.hbm_bw
ICI_BW = V5E.intra.bandwidth
DCI_BW = V5E.inter.bandwidth

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts"


def model_flops_per_device(rec: dict) -> float:
    n_chips = 512 if rec["mesh"] == "2x16x16" else 256
    n_active = rec["active_params"]
    tokens = rec["seq"] * rec["global_batch"] if rec["kind"] != "decode" \
        else rec["global_batch"]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n_active * tokens / n_chips


def roofline_terms(rec: dict) -> dict:
    s = rec["stats"]
    compute = s["dot_flops"] / PEAK_BF16
    memory = s["hbm_bytes"] / HBM_BW
    ici = s["ici_wire_bytes"] / ICI_BW
    dci = s["dci_wire_bytes"] / DCI_BW
    coll = ici + dci
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": coll, "ici_s": ici, "dci_s": dci}
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    mf = model_flops_per_device(rec)
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": mf / s["dot_flops"] if s["dot_flops"] else 0.0,
        "step_bound_s": bound,
        # fraction of bf16 peak achievable if the step ran exactly at the
        # max(term) bound — the roofline fraction reported in §Perf
        "roofline_fraction": (mf / PEAK_BF16) / bound if bound else 0.0,
    }


_SUGGESTIONS = {
    "compute": ("compute-bound: reduce padded-head / capacity-factor / remat "
                "waste, or increase per-chip batch to amortize fixed work"),
    "memory": ("memory-bound: fuse the attention softmax (Pallas flash "
               "kernel keeps scores in VMEM) and keep activations bf16"),
    "collective": ("collective-bound: shrink the gather scale (smaller "
                   "partition group / hierarchical staging) or trade TP for "
                   "data parallelism on the over-sharded axis"),
}


def load_records(tag: str = "") -> list[dict]:
    recs = []
    for p in sorted((ART / "dryrun").glob("*.json")):
        rec = json.loads(p.read_text())
        if (rec.get("tag") or "") == tag:
            recs.append(rec)
    return recs


def build_table(tag: str = "") -> list[dict]:
    rows = []
    for rec in load_records(tag):
        t = roofline_terms(rec)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "p": rec["partition_size"],
            **{k: t[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "ici_s", "dci_s", "dominant",
                                 "useful_ratio", "roofline_fraction")},
            "note": _SUGGESTIONS[t["dominant"]],
        })
    return rows


def markdown_table(rows: list[dict], mesh: str | None = "16x16") -> str:
    cols = ("arch", "shape", "mesh", "p", "compute_s", "memory_s",
            "collective_s", "dci_s", "dominant", "useful_ratio",
            "roofline_fraction")
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if mesh and r["mesh"] != mesh:
            continue
        cells = []
        for c in cols:
            v = r[c]
            cells.append(f"{v:.4g}" if isinstance(v, float) else str(v))
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = build_table()
    print(markdown_table(rows, mesh=None))
    (ART / "roofline.json").write_text(json.dumps(rows, indent=1))
