"""dbrx-132b — 16-expert top-4 fine-grained MoE.

[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) per-expert d_ff=10752 vocab=100352.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100_352,
    head_dim=128,
    mlp="swiglu",
    rope_theta=500_000.0,
    n_experts=16,
    n_shared_experts=0,
    top_k=4,
    max_seq=32768,
    notes="EP=16 -> one expert per model rank; "
          "full attention -> long_500k skipped",
)
