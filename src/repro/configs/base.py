"""Architecture configuration schema.

One ``ArchConfig`` per assigned architecture (exact public-literature
configuration) plus a ``smoke()`` reduction of the same family for CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Literal


Family = Literal["dense", "vlm", "encdec", "griffin", "xlstm", "moe"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"          # swiglu | geglu | gelu
    norm: str = "rms"            # rms | ln
    rope_theta: float = 500_000.0
    use_rope: bool = True
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- griffin / local attention ------------------------------------------
    window: int = 0              # local-attention window (0 = full)
    pattern: tuple[str, ...] = ()  # block pattern, e.g. ("rec","rec","attn")
    lru_width: int = 0           # RG-LRU channel count (0 -> d_model)
    conv_width: int = 4

    # --- vlm ------------------------------------------------------------------
    cross_interval: int = 0      # 1 cross-attn layer after every N self layers
    n_vision_tokens: int = 1024  # stub frontend output length

    # --- encdec -----------------------------------------------------------------
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500   # stub conv frontend output length

    # --- xlstm -------------------------------------------------------------------
    slstm_every: int = 0         # one sLSTM block per this many layers
    expand: float = 2.0          # mLSTM up-projection factor

    # --- serving / shapes ----------------------------------------------------
    max_seq: int = 32768
    sub_quadratic: bool = False  # eligible for long_500k

    # --- distribution hints ---------------------------------------------------
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate total parameter count (for partition-size heuristic and
        MODEL_FLOPS).  Computed from the layout builders, so exact: see
        models/build.py:param_count which sums the real layouts; this is the
        quick analytic version used before layouts exist."""
        from repro.models.build import exact_param_count

        return exact_param_count(self)


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=256,
        head_dim=16,
        max_seq=128,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=2, n_shared_experts=cfg.n_shared_experts, d_ff=32)
    if cfg.family == "griffin":
        kw.update(window=32, lru_width=64, n_layers=min(cfg.n_layers, 6))
    if cfg.family == "xlstm":
        kw.update(n_layers=4, n_heads=2, n_kv_heads=2)
    if cfg.family == "vlm":
        kw.update(n_layers=5, n_vision_tokens=16)
    if cfg.family == "encdec":
        kw.update(n_encoder_layers=2, n_layers=2, n_audio_frames=16)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
