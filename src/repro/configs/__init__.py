"""Config registry: the 10 assigned architectures + the paper's workloads."""

from repro.configs.base import ArchConfig, smoke_variant
from repro.configs.bert_paper import PAPER_CONFIGS
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from repro.configs.granite_8b import CONFIG as GRANITE_8B
from repro.configs.llama3_2_1b import CONFIG as LLAMA3_2_1B
from repro.configs.llama_3_2_vision_90b import CONFIG as LLAMA_3_2_VISION_90B
from repro.configs.qwen1_5_110b import CONFIG as QWEN1_5_110B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.yi_9b import CONFIG as YI_9B

ASSIGNED = (
    RECURRENTGEMMA_2B,
    LLAMA_3_2_VISION_90B,
    QWEN1_5_110B,
    GRANITE_8B,
    LLAMA3_2_1B,
    YI_9B,
    WHISPER_LARGE_V3,
    XLSTM_125M,
    DEEPSEEK_MOE_16B,
    DBRX_132B,
)

REGISTRY: dict[str, ArchConfig] = {c.name: c for c in ASSIGNED}
REGISTRY.update(PAPER_CONFIGS)


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key in REGISTRY:
        return REGISTRY[key]
    if name in REGISTRY:
        return REGISTRY[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")


# -- shapes (assignment): seq_len x global_batch -----------------------------
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}


def cells(include_skips: bool = False):
    """All (arch, shape) assignment cells; long_500k only for sub-quadratic
    archs unless include_skips (the skip itself is recorded in EXPERIMENTS)."""
    for cfg in ASSIGNED:
        for shape_name, spec in SHAPES.items():
            skip = shape_name == "long_500k" and not cfg.sub_quadratic
            if skip and not include_skips:
                continue
            yield cfg, shape_name, spec, skip
