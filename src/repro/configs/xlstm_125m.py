"""xlstm-125m — sLSTM + mLSTM blocks (xLSTM[7:1]-style interleave).

[arXiv:2405.04517; unverified]
12L d_model=768 4H vocab=50304 (d_ff=0: the blocks carry their own
up-projections).  Pattern: one sLSTM per 4 blocks -> (m,m,m,s) x 3.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    use_rope=False,
    slstm_every=4,
    expand=2.0,
    conv_width=4,
    max_seq=32768,
    sub_quadratic=True,
    notes="constant-size recurrent state -> runs long_500k; weights stored "
          "model-sharded but cell computed replicated per rank (DESIGN.md §2).",
)
