"""qwen1.5-110b — dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-110B; family-verified via Qwen/Qwen1.5-0.5B]
80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1_000_000.0,
    max_seq=32768,
    notes="full attention -> long_500k skipped",
)
