"""whisper-large-v3 — encoder-decoder speech model (conv frontend stubbed).

[arXiv:2212.04356; hf:openai/whisper-large-v3]
32 encoder + 32 decoder layers, d_model=1280 20H (MHA) d_ff=5120 vocab=51866.
input_specs provides precomputed mel-frame embeddings (1500 frames).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,            # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    mlp="gelu",
    norm="ln",
    use_rope=False,
    n_audio_frames=1500,
    max_seq=32768,
    notes="decode shapes lower the decoder with cross-attention to the "
          "encoded audio; 20 heads padded to 32 on the 16-wide model axis; "
          "full attention -> long_500k skipped.",
)
