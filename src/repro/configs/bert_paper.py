"""The paper's own workloads (Table 1): BERT-style dense LMs at 10B-50B.

Used by the scaling benchmarks to reproduce Figures 7-9 analytically.  We
model them as causal dense decoders of the same width/depth (the paper's
throughput analysis is agnostic to the attention masking direction).
"""

from repro.configs.base import ArchConfig


def _bert(name, hidden, inter, layers, heads, vocab=32008):
    return ArchConfig(
        name=name,
        family="dense",
        n_layers=layers,
        d_model=hidden,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=inter,
        vocab=vocab,
        head_dim=hidden // heads,
        mlp="gelu",
        norm="ln",
        use_rope=False,
        max_seq=512,
        notes="paper Table 1 workload",
    )


BERT_10B = _bert("bert-10b", 2560, 10240, 127, 40)
BERT_15B = _bert("bert-15b", 2560, 10240, 190, 40)
BERT_20B = _bert("bert-20b", 5120, 20480, 64, 40)
BERT_50B = _bert("bert-50b", 8192, 32768, 62, 40)
ROBERTA_20B = _bert("roberta-20b", 5120, 20480, 62, 40, vocab=50265)
GPT2_20B = _bert("gpt2-20b", 5120, 20480, 62, 40, vocab=50265)

PAPER_CONFIGS = {
    c.name: c
    for c in (BERT_10B, BERT_15B, BERT_20B, BERT_50B, ROBERTA_20B, GPT2_20B)
}
