"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1 attn : 2 recurrent.

[arXiv:2402.19427 (Griffin); hf:google/recurrentgemma-2b]
26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="griffin",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256_000,
    head_dim=256,
    mlp="geglu",
    norm="rms",
    rope_theta=10_000.0,
    window=2048,
    pattern=("rec", "rec", "attn"),
    lru_width=2560,
    conv_width=4,
    max_seq=32768,
    sub_quadratic=True,
    notes="26 = 8x(rec,rec,attn) + 2 rec tail; diagonal RG-LRU gates "
          "(DESIGN.md §2); 10 Q heads padded to 16 on the 16-wide model axis.",
)
