"""llama-3.2-vision-90b — VLM backbone with gated cross-attention image layers.

[hf:meta-llama/Llama-3.2-90B-Vision; unverified]
100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Backbone only: the vision frontend is a stub (input_specs provides patch
embeddings); 1 gated cross-attn layer after every 4 self-attn layers.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128_256,
    head_dim=128,
    mlp="swiglu",
    rope_theta=500_000.0,
    cross_interval=4,
    n_vision_tokens=1024,
    max_seq=32768,
    notes="full attention -> long_500k skipped",
)
