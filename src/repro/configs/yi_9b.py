"""yi-9b — llama-architecture GQA dense decoder.

[arXiv:2403.04652; hf:01-ai/Yi-9B]
48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    head_dim=128,
    mlp="swiglu",
    rope_theta=5_000_000.0,
    max_seq=32768,
    notes="full attention -> long_500k skipped",
)
