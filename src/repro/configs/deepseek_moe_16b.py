"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6.

[arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base]
28L d_model=2048 16H (MHA kv=16) per-expert d_ff=1408 vocab=102400.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    head_dim=128,
    mlp="swiglu",
    rope_theta=10_000.0,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    max_seq=32768,
    notes="experts sharded over the model axis (EP=16, 4 experts/rank); "
          "full attention -> long_500k skipped",
)
