"""JAX version compatibility shims.

The framework targets the modern JAX API surface (``jax.shard_map`` with
``check_vma``, ``jax.sharding.AxisType``, ``axis_types=`` on mesh
constructors) but must also run on JAX 0.4.x, where

  * ``shard_map`` lives in ``jax.experimental.shard_map`` and its
    replication-checking kwarg is spelled ``check_rep``;
  * ``jax.sharding.AxisType`` does not exist (all mesh axes behave like
    ``Auto``);
  * neither ``jax.make_mesh`` nor ``Mesh`` accepts ``axis_types``.

Everything version-sensitive is funneled through this module so the rest of
the codebase imports one spelling.  topology.py, mics.py, serving.py and the
test harnesses import from here, never from ``jax``/``jax.experimental``
directly.
"""

from __future__ import annotations

import enum
import inspect
from typing import Sequence

import jax
from jax.sharding import Mesh

__all__ = ["AxisType", "HAS_AXIS_TYPES", "shard_map", "make_mesh",
           "mesh_from_devices", "tpu_compiler_params"]


# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------

try:  # JAX >= 0.5: explicit-sharding axis types exist
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPES = True
except ImportError:  # JAX 0.4.x: every axis is implicitly "Auto"

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on JAX 0.4.x.

        Only used as a label; meshes on 0.4.x are always fully automatic,
        which is exactly the behaviour the framework asks for.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

try:  # modern spelling
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """``jax.shard_map`` with the ``check_vma`` kwarg on every JAX version.

    On 0.4.x the same knob is called ``check_rep``; the shim translates.
    Unknown extra kwargs are passed through (and will raise where
    unsupported, which is the right failure mode).
    """
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

_MAKE_MESH_HAS_AXIS_TYPES = "axis_types" in inspect.signature(
    jax.make_mesh).parameters

try:
    _MESH_HAS_AXIS_TYPES = "axis_types" in inspect.signature(
        Mesh.__init__).parameters
except (TypeError, ValueError):  # builtin/uninspectable __init__ on 0.4.x
    _MESH_HAS_AXIS_TYPES = False


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types=None) -> Mesh:
    """``jax.make_mesh`` accepting ``axis_types`` on every JAX version."""
    if axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names)


def mesh_from_devices(devices, axis_names: Sequence[str],
                      axis_types=None) -> Mesh:
    """``Mesh(devices, names)`` accepting ``axis_types`` on every version."""
    if axis_types is not None and _MESH_HAS_AXIS_TYPES:
        try:
            return Mesh(devices, axis_names, axis_types=axis_types)
        except TypeError:
            pass
    return Mesh(devices, axis_names)


# ---------------------------------------------------------------------------
# Pallas TPU compiler params
# ---------------------------------------------------------------------------

def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# compiled-executable introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every JAX version.

    JAX 0.4.x returns a list with one properties-dict per device; newer
    versions return the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
