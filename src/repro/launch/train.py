"""Training launcher.

Examples:
  # runnable on this host (reduced config, 1 device):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50

  # production lowering check for the full config (no execution):
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
      --shape train_4k --mesh multi
"""

from __future__ import annotations

import argparse
import logging

from repro.configs import get_config, smoke_variant
from repro.core.mics import MiCSConfig
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.data.pipeline import DataConfig
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--no-hierarchical", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    topo = MiCSTopology(make_host_mesh(1, 1, 1, 1))
    model = build_model(cfg, tp=topo.model_size)
    mcfg = MiCSConfig(micro_steps=args.micro_steps,
                      hierarchical=not args.no_hierarchical)
    oc = OptConfig(lr_max=args.lr, total_steps=args.steps,
                   warmup_steps=max(args.steps // 20, 1))
    dc = DataConfig(vocab=cfg.vocab, seq=args.seq,
                    global_batch=args.global_batch,
                    micro_steps=args.micro_steps)
    lc = LoopConfig(total_steps=args.steps,
                    checkpoint_every=args.checkpoint_every,
                    checkpoint_dir=args.checkpoint_dir)
    stats = train(model, topo, mcfg, oc, dc, lc)
    print(f"final loss {stats.losses[-1]:.4f} over {len(stats.losses)} steps; "
          f"restarts={stats.restarts}")


if __name__ == "__main__":
    main()
