"""Training launcher.

All parameter-gather and gradient-sync collectives run through the
CommEngine (core/comm.py): the flags below select its GatherPolicy
(topology / wire dtype / double-buffered prefetch) and SyncPolicy, or
``--policy auto`` delegates the choice to the link-model autotuner
(core/autotune.py) over ``--link-profile``.

Examples:
  # runnable on this host (reduced config, 1 device):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50

  # autotuned policies for an EFA-style network:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --policy auto --link-profile efa-100g

  # production lowering check for the full config (no execution):
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
      --shape train_4k --mesh multi --policy auto
"""

from __future__ import annotations

import argparse
import logging

from repro.configs import get_config, smoke_variant
from repro.core import memplan
from repro.core.autotune import cost_hop2_schedule, resolve_config
from repro.core.comm import CommEngine, policies_from_config
from repro.core.linkmodel import get_profile
from repro.core.mics import MiCSConfig
from repro.core.schedule import plan_boundary
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.data.pipeline import DataConfig
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--policy", choices=["manual", "auto"], default="manual",
                    help="'auto' picks gather topology / staging / wire "
                         "dtype from --link-profile via core/autotune.py")
    ap.add_argument("--link-profile", default="v5e",
                    help="link table for --policy auto (v5e, efa-100g, "
                         "efa-400g, or a registered custom profile)")
    ap.add_argument("--gather-order", default="inner_first",
                    choices=["inner_first", "outer_first"],
                    help="staged-gather order (CommEngine GatherPolicy): "
                         "reorder-free 2-stage vs paper-faithful 3-stage")
    ap.add_argument("--no-hierarchical", action="store_true",
                    help="one flat collective over the partition group "
                         "instead of staged gathers")
    ap.add_argument("--quant-gather", action="store_true",
                    help="int8 blockwise wire gathers (GatherPolicy "
                         "wire_dtype='int8'; under --policy auto this "
                         "*permits* rather than forces int8)")
    ap.add_argument("--hop1-wire-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="hop-1 gradient reduce-scatter wire: fp32 = the "
                         "exact staged adjoint, int8 = ZeRO++-qgZ "
                         "block-quantized stages with fp32 accumulation "
                         "(under --policy auto this permits int8 hop-1)")
    ap.add_argument("--prefetch", type=int, default=1,
                    help="1 = double-buffered lookahead gathers (default), "
                         "0 = serial reference schedule")
    ap.add_argument("--prefetch-carry", default="stored",
                    choices=["stored", "remat"],
                    help="prefetch backward residual: 'stored' carries the "
                         "gathered buffer (O(layers x flat_len) HBM), "
                         "'remat' re-gathers in the backward — one extra "
                         "all-gather per layer buys the residual down to "
                         "O(layers x shard); core/memplan.py prices both")
    ap.add_argument("--carry-offload", default="none",
                    choices=["none", "host"],
                    help="third residual strategy: stream the stored carry "
                         "through host memory (d2h in the forward, h2d in "
                         "the backward, core/hostoffload.py) — no backward "
                         "re-gather AND no O(layers x flat_len) HBM; priced "
                         "on the link model's host tier")
    ap.add_argument("--offload-opt", action="store_true",
                    help="host-offload the AdamW m/v shards: the state dict "
                         "keeps only params+step, moments stream through "
                         "the host stash around the boundary update "
                         "(bitwise-identical params trajectory)")
    ap.add_argument("--clip-mode", default="exact",
                    choices=["exact", "approx"],
                    help="boundary global-norm clip: 'exact' is the "
                         "barriered reference; 'approx' pipelines each "
                         "bucket's AdamW under the next bucket's hop-2 "
                         "with a one-bucket-stale clip factor "
                         "(core/schedule.py; under --policy auto this "
                         "permits rather than forces approx)")
    ap.add_argument("--hbm-budget-gb", type=float, default=0,
                    help="per-device HBM budget in GiB: the memory planner "
                         "gates --policy auto candidates on it and falls "
                         "back to the remat carry when the stored one "
                         "does not fit; 0 = no budget")
    ap.add_argument("--boundary-schedule", default="bucketed",
                    choices=["serial", "bucketed"],
                    help="gradient-accumulation boundary: bucketed hop-2 "
                         "software pipeline (core/schedule.py) or the "
                         "monolithic serial reference — bitwise identical")
    ap.add_argument("--hop2-bucket-mb", type=float, default=32.0,
                    help="hop-2 pipeline bucket size in fp32-gradient MB "
                         "(--policy auto ranks this axis itself)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    topo = MiCSTopology(make_host_mesh(1, 1, 1, 1))
    model = build_model(cfg, tp=topo.model_size)
    mcfg = MiCSConfig(micro_steps=args.micro_steps,
                      hierarchical=not args.no_hierarchical,
                      gather_order=args.gather_order,
                      quant_gather=args.quant_gather,
                      hop1_wire_dtype=args.hop1_wire_dtype,
                      prefetch=bool(args.prefetch),
                      prefetch_carry=args.prefetch_carry,
                      carry_offload=args.carry_offload,
                      offload_opt=args.offload_opt,
                      clip_mode=args.clip_mode,
                      policy=args.policy,
                      link_profile=args.link_profile,
                      boundary_schedule=args.boundary_schedule,
                      hop2_bucket_mb=args.hop2_bucket_mb,
                      hbm_budget_gb=args.hbm_budget_gb or None)
    mcfg, plan = resolve_config(mcfg, model, topo, mode="train")
    if plan is not None:
        print(plan.table())
    bplan = plan_boundary(model, topo, mode=mcfg.boundary_schedule,
                          bucket_mb=mcfg.hop2_bucket_mb,
                          clip_mode=mcfg.clip_mode)
    profile = get_profile(mcfg.link_profile)  # name or instance
    hop2 = cost_hop2_schedule(
        model, topo, profile,
        CommEngine.from_config(topo, mcfg).sync_policy,
        boundary=mcfg.boundary_schedule, bucket_mb=mcfg.hop2_bucket_mb,
        clip_mode=mcfg.clip_mode)
    print(f"boundary: {mcfg.boundary_schedule} x {bplan.n_buckets} buckets "
          f"({mcfg.hop2_bucket_mb:g} MB, clip={bplan.clip_mode}) — "
          f"modeled hop-2 {hop2['t_exposed_s']*1e6:.0f}us exposed / "
          f"{hop2['t_total_s']*1e6:.0f}us total on {profile.name}")
    gp, sp = policies_from_config(mcfg)
    lb = max((args.global_batch // args.micro_steps)
             // topo.data_parallel_size, 0)
    mem = memplan.predict_footprint(
        model, topo, gp, sp, micro_steps=args.micro_steps, mode="train",
        local_batch=lb, seq=args.seq, boundary=mcfg.boundary_schedule,
        hop2_bucket_mb=mcfg.hop2_bucket_mb, offload_opt=mcfg.offload_opt)
    print(f"memplan: {mem.total_gb:.3f} GiB predicted per device "
          f"(prefetch_carry={mcfg.prefetch_carry}, "
          f"carry_offload={mcfg.carry_offload}, "
          f"offload_opt={mcfg.offload_opt})")
    oc = OptConfig(lr_max=args.lr, total_steps=args.steps,
                   warmup_steps=max(args.steps // 20, 1))
    dc = DataConfig(vocab=cfg.vocab, seq=args.seq,
                    global_batch=args.global_batch,
                    micro_steps=args.micro_steps)
    lc = LoopConfig(total_steps=args.steps,
                    checkpoint_every=args.checkpoint_every,
                    checkpoint_dir=args.checkpoint_dir)
    stats = train(model, topo, mcfg, oc, dc, lc)
    print(f"final loss {stats.losses[-1]:.4f} over {len(stats.losses)} steps; "
          f"restarts={stats.restarts}")


if __name__ == "__main__":
    main()
