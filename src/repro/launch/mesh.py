"""Production mesh factories (assignment interface).

``make_production_mesh`` is the assignment-specified entry point; MiCS
refactors its data axis into (repl, shard) sub-axes via
``repro.core.topology.make_mics_mesh`` (same devices, same order).
"""

from __future__ import annotations

from repro.core.topology import (  # re-exported for launch scripts
    MiCSTopology,
    choose_partition_size,
    make_mics_mesh,
)
from repro.core.topology import make_production_mesh as _make_production_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) ('data','model') per pod; (2, 16, 16) ('pod','data','model')
    for the two-pod production configuration."""
    return _make_production_mesh(multi_pod=multi_pod)


def make_mics_topology(
    *, multi_pod: bool = False, partition_size: int | None = None,
    param_count: int | None = None, zero3: bool = False,
    tp: int | None = None, state_bytes_per_param: int | None = None,
):
    """Build the MiCS topology over the production mesh.

    partition_size defaults to the paper's heuristic (§5.1.1): the smallest
    group whose aggregate memory holds one model-state replica
    (state_bytes_per_param=2 models inference-only bf16 weights).
    zero3=True returns the ZeRO-3 baseline (partition = every data axis).
    tp < 16 factors the model axis into (dp2, tp), donating the remainder to
    data parallelism.
    """
    base = make_production_mesh(multi_pod=multi_pod)
    if partition_size is None:
        if param_count is None:
            raise ValueError("need partition_size or param_count")
        kw = {"model_axis": tp or 16}
        if state_bytes_per_param:
            kw["state_bytes_per_param"] = state_bytes_per_param
        partition_size = choose_partition_size(param_count, **kw)
    mesh = make_mics_mesh(base, partition_size, tp=tp)
    if zero3:
        part = ("pod", "repl", "shard") if multi_pod else ("repl", "shard")
        part = tuple(a for a in part if mesh.shape[a] > 1) or ("shard",)
        repl = tuple(a for a in ("dp2",) if mesh.shape[a] > 1)
    else:
        part = ("shard",)
        repl = tuple(a for a in ("pod", "repl", "dp2") if mesh.shape[a] > 1)
    return MiCSTopology(mesh, partition_axes=part, replication_axes=repl)
