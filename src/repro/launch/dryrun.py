"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh — (16,16) single pod and (2,16,16) two pods — and records
``memory_analysis()``, ``cost_analysis()`` and the trip-count-weighted
collective census (roofline inputs) to artifacts/dryrun/<cell>.json.

Communication policy: all collectives run through the CommEngine
(core/comm.py).  The manual flags (--gather-order, --quant-gather,
--prefetch, --prefetch-carry, ...) map 1:1 onto its
GatherPolicy/SyncPolicy; ``--policy auto`` instead hands the choice to the
link-model autotuner (core/autotune.py), which prints the ranked candidate
table for the ``--link-profile`` and records the chosen plan — plus a
predicted-vs-measured cross-check of the plan's per-stage wire bytes
against the compiled HLO census — into the cell artifact.

Memory: every cell records the memory planner's predicted per-device
footprint next to XLA's compiled ``memory_analysis()``
(plan-vs-compiled, core/memplan.py).  ``--hbm-budget-gb`` additionally
applies the paper's §3.1 rule — the minimal partition group whose
predicted footprint fits — when no --partition-size is pinned, and gates
``--policy auto`` candidates on feasibility (with the
``prefetch_carry='remat'`` mitigation joining the grid).  Training cells
additionally record the boundary scheduler's bucket plan
(``--boundary-schedule`` / ``--hop2-bucket-mb``, core/schedule.py) with
the link model's predicted exposed-vs-hidden hop-2 time and the measured
census evidence that hop-2 runs at bucket granularity interleaved with
boundary compute.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k \
      --mesh multi --policy auto --link-profile efa-100g
  python -m repro.launch.dryrun --all [--mesh both]
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config
from repro.core import memplan
from repro.core.autotune import (
    compare_census, cost_hop2_schedule, predict_traffic, resolve_config,
    resolve_scale,
)
from repro.core.comm import CommEngine, policies_from_config
from repro.core.linkmodel import get_profile
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state_shapes, make_batch_shapes,
)
from repro.core.schedule import plan_boundary
from repro.launch.mesh import make_mics_topology
from repro.models.build import active_param_count, build_model, exact_param_count
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze
from repro.runtime.serving import batch_axes_for, build_serve_steps, global_cache_shapes

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

TRAIN_MICRO_STEPS = 4  # paper §5.1.5 setup (s=4 gradient accumulation)


def input_specs(arch: str, shape: str, topo, model):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    spec = SHAPES[shape]
    seq, gb = spec["seq"], spec["global_batch"]
    if spec["kind"] == "train":
        return mics_train_inputs(model, seq, gb)
    if spec["kind"] == "prefill":
        return serve_prefill_inputs(model, topo, seq, gb)
    return serve_decode_inputs(model, topo, seq, gb)


def mics_train_inputs(model, seq, gb):
    return make_batch_shapes(model, gb, seq, TRAIN_MICRO_STEPS)


def serve_prefill_inputs(model, topo, seq, gb):
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds((gb, seq), jnp.int32)}
    cfg = model.cfg
    if cfg.family == "vlm":
        out["vision"] = sds((gb, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["audio"] = sds((gb, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return out


def serve_decode_inputs(model, topo, seq, gb):
    sds = jax.ShapeDtypeStruct
    baxes = batch_axes_for(topo, gb)
    caches, _ = global_cache_shapes(model, topo, gb, seq, baxes)
    return {
        "tokens": sds((gb, 1), jnp.int32),
        "pos": sds((), jnp.int32),
        "caches": caches,
        "seeds": sds((gb,), jnp.int32),
        "temps": sds((gb,), jnp.float32),
        "row_mask": sds((gb,), jnp.bool_),
    }


def run_cell(arch: str, shape: str, multi_pod: bool, mcfg: MiCSConfig,
             out_dir: pathlib.Path = ART, tag: str = "",
             partition_size: int | None = None, zero3: bool = False,
             tp: int | None = None, serve_footprint: bool = False) -> dict:
    cfg = get_config(arch)
    spec = SHAPES[shape]
    t0 = time.time()
    n_params = exact_param_count(cfg)
    scale_plan = None
    if mcfg.hbm_budget_gb is not None and partition_size is None \
            and not zero3:
        # the paper's §3.1 rule, analytically: minimal partition group
        # whose predicted per-device footprint fits the budget
        # (core/memplan.py); the chosen prefetch carry rides along.
        sizing_model = build_model(cfg, tp=tp or 16)
        # the partition group is carved from the 16-wide data axis; pods
        # and the dp2 leftover of a narrow tp replicate on top of it
        extra_repl = (2 if multi_pod else 1) * (16 // (tp or 16))
        partition_size, carry, scale_plan = resolve_scale(
            sizing_model, mcfg, data_extent=16,
            mode="train" if spec["kind"] == "train" else "serve",
            extra_replication=extra_repl)
        if carry == "host":   # third strategy: stored carry streamed to host
            mcfg = dataclasses.replace(mcfg, prefetch_carry="stored",
                                       carry_offload="host")
        else:
            mcfg = dataclasses.replace(mcfg, prefetch_carry=carry)
        print(f"memplan: p={partition_size} carry={carry} "
              f"({scale_plan.total_gb:.2f} GiB predicted vs budget "
              f"{mcfg.hbm_budget_gb:g} GiB)", flush=True)
    topo = make_mics_topology(
        multi_pod=multi_pod, param_count=n_params,
        partition_size=partition_size, zero3=zero3, tp=tp,
        state_bytes_per_param=2 if serve_footprint else None)
    model = build_model(cfg, tp=topo.model_size)

    mcfg, plan = resolve_config(
        mcfg, model, topo,
        mode="train" if spec["kind"] == "train" else "serve")
    if plan is not None:
        print(plan.table(), flush=True)
    engine = CommEngine.from_config(topo, mcfg)

    record = {
        "arch": cfg.name, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": spec["kind"], "seq": spec["seq"],
        "global_batch": spec["global_batch"],
        "zero3": zero3,
        "tp": topo.model_size,
        "partition_axes": list(topo.partition_axes),
        "partition_size": topo.partition_size,
        "replication_degree": topo.replication_degree,
        "params": n_params,
        "active_params": active_param_count(cfg),
        "micro_steps": TRAIN_MICRO_STEPS if spec["kind"] == "train" else 1,
        "mics": dataclasses.asdict(mcfg) | {
            "gather_dtype": jnp.dtype(mcfg.gather_dtype).name,
            "link_profile": str(getattr(mcfg.link_profile, "name",
                                        mcfg.link_profile)),
        },
        "comm": engine.describe(),
        "autotune": plan.describe() if plan is not None else None,
        "tag": tag,
    }

    # boundary scheduler: the static bucket plan + the link model's
    # hidden-vs-exposed hop-2 time for it (core/schedule.py, autotune).
    if spec["kind"] == "train":
        bplan = plan_boundary(model, topo, mode=mcfg.boundary_schedule,
                              bucket_mb=mcfg.hop2_bucket_mb,
                              clip_mode=mcfg.clip_mode)
        profile = get_profile(mcfg.link_profile)  # name or instance
        record["boundary"] = bplan.describe() | {
            "predicted": cost_hop2_schedule(
                model, topo, profile, engine.sync_policy,
                boundary=mcfg.boundary_schedule,
                bucket_mb=mcfg.hop2_bucket_mb,
                clip_mode=mcfg.clip_mode),
            "link_profile": profile.name,
        }

    serve_dtype = jnp.bfloat16 if serve_footprint else jnp.float32
    if mcfg.quant_gather:
        from repro.core.quant import BLOCK

        serve_params = {
            name: {
                "q": jax.ShapeDtypeStruct(shape, jnp.int8),
                "s": jax.ShapeDtypeStruct(
                    (*shape[:-1], shape[-1] // BLOCK), jnp.float32),
            }
            for name, shape in model.global_flat_shapes().items()
        }
        record["serve_param_dtype"] = "int8+blockscale"
    else:
        serve_params = {
            name: jax.ShapeDtypeStruct(shape, serve_dtype)
            for name, shape in model.global_flat_shapes().items()
        }
        record["serve_param_dtype"] = str(serve_dtype.__name__)

    if spec["kind"] == "train":
        step = build_train_step(model, topo, mcfg,
                                OptConfig(total_steps=1000))
        state = init_state_shapes(model, offload_opt=mcfg.offload_opt)
        batch = mics_train_inputs(model, spec["seq"], spec["global_batch"])
        lowered = step.lower(state, batch)
    elif spec["kind"] == "prefill":
        prefill_fn, _ = build_serve_steps(
            model, topo, mcfg, cache_len=spec["seq"],
            batch_axes=batch_axes_for(topo, spec["global_batch"]))
        lowered = prefill_fn.lower(
            serve_params,
            serve_prefill_inputs(model, topo, spec["seq"], spec["global_batch"]))
    else:  # decode
        baxes = batch_axes_for(topo, spec["global_batch"])
        _, decode_fn = build_serve_steps(
            model, topo, mcfg, cache_len=spec["seq"], batch_axes=baxes)
        inp = serve_decode_inputs(model, topo, spec["seq"], spec["global_batch"])
        lowered = decode_fn.lower(
            serve_params, inp["caches"], inp["tokens"], inp["pos"],
            inp["seeds"], inp["temps"], inp["row_mask"])

    record["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 1)

    ma = compiled.memory_analysis()
    if ma is not None:
        record["memory_analysis"] = {
            k: getattr(ma, k)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(ma, k)
        }
    # memory planner: predicted per-device footprint vs the compiled
    # analysis (plan-vs-compiled, core/memplan.py) for every cell.
    micro = record["micro_steps"]
    lb = max((spec["global_batch"] // micro) // topo.data_parallel_size, 0)
    gp_, sp_ = policies_from_config(mcfg)
    mem_plan = memplan.predict_footprint(
        model, topo, gp_, sp_, micro_steps=micro,
        mode="train" if spec["kind"] == "train" else "serve",
        local_batch=lb, seq=spec["seq"],
        boundary=mcfg.boundary_schedule,
        hop2_bucket_mb=mcfg.hop2_bucket_mb,
        offload_opt=mcfg.offload_opt)
    record["memplan"] = mem_plan.describe()
    record["memplan"]["hbm_budget_gb"] = mcfg.hbm_budget_gb
    if scale_plan is not None:
        record["memplan"]["resolved_partition_size"] = topo.partition_size
    if ma is not None and hasattr(ma, "temp_size_in_bytes"):
        meas = (ma.argument_size_in_bytes + ma.temp_size_in_bytes)
        record["memplan"]["compiled_total_bytes"] = meas
        record["memplan"]["plan_vs_compiled_ratio"] = (
            mem_plan.total_bytes / meas if meas else None)
    from repro.compat import cost_analysis

    ca = cost_analysis(compiled)
    # NB: XLA's cost analysis visits while bodies ONCE (no trip weighting);
    # kept raw for reference.  The roofline uses the trip-weighted stats.
    record["cost_analysis_raw"] = {
        k: ca[k] for k in ("flops", "bytes accessed", "transcendentals")
        if k in ca
    }

    mesh_shape = dict(zip(topo.mesh.axis_names,
                          topo.mesh.devices.shape))
    record["stats"] = analyze(
        compiled.as_text(), mesh_shape,
        partition_axes=topo.partition_axes,
        replication_axes=topo.replication_axes)
    # model-vs-census cross-check: the analytical per-stage wire bytes of
    # the ACTIVE policy against the measured census (upcast=True because
    # the dry-run compiles for host devices, where XLA widens bf16
    # collectives to f32 on the wire).
    predicted = predict_traffic(
        model, topo, engine.gather_policy, engine.sync_policy,
        micro_steps=record["micro_steps"],
        mode="train" if spec["kind"] == "train" else "serve",
        upcast_float_collectives=True)
    record["autotune_cross_check"] = compare_census(
        predicted["by_stage"], record["stats"]["by_stage"])
    # boundary cross-check: the compiled step must show hop-2 at the plan's
    # bucket granularity (measured census vs the static plan).
    if "boundary" in record:
        measured_b = record["stats"]["boundary"]
        record["boundary"]["measured"] = measured_b
        record["boundary"]["bucket_count_match"] = (
            topo.replication_degree == 1
            or measured_b["hop2_ops"]
            == record["boundary"]["n_hop2_collectives"])
    record["total_s"] = round(time.time() - t0, 1)

    out_dir.mkdir(parents=True, exist_ok=True)
    stem = f"{cfg.name}__{shape}__{record['mesh']}" + (f"__{tag}" if tag else "")
    (out_dir / f"{stem}.json").write_text(json.dumps(record, indent=1))
    return record


def main():
    global TRAIN_MICRO_STEPS
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--policy", choices=["manual", "auto"], default="manual",
                    help="'auto' = rank GatherPolicy/SyncPolicy candidates "
                         "over --link-profile (core/autotune.py), print the "
                         "ranked table and run the winner; 'manual' = use "
                         "the flags below verbatim")
    ap.add_argument("--link-profile", default="v5e",
                    help="link-bandwidth table for --policy auto: v5e, "
                         "efa-100g, efa-400g, or a registered custom "
                         "profile (core/linkmodel.py)")
    ap.add_argument("--hierarchical", type=int, default=1,
                    help="1 = staged hierarchical gathers (GatherPolicy "
                         "topology from --gather-order), 0 = one flat "
                         "collective over the partition group")
    ap.add_argument("--gather-order", default="inner_first",
                    choices=["inner_first", "outer_first"],
                    help="staged-gather order: inner_first = reorder-free "
                         "2-stage, outer_first = paper-faithful 3-stage")
    ap.add_argument("--sync-mode", default="2hop",
                    choices=["2hop", "allreduce_slice"],
                    help="SyncPolicy: 2-hop gradient sync vs the Fig-14 "
                         "all-reduce+slice ablation")
    ap.add_argument("--partition-size", type=int, default=0)
    ap.add_argument("--zero3", action="store_true")
    ap.add_argument("--bf16-scores", action="store_true")
    ap.add_argument("--quant-gather", action="store_true",
                    help="int8 block-quantized wire/serving-weight gathers "
                         "(GatherPolicy wire_dtype='int8'; under --policy "
                         "auto this *permits* rather than forces int8)")
    ap.add_argument("--hop1-wire-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="hop-1 gradient reduce-scatter wire: fp32 = exact "
                         "staged adjoint, int8 = ZeRO++-qgZ per-stage "
                         "block-quantized reduce-scatter (fp32 inter-stage "
                         "accumulation; under --policy auto this permits "
                         "rather than forces the int8 hop-1)")
    ap.add_argument("--compress-hop2", default="off",
                    choices=["off", "bf16", "int8"],
                    help="hop-2 replication-group all-reduce wire: bf16 "
                         "cast or the int8 quantized decompress leg "
                         "(core/schedule.py); 'off' = fp32")
    ap.add_argument("--prefetch", type=int, default=1,
                    help="1 = double-buffered lookahead gathers (layer i+1 "
                         "gathered during layer i's compute; the default), "
                         "0 = serial reference schedule")
    ap.add_argument("--prefetch-carry", default="stored",
                    choices=["stored", "remat"],
                    help="backward residual of the prefetch schedule: "
                         "'stored' carries the gathered buffer (no backward "
                         "re-gather, O(layers x flat_len) HBM), 'remat' "
                         "re-issues the gather in the backward (one extra "
                         "all-gather per layer, O(layers x shard) HBM — the "
                         "memory planner's mitigation knob)")
    ap.add_argument("--carry-offload", default="none",
                    choices=["none", "host"],
                    help="third residual strategy: stream the stored carry "
                         "through host memory over the link model's host "
                         "tier (d2h forward / h2d backward, "
                         "core/hostoffload.py) — no backward re-gather and "
                         "no O(layers x flat_len) HBM residency")
    ap.add_argument("--offload-opt", action="store_true",
                    help="host-offload the AdamW m/v shards around the "
                         "boundary update: the on-device state keeps only "
                         "params+step (memplan subtracts 8 bytes/element)")
    ap.add_argument("--clip-mode", default="exact",
                    choices=["exact", "approx"],
                    help="boundary clip: 'exact' = barriered global-norm "
                         "reference, 'approx' = bucket k's AdamW pipelined "
                         "under bucket k+1's hop-2 with a one-bucket-stale "
                         "clip factor (bucketed schedule only; under "
                         "--policy auto this permits rather than forces)")
    ap.add_argument("--hbm-budget-gb", type=float, default=0,
                    help="per-device HBM budget in GiB for the memory "
                         "planner (core/memplan.py): picks the minimal "
                         "partition group that fits (paper §3.1) when no "
                         "--partition-size is pinned, gates --policy auto "
                         "candidates, and reports plan-vs-compiled "
                         "footprints per cell; 0 = no budget")
    ap.add_argument("--boundary-schedule", default="bucketed",
                    choices=["serial", "bucketed"],
                    help="gradient-accumulation boundary: 'bucketed' "
                         "software-pipelines hop-2 buckets against the "
                         "norm/decompress compute (core/schedule.py), "
                         "'serial' is the monolithic reference")
    ap.add_argument("--hop2-bucket-mb", type=float, default=32.0,
                    help="fixed-byte bucket size of the hop-2 pipeline "
                         "(fp32 gradient megabytes; under --policy auto "
                         "the tuner ranks this axis itself)")
    ap.add_argument("--mlstm-chunk", type=int, default=0)
    ap.add_argument("--tp", type=int, default=0)
    ap.add_argument("--serve-footprint", action="store_true",
                    help="pick p from the inference memory footprint")
    ap.add_argument("--micro-steps", type=int, default=TRAIN_MICRO_STEPS)
    args = ap.parse_args()
    TRAIN_MICRO_STEPS = args.micro_steps

    mcfg = MiCSConfig(
        micro_steps=TRAIN_MICRO_STEPS,
        hierarchical=bool(args.hierarchical),
        gather_order=args.gather_order,
        sync_mode=args.sync_mode,
        scores_bf16=args.bf16_scores,
        mlstm_chunk=args.mlstm_chunk,
        quant_gather=args.quant_gather,
        hop1_wire_dtype=args.hop1_wire_dtype,
        compress_hop2=(False if args.compress_hop2 == "off"
                       else args.compress_hop2),
        prefetch=bool(args.prefetch),
        prefetch_carry=args.prefetch_carry,
        carry_offload=args.carry_offload,
        offload_opt=args.offload_opt,
        clip_mode=args.clip_mode,
        policy=args.policy,
        link_profile=args.link_profile,
        boundary_schedule=args.boundary_schedule,
        hop2_bucket_mb=args.hop2_bucket_mb,
        hbm_budget_gb=args.hbm_budget_gb or None,
    )

    todo = []
    if args.all:
        for cfg, shape, spec, skip in cells():
            todo.append((cfg.name, shape))
    else:
        todo.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in todo:
        for multi in meshes:
            label = f"{arch} x {shape} x {'multi' if multi else 'single'}"
            try:
                rec = run_cell(arch, shape, multi, mcfg, tag=args.tag,
                               partition_size=args.partition_size or None,
                               zero3=args.zero3, tp=args.tp or None,
                               serve_footprint=args.serve_footprint)
                pf = rec["stats"]["prefetch"]
                msg = (f"OK   {label}: compile={rec['compile_s']}s "
                       f"flops={rec['stats']['dot_flops']:.3e} "
                       f"wire={rec['stats']['total_wire_bytes']:.3e}B "
                       f"carried_gathers={pf['carried_all_gathers']}")
                mp = rec.get("memplan", {})
                if mp:
                    msg += f" mem={mp['total_gib']:.2f}GiB"
                    if mp.get("plan_vs_compiled_ratio"):
                        msg += (" (plan/compiled="
                                f"{mp['plan_vs_compiled_ratio']:.2f})")
                if "boundary" in rec:
                    bd, pr = rec["boundary"], rec["boundary"]["predicted"]
                    msg += (f" hop2[{bd['mode']}x{bd['n_hop2_collectives']}]="
                            f"{pr['t_exposed_s']*1e6:.0f}us exposed"
                            f"/{pr['t_total_s']*1e6:.0f}us total"
                            f" interleaved="
                            f"{bd['measured']['interleaved']}")
                print(msg, flush=True)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"FAIL {label}: {type(e).__name__}: {str(e)[:400]}",
                      flush=True)
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
