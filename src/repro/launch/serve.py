"""Serving launcher: prefill a batch of prompts, then greedy-decode.

Weight gathers run through the same CommEngine as training (decode
re-gathers every layer each step); ``--policy auto`` lets the link-model
autotuner pick the gather topology/wire dtype for ``--link-profile``
(serving mode: forward gathers only, so int8 wire wins once
``--quant-gather`` permits it) and prints the ranked serve table —
candidates now carry the decode axes too: KV dtype (up to the
``--kv-dtype`` numerics ceiling), block size and planner-derived
residency, priced by ``cost_decode_step`` at ``--arrival-rate``.

Runnable on this host with reduced configs:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --prompt-len 16 --decode-tokens 8 --policy auto --arrival-rate 0.5
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.autotune import resolve_config
from repro.core.mics import MiCSConfig, init_state
from repro.core.quant import quantize_state
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.runtime.serving import build_serve_steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--policy", choices=["manual", "auto"], default="manual",
                    help="'auto' picks the gather policy from --link-profile")
    ap.add_argument("--link-profile", default="v5e")
    ap.add_argument("--quant-gather", action="store_true",
                    help="int8 wire gathers (a permission under --policy "
                         "auto)")
    ap.add_argument("--prefetch", type=int, default=1,
                    help="1 = double-buffered lookahead gathers, 0 = serial")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="offered load (requests/s/replica) the serve "
                         "autotuner prices decode policies against; 0 = "
                         "throughput-saturated")
    ap.add_argument("--kv-dtype", choices=["fp32", "bf16", "int8"],
                    default="bf16",
                    help="KV-cache storage dtype; under --policy auto this "
                         "is the numerics ceiling the planner may narrow to")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="paged-KV block size in token positions")
    ap.add_argument("--max-resident-requests", type=int, default=0,
                    help="cap on concurrently resident requests per "
                         "replica; 0 = planner-derived from the HBM budget")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    topo = MiCSTopology(make_host_mesh(1, 1, 1, 1))
    model = build_model(cfg, tp=topo.model_size)
    state = init_state(model, topo)
    params = state["params"]

    cache_len = args.prompt_len + args.decode_tokens
    mcfg = MiCSConfig(policy=args.policy, link_profile=args.link_profile,
                      quant_gather=args.quant_gather,
                      prefetch=bool(args.prefetch),
                      kv_dtype=args.kv_dtype,
                      kv_block_size=args.kv_block_size,
                      max_resident_requests=args.max_resident_requests)
    mcfg, plan = resolve_config(mcfg, model, topo, mode="serve",
                                seq=cache_len,
                                arrival_rate=args.arrival_rate)
    if plan is not None:
        print(plan.table())
        print(f"serve policy: kv_dtype={mcfg.kv_dtype} "
              f"kv_block_size={mcfg.kv_block_size} "
              f"max_resident_requests={mcfg.max_resident_requests}")
    if mcfg.quant_gather:  # deployment-time int8 conversion (quant.py)
        params = quantize_state(params)
    prefill_fn, decode_fn = build_serve_steps(
        model, topo, mcfg, cache_len)

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["audio"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.time()
    logits, caches = prefill_fn(params, batch)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    # greedy continuation
    tok = jnp.argmax(jnp.asarray(logits[:, -1:]), axis=-1).astype(jnp.int32)
    outs = []
    t0 = time.time()
    for i in range(args.decode_tokens):
        pos = jnp.int32(args.prompt_len + i)
        logits, tok, caches = decode_fn(params, caches, tok, pos)
        tok = tok.astype(jnp.int32)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    print(f"decoded {args.decode_tokens} tokens x{args.batch} in {dt:.2f}s "
          f"({args.decode_tokens*args.batch/dt:.1f} tok/s)")
    print("sampled ids:", np.stack(outs, axis=1).tolist())


if __name__ == "__main__":
    main()
