"""Serving launcher: fixed-batch greedy decode or resilient continuous
batching.

Weight gathers run through the same CommEngine as training (decode
re-gathers every layer each step); ``--policy auto`` lets the link-model
autotuner pick the gather topology/wire dtype for ``--link-profile``
(serving mode: forward gathers only, so int8 wire wins once
``--quant-gather`` permits it) and prints the ranked serve table —
candidates now carry the decode axes too: KV dtype (up to the
``--kv-dtype`` numerics ceiling), block size and planner-derived
residency, priced by ``cost_decode_step`` at ``--arrival-rate``.

``--continuous`` switches to the fault-tolerant continuous-batching
engine (runtime/resilient.py): a seeded request trace through the paged
scheduler with deadline-aware admission (``--deadline-ms``, mapped to
scheduler ticks via the measured warm step time), a bounded queue
(``--max-queue``), graceful degradation (``--shed-policy degrade``) and a
scripted fault timeline (``--fault-plan "preempt@20x4,grow@40x4,crash@60"``
— see ``core/faults.FaultPlan.parse``; world-change faults need a
multi-device mesh, e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  At exit the
request-lifecycle ledger is printed: where every submission ended up
(completed / shed-with-reason / replayed), latency and queue-depth
percentiles in ticks, world changes and ladder transitions.

Runnable on this host with reduced configs:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --prompt-len 16 --decode-tokens 8 --policy auto --arrival-rate 0.5
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --continuous --requests 8 --max-queue 6 --deadline-ms 2000 \
      --shed-policy degrade --fault-plan crash@6
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.autotune import resolve_config
from repro.core.faults import FaultPlan
from repro.core.mics import MiCSConfig, init_state
from repro.core.quant import quantize_state
from repro.core.topology import (
    MiCSTopology, elastic_host_topology, make_host_mesh,
)
from repro.models.build import build_model
from repro.runtime.serving import build_serve_steps


def serve_continuous(cfg, mcfg, args) -> None:
    """The resilient continuous-batching path (runtime/resilient.py)."""
    from repro.runtime.batching import DegradationLadder, Request
    from repro.runtime.resilient import ResilientServeLoop, ServeLoopConfig

    # the mesh spans every ambient device (dp = world, tp = 1), so scripted
    # world-change faults have devices to lose
    n_dev = len(jax.devices())
    topo = elastic_host_topology(n_dev, 1, tp=1)
    model = build_model(cfg, tp=1)

    block_size = mcfg.kv_block_size
    positions = args.prompt_len + args.decode_tokens
    max_blocks = -(-positions // block_size)
    sc = ServeLoopConfig(
        slots_local=4, nb_local=4 * max_blocks + 1, block_size=block_size,
        max_blocks=max_blocks, chunk=min(8, args.prompt_len), top_k=8,
        reserve="full", max_queue=args.max_queue, backoff_base=2,
        arrival_rate=args.arrival_rate)
    ladder = None
    if args.shed_policy == "degrade":
        ladder = DegradationLadder(
            [{"kv_dtype": mcfg.kv_dtype, "resident_cap": 0,
              "label": "configured"},
             {"kv_dtype": mcfg.kv_dtype, "resident_cap": 2,
              "label": "tightened"}],
            high_water=0.75, low_water=0.25, dwell=4)
    fault = FaultPlan.parse(args.fault_plan) if args.fault_plan else None
    loop = ResilientServeLoop(model, topo, mcfg, sc,
                              fault_injector=fault, ladder=ladder)

    # warm the decode step and measure it: the tick -> wall-time price that
    # turns --deadline-ms into a scheduler-tick deadline
    B = loop.batcher.batch
    zero = lambda s, d: jnp.zeros(s, d)
    for _ in range(3):
        t0 = time.time()
        tok, _lg, caches = loop.step_one(
            loop.params, loop.caches, zero((B, 1), jnp.int32),
            zero((B,), jnp.int32), zero((B,), jnp.int32),
            zero((B, max_blocks), jnp.int32), zero((B,), jnp.int32),
            zero((B,), jnp.float32))
        jax.block_until_ready(tok)
        loop.caches = caches
        tick_s = time.time() - t0
    deadline_ticks = (max(1, int(args.deadline_ms / 1e3 / tick_s))
                      if args.deadline_ms > 0 else None)
    print(f"warm decode step: {tick_s*1e3:.1f} ms/tick"
          + (f" -> deadline {deadline_ticks} ticks" if deadline_ticks
             else ""))

    rng = np.random.default_rng(0)
    reqs = [Request(
        rid=i,
        prompt=rng.integers(1, cfg.vocab, args.prompt_len).astype(int)
        .tolist(),
        max_new_tokens=args.decode_tokens, temperature=0.7, seed=1000 + i,
        deadline_tick=deadline_ticks)
        for i in range(args.requests)]
    arrivals = ([int(i / args.arrival_rate) for i in range(len(reqs))]
                if args.arrival_rate > 0 else None)

    t0 = time.time()
    rep = loop.run(reqs, arrivals)
    dt = time.time() - t0
    tokens = sum(len(t) for t in rep["completions"].values())
    print(f"served {rep['ledger']['completed']}/{len(reqs)} requests, "
          f"{tokens} tokens in {dt:.2f}s ({tokens/dt:.1f} tok/s), "
          f"{rep['ticks']} ticks on a {rep['world']}-device world")
    print("lifecycle ledger:", json.dumps(rep["ledger"], indent=1))
    if rep["world_changes"]:
        print("world changes:", json.dumps(rep["world_changes"], indent=1,
                                           default=str))
    if rep["ladder_transitions"]:
        print("ladder transitions:",
              json.dumps(rep["ladder_transitions"], indent=1))
    if rep["shed"]:
        print("shed:", rep["shed"])
    assert rep["ledger"]["accounted"], "lifecycle ledger lost a request"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--policy", choices=["manual", "auto"], default="manual",
                    help="'auto' picks the gather policy from --link-profile")
    ap.add_argument("--link-profile", default="v5e")
    ap.add_argument("--quant-gather", action="store_true",
                    help="int8 wire gathers (a permission under --policy "
                         "auto)")
    ap.add_argument("--prefetch", type=int, default=1,
                    help="1 = double-buffered lookahead gathers, 0 = serial")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="offered load (requests/s/replica) the serve "
                         "autotuner prices decode policies against; 0 = "
                         "throughput-saturated")
    ap.add_argument("--kv-dtype", choices=["fp32", "bf16", "int8"],
                    default="bf16",
                    help="KV-cache storage dtype; under --policy auto this "
                         "is the numerics ceiling the planner may narrow to")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="paged-KV block size in token positions")
    ap.add_argument("--max-resident-requests", type=int, default=0,
                    help="cap on concurrently resident requests per "
                         "replica; 0 = planner-derived from the HBM budget")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching through the resilient serve "
                         "loop instead of the fixed-batch path")
    ap.add_argument("--requests", type=int, default=8,
                    help="[--continuous] synthetic requests to serve")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="[--continuous] per-request completion SLO; "
                         "mapped to scheduler ticks via the measured warm "
                         "step time (0 = no deadline)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="[--continuous] waiting-queue bound; submissions "
                         "beyond it are shed with reason queue_full "
                         "(0 = unbounded)")
    ap.add_argument("--shed-policy", choices=["reject", "degrade"],
                    default="reject",
                    help="[--continuous] 'reject' sheds typed on overload; "
                         "'degrade' also walks the degradation ladder "
                         "(residency tightening) under queue pressure")
    ap.add_argument("--fault-plan", default="",
                    help="[--continuous] scripted fault timeline, e.g. "
                         "'preempt@20x4,grow@40x4,crash@60' "
                         "(kind@tick[xN]; kinds: preempt notice grow slow "
                         "evict crash)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    topo = MiCSTopology(make_host_mesh(1, 1, 1, 1))
    model = build_model(cfg, tp=topo.model_size)
    state = init_state(model, topo)
    params = state["params"]

    cache_len = args.prompt_len + args.decode_tokens
    mcfg = MiCSConfig(policy=args.policy, link_profile=args.link_profile,
                      quant_gather=args.quant_gather,
                      prefetch=bool(args.prefetch),
                      kv_dtype=args.kv_dtype,
                      kv_block_size=args.kv_block_size,
                      max_resident_requests=args.max_resident_requests)
    mcfg, plan = resolve_config(mcfg, model, topo, mode="serve",
                                seq=cache_len,
                                arrival_rate=args.arrival_rate)
    if plan is not None:
        print(plan.table())
        print(f"serve policy: kv_dtype={mcfg.kv_dtype} "
              f"kv_block_size={mcfg.kv_block_size} "
              f"max_resident_requests={mcfg.max_resident_requests}")
    if args.continuous:
        if mcfg.quant_gather:
            # the resilient loop's params provider reloads fp weights on
            # every world change; int8 wire stays a fixed-path feature
            mcfg = dataclasses.replace(mcfg, quant_gather=False)
        serve_continuous(cfg, mcfg, args)
        return
    if mcfg.quant_gather:  # deployment-time int8 conversion (quant.py)
        params = quantize_state(params)
    prefill_fn, decode_fn = build_serve_steps(
        model, topo, mcfg, cache_len)

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["audio"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.time()
    logits, caches = prefill_fn(params, batch)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    # greedy continuation
    tok = jnp.argmax(jnp.asarray(logits[:, -1:]), axis=-1).astype(jnp.int32)
    outs = []
    t0 = time.time()
    for i in range(args.decode_tokens):
        pos = jnp.int32(args.prompt_len + i)
        logits, tok, caches = decode_fn(params, caches, tok, pos)
        tok = tok.astype(jnp.int32)
        outs.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    print(f"decoded {args.decode_tokens} tokens x{args.batch} in {dt:.2f}s "
          f"({args.decode_tokens*args.batch/dt:.1f} tok/s)")
    print("sampled ids:", np.stack(outs, axis=1).tolist())


if __name__ == "__main__":
    main()
