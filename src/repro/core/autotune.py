"""Bandwidth-aware GatherPolicy/SyncPolicy autotuner (the paper's §3-§4
decision procedure, run analytically over a :mod:`repro.core.linkmodel`
profile).

PR 1 centralized every collective behind the CommEngine and taught the HLO
census (``roofline/hlo_stats.analyze``) to attribute wire bytes to policy
stages (``param_gather.{flat,inner,outer}``, ``grad_rs.*``, ``hop2``).  This
module closes the loop: given a model, a MiCS topology and a link profile it

1. **predicts** the same per-stage census analytically
   (:func:`predict_traffic` — per-pool flat-buffer sizes x the schedule's
   collective event counts x ring-algorithm byte fractions, in the census's
   exact units so model and measurement are directly comparable), and
2. **costs** every candidate policy with the α-β model over the profile's
   two link tiers (:func:`rank_policies` — topology x inner factor x wire
   dtype x hop-2 compression x boundary schedule: the hop-2 stage is costed
   per bucket size as hidden-vs-exposed pipeline time,
   :func:`cost_hop2_schedule`, so ``hop2_bucket_mb`` is a ranked candidate
   axis), returning a ranked :class:`Plan`, and
3. **resolves** ``MiCSConfig(policy="auto")`` into the concrete winning
   config (:func:`resolve_config`), which is what ``build_train_step``,
   ``build_serve_steps`` and ``launch/dryrun.py`` call, and
4. **gates on memory** (``hbm_budget_gb``): every candidate is priced per
   device by the analytical HBM footprint model (core/memplan.py, the
   same predicted-vs-compiled discipline as the wire-byte census),
   infeasible candidates are filtered from selection, the
   ``prefetch_carry='remat'`` mitigation joins the grid, and
   :func:`resolve_scale` implements the paper's §3.1 rule — the minimal
   partition-group size whose aggregate memory holds the model states.

The per-stage byte identity worth knowing: a staged gather moves exactly the
same per-participant total as the flat gather —

    M(i-1)/p + M(o-1)/o  ==  M(p-1)/p        (p = i*o)

— hierarchical staging never saves bytes, it *moves them between tiers*
(only M(o-1)/p of an outer-first gather crosses the slow tier, vs the whole
M(p-1)/p of a flat ring that bottlenecks on it).  That is the entire MiCS
§3.3 argument, and why the ranking depends on the link table.

Numerics policy: the tuner ranks lossy candidates (int8 gather wire,
bf16/int8 hop-2, int8 qgZ hop-1) alongside lossless ones, but only
*selects* them when the config opted into that exact mechanism
(``quant_gather=True`` — int8 *weight* wire, whose gradient adjoint stays
exact; ``compress_hop2=True``/``"bf16"``/``"int8"`` — the hop-2 wire, with
``"int8"`` also permitting the milder bf16; ``hop1_wire_dtype="int8"`` —
the lossy qgZ gradient wire).  Permissions are per-mechanism on purpose:
``policy="auto"`` never silently changes training numerics beyond what the
flag the user set already meant.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import memplan as M
from repro.core.comm import (
    WIRE_DTYPES, GatherPolicy, SyncPolicy, policies_from_config,
)
from repro.core.linkmodel import GIB, LinkProfile, get_profile
from repro.core.quant import BLOCK
from repro.core.schedule import plan_boundary
from repro.core.topology import MiCSTopology, default_hierarchy_inner

# int8 collectives ship two payloads per stage (q int8 + one f32 absmax
# scale per BLOCK elements) — ~1.03 bytes/element on the wire.
INT8_WIRE_BYTES = 1.0 + 4.0 / BLOCK
# census bytes-per-element on the wire, by wire dtype.
_WIRE_BYTES = {"fp32": 4.0, "bf16": 2.0, "int8": INT8_WIRE_BYTES}
# gradient reduce-scatter element bytes under the uncompressed hop-1 wire
# (hop1_wire_dtype='fp32'): the adjoint runs in the gather wire dtype for
# float wires and in fp32 for int8 gathers (straight-through — the int8
# *gather* never quantizes its cotangent; that is qgZ's job, below).
_GRAD_BYTES_HOP1_FP32 = {"fp32": 4.0, "bf16": 2.0, "int8": 4.0}


def grad_wire_bytes(gather_wire: str, hop1_wire: str) -> float:
    """Adjoint reduce-scatter bytes/element for (gather wire, hop-1 wire).

    ``hop1_wire='fp32'`` is the legacy uncompressed adjoint (dtype follows
    the gather); ``'bf16'`` narrows the cotangent; ``'int8'`` is the qgZ
    per-stage block-quantized reduce-scatter — int8 payload + f32 scale
    traffic on every hop regardless of the forward wire (this is what flips
    the int8 *weight*-gather ranking in training: its fp32 straight-through
    adjoint stops dominating the gradient bytes)."""
    if hop1_wire == "int8":
        return INT8_WIRE_BYTES
    if hop1_wire == "bf16":
        return 2.0
    return _GRAD_BYTES_HOP1_FP32[gather_wire]


# Per-element HBM bytes of one qgZ stage's quantize + dequantize-accumulate
# (read fp32, write int8+scales; read int8+scales, accumulate fp32) — the
# compute overhead int8 hop-1 pays per stage on top of its wire time.
QGZ_COMPUTE_BYTES_PER_ELEM = 10.0


# ---------------------------------------------------------------------------
# stage structure: (label, group size, positions, wire fraction) per stage
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One ring stage of a gather policy over the partition group.

    ``wire_frac``: per-participant wire bytes of this stage as a fraction of
    the full gathered buffer M (census convention).  ``positions`` is one
    representative replica group in partition-group linear coordinates
    (slowest axis major) — what the link tier is decided from.
    """

    label: str                 # 'flat' | 'inner' | 'outer'
    group_size: int
    positions: tuple[int, ...]
    wire_frac: float


def _partition_axis_sizes(topo: MiCSTopology) -> list[int]:
    return [topo.axis_size(a) for a in topo.partition_axes]


def resolve_inner(topo: MiCSTopology, inner: int | None) -> tuple[int, int]:
    """(outer, inner) factorization a candidate actually runs with."""
    p = topo.partition_size
    sizes = _partition_axis_sizes(topo)
    if len(sizes) > 1:
        return sizes[0], p // sizes[0]
    if inner is None:
        inner = default_hierarchy_inner(p)
    if p % inner:
        raise ValueError(f"inner {inner} does not divide p={p}")
    return p // inner, inner


def island_size(topo: MiCSTopology, profile: LinkProfile) -> int:
    """Fast-tier island extent in partition-group linear coordinates.

    Single-axis groups are contiguous ranks sharing the profile's node;
    multi-axis groups additionally cross the slowest mesh axis (pod) at
    every ``p / size(slowest)`` positions, whichever boundary comes first.
    """
    p = topo.partition_size
    sizes = _partition_axis_sizes(topo)
    if len(sizes) > 1:
        return min(profile.node_size, p // sizes[0])
    return min(profile.node_size, p)


def _hop2_tier(topo: MiCSTopology, profile: LinkProfile) -> str:
    """Link tier of the replication-group all-reduce.

    Replication peers are same-local-rank devices of *different* partition
    groups: stride ``p`` apart along the data axis (and across pods when a
    pod axis replicates).  Unlike partition stages, their coordinates live
    in the data-axis space, where the fast island is the profile's full
    node_size.
    """
    from repro.core.topology import POD_AXIS

    if POD_AXIS in topo.replication_axes \
            and topo.axis_size(POD_AXIS) > 1:
        return "inter"
    p = topo.partition_size
    positions = range(0, topo.replication_degree * p, p)
    return profile.group_tier(positions)


def gather_stages(topology: str, topo: MiCSTopology,
                  inner: int | None = None) -> list[StageSpec]:
    """Ring stages of one full-buffer gather under ``topology``.

    The same (label -> wire_frac) set describes the adjoint reduce-scatter:
    the stages run in reverse with identical per-stage wire bytes.
    """
    p = topo.partition_size
    if p == 1:
        return []
    if topology == "flat":
        return [StageSpec("flat", p, tuple(range(p)), (p - 1) / p)]
    outer, inner_f = resolve_inner(topo, inner)
    if outer == 1 or inner_f == 1:  # staging degenerates to one collective
        return [StageSpec("flat", p, tuple(range(p)), (p - 1) / p)]
    inner_grp = tuple(range(inner_f))                 # contiguous fast run
    outer_grp = tuple(range(0, p, inner_f))           # strided slow group
    if topology == "inner_first":
        return [
            StageSpec("inner", inner_f, inner_grp, (inner_f - 1) / p),
            StageSpec("outer", outer, outer_grp, (outer - 1) / outer),
        ]
    if topology == "outer_first":
        return [
            StageSpec("outer", outer, outer_grp, (outer - 1) / p),
            StageSpec("inner", inner_f, inner_grp, (inner_f - 1) / inner_f),
        ]
    raise ValueError(f"unknown topology {topology!r}")


# ---------------------------------------------------------------------------
# collective event counts per schedule
# ---------------------------------------------------------------------------

def _event_counts(stack: int, s: int, *, scanned: bool, prefetch: bool,
                  mode: str, carry: str = "stored") -> dict[str, float]:
    """How many gather / reduce-scatter events one pool contributes per step.

    Derived from the schedules in models/lm.py + core/mics.py and verified
    instruction-exactly against the measured census by
    tests/autotune_harness.py:

    * scanned pools run under ``jax.checkpoint``: the serial schedule
      re-gathers every layer in the backward pass (``2·s·stack`` gathers);
      the double-buffered prefetch schedule instead *carries* the gathered
      buffer as a backward residual — no backward re-gather — at the price
      of one wrap-around lookahead per micro-step, and its loop-invariant
      prologue gather (layer 0) is hoisted out of the micro loop by XLA
      (``s·stack + 1`` gathers total, DESIGN.md §4).
    * ``carry='remat'`` keeps the prefetch forward but re-issues every
      layer's gather in the backward (``2·s·stack + 1`` total) — the
      memory-planner knob trading one all-gather per layer for the
      O(layers x flat_len) carry residual; its adjoints come only from the
      backward re-gathers (``s·stack``), the forward lookahead gathers are
      outside the differentiated region (models/lm.py custom VJP).
    * ``carry='host'`` (``GatherPolicy.carry_offload='host'``) keeps the
      stored forward's gather count (``s·stack + 1``) — the carry streams
      to host memory instead of re-gathering — while its hand-rolled
      backward contributes exactly one adjoint per layer (``s·stack``,
      like remat: the prologue gather sits outside the custom VJP).  The
      d2h/h2d stream itself is not wire traffic; ``cost_candidate`` prices
      it on the profile's ``host`` tier.
    * embed/head pools are gathered outside the layer scans; the gather is
      loop-invariant across micro-steps, so XLA hoists it out of the micro
      loop entirely: ONE gather per step, however many micro-steps.
    * every gather whose cotangent is needed contributes one adjoint
      reduce-scatter per micro-step — per layer plus, under the stored
      prefetch carry, the prologue gather's adjoint (``s·(stack+1)``).
    """
    if mode == "serve":
        ag = stack + 1 if (prefetch and scanned and stack > 1) else stack
        return {"ag": float(ag), "rs": 0.0}
    if scanned and prefetch and stack > 1:
        if carry == "remat":
            ag = 2 * s * stack + 1    # prefetch fwd + backward re-gather
            rs = s * stack
        elif carry == "host":
            ag = s * stack + 1        # stored forward, host-resident carry
            rs = s * stack            # one hand-rolled adjoint per layer
        else:
            ag = s * stack + 1
            rs = s * (stack + 1)
    elif scanned:
        ag = 2 * s * stack        # forward + checkpoint re-gather
        rs = s * stack
    else:
        ag = 1 * stack            # hoisted out of the micro loop
        rs = s * stack
    return {"ag": float(ag), "rs": float(rs)}


# ---------------------------------------------------------------------------
# the analytical census
# ---------------------------------------------------------------------------

def predict_traffic(
    model,
    topo: MiCSTopology,
    gather: GatherPolicy,
    sync: SyncPolicy,
    *,
    micro_steps: int = 1,
    mode: str = "train",
    profile: LinkProfile | None = None,
    upcast_float_collectives: bool = False,
) -> dict:
    """Analytical per-stage wire-byte census of one training/serving step.

    Returns ``{"by_stage": {label: {wire_bytes, count, group_size, tier,
    events}}, "local_copy_bytes": float}`` in exactly the units of
    ``hlo_stats.analyze(...)["by_stage"]`` so the two can be compared
    stage-by-stage (tests/autotune_harness.py does, within padding
    tolerance).  ``tier`` is resolved against ``profile`` when given
    (cost-model input), else marked ``"?"``.

    ``upcast_float_collectives=True`` models the XLA *CPU* backend, which
    widens sub-f32 float collectives to f32 on the wire (bf16 gathers,
    bf16 hop-1/hop-2; int8 payloads and their f32 scales are untouched) —
    set it when comparing against a census measured on host devices; leave
    False for the real link cost.
    """
    p = topo.partition_size
    s = int(micro_steps)
    by_stage: dict[str, dict] = {}
    local_copy = 0.0

    def acc(label: str, spec: StageSpec, nbytes: float, events: float,
            ncoll: float, tier: str = "?"):
        e = by_stage.setdefault(label, {
            "wire_bytes": 0.0, "count": 0.0, "events": 0.0,
            "group_size": spec.group_size, "tier": tier,
        })
        e["wire_bytes"] += nbytes
        e["count"] += ncoll
        e["events"] += events

    def stage_tier(spec: StageSpec) -> str:
        if profile is None:
            return "?"
        isl = island_size(topo, profile)
        return "intra" if len({q // isl for q in spec.positions}) <= 1 \
            else "inter"

    stages = gather_stages(gather.topology, topo, gather.inner)
    hop1_int8 = sync.hop1_wire_dtype == "int8" and p > 1
    hop2_int8 = sync.hop2_wire_dtype == "int8"
    wire_b = _WIRE_BYTES[gather.wire_dtype]
    grad_b = grad_wire_bytes(gather.wire_dtype, sync.hop1_wire_dtype)
    hop2_b = _WIRE_BYTES[sync.hop2_wire_dtype]
    if upcast_float_collectives:
        if gather.wire_dtype == "bf16":
            wire_b = 4.0
        if not hop1_int8:
            grad_b = 4.0
        if not hop2_int8:
            hop2_b = 4.0
    colls_per_event = 2 if gather.wire_dtype == "int8" else 1
    # qgZ ships two payloads per stage (int8 q + f32 scales, both as
    # all-to-alls); a float adjoint is one psum_scatter per stage.
    rs_colls_per_event = 2 if hop1_int8 else 1
    # int8 hop 2 = quantized RS (2 all-to-alls) + quantized AG (2 gathers).
    hop2_colls = 4 if hop2_int8 else 1
    reorder = (gather.topology == "outer_first"
               and any(st.label == "outer" for st in stages))

    scanned = {pl.name for pl in model.pools}
    carry = "host" if getattr(gather, "carry_offload", "none") == "host" \
        else gather.prefetch_carry
    for pool in model.all_pools():
        stack, _tp, flat_len = model.global_flat_shapes()[pool.name]
        n = _event_counts(stack, s, scanned=pool.name in scanned,
                          prefetch=gather.prefetch, mode=mode,
                          carry=carry)
        m_gather = flat_len * wire_b
        m_grad = flat_len * grad_b
        for st in stages:
            acc(f"param_gather.{st.label}", st,
                n["ag"] * st.wire_frac * m_gather, n["ag"],
                n["ag"] * colls_per_event, stage_tier(st))
            if mode == "train" and n["rs"] and sync.mode == "2hop":
                acc(f"grad_rs.{st.label}", st,
                    n["rs"] * st.wire_frac * m_grad, n["rs"],
                    n["rs"] * rs_colls_per_event, stage_tier(st))
        if reorder:
            local_copy += (n["ag"] + (n["rs"] if mode == "train" else 0.0)) \
                * flat_len * wire_b

        # hop 2: replication-group all-reduce once per step per pool
        if (mode == "train" and sync.mode == "2hop"
                and topo.replication_degree > 1):
            r = topo.replication_degree
            ob = stack * (flat_len / p) * hop2_b
            spec = StageSpec("hop2", r, tuple(range(0, r * p, p)), 0.0)
            acc("hop2", spec, 2.0 * ob * (r - 1) / r, 1.0, hop2_colls,
                _hop2_tier(topo, profile) if profile else "?")

    return {"by_stage": by_stage, "local_copy_bytes": local_copy}


def compare_census(predicted: dict, measured: dict,
                   prefixes: tuple[str, ...] = ("param_gather", "grad_rs",
                                                "hop2")) -> dict:
    """Stage-by-stage predicted-vs-measured wire bytes (census units).

    Only CommEngine-owned stages are compared (tensor-parallel
    ``model_gather``/``tp_allreduce`` traffic is out of the tuner's scope).
    """
    keys = {k for k in (*predicted, *measured)
            if k.split(".")[0] in {p.split(".")[0] for p in prefixes}}
    out = {}
    for k in sorted(keys):
        pred = predicted.get(k, {}).get("wire_bytes", 0.0)
        meas = measured.get(k, {}).get("wire_bytes", 0.0)
        out[k] = {
            "predicted_wire_bytes": pred,
            "measured_wire_bytes": meas,
            "ratio": (meas / pred) if pred else (1.0 if not meas else float("inf")),
        }
    return out


# ---------------------------------------------------------------------------
# hop-2 boundary-schedule costing (hidden vs exposed time per bucket size)
# ---------------------------------------------------------------------------

# Per-element HBM bytes of the compute a bucketed hop-2 can hide behind the
# next bucket's collective: reading the fp32 reduction result, writing the
# decompressed fp32 value (bf16 hop-2 wire), and the squared-norm partial's
# read.  Under the EXACT clip this is all that can hide — the global-norm
# barrier pins every AdamW shard update after the last bucket's partial
# (core/schedule.py's ordering argument).  Under the APPROX clip
# (``clip_mode='approx'``) bucket k-1's AdamW pipelines under bucket k's
# collective too, adding :data:`ADAMW_STREAM_BYTES_PER_ELEM` of hideable
# work per element.
HOP2_HIDE_BYTES_PER_ELEM = 12.0
# HBM bytes/element of one AdamW shard update: read p/m/v/g fp32 (16),
# write p/m/v fp32 (12) — the compute the approx-clip pipeline interleaves
# between hop-2 collectives.
ADAMW_STREAM_BYTES_PER_ELEM = 28.0

DEFAULT_HOP2_BUCKET_MB = 32.0
HOP2_BUCKET_MB_CANDIDATES = (4.0, 32.0, 128.0)


def cost_hop2_schedule(
    model,
    topo: MiCSTopology,
    profile: str | LinkProfile,
    sync: SyncPolicy,
    *,
    boundary: str = "serial",
    bucket_mb: float = DEFAULT_HOP2_BUCKET_MB,
    clip_mode: str = "exact",
) -> dict:
    """α-β cost of the boundary hop-2 under a schedule.

    ``serial``: one all-reduce per pool, fully exposed (the seed boundary —
    the optimizer waits for the whole tree).  ``bucketed``: fixed-byte
    buckets software-pipelined against the per-bucket norm/decompress
    compute (core/schedule.py); bucket *k*'s collective hides behind bucket
    *k−1*'s compute, so the exposed time under the exact clip is

        t_c[0] + Σ_{k≥1} max(0, t_c[k] − t_x[k−1])

    where ``t_c`` is each bucket's ring time and ``t_x`` the hideable
    compute (:data:`HOP2_HIDE_BYTES_PER_ELEM` over the profile's HBM
    bandwidth).  Smaller buckets expose less head time but pay one
    ``2(r−1)·α`` startup per bucket — the trade the tuner ranks
    ``hop2_bucket_mb`` over.

    ``clip_mode='approx'`` removes the global clip barrier: each bucket's
    AdamW update (:data:`ADAMW_STREAM_BYTES_PER_ELEM` more hideable bytes)
    pipelines under the next bucket's collective, and the head term
    ``t_c[0]`` drops too — bucket 0's clip factor needs no hop-2 result
    (the running norm through bucket −1 is empty, factor 1), so its
    collective hides under the pre-boundary backward epilogue.  Exposed
    time can reach zero — the fully-overlapped step.

    Returns ``{"t_total_s", "t_exposed_s", "t_hidden_s", "n_buckets",
    "clip_mode"}`` (zeros when hop 2 is absent).
    """
    profile = get_profile(profile)
    r = topo.replication_degree
    out = {"t_total_s": 0.0, "t_exposed_s": 0.0, "t_hidden_s": 0.0,
           "n_buckets": 0, "clip_mode": clip_mode}
    if r <= 1 or sync.mode != "2hop":
        return out
    tier = _hop2_tier(topo, profile)
    hop2_b = _WIRE_BYTES[sync.hop2_wire_dtype]
    quantized = sync.hop2_wire_dtype == "int8"
    # plan_boundary validates (boundary, clip_mode) compatibility.
    plan = plan_boundary(model, topo, mode=boundary, bucket_mb=bucket_mb,
                         clip_mode=clip_mode)
    approx = plan.clip_mode == "approx"
    hide_b = HOP2_HIDE_BYTES_PER_ELEM + (
        ADAMW_STREAM_BYTES_PER_ELEM if approx else 0.0)

    t_c: list[float] = []   # per-payload collective time, canonical order
    t_x: list[float] = []   # per-payload hideable compute time
    for n in plan.hop2_payload_elems():
        wire = 2.0 * n * hop2_b * (r - 1) / r
        t_c.append(profile.ring_time(tier, r, wire)
                   + (r - 1) * profile.link(tier).alpha)  # 2(r-1) hops
        if quantized:
            # quantize + dequantize both legs of the decomposed all-reduce
            t_c[-1] += profile.hbm_time(2 * n * QGZ_COMPUTE_BYTES_PER_ELEM)
        t_x.append(profile.hbm_time(n * hide_b))

    total = sum(t_c)
    if boundary == "serial" or not t_c:
        exposed = total
    else:
        head = 0.0 if approx else t_c[0]
        exposed = head + sum(
            max(0.0, t_c[k] - t_x[k - 1]) for k in range(1, len(t_c)))
    out.update(t_total_s=total, t_exposed_s=exposed,
               t_hidden_s=total - exposed, n_buckets=len(t_c))
    return out


# ---------------------------------------------------------------------------
# alpha-beta costing + ranking
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Candidate:
    """One costed (GatherPolicy, SyncPolicy, boundary schedule) combination."""

    gather: GatherPolicy
    sync: SyncPolicy
    t_comm_s: float                      # modeled collective seconds / step
    t_by_stage: dict
    bytes_by_stage: dict
    inter_wire_bytes: float              # slow-tier bytes / step
    lossy_wire: bool
    lossy_hop2: bool
    lossy_hop1: bool = False             # qgZ/bf16-compressed hop-1 wire
    boundary: str = "serial"             # hop-2 boundary schedule
    hop2_bucket_mb: float = DEFAULT_HOP2_BUCKET_MB
    clip_mode: str = "exact"             # boundary clip (approx = pipelined)
    n_hop2_buckets: int = 0
    t_hop2_total_s: float = 0.0          # full hop-2 ring time
    t_hop2_exposed_s: float = 0.0        # what actually serializes the step
    mem_bytes: float = 0.0               # memplan per-device footprint
    # -- serve-mode decode pricing (mode="serve" only) --------------------
    kv_dtype: str = "bf16"               # paged KV block dtype
    resident_requests: int = 0           # predicted residents per device
    t_decode_s: float = 0.0              # modeled decode-step seconds
    tokens_per_s: float = 0.0            # modeled global decode throughput

    def describe(self) -> dict:
        return {
            "gather": dataclasses.asdict(self.gather),
            "sync": dataclasses.asdict(self.sync),
            "t_comm_s": self.t_comm_s,
            "t_by_stage": dict(self.t_by_stage),
            "bytes_by_stage": {
                k: v["wire_bytes"] for k, v in self.bytes_by_stage.items()},
            "inter_wire_bytes": self.inter_wire_bytes,
            "lossy": self.lossy_wire or self.lossy_hop2 or self.lossy_hop1,
            "boundary": self.boundary,
            "hop2_bucket_mb": self.hop2_bucket_mb,
            "clip_mode": self.clip_mode,
            "carry_offload": self.gather.carry_offload,
            "n_hop2_buckets": self.n_hop2_buckets,
            "t_hop2_total_s": self.t_hop2_total_s,
            "t_hop2_exposed_s": self.t_hop2_exposed_s,
            "t_hop2_hidden_s": self.t_hop2_total_s - self.t_hop2_exposed_s,
            "mem_bytes": self.mem_bytes,
            "mem_gib": self.mem_bytes / GIB,
            "kv_dtype": self.kv_dtype,
            "resident_requests": self.resident_requests,
            "t_decode_s": self.t_decode_s,
            "tokens_per_s": self.tokens_per_s,
        }


@dataclasses.dataclass(frozen=True)
class Plan:
    """Ranked autotuning outcome for one (model, topo, profile)."""

    profile: LinkProfile
    mode: str
    micro_steps: int
    candidates: tuple[Candidate, ...]    # best first
    chosen: Candidate
    hbm_budget_gb: float | None = None   # GiB gate the ranking was filtered on

    def describe(self) -> dict:
        return {
            "profile": self.profile.name,
            "mode": self.mode,
            "micro_steps": self.micro_steps,
            "hbm_budget_gb": self.hbm_budget_gb,
            "chosen": self.chosen.describe(),
            "ranking": [c.describe() for c in self.candidates],
        }

    def table(self, top: int | None = 8) -> str:
        """Human-readable ranked table (what ``dryrun --policy auto``
        prints)."""
        budget = "" if self.hbm_budget_gb is None \
            else f" hbm_budget={self.hbm_budget_gb:g}GiB"
        serve = self.mode == "serve"
        head = (f"  {'rank':>4} {'topology':<12} {'inner':>5} {'wire':>5} "
                f"{'pf':>3} {'kv':>5} {'res':>5} "
                f"{'t_comm_ms':>10} {'t_dec_ms':>9} {'tok_s':>9} "
                f"{'mem_GB':>7}") if serve else (
                f"  {'rank':>4} {'topology':<12} {'inner':>5} {'wire':>5} "
                f"{'hop1':>5} {'hop2':>5} {'sched':>6} {'bkt_MB':>6} "
                f"{'clip':>6} {'carry':>6} {'off':>4} "
                f"{'t_comm_ms':>10} {'h2_exp_ms':>9} {'inter_MB':>9} "
                f"{'mem_GB':>7}")
        rows = [f"autotune[{self.profile.name}] mode={self.mode}{budget} "
                f"(chosen marked *):", head]
        cands = self.candidates[:top] if top else self.candidates
        for i, c in enumerate(cands):
            mark = "*" if c is self.chosen else " "
            mem = f"{c.mem_bytes / GIB:.2f}" if c.mem_bytes else "-"
            if serve:
                rows.append(
                    f" {mark}{i:>4} {c.gather.topology:<12} "
                    f"{str(c.gather.inner or '-'):>5} "
                    f"{c.gather.wire_dtype:>5} "
                    f"{'y' if c.gather.prefetch else 'n':>3} "
                    f"{c.kv_dtype:>5} {c.resident_requests:>5} "
                    f"{c.t_comm_s * 1e3:>10.3f} "
                    f"{c.t_decode_s * 1e3:>9.3f} "
                    f"{c.tokens_per_s:>9.0f} "
                    f"{mem:>7}")
                continue
            sched = "bucket" if c.boundary == "bucketed" else "serial"
            bkt = f"{c.hop2_bucket_mb:g}" if c.boundary == "bucketed" else "-"
            off = "host" if c.gather.carry_offload == "host" else "-"
            rows.append(
                f" {mark}{i:>4} {c.gather.topology:<12} "
                f"{str(c.gather.inner or '-'):>5} {c.gather.wire_dtype:>5} "
                f"{c.sync.hop1_wire_dtype:>5} "
                f"{c.sync.hop2_wire_dtype:>5} {sched:>6} {bkt:>6} "
                f"{c.clip_mode:>6} {c.gather.prefetch_carry:>6} {off:>4} "
                f"{c.t_comm_s * 1e3:>10.3f} "
                f"{c.t_hop2_exposed_s * 1e3:>9.3f} "
                f"{c.inter_wire_bytes / 1e6:>9.2f} "
                f"{mem:>7}")
        if self.chosen not in cands:
            rows.append(f"  ... chosen: {self.chosen.describe()['gather']}")
        return "\n".join(rows)


def cost_candidate(
    model,
    topo: MiCSTopology,
    profile: LinkProfile,
    gather: GatherPolicy,
    sync: SyncPolicy,
    *,
    micro_steps: int = 1,
    mode: str = "train",
    boundary: str = "serial",
    hop2_bucket_mb: float = DEFAULT_HOP2_BUCKET_MB,
    clip_mode: str = "exact",
) -> Candidate:
    """α-β time of one candidate: per-stage ring times over the profile's
    tiers + the outer-first reorder copy.  The hop-2 stage is costed by the
    boundary schedule (:func:`cost_hop2_schedule`): only its *exposed* time
    enters ``t_comm_s`` — under the bucketed pipeline the hidden fraction
    overlaps boundary compute and no longer serializes the step, and the
    approx clip (``clip_mode='approx'``) additionally pipelines AdamW
    under the collectives.  A host-offloaded carry
    (``gather.carry_offload='host'``) adds a ``host_offload`` stage: the
    2 x stack x flat_len bytes/micro-step each scanned pool streams over
    the profile's host tier (the price of freeing that HBM)."""
    pred = predict_traffic(model, topo, gather, sync,
                           micro_steps=micro_steps, mode=mode,
                           profile=profile)
    hop1_int8 = (sync.hop1_wire_dtype == "int8"
                 and topo.partition_size > 1 and mode == "train")
    t_by_stage: dict[str, float] = {}
    total = 0.0
    inter_bytes = 0.0
    for label, e in pred["by_stage"].items():
        if label == "hop2":
            continue  # costed by the boundary schedule below
        g = e["group_size"]
        hops = g - 1
        link = profile.link(e["tier"])
        t = e["events"] * hops * link.alpha + e["wire_bytes"] / link.bandwidth
        if hop1_int8 and label.startswith("grad_rs"):
            # quantize/dequantize-accumulate compute of each qgZ stage:
            # the stage streams ~g/(g-1) of its wire elements through HBM.
            elems = e["wire_bytes"] / INT8_WIRE_BYTES * g / max(hops, 1)
            t += profile.hbm_time(elems * QGZ_COMPUTE_BYTES_PER_ELEM)
        t_by_stage[label] = t
        total += t
        if e["tier"] == "inter":
            inter_bytes += e["wire_bytes"]
    hop2 = {"t_total_s": 0.0, "t_exposed_s": 0.0, "n_buckets": 0}
    if mode == "train" and "hop2" in pred["by_stage"]:
        hop2 = cost_hop2_schedule(model, topo, profile, sync,
                                  boundary=boundary, bucket_mb=hop2_bucket_mb,
                                  clip_mode=clip_mode)
        t_by_stage["hop2"] = hop2["t_exposed_s"]
        total += hop2["t_exposed_s"]
        if pred["by_stage"]["hop2"]["tier"] == "inter":
            inter_bytes += pred["by_stage"]["hop2"]["wire_bytes"]
    if pred["local_copy_bytes"]:
        t_by_stage["reorder.copy"] = profile.copy_time(
            pred["local_copy_bytes"])
        total += t_by_stage["reorder.copy"]
    if (mode == "train"
            and getattr(gather, "carry_offload", "none") == "host"):
        # d2h (forward put) + h2d (backward get) of every scanned pool's
        # carried buffer, once per layer per micro-step.  Priced serially
        # on the host tier — pessimistic (the streams overlap layer
        # compute on a real DMA engine), which keeps host-carry rows from
        # outranking in-HBM ones on time; they win only through the memory
        # gate, which is their purpose.
        cb = M._COMPUTE_BYTES[gather.wire_dtype]
        host_bytes = 0.0
        host_events = 0
        scanned = {pl.name for pl in model.pools}
        for name, (stack, _tp, flat_len) in \
                model.global_flat_shapes().items():
            if name in scanned and stack > 1:
                host_bytes += 2.0 * micro_steps * stack * flat_len * cb
                host_events += 2 * micro_steps * stack
        if host_bytes:
            t_by_stage["host_offload"] = profile.xfer_time(
                "host", host_bytes, host_events)
            total += t_by_stage["host_offload"]
    return Candidate(
        gather=gather, sync=sync, t_comm_s=total, t_by_stage=t_by_stage,
        bytes_by_stage=pred["by_stage"], inter_wire_bytes=inter_bytes,
        lossy_wire=gather.wire_dtype == "int8",
        lossy_hop2=sync.hop2_wire_dtype != "fp32",
        lossy_hop1=sync.hop1_wire_dtype != "fp32",
        boundary=boundary, hop2_bucket_mb=hop2_bucket_mb,
        clip_mode=clip_mode,
        n_hop2_buckets=hop2["n_buckets"],
        t_hop2_total_s=hop2["t_total_s"],
        t_hop2_exposed_s=hop2["t_exposed_s"],
    )


# kv_dtype permission ladder: MiCSConfig.kv_dtype is a numerics *ceiling*
# — the serve tuner may pick any dtype at or below its lossiness, never a
# lossier one the user did not opt into.
KV_DTYPES = ("fp32", "bf16", "int8")
_KV_LOSS = {d: i for i, d in enumerate(KV_DTYPES)}
DEFAULT_SERVE_CTX = 2048


def cost_decode_step(
    model,
    topo: MiCSTopology,
    profile: str | LinkProfile,
    gather: GatherPolicy,
    *,
    resident: int,
    ctx_len: int,
    kv_dtype: str = "bf16",
    chunk: int = 1,
    t_comm_s: float | None = None,
) -> dict:
    """Roofline model of one continuous-batching decode step.

    Decode re-gathers every layer's weights each step, so the step time is
    the interplay of a batch-independent weight stream and batch-dependent
    attention/GEMM work:

    * ``t_comm`` — the gather wire time (``cost_candidate`` serve mode);
    * ``t_weights`` — streaming the gathered buffers out of HBM once;
    * ``t_flops`` — ``2 * P_local * resident * chunk`` matmul FLOPs;
    * ``t_kv`` — reading every resident request's block-rounded KV pages
      (``memplan.kv_token_bytes``) for attention.

    Under a prefetched gather the wire time overlaps the previous layer's
    compute (``max``); a serial gather exposes it (``sum``).  ``resident``
    is per-device rows; throughput scales by the data-parallel width.
    """
    profile = get_profile(profile)
    weight_bytes = 0.0
    n_params_local = 0.0
    cb = M._COMPUTE_BYTES[gather.wire_dtype]
    for _name, (stack, _tp, flat_len) in model.global_flat_shapes().items():
        weight_bytes += stack * flat_len * cb
        n_params_local += stack * flat_len
    if t_comm_s is None:
        t_comm_s = cost_candidate(model, topo, profile, gather,
                                  SyncPolicy("2hop", "fp32", "fp32"),
                                  mode="serve").t_comm_s
    t_comm = t_comm_s
    t_weights = profile.hbm_time(weight_bytes)
    t_flops = 2.0 * n_params_local * resident * chunk / profile.peak_flops
    kv_bytes = resident * ctx_len * M.kv_token_bytes(model, kv_dtype)
    t_kv = profile.hbm_time(kv_bytes)
    t_compute = t_weights + t_flops + t_kv
    t_step = max(t_comm, t_compute) if gather.prefetch \
        else t_comm + t_compute
    dp = getattr(topo, "data_parallel_size", 1)
    tok_s = resident * chunk * dp / t_step if t_step > 0 else 0.0
    return {"t_step_s": t_step, "t_comm_s": t_comm, "t_weights_s": t_weights,
            "t_flops_s": t_flops, "t_kv_s": t_kv, "tokens_per_s": tok_s}


def enumerate_candidates(
    topo: MiCSTopology,
    *,
    prefetch: bool = True,
    wires: tuple[str, ...] = WIRE_DTYPES,
    hop1_wires: tuple[str, ...] = ("fp32", "int8"),
    mode: str = "train",
) -> list[tuple[GatherPolicy, SyncPolicy]]:
    """Candidate grid: topology x inner x wire dtype x hop-1 x hop-2 wire.

    The hop-1 axis defaults to {fp32, int8}: bf16 hop-1 is a manual option
    (``MiCSConfig(hop1_wire_dtype="bf16")``) but is dominated in the grid —
    it is lossy like qgZ while moving 2x its bytes.  Serving has no
    gradients, so the hop-1 axis collapses there; likewise at p == 1.
    """
    p = topo.partition_size
    gathers: list[GatherPolicy] = []
    for wire in wires:
        gathers.append(GatherPolicy("flat", wire, None, prefetch))
        if p < 4:
            continue  # staging degenerates below 2x2
        if len(topo.partition_axes) > 1:
            inners: list[int | None] = [None]  # factorization = axis split
        else:
            inners = [d for d in range(2, p) if p % d == 0]
        for inner in inners:
            for topology in ("inner_first", "outer_first"):
                gathers.append(GatherPolicy(topology, wire, inner, prefetch))
    hop2_wires = ("fp32", "bf16", "int8") \
        if topo.replication_degree > 1 else ("fp32",)
    if mode != "train" or p == 1:
        hop1s: tuple[str, ...] = ("fp32",)
    else:
        hop1s = tuple(dict.fromkeys(hop1_wires))  # de-dup, keep order
    return [(g, SyncPolicy("2hop", h2, h1))
            for g in gathers for h2 in hop2_wires for h1 in hop1s]


def enumerate_hop2_schedules(topo: MiCSTopology,
                             mode: str = "train") -> list[tuple[str, float]]:
    """Boundary-schedule axis of the candidate grid: the serial reference
    plus the bucketed pipeline at each :data:`HOP2_BUCKET_MB_CANDIDATES`
    size.  Collapses to one entry when hop 2 is absent (no replication, or
    serving — the boundary never runs)."""
    if mode != "train" or topo.replication_degree <= 1:
        return [("bucketed", DEFAULT_HOP2_BUCKET_MB)]
    return [("serial", DEFAULT_HOP2_BUCKET_MB)] + [
        ("bucketed", mb) for mb in HOP2_BUCKET_MB_CANDIDATES]


def rank_policies(
    model,
    topo: MiCSTopology,
    profile: str | LinkProfile,
    *,
    micro_steps: int = 1,
    prefetch: bool = True,
    mode: str = "train",
    allow_int8: bool = False,
    allow_bf16_hop2: bool = False,
    allow_int8_hop1: bool = False,
    allow_int8_hop2: bool = False,
    allow_approx_clip: bool = False,
    hbm_budget_gb: float | None = None,
    local_batch: int = 0,
    seq: int = 0,
    offload_opt: bool = False,
    kv_ceiling: str = "bf16",
    kv_block_size: int = 16,
    serve_ctx: int = 0,
    max_resident: int = 0,
    arrival_rate: float = 0.0,
) -> Plan:
    """Cost every candidate and rank by modeled collective time.

    The chosen plan is the fastest candidate whose numerics the caller
    opted into (``allow_int8`` — int8 gather wire, ``allow_bf16_hop2`` /
    ``allow_int8_hop2`` — the compressed hop-2 wires (the int8 opt-in also
    permits the milder bf16), ``allow_int8_hop1`` — the qgZ hop-1 wire);
    the full ranking (including lossy rows) is kept for the dry-run table
    and BENCH artifacts.

    ``hbm_budget_gb`` adds the memory planner's gate (core/memplan.py):
    every candidate is priced per device, the ``prefetch_carry='remat'``
    and ``carry_offload='host'`` mitigations join the grid, infeasible
    candidates are excluded from selection (they stay in the ranking,
    marked by their ``mem_bytes``), and
    :class:`repro.core.memplan.MemoryBudgetError` is raised — never a
    silently empty plan — when nothing numerics-eligible fits.
    ``local_batch``/``seq`` size the activation terms (0 = model states +
    comm buffers only).

    The approx clip joins the grid on every bucketed-boundary candidate
    (``clip_mode`` column) but is selected only under
    ``allow_approx_clip`` — like the lossy wires, it changes numerics
    (one-bucket-stale clip factor) and must be opted into
    (``MiCSConfig(clip_mode="approx")``).  ``offload_opt`` is a config
    passthrough that shifts the m/v shards off-device in the footprint
    pricing; it is not a ranked axis (it has no policy interaction).
    """
    profile = get_profile(profile)
    carries = ("stored",) if hbm_budget_gb is None \
        else ("stored", "remat", "host")
    serve = mode == "serve"
    # serving ranks the prefetch toggle itself (overlap vs serial gathers
    # changes the decode roofline); training takes it as a caller input.
    prefetches = (True, False) if serve else (prefetch,)
    cands = []
    for pf in prefetches:
      for g, s in enumerate_candidates(topo, prefetch=pf, mode=mode):
        for boundary, bucket_mb in enumerate_hop2_schedules(topo, mode):
            clips = ("exact", "approx") if (
                boundary == "bucketed" and mode == "train"
                and topo.replication_degree > 1) else ("exact",)
            for clip in clips:
                for carry in carries:
                    if carry != "stored" and not (
                            g.prefetch and mode == "train"):
                        continue   # carries only differ with a backward
                    if carry == "host":
                        g2 = dataclasses.replace(
                            g, prefetch_carry="stored", carry_offload="host")
                    else:
                        g2 = dataclasses.replace(g, prefetch_carry=carry)
                    c = cost_candidate(model, topo, profile, g2, s,
                                       micro_steps=micro_steps, mode=mode,
                                       boundary=boundary,
                                       hop2_bucket_mb=bucket_mb,
                                       clip_mode=clip)
                    if serve:
                        if getattr(model, "cfg", None) is None:
                            # duck-typed planner stubs carry no attention
                            # dims: rank the gather axes alone, without
                            # the KV/residency grid (defaults sort these
                            # by t_comm_s, the pre-KV serve behavior)
                            mem = M.predict_footprint(
                                model, topo, g2, s, mode="serve")
                            cands.append(dataclasses.replace(
                                c, mem_bytes=mem.total_bytes))
                            continue
                        # KV-dtype axis: residency from the free HBM after
                        # the base footprint, decode step from the roofline.
                        ctx = serve_ctx or DEFAULT_SERVE_CTX
                        cap_bytes = hbm_budget_gb * GIB if hbm_budget_gb \
                            else float(profile.hbm_bytes)
                        for kv in KV_DTYPES:
                            res = M.max_resident_requests(
                                model, topo, g2, s, hbm_bytes=cap_bytes,
                                ctx_len=ctx, kv_block_size=kv_block_size,
                                kv_dtype=kv)
                            if max_resident:
                                res = min(res, max_resident)
                            dec = cost_decode_step(
                                model, topo, profile, g2,
                                resident=max(res, 1), ctx_len=ctx,
                                kv_dtype=kv, t_comm_s=c.t_comm_s)
                            blocks = -(-ctx // kv_block_size)
                            mem_kv = M.predict_footprint(
                                model, topo, g2, s, mode="serve",
                                kv_pages_tokens=res * blocks * kv_block_size,
                                kv_dtype=kv)
                            cands.append(dataclasses.replace(
                                c, mem_bytes=mem_kv.total_bytes,
                                kv_dtype=kv, resident_requests=res,
                                t_decode_s=dec["t_step_s"],
                                tokens_per_s=dec["tokens_per_s"]))
                        continue
                    mem = M.predict_footprint(
                        model, topo, g2, s, micro_steps=micro_steps,
                        mode=mode, local_batch=local_batch, seq=seq,
                        boundary=boundary, hop2_bucket_mb=bucket_mb,
                        offload_opt=offload_opt and mode == "train")
                    cands.append(dataclasses.replace(
                        c, mem_bytes=mem.total_bytes))
    # modeled time first; among time-ties the smaller footprint wins (which
    # is what makes remat the tie-break choice at p=1, where the extra
    # backward re-gather moves zero wire bytes).  Exact clip and the
    # in-HBM carry sort before approx/host on full ties — reference
    # numerics and no host traffic unless they buy something.  Serving
    # sorts by the decode roofline instead (throughput breaks ties).
    if serve:
        cands.sort(key=lambda c: (c.t_decode_s, -c.tokens_per_s,
                                  c.t_comm_s, _KV_LOSS[c.kv_dtype],
                                  c.gather.topology, c.gather.wire_dtype,
                                  not c.gather.prefetch, c.mem_bytes))
    else:
        cands.sort(key=lambda c: (c.t_comm_s, c.gather.topology,
                              c.gather.wire_dtype, c.sync.hop1_wire_dtype,
                              c.sync.hop2_wire_dtype,
                              c.boundary, c.hop2_bucket_mb,
                              c.clip_mode != "exact",
                              c.mem_bytes, c.gather.prefetch_carry,
                              c.gather.carry_offload != "none"))

    def hop2_ok(c: Candidate) -> bool:
        wire = c.sync.hop2_wire_dtype
        if wire == "bf16":
            return allow_bf16_hop2 or allow_int8_hop2
        if wire == "int8":
            return allow_int8_hop2
        return True

    def fits(c: Candidate) -> bool:
        return hbm_budget_gb is None \
            or c.mem_bytes <= hbm_budget_gb * GIB
    kv_cap = _KV_LOSS.get(kv_ceiling, _KV_LOSS["bf16"])
    eligible = [c for c in cands
                if (allow_int8 or not c.lossy_wire)
                and hop2_ok(c)
                and (allow_int8_hop1 or not c.lossy_hop1)
                and (allow_approx_clip or c.clip_mode == "exact")
                and (not serve or _KV_LOSS[c.kv_dtype] <= kv_cap)]
    feasible = [c for c in eligible if fits(c)]
    if hbm_budget_gb is not None and eligible and not feasible:
        smallest = min(eligible, key=lambda c: c.mem_bytes)
        raise M.MemoryBudgetError(
            f"no eligible policy fits hbm_budget_gb={hbm_budget_gb} on "
            f"p={topo.partition_size}: the smallest candidate "
            f"({smallest.gather.topology}/{smallest.gather.wire_dtype}, "
            f"prefetch_carry={smallest.gather.prefetch_carry!r}) needs "
            f"{smallest.mem_bytes / 1024**3:.3f} GiB per device; grow the "
            f"partition group (memplan.min_partition_size) or the budget")
    pool = feasible or eligible or cands
    # a target arrival rate prefers the lowest-latency candidate that still
    # meets the demanded decode throughput; none meeting it -> fastest.
    meeting = [c for c in pool
               if not arrival_rate or c.tokens_per_s >= arrival_rate]
    chosen = (meeting or pool)[0]
    return Plan(profile=profile, mode=mode, micro_steps=micro_steps,
                candidates=tuple(cands), chosen=chosen,
                hbm_budget_gb=hbm_budget_gb)


# ---------------------------------------------------------------------------
# MiCSConfig resolution (policy="auto")
# ---------------------------------------------------------------------------

def resolve_config(mcfg, model, topo: MiCSTopology, *,
                   mode: str = "train", local_batch: int = 0, seq: int = 0,
                   arrival_rate: float = 0.0):
    """Resolve ``MiCSConfig(policy="auto")`` into concrete policy fields.

    Returns ``(resolved_config, plan)``; manual configs pass through with
    ``plan=None``.  The winning GatherPolicy/SyncPolicy is mapped back onto
    the legacy config fields so ``CommEngine.from_config`` (the one place
    those fields are interpreted) reconstructs exactly the chosen policies.

    With ``mcfg.hbm_budget_gb`` set, the memory planner gates the ranking
    (core/memplan.py): infeasible candidates are filtered out, the
    ``prefetch_carry='remat'`` mitigation joins the grid (chosen only when
    the stored carry does not fit — it costs one extra all-gather per
    layer), and a clear :class:`repro.core.memplan.MemoryBudgetError` is
    raised when nothing fits on this topology's partition group.  Use
    :func:`resolve_scale` to pick the partition-group *size* itself — the
    paper's §3.1 minimal-group rule.
    """
    if getattr(mcfg, "policy", "manual") != "auto":
        return mcfg, None
    plan = rank_policies(
        model, topo, mcfg.link_profile,
        micro_steps=mcfg.micro_steps, prefetch=mcfg.prefetch, mode=mode,
        # per-mechanism permissions: quant_gather opts into the int8
        # *weight* wire only (its adjoint stays exact) — the lossy qgZ
        # gradient wire needs its own explicit hop1_wire_dtype opt-in
        allow_int8=mcfg.quant_gather,
        allow_bf16_hop2=mcfg.compress_hop2 in (True, "bf16", "int8"),
        allow_int8_hop2=mcfg.compress_hop2 == "int8",
        allow_int8_hop1=mcfg.hop1_wire_dtype == "int8",
        # approx clip is an approximation permission like the lossy wires
        allow_approx_clip=getattr(mcfg, "clip_mode", "exact") == "approx",
        hbm_budget_gb=getattr(mcfg, "hbm_budget_gb", None),
        local_batch=local_batch, seq=seq,
        offload_opt=getattr(mcfg, "offload_opt", False),
        # serve axes: the configured kv_dtype is the numerics ceiling, the
        # configured residency (0 = planner-derived) caps the pool sizing
        kv_ceiling=getattr(mcfg, "kv_dtype", "bf16"),
        kv_block_size=getattr(mcfg, "kv_block_size", 16),
        serve_ctx=seq,
        max_resident=getattr(mcfg, "max_resident_requests", 0),
        arrival_rate=arrival_rate,
    )
    g, s = plan.chosen.gather, plan.chosen.sync
    if g.wire_dtype == "fp32":
        gather_dtype = jnp.float32
    else:  # bf16 wire, and int8's dequantized compute dtype
        gather_dtype = jnp.bfloat16
    resolved = dataclasses.replace(
        mcfg,
        policy="manual",
        hierarchical=g.topology != "flat",
        gather_order=g.topology if g.topology != "flat" else "inner_first",
        hierarchy_inner=g.inner,
        gather_dtype=gather_dtype,
        quant_gather=g.wire_dtype == "int8",
        sync_mode="2hop",
        compress_hop2=(s.hop2_wire_dtype
                       if s.hop2_wire_dtype != "fp32" else False),
        hop1_wire_dtype=s.hop1_wire_dtype,
        prefetch_carry=g.prefetch_carry,
        carry_offload=getattr(g, "carry_offload", "none"),
        boundary_schedule=plan.chosen.boundary,
        hop2_bucket_mb=plan.chosen.hop2_bucket_mb,
        clip_mode=plan.chosen.clip_mode,
    )
    if mode == "serve":
        # decode-policy round-trip: the winning KV dtype, prefetch toggle
        # and planner-derived residency land back on the config so the
        # paged engine (runtime/paged.py) builds exactly what was ranked.
        resolved = dataclasses.replace(
            resolved,
            prefetch=g.prefetch,
            kv_dtype=plan.chosen.kv_dtype,
            max_resident_requests=plan.chosen.resident_requests,
        )
    return resolved, plan


def resolve_scale(model, mcfg, *, data_extent: int, mode: str = "train",
                  local_batch: int = 0, seq: int = 0,
                  extra_replication: int = 1):
    """The paper's §3.1 scale-aware partitioning rule for ``MiCSConfig``.

    Returns ``(partition_size, carry, mem_plan)`` — the *minimal*
    partition-group size over a data axis of ``data_extent`` whose
    predicted per-device footprint fits ``mcfg.hbm_budget_gb`` GiB, trying
    the stored carry first, the remat mitigation second and the
    host-offloaded carry (``carry == "host"`` ->
    ``MiCSConfig(carry_offload="host")``) third at every size (a smaller
    group rescued by remat or host offload beats a larger stored one:
    smaller groups keep collectives on faster tiers, which is the whole
    point of scale-aware partitioning).  With ``mcfg.offload_opt`` the
    m/v shards leave the footprint too, shrinking the minimal group
    further.  Raises
    :class:`repro.core.memplan.MemoryBudgetError` when even the full data
    axis (ZeRO-3 scale) does not fit.  ``extra_replication`` covers the
    data-parallel axes the group cannot span (pods, the dp2 leftover of a
    narrow tp) so hop-2 staging is priced even at p == data_extent.
    ``launch/dryrun.py --hbm-budget-gb`` applies this before building the
    topology.
    """
    if getattr(mcfg, "hbm_budget_gb", None) is None:
        raise ValueError("resolve_scale needs MiCSConfig.hbm_budget_gb")
    gp, sp = policies_from_config(mcfg)
    carries = ("stored", "remat", "host") if gp.prefetch and mode == "train" \
        else ("stored",)
    return M.min_partition_size(
        model, data_extent=data_extent, hbm_budget_gb=mcfg.hbm_budget_gb,
        gather=gp, sync=sp, micro_steps=mcfg.micro_steps, mode=mode,
        local_batch=local_batch, seq=seq,
        boundary=mcfg.boundary_schedule,
        hop2_bucket_mb=mcfg.hop2_bucket_mb, carries=carries,
        offload_opt=getattr(mcfg, "offload_opt", False) and mode == "train",
        extra_replication=extra_replication)


def resolve_world(model, mcfg, *, n_devices: int, tp: int = 1,
                  partition_size: int | None = None, mode: str = "train",
                  local_batch: int = 0, seq: int = 0):
    """Re-pick partition-group size + carry for an ``n_devices`` world.

    The elastic train loop's policy half (runtime/train_loop.py calls this
    on every :class:`repro.core.faults.WorldChangeError` — pod loss or
    grow-back — before rebuilding the mesh): with ``mcfg.hbm_budget_gb``
    set it re-runs :func:`resolve_scale` so the degraded/grown world gets
    the paper's §3.1 minimal-fitting group (and the carry mitigation that
    rescued it); without a budget it keeps the previous ``partition_size``
    where it still divides the new data extent, else the largest divisor
    below it.  Everything here is analytic and deterministic, which is what
    makes an in-loop resume bitwise-reproducible by a cold restore with the
    same arguments (the kill-a-device contract, tests/elastic_harness.py).

    Returns ``(partition_size, mcfg2, info)`` where ``mcfg2`` carries the
    chosen carry/offload fields and ``info`` is a ledger-friendly dict.
    """
    if n_devices <= 0 or n_devices % max(tp, 1):
        raise ValueError(
            f"world of {n_devices} devices cannot carry tp={tp} "
            f"(flat layouts are TP-local: tp must divide the world)")
    data_extent = n_devices // max(tp, 1)
    if getattr(mcfg, "hbm_budget_gb", None) is not None:
        p, carry, mem_plan = resolve_scale(
            model, mcfg, data_extent=data_extent, mode=mode,
            local_batch=local_batch, seq=seq)
        if carry == "host":
            mcfg2 = dataclasses.replace(
                mcfg, prefetch_carry="stored", carry_offload="host")
        else:
            mcfg2 = dataclasses.replace(
                mcfg, prefetch_carry=carry, carry_offload="none")
        info = {"rule": "resolve_scale", "carry": carry,
                "hbm_budget_gb": mcfg.hbm_budget_gb,
                "mem_gib": mem_plan.total_bytes / GIB}
    else:
        prefer = min(partition_size or data_extent, data_extent)
        p = max(d for d in range(1, prefer + 1) if data_extent % d == 0)
        mcfg2, info = mcfg, {"rule": "keep", "carry": mcfg.prefetch_carry}
    info.update(partition_size=p, data_extent=data_extent, tp=tp,
                n_devices=n_devices)
    return p, mcfg2, info


def rerank_serve_world(model, topo: MiCSTopology, mcfg, *, seq: int = 0,
                       arrival_rate: float = 0.0):
    """Re-rank the serve policy grid for a changed world, numerics pinned.

    The resilient serve loop's policy half (runtime/resilient.py): after a
    preemption/grow-back the survivors' link geometry changed, so the
    gather topology, prefetch and planner residency that won on the old
    world may lose on the new one — :func:`rank_policies(mode="serve")` is
    re-run under the *same* ``hbm_budget_gb``.

    Numerics are pinned on purpose: the wire/compute dtype
    (``gather_dtype``/``quant_gather``), the KV dtype and the KV block
    size are copied back from the pre-fault config after the re-rank, so
    only bitwise-neutral axes (gather topology, inner factor, prefetch,
    residency) may move.  That is what keeps replayed completions
    bitwise-identical to the fault-free run — the serve harness pins paged
    attention as invariant to block table layout and gather staging, but
    not to dtype changes.

    Returns ``(mcfg2, plan)``; ``plan`` is the ranked serve table (always
    produced, even for manual configs — the re-rank is the point).
    """
    base = dataclasses.replace(mcfg, policy="auto", max_resident_requests=0)
    resolved, plan = resolve_config(base, model, topo, mode="serve", seq=seq,
                                    arrival_rate=arrival_rate)
    # the re-resolved config is concrete (policy="manual"), so downstream
    # builders cannot re-rank away the pins below
    pinned = dataclasses.replace(
        resolved,
        gather_dtype=mcfg.gather_dtype, quant_gather=mcfg.quant_gather,
        kv_dtype=mcfg.kv_dtype, kv_block_size=mcfg.kv_block_size)
    return pinned, plan
