"""Int8 blockwise quantization primitives (ZeRO++ qwZ/qgZ-style).

These are the dtype-level building blocks; the *collective* policies that
use them live in ``core/comm.py`` / ``core/collectives.py``:

* **qwZ** (weights): int8 wire gathers for training and serving with the
  straight-through exact adjoint (``GatherPolicy.wire_dtype='int8'``).
  ``quantize_state`` remains the deployment-time conversion producing
  stored ``{'q','s'}`` serving weights.
* **qgZ** (gradients): the per-stage block-quantized hierarchical
  reduce-scatter (``collectives.quantized_reduce_scatter``,
  ``SyncPolicy.hop1_wire_dtype='int8'``) and the int8 hop-2 leg
  (``collectives.quantized_all_reduce``).  Gradient quantization uses the
  *stochastic* rounding mode below so each quantize step is unbiased in
  expectation — dequantized sums estimate the true reduction without a
  systematic drift term.

Decode steps re-gather every layer's weights across the partition group each
step; at batch sizes that fit real serving traffic this is the binding
roofline term (EXPERIMENTS.md).  Storing serving weights as int8 with
per-block absmax scales halves the gather wire bytes *and* the HBM read
traffic vs bf16 (1.03 B/param vs 2), at ~0.2-0.4% relative weight error —
standard W8 inference practice (cf. LLM.int8()/SmoothQuant), applied here to
the *collective* rather than the matmul:

    stored:  q  int8 [*, L]               (flat pools, MiCS-sharded as usual)
             s  f32  [*, ceil(L/BLOCK)]   (absmax scale per 128-elem block)
    use:     all-gather(q) + all-gather(s)  ->  dequant  ->  unflatten

Ragged tails are supported: ``L`` need not be a multiple of ``BLOCK`` — the
final block is short (quantized against its own absmax), so arbitrary
bucket/chunk sizes from ``flat_param.partition_buckets`` and the qgZ stage
chunking quantize cleanly.  Aligned inputs produce bit-identical results to
the historical aligned-only implementation.

Master states stay fp32 either way: stored-int8 weights are a one-time
deployment conversion (`quantize_state`), while training's int8 *wire*
collectives quantize transiently per stage and accumulate in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 128


def n_blocks(length: int) -> int:
    """Scale entries for a flat buffer of ``length`` elements (ragged-aware)."""
    return -(-length // BLOCK)


def quantize_flat(
    flat: jax.Array, *, key: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """flat [..., L] -> (int8 [..., L], f32 [..., ceil(L/BLOCK)]).

    ``key=None`` (default) rounds to nearest — deterministic and
    bitwise-reproducible, the qwZ weight-wire mode.  With a PRNG ``key`` the
    rounding is *stochastic*: ``floor(v + u)`` with ``u ~ U[0, 1)``, so
    ``E[dequantize(quantize(x))] == x`` elementwise (the qgZ gradient-wire
    mode; the unbiasedness is what keeps quantized reductions drift-free).
    """
    *lead, L = flat.shape
    nb = n_blocks(L)
    pad = nb * BLOCK - L
    x = flat.astype(jnp.float32)
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    blocks = x.reshape(*lead, nb, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    v = blocks / scale[..., None]
    if key is None:
        q = jnp.round(v)
    else:
        q = jnp.floor(v + jax.random.uniform(key, blocks.shape))
    q = jnp.clip(q, -127, 127).astype(jnp.int8).reshape(*lead, nb * BLOCK)
    if pad:
        q = q[..., :L]
    return q, scale


def dequantize_flat(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of :func:`quantize_flat` (ragged tails follow the scale count)."""
    *lead, L = q.shape
    nb = scale.shape[-1]
    pad = nb * BLOCK - L
    x = q.astype(jnp.float32)
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    out = (x.reshape(*lead, nb, BLOCK) * scale[..., None])
    out = out.reshape(*lead, nb * BLOCK)
    if pad:
        out = out[..., :L]
    return out.astype(dtype)


def quantize_state(params: dict[str, jax.Array]) -> dict[str, dict]:
    """Training/serving fp32 flat pools -> {'q':…, 's':…} per pool."""
    out = {}
    for name, flat in params.items():
        q, s = quantize_flat(flat)
        out[name] = {"q": q, "s": s}
    return out
