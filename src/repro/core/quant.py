"""Int8 blockwise quantization primitives (ZeRO++ qwZ-style).

These are the dtype-level building blocks; the *collective* policy that
uses them — int8 wire gathers for training and serving, with the
straight-through exact adjoint — lives in ``core/comm.py`` (CommEngine,
``GatherPolicy.wire_dtype='int8'``).  ``quantize_state`` remains the
deployment-time conversion producing stored ``{'q','s'}`` serving weights.

Decode steps re-gather every layer's weights across the partition group each
step; at batch sizes that fit real serving traffic this is the binding
roofline term (EXPERIMENTS.md).  Storing serving weights as int8 with
per-block absmax scales halves the gather wire bytes *and* the HBM read
traffic vs bf16 (1.03 B/param vs 2), at ~0.2-0.4% relative weight error —
standard W8 inference practice (cf. LLM.int8()/SmoothQuant), applied here to
the *collective* rather than the matmul:

    stored:  q  int8 [*, flat_len]       (flat pools, MiCS-sharded as usual)
             s  f32  [*, flat_len/BLOCK] (absmax scale per 128-elem block)
    use:     all-gather(q) + all-gather(s)  ->  dequant  ->  unflatten

Master states stay fp32 either way: stored-int8 weights are a one-time
deployment conversion (`quantize_state`), while training's int8 *wire*
gathers quantize transiently per collective and keep gradients fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 128


def quantize_flat(flat: jax.Array) -> tuple[jax.Array, jax.Array]:
    """flat [..., L] (L % BLOCK == 0) -> (int8 [..., L], f32 [..., L/BLOCK])."""
    *lead, L = flat.shape
    if L % BLOCK:
        raise ValueError(f"flat length {L} not a multiple of {BLOCK}")
    blocks = flat.astype(jnp.float32).reshape(*lead, L // BLOCK, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    return q.astype(jnp.int8).reshape(*lead, L), scale


def dequantize_flat(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    *lead, L = q.shape
    blocks = q.astype(jnp.float32).reshape(*lead, L // BLOCK, BLOCK)
    out = blocks * scale[..., None]
    return out.reshape(*lead, L).astype(dtype)


def quantize_state(params: dict[str, jax.Array]) -> dict[str, dict]:
    """Training/serving fp32 flat pools -> {'q':…, 's':…} per pool."""
    out = {}
    for name, flat in params.items():
        q, s = quantize_flat(flat)
        out[name] = {"q": q, "s": s}
    return out
