"""Analytical per-device HBM footprint model (the memory planner).

MiCS's scale-aware partitioning rule (§3.1) is a *memory* rule: choose the
minimal partition group whose aggregate device memory holds the model
states, so collectives stay small and fast.  The autotuner (core/autotune)
ranks policies by predicted communication time; this module supplies the
other half of the decision — what each candidate *costs in HBM* — so the
planner can reject configurations that would OOM and implement the paper's
rule analytically (:func:`min_partition_size`).

The footprint of one training step decomposes per device into

* **arguments** — the donated state (fp32 param/m/v shards, exact by
  construction) plus the batch;
* **transients** — everything the compiled step allocates on top:
  the fp32 gradient accumulator and its loop double-buffer, the
  hop-2-reduced gradient copy, the flat-param gather buffers (x2 under
  double-buffered prefetch), the prefetch-carry backward residual, hop-2
  bucket staging, qgZ / int8-wire quantization scratch, activation
  checkpoints and the logits/CE workspace.

Every component is priced from the same static quantities the autotuner's
traffic model reads (``model.global_flat_shapes()``, the topology's
partition size / replication degree, the policies), so the two models stay
composable.  The prediction is verified against XLA's own compiled
``memory_analysis()`` on the 8-device harness — the same
predicted-vs-compiled discipline ``autotune.predict_traffic`` applies to
wire bytes (tests/memplan_harness.py; argument bytes must match exactly,
transients within :data:`MEM_RTOL`).

Calibration notes (documented tolerance): the transient model is
calibrated against the XLA *CPU* backend the harness compiles for.  Two
empirical observations are baked in: the stored prefetch carry persists
its stacked residual at fp32 (the adjoint's accumulation dtype) plus the
rotated shard copy, and the gradient accumulator is double-buffered across
the micro-step loop.  :data:`MEM_RTOL` (±35%) absorbs backend-specific
fusion and scratch variation; argument bytes carry no tolerance at all.

Degenerate cases are first-class: a single-device mesh (p = 1, nothing on
the wire, no hop 2), a partition group spanning the whole world (ZeRO-3,
no replication → no hop-2 staging), and budgets smaller than any candidate
(:class:`MemoryBudgetError`, never a silent empty plan).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.comm import GatherPolicy, SyncPolicy
from repro.core.linkmodel import GIB
from repro.core.quant import BLOCK

# Documented tolerance of the transient-footprint model vs XLA's compiled
# memory_analysis() (CPU backend; argument bytes are exact, no tolerance).
MEM_RTOL = 0.35

# bytes/element of the gathered compute buffer, per gather wire dtype (the
# int8 wire dequantizes into the bf16 compute dtype).
_COMPUTE_BYTES = {"fp32": 4, "bf16": 2, "int8": 2}
# int8 wire scratch: q payload + one f32 absmax scale per BLOCK elements.
_INT8_BYTES = 1.0 + 4.0 / BLOCK
# Per-element scratch of the qgZ hop-1 wire on the largest in-flight
# cotangent buffer.  Calibrated to the XLA CPU backend the harness verifies
# against, which does NOT fuse the threefry-dither / quantize / exchange /
# dequantize chain — ~33 full-width temporaries (u32 random bits, f32
# uniforms, block-shaped chunks, per-stage exchange copies) are live at
# once.  On accelerator backends with fused RNG this is pessimistic, which
# errs on the safe side for OOM rejection.
QGZ_SCRATCH_BYTES_PER_ELEM = 133.0


class MemoryBudgetError(ValueError):
    """No candidate fits the HBM budget (raised instead of an empty plan)."""


# KV-cache element bytes per kv_dtype (int8 adds f32 scales separately).
_KV_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


def kv_token_bytes(model, kv_dtype: str = "bf16") -> float:
    """Per-device HBM bytes one cached token costs across all layers.

    Prices the paged KV pool (runtime/paged.py): k + v at ``kv_dtype``
    over the rank-local KV head slots, plus the per-(token, head,
    128-block) f32 scale pages of the int8 layout.  Analytic and jax-free
    — the same ``attn_dims`` the model builds its caches from.
    """
    from repro.models.dims import attn_dims

    cfg = model.cfg
    tp = max(int(getattr(model, "tp", 1)), 1)
    ad = attn_dims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.resolved_head_dim, tp)
    per_layer = 2.0 * ad.hkv_local * ad.head_dim * _KV_BYTES[kv_dtype]
    if kv_dtype == "int8":
        per_layer += 2.0 * ad.hkv_local * math.ceil(ad.head_dim / BLOCK) * 4.0
    return per_layer * cfg.n_layers


def max_resident_requests(
    model,
    topo,
    gather: GatherPolicy,
    sync: SyncPolicy,
    *,
    hbm_bytes: float,
    ctx_len: int,
    kv_block_size: int = 16,
    kv_dtype: str = "bf16",
) -> int:
    """How many requests of ``ctx_len`` positions fit per device.

    Free HBM after the serve-mode base footprint (param shards + gather
    buffers), divided by one request's block-rounded KV bytes.  This is
    what sizes the paged pool (``MiCSConfig.max_resident_requests == 0``)
    and what the serve harness verifies against the compiled
    ``memory_analysis()`` (same discipline as the training planner).
    """
    base = predict_footprint(model, topo, gather, sync, mode="serve")
    free = float(hbm_bytes) - base.total_bytes
    blocks = math.ceil(max(ctx_len, 1) / kv_block_size)
    per_req = blocks * kv_block_size * kv_token_bytes(model, kv_dtype)
    return max(int(free // per_req), 0)


# graceful-degradation dtype order: each step right is lossier but smaller
_KV_LADDER = ("fp32", "bf16", "int8")


def degradation_levels(
    model,
    topo,
    gather: GatherPolicy,
    sync: SyncPolicy,
    *,
    hbm_bytes: float,
    ctx_len: int,
    kv_block_size: int = 16,
    kv_ceiling: str = "bf16",
    tighten: float = 0.5,
) -> list[dict]:
    """Price a graceful-degradation ladder for the serving scheduler.

    Returns ordered ``{"kv_dtype", "resident_cap", "label"}`` levels for
    :class:`repro.runtime.batching.DegradationLadder` (plain dicts — core
    must not import runtime):

    - level 0: the configured operating point — ``kv_ceiling`` KV at the
      full :func:`max_resident_requests` residency;
    - level 1: same dtype, residency tightened by ``tighten`` — fewer
      residents means fewer evictions and less replayed work under
      ``reserve="min"`` thrash;
    - level 2+: one lossier KV dtype per level (bf16 → int8), each priced
      at its own (larger) planner residency, again tightened.

    Every cap is at least 1, so the ladder degrades throughput and
    numerics but can never deadlock admission.
    """
    if kv_ceiling not in _KV_LADDER:
        raise ValueError(f"unknown kv dtype {kv_ceiling!r}")
    if not 0.0 < tighten <= 1.0:
        raise ValueError("tighten must be in (0, 1]")

    def cap(dt):
        return max_resident_requests(
            model, topo, gather, sync, hbm_bytes=hbm_bytes, ctx_len=ctx_len,
            kv_block_size=kv_block_size, kv_dtype=dt)

    r0 = cap(kv_ceiling)
    levels = [
        {"kv_dtype": kv_ceiling, "resident_cap": max(r0, 1),
         "label": "configured"},
        {"kv_dtype": kv_ceiling, "resident_cap": max(int(r0 * tighten), 1),
         "label": "tightened"},
    ]
    for dt in _KV_LADDER[_KV_LADDER.index(kv_ceiling) + 1:]:
        levels.append({"kv_dtype": dt,
                       "resident_cap": max(int(cap(dt) * tighten), 1),
                       "label": f"kv_{dt}"})
    return levels


@dataclasses.dataclass(frozen=True)
class DeviceGrid:
    """The three sizes the footprint model needs — duck-types MiCSTopology
    so the planner runs device-free (partition-group auto-sizing iterates
    these without building meshes)."""

    partition_size: int
    replication_degree: int = 1


@dataclasses.dataclass(frozen=True)
class MemPlan:
    """Predicted per-device HBM footprint of one step."""

    components: dict           # transient component -> bytes
    args_bytes: float          # donated state + batch (exact)
    mode: str

    @property
    def temp_bytes(self) -> float:
        return float(sum(self.components.values()))

    @property
    def total_bytes(self) -> float:
        return self.args_bytes + self.temp_bytes

    @property
    def total_gb(self) -> float:
        return self.total_bytes / GIB

    def describe(self) -> dict:
        return {
            "args_bytes": self.args_bytes,
            "temp_bytes": self.temp_bytes,
            "total_bytes": self.total_bytes,
            "total_gib": self.total_gb,
            "components": dict(self.components),
            "mode": self.mode,
        }


def _pool_shapes(model) -> dict:
    return model.global_flat_shapes()


def predict_footprint(
    model,
    topo,
    gather: GatherPolicy,
    sync: SyncPolicy,
    *,
    micro_steps: int = 1,
    mode: str = "train",
    local_batch: int = 0,
    seq: int = 0,
    boundary: str = "bucketed",
    hop2_bucket_mb: float = 32.0,
    offload_opt: bool = False,
    kv_pages_tokens: int = 0,
    kv_dtype: str = "bf16",
    decode_batch: int = 0,
    decode_ctx: int = 0,
    decode_chunk: int = 0,
    kv_max_blocks: int = 0,
) -> MemPlan:
    """Per-device HBM footprint of one training/serving step.

    ``topo`` needs only ``partition_size`` and ``replication_degree``
    (:class:`DeviceGrid` suffices).  ``local_batch``/``seq`` size the
    activation-checkpoint and logits terms; pass 0 to price model states
    and communication buffers only (what ``resolve_config`` does — the
    dry-run passes the real shapes).  All byte counts are per device.

    Host offload shifts bytes out of this budget: with
    ``gather.carry_offload='host'`` the stored prefetch carry's
    O(stack x flat_len) residual leaves HBM (only the rotated shard copy
    and a transient full buffer remain, same as remat), and with
    ``offload_opt=True`` the fp32 ``m``/``v`` shards leave the donated
    arguments entirely (2 x state shard bytes), replaced by a transient
    staging term for the shards streamed back during the boundary.  The
    *time* cost of those streams is priced by the autotuner against the
    link model's ``host`` tier — this module only accounts bytes.
    """
    p = max(int(topo.partition_size), 1)
    repl = max(int(getattr(topo, "replication_degree", 1)), 1)
    cb = _COMPUTE_BYTES[gather.wire_dtype]
    shapes = _pool_shapes(model)
    scanned = {pl.name for pl in model.pools}
    train = mode == "train"

    shard4 = {name: stack * math.ceil(flat_len / p) * 4
              for name, (stack, _tp, flat_len) in shapes.items()}
    s4 = float(sum(shard4.values()))          # one fp32 state copy / device

    # -- arguments (exact): fp32 params (+ m + v unless host-offloaded)
    # shards, step scalar, batch --
    state_copies = 1.0 if offload_opt else 3.0
    args = state_copies * s4 + 4.0 if train else s4
    if train and local_batch and seq:
        # tokens + targets (int32) + mask (f32), stacked over micro-steps
        args += micro_steps * local_batch * seq * 12.0

    comp: dict[str, float] = {}

    def add(name: str, nbytes: float):
        if nbytes > 0:
            comp[name] = comp.get(name, 0.0) + float(nbytes)

    # -- gather buffers: the full flat buffer per pool being applied -------
    prefetching = gather.prefetch
    max_flat = 0
    for name, (stack, _tp, flat_len) in shapes.items():
        max_flat = max(max_flat, flat_len)
        nbuf = 2 if (prefetching and name in scanned and stack > 1) else 1
        add("gather_buffers", flat_len * cb * nbuf)
    if gather.wire_dtype == "int8" and p > 1:
        # in-flight (q, scales) payloads of the largest gather
        add("int8_wire_scratch", 2 * max_flat * _INT8_BYTES)
    if gather.topology == "outer_first" and p > 1:
        add("reorder_copy", max_flat * cb)

    if not train:
        if local_batch and seq:
            for name, (stack, _tp, flat_len) in shapes.items():
                if name in scanned and getattr(model, "cfg", None):
                    add("activation_ckpt",
                        stack * local_batch * seq * model.cfg.d_model * cb)
        # paged-KV serving (runtime/paged.py): the block pool is a donated
        # argument like the param shards, exact by construction; the decode
        # step's transients are the per-layer gathered [b, MB*bs, h, dh]
        # k/v views plus the sampling logits workspace.
        if kv_pages_tokens:
            pool = kv_pages_tokens * kv_token_bytes(model, kv_dtype)
            args += pool
            # the decode scan double-buffers the donated pool carry; pools
            # stored narrower than fp32 additionally stage their f32
            # upcast during the write/read fusion (observed on the XLA
            # buffer ledger, held to MEM_RTOL by the serve harness)
            add("kv_pool_update", pool)
            if kv_dtype != "fp32":
                add("kv_pool_update",
                    kv_pages_tokens * kv_token_bytes(model, "fp32"))
        if decode_batch and decode_chunk:
            # the scheduler's fixed-shape plan rows (runtime/batching
            # StepPlan): tokens [b, chunk] + block table [b, max_blocks]
            # + pos/n_new/seeds (int32) + temps (f32) — donated-arg peers
            # of the KV pool, 4 bytes each.
            args += decode_batch * (decode_chunk + kv_max_blocks + 4) * 4.0
        if decode_batch and decode_ctx and getattr(model, "cfg", None):
            from repro.models.dims import attn_dims

            mcfg_ = model.cfg
            tp = max(int(getattr(model, "tp", 1)), 1)
            ad = attn_dims(mcfg_.d_model, mcfg_.n_heads, mcfg_.n_kv_heads,
                           mcfg_.resolved_head_dim, tp)
            view = 2.0 * decode_batch * decode_ctx * ad.hkv_local \
                * ad.head_dim * cb
            if kv_dtype == "int8":   # dequantize reads q + f32 scales too
                view += 2.0 * decode_batch * decode_ctx * ad.hkv_local \
                    * (ad.head_dim + math.ceil(ad.head_dim / BLOCK) * 4)
            add("kv_gather_view", view)
            vocab = int(getattr(model, "vocab_padded", mcfg_.vocab))
            add("decode_logits", decode_batch * (vocab // tp) * 8)
        return MemPlan(components=comp, args_bytes=args, mode=mode)

    # -- gradient accumulator + its micro-loop double buffer ---------------
    add("grad_accum", s4)
    add("grad_loop_buffer", s4)
    # -- the hop-2-reduced fp32 gradient copy the boundary materializes ----
    add("boundary_reduced", s4)
    # -- backward: the largest full-buffer cotangent (fp32 adjoint input) --
    add("gather_adjoint", max_flat * 4)

    # -- prefetch-carry backward residual (GatherPolicy.prefetch_carry) ----
    # stored: the stacked carried buffer persists at fp32 (observed: the
    # adjoint accumulation dtype) + the rotated shard copy.  remat: only
    # the rotated shard copy + one transient re-gathered buffer.  Mirrors
    # models/lm.py's routing: enc-dec *decoder* pools consume the encoder
    # output and fall back to the stored carry even under remat (a custom
    # VJP may not close over a gradient-carrying enc_out), so they are
    # priced as stored — the budget gate must not under-predict them.
    # Host offload ('carry_offload') prices like remat — the stacked
    # residual streams to host memory, leaving the rolled shard copy and
    # one transient full buffer — and shares remat's enc-dec fallback
    # (decoder pools keep the stored carry, models/lm.py routing).
    cfg = getattr(model, "cfg", None)
    family = getattr(cfg, "family", None)
    offload_carry = getattr(gather, "carry_offload", "none") == "host"
    for name, (stack, _tp, flat_len) in shapes.items():
        if not (prefetching and name in scanned and stack > 1):
            continue
        rolled = stack * math.ceil(flat_len / p) * 4
        eligible = not (family == "encdec" and not name.startswith("enc"))
        if eligible and (gather.prefetch_carry == "remat" or offload_carry):
            add("prefetch_carry", rolled + flat_len * cb)
        else:
            add("prefetch_carry", stack * flat_len * 4 + rolled)

    # -- activation checkpoints + logits/CE workspace ----------------------
    if local_batch and seq and cfg is not None:
        for name, (stack, _tp, flat_len) in shapes.items():
            if name in scanned:
                add("activation_ckpt",
                    stack * local_batch * seq * cfg.d_model * cb)
        tp = max(int(getattr(model, "tp", 1)), 1)
        vocab = int(getattr(model, "vocab_padded", cfg.vocab))
        add("logits_ce", local_batch * seq * (vocab // tp) * 8)

    # -- hop-2 staging (replication-group boundary) ------------------------
    if repl > 1 and sync.mode == "2hop":
        max_shard4 = max(shard4.values())
        eff = max_shard4 if boundary == "serial" \
            else min(hop2_bucket_mb * 1e6, max_shard4)
        add("hop2_staging", 2 * eff)
        if sync.hop2_wire_dtype == "int8":
            add("hop2_qgz_scratch", 2 * eff / 4 * _INT8_BYTES)

    # -- qgZ hop-1 scratch --------------------------------------------------
    if sync.hop1_wire_dtype == "int8" and p > 1:
        add("qgz_scratch", max_flat * QGZ_SCRATCH_BYTES_PER_ELEM)

    # -- host-offloaded optimizer staging ----------------------------------
    # The m/v shards of the pool being updated stream back for the AdamW
    # update (core/schedule.py fetches per pool under the exact clip, per
    # bucket under approx).  They add NO temp bytes: the fetched moments
    # land after the boundary's reduced-gradient buffers retire, and XLA's
    # buffer assigner reuses those slots (verified against
    # memory_analysis() in tests/memplan_harness.py::offload_lowers_peak —
    # pricing a 2x-max-shard staging term there overshoots the compiled
    # temps by exactly that amount), so offload_opt only shrinks the
    # argument bytes above.

    return MemPlan(components=comp, args_bytes=args, mode=mode)


# ---------------------------------------------------------------------------
# scale-aware partition-group auto-sizing (the paper's §3.1 rule)
# ---------------------------------------------------------------------------

def partition_size_candidates(data_extent: int) -> list[int]:
    """Partition-group sizes a data axis of ``data_extent`` admits,
    ascending — every divisor, so the minimal fitting one is exact."""
    if data_extent < 1:
        raise ValueError(f"data_extent must be >= 1, got {data_extent}")
    return [d for d in range(1, data_extent + 1) if data_extent % d == 0]


def min_partition_size(
    model,
    *,
    data_extent: int,
    hbm_budget_gb: float,
    gather: GatherPolicy = GatherPolicy(),
    sync: SyncPolicy = SyncPolicy(),
    micro_steps: int = 1,
    mode: str = "train",
    local_batch: int = 0,
    seq: int = 0,
    boundary: str = "bucketed",
    hop2_bucket_mb: float = 32.0,
    carries: tuple = ("stored",),
    offload_opt: bool = False,
    extra_replication: int = 1,
) -> tuple[int, str, MemPlan]:
    """The paper's scale-aware partitioning rule, analytically.

    Walks partition-group sizes ascending (divisors of ``data_extent`` —
    the mesh axis the partition group is carved from) and returns the
    first ``(p, carry, plan)`` whose predicted per-device footprint fits
    ``hbm_budget_gb`` GiB — the *minimal* group that fits, trying each
    entry of ``carries`` in order at every size (pass
    ``("stored", "remat", "host")`` to let the remat and host-offload
    mitigations rescue a smaller group before growing it; ``"host"``
    means the stored carry streamed to host memory,
    ``GatherPolicy.carry_offload='host'``, and is skipped when the gather
    policy does not prefetch).  ``extra_replication`` multiplies the
    replication degree for data-parallel axes the group cannot span (the
    pod axis of a multi-pod mesh, the dp2 leftover of tp < model axis) so
    hop-2 staging is priced even when p == data_extent.  Raises
    :class:`MemoryBudgetError` when even the whole data axis (ZeRO-3
    scale) does not fit — never a silent empty plan.
    """
    budget = float(hbm_budget_gb) * GIB
    best = None
    for p in partition_size_candidates(data_extent):
        grid = DeviceGrid(
            partition_size=p,
            replication_degree=(data_extent // p) * max(extra_replication, 1))
        for carry in carries:
            if carry == "host":
                if not gather.prefetch:
                    continue
                g2 = dataclasses.replace(
                    gather, prefetch_carry="stored", carry_offload="host")
            else:
                g2 = dataclasses.replace(
                    gather, prefetch_carry=carry, carry_offload="none")
            plan = predict_footprint(
                model, grid, g2, sync, micro_steps=micro_steps, mode=mode,
                local_batch=local_batch, seq=seq, boundary=boundary,
                hop2_bucket_mb=hop2_bucket_mb, offload_opt=offload_opt)
            if best is None or plan.total_bytes < best[2].total_bytes:
                best = (p, carry, plan)
            if plan.total_bytes <= budget:
                return p, carry, plan
    assert best is not None
    raise MemoryBudgetError(
        f"no partition group fits hbm_budget_gb={hbm_budget_gb}: the "
        f"smallest candidate (p={best[0]}, prefetch_carry={best[1]!r}) "
        f"needs {best[2].total_gb:.3f} GiB per device "
        f"(args {best[2].args_bytes / GIB:.3f} + "
        f"temp {best[2].temp_bytes / GIB:.3f}); raise the budget, shrink "
        f"the model, or grow the mesh")
