"""CommEngine: the single construction point for every MiCS collective.

The paper's win comes from *who* talks (partition groups of size p, §3.2) and
*how* they talk (hierarchical staging §3.3, coalesced flat buffers §4,
two-hop gradient sync §3.4).  Before this module those policy decisions were
smeared across ``collectives.py``, ``mics.py``, ``quant.py`` and
``serving.py`` as ad-hoc flags; here they are one object:

* :class:`GatherPolicy` — per-pool choice of collective **topology**
  (``flat`` single collective / ``inner_first`` 2-stage / ``outer_first``
  paper-faithful 3-stage), **wire dtype** (``fp32`` / ``bf16`` / ``int8``
  blockwise-quantized à la ZeRO++ qwZ — subsuming the old serving-only
  ``quant.py`` path), and the **double-buffered prefetch schedule** (layer
  i+1's all-gather issued during layer i's compute).
* :class:`SyncPolicy` — hop-1 adjoint mode (exact staged reduce-scatter vs
  the Fig-14 ``allreduce_slice`` ablation), the hop-1 wire dtype (``fp32``
  exact / ``bf16`` / ``int8`` ZeRO++-qgZ-style per-stage block-quantized
  reduce-scatter with fp32 inter-stage accumulation), and hop-2 wire
  compression (``fp32`` / ``bf16`` / ``int8`` quantized all-reduce).
* :class:`CommEngine` — binds the policies to a :class:`MiCSTopology` and
  owns the **centralized custom-VJP machinery**: each forward gather policy
  is paired with its *exact* adjoint reduce-scatter
  (``collectives.hierarchical_reduce_scatter`` mirrors the gather stages in
  reverse), so hop-1 gradient synchronization materializes identically for
  every topology/wire combination from plain ``jax.grad``.

Consumers (``mics.build_train_step``, ``runtime/serving.py``,
``launch/dryrun.py``, ``benchmarks``) construct a CommEngine from
``MiCSConfig``/``MiCSTopology`` via :meth:`CommEngine.from_config` and never
touch raw collectives again.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collectives as C
from repro.core import quant as Q
from repro.core.flat_param import model_gather_fn_for
from repro.core.topology import MODEL_AXIS, MiCSTopology, hierarchy_factors

GATHER_TOPOLOGIES = ("flat", "inner_first", "outer_first")
WIRE_DTYPES = ("fp32", "bf16", "int8")
PREFETCH_CARRIES = ("stored", "remat")
CARRY_OFFLOADS = ("none", "host")
SYNC_MODES = ("2hop", "allreduce_slice")
HOP1_WIRE_DTYPES = ("fp32", "bf16", "int8")
HOP2_WIRE_DTYPES = ("fp32", "bf16", "int8")
GRAD_ROUNDINGS = ("stochastic", "nearest")

_WIRE_JNP = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class GatherPolicy:
    """How a flat-param pool is all-gathered across its partition group.

    ``prefetch_carry`` decides what the double-buffered schedule keeps for
    the backward pass (only meaningful with ``prefetch=True``):
    ``'stored'`` carries the gathered flat buffer as a per-layer scan
    residual (no backward re-gather — the seed behavior, O(layers x
    flat_len) HBM); ``'remat'`` drops the carried buffer and re-issues the
    gather inside the backward instead (one extra all-gather per layer,
    O(layers x shard) HBM — the memory-planner mitigation knob,
    models/lm.py).

    ``carry_offload='host'`` is the third residual strategy: keep the
    stored carry's schedule (no backward re-gather) but stream each
    layer's gathered buffer to host memory in the forward and back to
    device in the backward (core/hostoffload.py) — O(layers x shard) HBM
    like remat, priced as the link model's host tier instead of an extra
    all-gather.  It composes with the *stored* carry only (it replaces
    the stored residual's residency, not remat's re-gather).
    """

    topology: str = "inner_first"  # 'flat' | 'inner_first' | 'outer_first'
    wire_dtype: str = "bf16"       # 'fp32' | 'bf16' | 'int8' (ZeRO++ qwZ)
    inner: int | None = None       # intra-"node" factor for staged gathers
    prefetch: bool = True          # one-slot lookahead layer scan
    prefetch_carry: str = "stored"  # 'stored' | 'remat' backward residual
    carry_offload: str = "none"    # 'none' | 'host' (d2h/h2d carry stream)

    def __post_init__(self):
        if self.topology not in GATHER_TOPOLOGIES:
            raise ValueError(f"unknown gather topology {self.topology!r}")
        if self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(f"unknown wire dtype {self.wire_dtype!r}")
        if self.prefetch_carry not in PREFETCH_CARRIES:
            raise ValueError(
                f"unknown prefetch_carry {self.prefetch_carry!r} "
                f"(expected one of {PREFETCH_CARRIES})")
        if self.carry_offload not in CARRY_OFFLOADS:
            raise ValueError(
                f"unknown carry_offload {self.carry_offload!r} "
                f"(expected one of {CARRY_OFFLOADS})")
        if self.carry_offload == "host" and not (
                self.prefetch and self.prefetch_carry == "stored"):
            raise ValueError(
                "carry_offload='host' requires prefetch=True and "
                "prefetch_carry='stored' (it offloads the stored carry's "
                "residual; remat has no carried buffer to offload)")


@dataclasses.dataclass(frozen=True)
class SyncPolicy:
    """How gradients synchronize (paper §3.4).

    ``hop1_wire_dtype`` is what the per-micro-step adjoint reduce-scatter
    ships: ``'fp32'`` keeps today's behavior (the staged reduce-scatter runs
    in the gather's natural cotangent dtype — bitwise identical to the
    pre-qgZ tree), ``'bf16'`` casts the cotangent before the float staged
    reduce-scatter, ``'int8'`` is the ZeRO++-qgZ analogue — a per-stage
    block-quantized reduce-scatter (int8 + f32 block scales per hop, fp32
    accumulation between hops, ``collectives.quantized_reduce_scatter``).
    ``hop2_wire_dtype='int8'`` is the matching boundary leg (quantized
    reduce-scatter + all-gather, ``collectives.quantized_all_reduce``).
    ``grad_rounding`` picks the int8 gradient quantizer's rounding:
    ``'stochastic'`` (unbiased in expectation, the default) or ``'nearest'``.
    """

    mode: str = "2hop"             # '2hop' | 'allreduce_slice' (Fig 14)
    hop2_wire_dtype: str = "fp32"  # 'fp32' | 'bf16' | 'int8' hop-2 wire
    hop1_wire_dtype: str = "fp32"  # 'fp32' | 'bf16' | 'int8' (ZeRO++ qgZ)
    grad_rounding: str = "stochastic"  # int8 gradient-quantizer rounding

    def __post_init__(self):
        if self.mode not in SYNC_MODES:
            raise ValueError(f"unknown sync mode {self.mode!r}")
        if self.hop2_wire_dtype not in HOP2_WIRE_DTYPES:
            raise ValueError(f"unknown hop-2 wire dtype {self.hop2_wire_dtype!r}")
        if self.hop1_wire_dtype not in HOP1_WIRE_DTYPES:
            raise ValueError(f"unknown hop-1 wire dtype {self.hop1_wire_dtype!r}")
        if self.grad_rounding not in GRAD_ROUNDINGS:
            raise ValueError(f"unknown grad rounding {self.grad_rounding!r}")
        if self.hop1_wire_dtype != "fp32" and self.mode != "2hop":
            raise ValueError(
                "hop-1 wire compression requires the 2hop schedule (the "
                "allreduce_slice ablation has no staged hop-1 to compress)")

    @property
    def stochastic(self) -> bool:
        return self.grad_rounding == "stochastic"


def policies_from_config(mcfg) -> tuple[GatherPolicy, SyncPolicy]:
    """Interpret a ``MiCSConfig``'s legacy flags as (GatherPolicy,
    SyncPolicy) — topology-free, so the memory planner and partition-group
    auto-sizing can price policies before any mesh exists.  The one place
    those flags are interpreted (``CommEngine.from_config`` calls this)."""
    topology = mcfg.gather_order if mcfg.hierarchical else "flat"
    compute = jnp.dtype(mcfg.gather_dtype)
    if mcfg.quant_gather:
        wire = "int8"
    else:
        wire = "bf16" if compute == jnp.dtype(jnp.bfloat16) else "fp32"
    gp = GatherPolicy(
        topology=topology,
        wire_dtype=wire,
        inner=mcfg.hierarchy_inner,
        prefetch=getattr(mcfg, "prefetch", True),
        prefetch_carry=getattr(mcfg, "prefetch_carry", "stored"),
        carry_offload=getattr(mcfg, "carry_offload", "none"),
    )
    hop2 = mcfg.compress_hop2  # bool (legacy) or wire-dtype string
    if hop2 is True:
        hop2 = "bf16"
    elif not hop2:
        hop2 = "fp32"
    sp = SyncPolicy(
        mode=mcfg.sync_mode,
        hop2_wire_dtype=hop2,
        hop1_wire_dtype=getattr(mcfg, "hop1_wire_dtype", "fp32"),
        grad_rounding=getattr(mcfg, "grad_rounding", "stochastic"),
    )
    return gp, sp


class CommEngine:
    """Owns every parameter-gather and gradient-sync collective of one run.

    One engine per (topology, policy) pair; construction is cheap and the
    engine is closed over by jitted step functions (all members are static).
    """

    def __init__(
        self,
        topo: MiCSTopology,
        gather_policy: GatherPolicy = GatherPolicy(),
        sync_policy: SyncPolicy = SyncPolicy(),
        *,
        compute_dtype: Any = jnp.bfloat16,
        model_axis: str = MODEL_AXIS,
    ):
        self.topo = topo
        self.gather_policy = gather_policy
        self.sync_policy = sync_policy
        self.compute_dtype = compute_dtype
        self.model_axis = model_axis
        self._model_gather_fn = model_gather_fn_for(model_axis, topo.model_size)
        self._gather_vjp = self._build_gather_vjp(quantized=False)
        self._quant_gather_vjp = self._build_gather_vjp(quantized=True)
        self._gather_vjp_seeded = self._build_gather_vjp(
            quantized=False, seeded=True)
        self._quant_gather_vjp_seeded = self._build_gather_vjp(
            quantized=True, seeded=True)
        self._host_stash = None     # lazy (hostoffload.HostStash)
        self._carry_tags: dict = {}  # pool name -> stash tag

    # -- construction -------------------------------------------------------
    @classmethod
    def from_config(cls, topo: MiCSTopology, mcfg) -> "CommEngine":
        """Map a ``MiCSConfig`` onto gather/sync policies (the one place the
        legacy flags are interpreted)."""
        gp, sp = policies_from_config(mcfg)
        return cls(topo, gp, sp, compute_dtype=mcfg.gather_dtype)

    # -- properties ---------------------------------------------------------
    @property
    def prefetch(self) -> bool:
        return self.gather_policy.prefetch

    @property
    def prefetch_carry(self) -> str:
        return self.gather_policy.prefetch_carry

    @property
    def carry_offload(self) -> str:
        return self.gather_policy.carry_offload

    @property
    def partition_size(self) -> int:
        return self.topo.partition_size

    @property
    def host_stash(self):
        """Lazy host-memory stash bound to this topology's mesh — the
        d2h/h2d stream backing ``carry_offload='host'`` and the offloaded
        optimizer moments (core/hostoffload.py)."""
        if self._host_stash is None:
            from repro.core.hostoffload import HostStash

            self._host_stash = HostStash(
                tuple(zip(self.topo.mesh.axis_names,
                          self.topo.mesh.devices.shape)))
        return self._host_stash

    def carry_tag(self, pool_name: str) -> int:
        """Stable per-engine stash tag for a pool's offloaded carry."""
        from repro.core.hostoffload import TAG_CARRY_BASE

        if pool_name not in self._carry_tags:
            self._carry_tags[pool_name] = TAG_CARRY_BASE + len(self._carry_tags)
        return self._carry_tags[pool_name]

    def gather_out_dtype(self):
        """Dtype of :meth:`gather_flat`'s full buffer (the wire dtype for
        float wires, the compute dtype for the int8 wire)."""
        gp = self.gather_policy
        if gp.wire_dtype == "int8":
            return jnp.dtype(self.compute_dtype)
        return jnp.dtype(_WIRE_JNP[gp.wire_dtype])

    def describe(self) -> dict:
        """Static policy record (dry-run artifacts, BENCH json)."""
        outer, inner = hierarchy_factors(self.topo, self.gather_policy.inner) \
            if self.topo.partition_size > 1 else (1, 1)
        return {
            "gather": dataclasses.asdict(self.gather_policy),
            "sync": dataclasses.asdict(self.sync_policy),
            "compute_dtype": jnp.dtype(self.compute_dtype).name,
            "partition_axes": list(self.topo.partition_axes),
            "replication_axes": list(self.topo.replication_axes),
            "partition_size": self.topo.partition_size,
            "replication_degree": self.topo.replication_degree,
            "hierarchy": {"outer": outer, "inner": inner},
        }

    # -- raw policy collectives (no VJP override) ---------------------------
    def _policy_all_gather(self, x: jax.Array) -> jax.Array:
        gp = self.gather_policy
        if self.topo.partition_size == 1:
            return x
        if gp.topology == "flat":
            return C.flat_all_gather(x, self.topo.partition_axes)
        return C.hierarchical_all_gather(
            x, self.topo, order=gp.topology, inner=gp.inner)

    def _policy_reduce_scatter(self, g: jax.Array) -> jax.Array:
        gp = self.gather_policy
        if self.topo.partition_size == 1:
            return g
        if gp.topology == "flat":
            return C.hop1_reduce_scatter(g, self.topo)
        return C.hierarchical_reduce_scatter(
            g, self.topo, order=gp.topology, inner=gp.inner)

    # -- centralized custom-VJP gathers -------------------------------------
    def _adjoint(self, ct: jax.Array, seed=None) -> jax.Array:
        """Hop-1 of §3.4 — or the Fig-14 alternative schedule's full
        all-reduce + slice when the ablation is selected.

        The wire is picked by ``SyncPolicy.hop1_wire_dtype``: ``fp32`` runs
        the staged reduce-scatter in the cotangent's own dtype (bitwise
        today's behavior), ``bf16`` narrows the cotangent first, ``int8``
        runs the qgZ per-stage block-quantized reduce-scatter (int8 + f32
        scales per hop, fp32 accumulation between hops) mirroring the
        gather topology.  The return dtype always matches the cotangent, so
        every gather policy composes with every hop-1 wire.  ``seed`` is the
        step-varying dither seed for the int8 wire's stochastic rounding
        (threaded from the train step; the float wires ignore it).
        """
        if self.sync_policy.mode == "allreduce_slice":
            return C.alternative_sync(ct, self.topo)
        hop1 = self.sync_policy.hop1_wire_dtype
        if hop1 == "int8" and self.topo.partition_size > 1:
            gp = self.gather_policy
            out = C.quantized_reduce_scatter(
                ct, self.topo, topology=gp.topology, inner=gp.inner,
                stochastic=self.sync_policy.stochastic, seed=seed)
            return out.astype(ct.dtype)
        if hop1 == "bf16":
            return self._policy_reduce_scatter(
                ct.astype(jnp.bfloat16)).astype(ct.dtype)
        return self._policy_reduce_scatter(ct)

    def _build_gather_vjp(self, *, quantized: bool, seeded: bool = False):
        """One parameterized builder for both wire families.

        ``quantized=False``: the float wire — gather the row as-is (callers
        cast to the wire dtype).  ``quantized=True``: the int8 blockwise
        wire (ZeRO++ qwZ) — quantize the local fp32 shard to (int8 q, f32
        block scales), all-gather both with the policy topology, dequantize
        to the compute dtype.  Either way the backward is straight-through:
        :meth:`_adjoint` of the (float) cotangent — the exact staged
        reduce-scatter, or its bf16/int8-wire variant when ``SyncPolicy``
        compresses hop 1; the forward quantizer is never differentiated.

        ``seeded=True`` builds the ``gather(row, seed)`` variant: ``seed``
        is a traced int32 scalar (the training step counter) carried as a
        VJP residual into the adjoint, where the int8 hop-1 wire folds it
        into its stochastic-rounding dither key in place of the payload
        fingerprint — the step-varying, value-independent dither the
        ROADMAP qgZ follow-on asked for.  The seed is inert data (integer
        cotangent is float0); float hop-1 wires ignore it entirely.
        """

        def fwd_gather(row):
            if not quantized:
                return self._policy_all_gather(row)
            q, s = Q.quantize_flat(row)
            qg = self._policy_all_gather(q)
            sg = self._policy_all_gather(s)
            return Q.dequantize_flat(qg, sg, dtype=self.compute_dtype)

        if seeded:

            @jax.custom_vjp
            def gather(row, seed):
                return fwd_gather(row)

            def fwd(row, seed):
                return fwd_gather(row), seed

            def bwd(seed, ct):
                if quantized:
                    ct = ct.astype(jnp.float32)
                ct_seed = np.zeros(jnp.shape(seed), jax.dtypes.float0)
                return self._adjoint(ct, seed=seed), ct_seed

            gather.defvjp(fwd, bwd)
            return gather

        @jax.custom_vjp
        def gather(row):
            return fwd_gather(row)

        def fwd(row):
            return fwd_gather(row), None

        def bwd(_, ct):
            if quantized:
                ct = ct.astype(jnp.float32)
            return (self._adjoint(ct),)

        gather.defvjp(fwd, bwd)
        return gather

    # -- public gather API --------------------------------------------------
    def gather_flat(self, row, *, seed=None) -> jax.Array:
        """Gather one layer's flat shard into the full flat buffer.

        ``row`` is either a float shard ``[S_local]`` or a pre-quantized
        serving dict ``{'q': int8, 's': f32}`` (``quant.quantize_state``).
        Float wires return the buffer in the wire dtype (which doubles as
        the compute dtype — ``from_config`` keeps them identical); int8
        and stored-int8 rows dequantize to ``compute_dtype``.  One call per
        layer — the coalesced communication of paper §4 by construction.

        ``seed`` (optional traced int32, the training step counter) rides
        the VJP into the adjoint so the int8 qgZ hop-1 wire draws
        step-varying, value-independent dither; ``None`` keeps the legacy
        payload-fingerprint dither (serving and standalone gathers).
        """
        gp = self.gather_policy
        if isinstance(row, dict):  # stored-int8 serving weights
            qg = self._policy_all_gather(row["q"])
            sg = self._policy_all_gather(row["s"])
            return Q.dequantize_flat(qg, sg, dtype=self.compute_dtype)
        if gp.wire_dtype == "int8":
            if self.topo.partition_size == 1:  # nothing on the wire
                return row.astype(self.compute_dtype)
            if seed is not None:
                return self._quant_gather_vjp_seeded(row, seed)
            return self._quant_gather_vjp(row)
        row = row.astype(_WIRE_JNP[gp.wire_dtype])
        if seed is not None:
            return self._gather_vjp_seeded(row, seed)
        return self._gather_vjp(row)

    def unflatten(self, pool, full: jax.Array) -> dict[str, jax.Array]:
        """Rebuild layer tensors, reassembling model-axis-sharded segments."""
        return pool.layout.unflatten(full, model_gather_fn=self._model_gather_fn)

    def gather(self, pool, row, *, seed=None) -> dict[str, jax.Array]:
        return self.unflatten(pool, self.gather_flat(row, seed=seed))

    def gather_flat_adjoint(self, ct: jax.Array, *, seed=None) -> jax.Array:
        """The standalone hop-1 adjoint of :meth:`gather_flat`: full-buffer
        cotangent in, fp32 shard cotangent out.

        Composes exactly what autodiff of ``gather_flat`` composes —
        the custom-VJP backward (:meth:`_adjoint`, including the bf16/int8
        hop-1 wire variants) plus the transpose of the outer wire-dtype
        cast back to the fp32 row — *without* re-running the gather
        forward.  The host-offload carry's hand-rolled backward
        (models/lm.py) needs precisely this: it already holds the full
        buffer (streamed back from the host), so ``jax.vjp`` of the gather
        would re-issue the all-gather for nothing.
        """
        gp = self.gather_policy
        if gp.wire_dtype == "int8":
            if self.topo.partition_size == 1:   # forward was a pure cast
                return ct.astype(jnp.float32)
            return self._adjoint(ct.astype(jnp.float32), seed=seed)
        return self._adjoint(ct, seed=seed).astype(jnp.float32)

    # -- gradient synchronization ------------------------------------------
    def hop1_reduce_scatter(self, g: jax.Array) -> jax.Array:
        """Explicit hop-1 (tests / alternative schedules); normally this
        arises as the VJP of :meth:`gather_flat`."""
        return self._policy_reduce_scatter(g)

    def hop2(self, g: jax.Array, *, salt: int = 0, seed=None) -> jax.Array:
        """Replication-group all-reduce at the gradient-accumulation
        boundary (§3.4 hop 2), with optional bf16 or int8 wire compression.
        A no-op under the alternative schedule (its backward already
        all-reduced globally).

        ``int8`` is the quantized decompress leg: reduce-scatter +
        all-gather, both shipping (int8 q, f32 block scales) with an fp32
        accumulation in between (``collectives.quantized_all_reduce``);
        ``salt`` decorrelates the stochastic-rounding dither across payloads
        and ``seed`` (the traced step counter) across steps — both ignored
        by the float wires.
        """
        if self.sync_policy.mode != "2hop":
            return g
        wire = self.sync_policy.hop2_wire_dtype
        if wire == "int8" and self.topo.replication_degree > 1:
            return C.quantized_all_reduce(
                g, self.topo, salt=salt,
                stochastic=self.sync_policy.stochastic, seed=seed)
        if wire == "bf16":
            g = g.astype(jnp.bfloat16)
        g = C.hop2_all_reduce(g, self.topo)
        return g.astype(jnp.float32)

    def hop2_bucketed(self, bucket: jax.Array, *, salt: int = 0,
                      seed=None) -> jax.Array:
        """Hop 2 at bucket granularity: the identical replication-group
        all-reduce (same axes, same optional wire compression) applied to
        one fixed-byte slice of a pool's flat gradient shard.

        The boundary scheduler (core/schedule.py) issues these one bucket
        ahead of the dependent norm/decompress compute so the collective
        overlaps it.  Because ``psum`` (and the bf16 cast) is elementwise,
        a bucket of the reduced buffer is bitwise equal to the reduction of
        the bucket — which is what makes the bucketed boundary exactly
        equivalent to the serial reference for the fp32/bf16 wires.  The
        int8 wire's quantization blocks follow the *payload*, so its
        schedules agree only to quantization error (core/collectives.py).
        This stays the single construction point for the collective: same
        code path as :meth:`hop2`, just a different payload shape.
        """
        return self.hop2(bucket, salt=salt, seed=seed)

    # -- misc reductions -----------------------------------------------------
    def partition_coord(self):
        """Linearized index of this device within its partition group."""
        return C._partition_coord(self.topo)

    def replica_mean(self, x: jax.Array) -> jax.Array:
        return C.replica_mean(x, self.topo)
