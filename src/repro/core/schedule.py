"""Boundary scheduler: the gradient-accumulation boundary as a bucketed
software pipeline (hop-2 overlap, ROADMAP "Async hop-2 overlap").

The boundary of one training step is ``hop-2 all-reduce -> global-norm clip
-> AdamW`` (paper §3.4: the expensive cross-replica sync runs once per
accumulation boundary).  The seed implementation ran it as a monolithic
barrier: every pool's hop-2 completed before a single optimizer FLOP
issued.  This module refactors the boundary into a **plan + two schedules**:

* :func:`plan_boundary` partitions each pool's local gradient shard
  (``[stack, 1, shard_len]`` fp32) into fixed-byte buckets
  (``core/flat_param.partition_buckets``) in one canonical order — pools in
  ``model.all_pools()`` order, offsets ascending.  Bucket count is a
  compile-time property of ``(model, topo, hop2_bucket_mb)``.
* ``serial`` schedule (:func:`apply_boundary`, the reference path): hop-2
  the whole gradient tree first, then compute, exactly like the seed.
* ``bucketed`` schedule: a software pipeline over the plan's buckets —
  bucket *k*'s hop-2 collective (``CommEngine.hop2_bucketed``) is issued
  *before* bucket *k−1*'s dependent compute (squared-norm partial, bf16
  wire decompress), so the collective has no data dependency on that
  compute and XLA's scheduler can overlap the two.  Once the clip scale is
  known the AdamW shard update runs per pool with the scale folded in.

**The exact-clip ordering argument.**  Global-norm clipping needs the norm
of *every* gradient element before *any* update applies, so the AdamW pass
can never overlap the last bucket's hop-2 — but everything before it can.
To keep the two schedules bitwise identical at every bucket size, both
compute the squared norm the same way: a left-fold over per-bucket partials
in the plan's canonical order (the serial path folds over slices of the
pool-wise-reduced buffer; the bucketed path over the bucket-wise-reduced
buffers — elementwise ``psum``/casts commute with slicing, so the inputs
are bitwise equal, and the fold order is literally the same Python loop).
The denominator (``micro_steps * data_parallel``) and the clip factor are
folded into one ``grad_scale`` passed to ``adamw_shard_update`` — no
standalone full-gradient-tree division pass on either schedule.

**The int8 decompress leg** (qgZ follow-on).  With
``SyncPolicy.hop2_wire_dtype='int8'`` each hop-2 payload runs as a
block-quantized all-reduce (``collectives.quantized_all_reduce``: int8 +
f32 scales on both legs, fp32 accumulation between them), and the hidden
per-bucket compute grows the block *dequantize* on top of the norm
partial.  Unlike the elementwise bf16 cast, the quantization blocks follow
the payload, so int8 hop-2 results depend on payload granularity: serial
and bucketed agree to quantization error, not bitwise — the bitwise
schedule-equivalence guarantee above is for the fp32/bf16 wires.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.flat_param import partition_buckets
from repro.core.topology import MODEL_AXIS, MiCSTopology
from repro.optim.adamw import OptConfig, adamw_shard_update

BOUNDARY_SCHEDULES = ("serial", "bucketed")

# fp32 gradient accumulator bytes per element — what a bucket's byte budget
# is measured in (the wire payload may be narrower under bf16 hop-2).
GRAD_ITEMSIZE = 4


@dataclasses.dataclass(frozen=True)
class BucketRef:
    """One bucket: a static ``[lo, hi)`` slice of ``pool``'s flattened
    local gradient shard."""

    pool: str
    lo: int
    hi: int

    @property
    def elems(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class BoundaryPlan:
    """Static schedule of one gradient-accumulation boundary."""

    mode: str                          # 'serial' | 'bucketed'
    bucket_mb: float
    shard_elems: dict                  # pool -> local grad elements
    buckets: tuple                     # BucketRef, canonical order

    def __post_init__(self):
        if self.mode not in BOUNDARY_SCHEDULES:
            raise ValueError(f"unknown boundary schedule {self.mode!r} "
                             f"(expected one of {BOUNDARY_SCHEDULES})")

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def pool_buckets(self, pool: str) -> list:
        return [b for b in self.buckets if b.pool == pool]

    def hop2_payload_elems(self) -> list:
        """Element counts of the hop-2 collectives this plan issues, in
        order: one whole-pool payload per pool under ``serial``, one per
        bucket under ``bucketed``.  The single source of truth shared by
        the executor (:func:`apply_boundary`), the cost model
        (``autotune.cost_hop2_schedule``) and the census cross-checks
        (``dryrun``'s ``bucket_count_match``)."""
        if self.mode == "serial":
            return list(self.shard_elems.values())   # all_pools() order
        return [b.elems for b in self.buckets]

    @property
    def n_hop2_collectives(self) -> int:
        return len(self.hop2_payload_elems())

    def describe(self) -> dict:
        """Static record for dry-run artifacts / BENCH json."""
        per_pool = {}
        for b in self.buckets:
            per_pool[b.pool] = per_pool.get(b.pool, 0) + 1
        return {
            "mode": self.mode,
            "bucket_mb": self.bucket_mb,
            "n_buckets": self.n_buckets,
            "n_hop2_collectives": self.n_hop2_collectives,
            "buckets_per_pool": per_pool,
            "max_bucket_bytes": max(
                (b.elems * GRAD_ITEMSIZE for b in self.buckets), default=0),
        }


def plan_boundary(model, topo: MiCSTopology, *, mode: str,
                  bucket_mb: float) -> BoundaryPlan:
    """Bucketize every pool's local gradient shard into fixed-byte buckets.

    The same plan backs both schedules: the serial reference uses it only
    to order the squared-norm partials (so it stays bitwise comparable to
    the bucketed pipeline at any bucket size), the bucketed schedule
    additionally issues one hop-2 collective per bucket.
    """
    p = topo.partition_size
    shard_elems = {}
    buckets = []
    for pool in model.all_pools():
        stack, _tp, flat_len = model.global_flat_shapes()[pool.name]
        n = stack * (flat_len // p)
        shard_elems[pool.name] = n
        for lo, hi in partition_buckets(n, bucket_mb, GRAD_ITEMSIZE):
            buckets.append(BucketRef(pool.name, lo, hi))
    return BoundaryPlan(mode=mode, bucket_mb=float(bucket_mb),
                        shard_elems=shard_elems, buckets=tuple(buckets))


def _sq(bucket: jax.Array) -> jax.Array:
    """One bucket's squared-norm partial (fp32)."""
    return jnp.sum(jnp.square(bucket))


def _reduce_serial(plan: BoundaryPlan, comm, flat_grads: dict, seed=None):
    """Reference: whole-pool hop-2 first, then per-bucket norm partials.

    ``salt`` (the pool index) seeds the int8 hop-2 wire's stochastic-
    rounding dither per payload and ``seed`` (the traced step counter)
    decorrelates it across steps; the float wires ignore both.
    """
    reduced = {name: comm.hop2(g, salt=i, seed=seed)
               for i, (name, g) in enumerate(flat_grads.items())}
    sq_parts = [
        _sq(lax.slice_in_dim(reduced[b.pool], b.lo, b.hi, axis=0))
        for b in plan.buckets
    ]
    return reduced, sq_parts


def _reduce_bucketed(plan: BoundaryPlan, comm, flat_grads: dict, seed=None):
    """Software pipeline: issue bucket k's hop-2, then run bucket k−1's
    dependent compute (squared-norm partial + wire decompress — the bf16
    upcast, or the int8 leg's block dequantize).  The collective of bucket
    k has no data dependency on bucket k−1's compute, which is what lets
    the backend overlap the two; the drain step handles the last bucket.
    The global bucket index salts the int8 wire's dither so no two
    payloads of one boundary share a key (offsets repeat across pools —
    every pool has a bucket at lo=0 — so the plan-order index is the salt).
    """
    parts: dict[str, list] = {name: [] for name in flat_grads}
    sq_parts: list[jax.Array] = []
    pending = None  # (BucketRef, in-flight reduced bucket)

    def retire(ref, reduced_bucket):
        sq_parts.append(_sq(reduced_bucket))
        parts[ref.pool].append(reduced_bucket)

    for i, ref in enumerate(plan.buckets):
        raw = lax.slice_in_dim(flat_grads[ref.pool], ref.lo, ref.hi, axis=0)
        in_flight = comm.hop2_bucketed(raw, salt=i, seed=seed)  # bucket k
        if pending is not None:
            retire(*pending)                  # compute for bucket k−1
        pending = (ref, in_flight)
    if pending is not None:
        retire(*pending)

    reduced = {
        name: (jnp.concatenate(bufs) if len(bufs) > 1 else bufs[0])
        for name, bufs in parts.items() if bufs
    }
    return reduced, sq_parts


def apply_boundary(
    plan: BoundaryPlan,
    comm,
    model,
    topo: MiCSTopology,
    oc: OptConfig,
    state: dict,
    grads: dict,
    denom: float,
    seed=None,
):
    """Run one gradient-accumulation boundary under ``plan``.

    ``grads`` holds per-pool fp32 accumulated gradient *sums* (local shards,
    ``[stack, 1, shard_len]``); ``denom`` is the mean divisor
    (``micro_steps * data_parallel``).  Returns
    ``(new_params, new_m, new_v, grad_norm)`` with the global-norm clip
    applied exactly — the norm is reduced from every bucket's partial
    before any shard update issues.  ``seed`` (the traced step counter)
    feeds the int8 hop-2 wire's stochastic-rounding dither; float wires
    ignore it.
    """
    flat_grads = {
        name: grads[name].reshape(-1) for name in plan.shard_elems
    }
    if plan.mode == "bucketed":
        reduced, sq_parts = _reduce_bucketed(plan, comm, flat_grads, seed)
    else:
        reduced, sq_parts = _reduce_serial(plan, comm, flat_grads, seed)

    # ---- exact global-norm clip, denominator folded -----------------------
    sq_local = jnp.float32(0.0)
    for part in sq_parts:               # fixed left-fold, canonical order
        sq_local = sq_local + part
    sq = lax.psum(sq_local, topo.partition_axes + (MODEL_AXIS,))
    gnorm = jnp.sqrt(sq) / denom
    clip = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
    grad_scale = clip / denom           # mean + clip in one fused factor

    # ---- AdamW on fp32 shards, clip scale folded in -----------------------
    shard_coord = comm.partition_coord()
    new_params, new_m, new_v = {}, {}, {}
    for pool in model.all_pools():
        name = pool.name
        g = reduced[name].reshape(grads[name].shape)
        shard_len = g.shape[-1]
        start = shard_coord * shard_len
        dm = pool.layout.decay_mask_for_shard(start, shard_len)
        pm = pool.layout.padding_mask_for_shard(start, shard_len)
        p, m, v = adamw_shard_update(
            state["params"][name], g, state["m"][name], state["v"][name],
            state["step"], oc, decay_mask=dm, pad_mask=pm,
            grad_scale=grad_scale)
        new_params[name], new_m[name], new_v[name] = p, m, v
    return new_params, new_m, new_v, gnorm
