"""Boundary scheduler: the gradient-accumulation boundary as a bucketed
software pipeline (hop-2 overlap, ROADMAP "Async hop-2 overlap").

The boundary of one training step is ``hop-2 all-reduce -> global-norm clip
-> AdamW`` (paper §3.4: the expensive cross-replica sync runs once per
accumulation boundary).  The seed implementation ran it as a monolithic
barrier: every pool's hop-2 completed before a single optimizer FLOP
issued.  This module refactors the boundary into a **plan + two schedules**:

* :func:`plan_boundary` partitions each pool's local gradient shard
  (``[stack, 1, shard_len]`` fp32) into fixed-byte buckets
  (``core/flat_param.partition_buckets``) in one canonical order — pools in
  ``model.all_pools()`` order, offsets ascending.  Bucket count is a
  compile-time property of ``(model, topo, hop2_bucket_mb)``.
* ``serial`` schedule (:func:`apply_boundary`, the reference path): hop-2
  the whole gradient tree first, then compute, exactly like the seed.
* ``bucketed`` schedule: a software pipeline over the plan's buckets —
  bucket *k*'s hop-2 collective (``CommEngine.hop2_bucketed``) is issued
  *before* bucket *k−1*'s dependent compute (squared-norm partial, bf16
  wire decompress), so the collective has no data dependency on that
  compute and XLA's scheduler can overlap the two.  Once the clip scale is
  known the AdamW shard update runs per pool with the scale folded in.

**The exact-clip ordering argument.**  Global-norm clipping needs the norm
of *every* gradient element before *any* update applies, so the AdamW pass
can never overlap the last bucket's hop-2 — but everything before it can.
To keep the two schedules bitwise identical at every bucket size, both
compute the squared norm the same way: a left-fold over per-bucket partials
in the plan's canonical order (the serial path folds over slices of the
pool-wise-reduced buffer; the bucketed path over the bucket-wise-reduced
buffers — elementwise ``psum``/casts commute with slicing, so the inputs
are bitwise equal, and the fold order is literally the same Python loop).
The denominator (``micro_steps * data_parallel``) and the clip factor are
folded into one ``grad_scale`` passed to ``adamw_shard_update`` — no
standalone full-gradient-tree division pass on either schedule.

**The approximate-clip pipeline** (``clip_mode="approx"``).  The exact
clip's single barrier — no update before the complete norm — is the last
serially-exposed dependency of the boundary.  Approx mode removes it:
bucket *k*'s AdamW shard update runs under bucket *k+1*'s in-flight hop-2
using the **running** squared norm through bucket *k−1* (a one-bucket-
stale clip factor), so the whole boundary becomes one software pipeline
``issue hop-2(k) → AdamW(k−1, stale norm) → fold psum(k−1)`` with no
global barrier.  The drain step folds the final bucket's partial *first*,
so the last bucket (and the reported ``grad_norm`` metric) sees the
complete norm.  Degenerate guarantees: a one-bucket plan's only update is
the drain's complete-norm update — the exact schedule's ordering; and
whenever the clip is inactive (``gnorm <= clip_norm`` at every prefix —
e.g. a huge ``clip_norm``), every prefix factor is exactly 1.0 and the
update arithmetic is element-for-element the exact path's: the loss and
``grad_norm`` trajectories are bitwise identical at any bucket count, and
parameters agree to the final ulp (the pipelined program fuses the
elementwise AdamW chain differently, so XLA may round its last op
differently — tests/schedule_harness.py pins the tolerance).

*Divergence bound.*  The running norm is a prefix of the full sum, so
``gnorm_k <= gnorm`` and the stale factor ``c_k = min(1, C/gnorm_k)``
over-estimates the exact ``c = min(1, C/gnorm)``: each bucket's applied
gradient is the exact one scaled by ``c_k/c ∈ [1, gnorm/gnorm_k]`` — the
update direction per bucket is unchanged, only under-clipped, and the
applied step magnitude stays bounded by the Adam trust region (the
update is ``lr``-bounded elementwise regardless of ``grad_scale``).  The
discrepancy is largest for bucket 0 (factor ``min(1, C/gnorm)^-1``,
clamped to 1 whenever clipping is inactive) and vanishes as the prefix
grows; a tiny-LM convergence smoke (tests/schedule_harness.py) bounds the
end-to-end effect — final loss within ``APPROX_CLIP_LOSS_RTOL`` of the
exact reference with clipping engaged.

**Host-offloaded optimizer shards** (``offload_opt=True``).  The AdamW
``m``/``v`` shards are touched exactly once per boundary, so both
schedules can stream them from host memory around the update
(core/hostoffload.py: ordered-io_callback d2h/h2d stash, lazily
zero-initialized) instead of keeping them HBM-resident — the state dict
then carries only ``params``/``step`` and the memory planner subtracts
``2 × 4`` bytes/element from the per-device footprint.  The params
trajectory is bitwise unchanged (the fetched moments are bitwise the
stored ones).

**The int8 decompress leg** (qgZ follow-on).  With
``SyncPolicy.hop2_wire_dtype='int8'`` each hop-2 payload runs as a
block-quantized all-reduce (``collectives.quantized_all_reduce``: int8 +
f32 scales on both legs, fp32 accumulation between them), and the hidden
per-bucket compute grows the block *dequantize* on top of the norm
partial.  Unlike the elementwise bf16 cast, the quantization blocks follow
the payload, so int8 hop-2 results depend on payload granularity: serial
and bucketed agree to quantization error, not bitwise — the bitwise
schedule-equivalence guarantee above is for the fp32/bf16 wires.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.flat_param import partition_buckets
from repro.core.hostoffload import TAG_M, TAG_V
from repro.core.topology import MODEL_AXIS, MiCSTopology
from repro.optim.adamw import OptConfig, adamw_shard_update

BOUNDARY_SCHEDULES = ("serial", "bucketed")
CLIP_MODES = ("exact", "approx")

# Convergence-smoke tolerance of the approx clip: the tiny-LM final loss
# must sit within this relative tolerance of the exact reference
# (tests/schedule_harness.py::approx_convergence — the documented bound).
APPROX_CLIP_LOSS_RTOL = 0.05

# fp32 gradient accumulator bytes per element — what a bucket's byte budget
# is measured in (the wire payload may be narrower under bf16 hop-2).
GRAD_ITEMSIZE = 4


@dataclasses.dataclass(frozen=True)
class BucketRef:
    """One bucket: a static ``[lo, hi)`` slice of ``pool``'s flattened
    local gradient shard."""

    pool: str
    lo: int
    hi: int

    @property
    def elems(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class BoundaryPlan:
    """Static schedule of one gradient-accumulation boundary."""

    mode: str                          # 'serial' | 'bucketed'
    bucket_mb: float
    shard_elems: dict                  # pool -> local grad elements
    buckets: tuple                     # BucketRef, canonical order
    clip_mode: str = "exact"           # 'exact' barrier | 'approx' pipeline

    def __post_init__(self):
        if self.mode not in BOUNDARY_SCHEDULES:
            raise ValueError(f"unknown boundary schedule {self.mode!r} "
                             f"(expected one of {BOUNDARY_SCHEDULES})")
        if self.clip_mode not in CLIP_MODES:
            raise ValueError(f"unknown clip_mode {self.clip_mode!r} "
                             f"(expected one of {CLIP_MODES})")
        if self.clip_mode == "approx" and self.mode != "bucketed":
            raise ValueError(
                "clip_mode='approx' requires the bucketed boundary schedule "
                "(the serial reference has no bucket pipeline to hide the "
                "optimizer under)")

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def pool_buckets(self, pool: str) -> list:
        return [b for b in self.buckets if b.pool == pool]

    def hop2_payload_elems(self) -> list:
        """Element counts of the hop-2 collectives this plan issues, in
        order: one whole-pool payload per pool under ``serial``, one per
        bucket under ``bucketed``.  The single source of truth shared by
        the executor (:func:`apply_boundary`), the cost model
        (``autotune.cost_hop2_schedule``) and the census cross-checks
        (``dryrun``'s ``bucket_count_match``)."""
        if self.mode == "serial":
            return list(self.shard_elems.values())   # all_pools() order
        return [b.elems for b in self.buckets]

    @property
    def n_hop2_collectives(self) -> int:
        return len(self.hop2_payload_elems())

    def describe(self) -> dict:
        """Static record for dry-run artifacts / BENCH json."""
        per_pool = {}
        for b in self.buckets:
            per_pool[b.pool] = per_pool.get(b.pool, 0) + 1
        return {
            "mode": self.mode,
            "clip_mode": self.clip_mode,
            "bucket_mb": self.bucket_mb,
            "n_buckets": self.n_buckets,
            "n_hop2_collectives": self.n_hop2_collectives,
            "buckets_per_pool": per_pool,
            "max_bucket_bytes": max(
                (b.elems * GRAD_ITEMSIZE for b in self.buckets), default=0),
        }


def plan_boundary(model, topo: MiCSTopology, *, mode: str,
                  bucket_mb: float, clip_mode: str = "exact") -> BoundaryPlan:
    """Bucketize every pool's local gradient shard into fixed-byte buckets.

    The same plan backs both schedules: the serial reference uses it only
    to order the squared-norm partials (so it stays bitwise comparable to
    the bucketed pipeline at any bucket size), the bucketed schedule
    additionally issues one hop-2 collective per bucket.  ``clip_mode``
    selects the exact global-norm-clip barrier (the reference) or the
    approximate one-bucket-stale clip pipeline (module docstring).
    """
    p = topo.partition_size
    shard_elems = {}
    buckets = []
    for pool in model.all_pools():
        stack, _tp, flat_len = model.global_flat_shapes()[pool.name]
        n = stack * (flat_len // p)
        shard_elems[pool.name] = n
        for lo, hi in partition_buckets(n, bucket_mb, GRAD_ITEMSIZE):
            buckets.append(BucketRef(pool.name, lo, hi))
    return BoundaryPlan(mode=mode, bucket_mb=float(bucket_mb),
                        shard_elems=shard_elems, buckets=tuple(buckets),
                        clip_mode=clip_mode)


def _sq(bucket: jax.Array) -> jax.Array:
    """One bucket's squared-norm partial (fp32)."""
    return jnp.sum(jnp.square(bucket))


def _reduce_serial(plan: BoundaryPlan, comm, flat_grads: dict, seed=None):
    """Reference: whole-pool hop-2 first, then per-bucket norm partials.

    ``salt`` (the pool index) seeds the int8 hop-2 wire's stochastic-
    rounding dither per payload and ``seed`` (the traced step counter)
    decorrelates it across steps; the float wires ignore both.
    """
    reduced = {name: comm.hop2(g, salt=i, seed=seed)
               for i, (name, g) in enumerate(flat_grads.items())}
    sq_parts = [
        _sq(lax.slice_in_dim(reduced[b.pool], b.lo, b.hi, axis=0))
        for b in plan.buckets
    ]
    return reduced, sq_parts


def _reduce_bucketed(plan: BoundaryPlan, comm, flat_grads: dict, seed=None):
    """Software pipeline: issue bucket k's hop-2, then run bucket k−1's
    dependent compute (squared-norm partial + wire decompress — the bf16
    upcast, or the int8 leg's block dequantize).  The collective of bucket
    k has no data dependency on bucket k−1's compute, which is what lets
    the backend overlap the two; the drain step handles the last bucket.
    The global bucket index salts the int8 wire's dither so no two
    payloads of one boundary share a key (offsets repeat across pools —
    every pool has a bucket at lo=0 — so the plan-order index is the salt).
    """
    parts: dict[str, list] = {name: [] for name in flat_grads}
    sq_parts: list[jax.Array] = []
    pending = None  # (BucketRef, in-flight reduced bucket)

    def retire(ref, reduced_bucket):
        sq_parts.append(_sq(reduced_bucket))
        parts[ref.pool].append(reduced_bucket)

    for i, ref in enumerate(plan.buckets):
        raw = lax.slice_in_dim(flat_grads[ref.pool], ref.lo, ref.hi, axis=0)
        in_flight = comm.hop2_bucketed(raw, salt=i, seed=seed)  # bucket k
        if pending is not None:
            retire(*pending)                  # compute for bucket k−1
        pending = (ref, in_flight)
    if pending is not None:
        retire(*pending)

    reduced = {
        name: (jnp.concatenate(bufs) if len(bufs) > 1 else bufs[0])
        for name, bufs in parts.items() if bufs
    }
    return reduced, sq_parts


def _bucket_masks(pool, ref: BucketRef, shard_coord, shard_len: int):
    """Decay/padding masks for one bucket of a pool's flattened shard.

    The flattened ``[stack * shard_len]`` buffer broadcasts the per-shard
    layout masks over stack rows, so flat index ``f`` maps to layout
    position ``shard_coord*shard_len + (f % shard_len)`` — these are
    exactly slices of ``decay_mask_for_shard``/``padding_mask_for_shard``,
    which keeps the per-bucket AdamW bitwise equal to the sliced full-shard
    update.
    """
    local = (ref.lo + jnp.arange(ref.elems, dtype=jnp.int32)) % shard_len
    gidx = shard_coord * shard_len + local
    dm = jnp.ones((ref.elems,), jnp.float32)
    for lo, hi in pool.layout.nodecay_ranges():
        if lo >= hi:
            continue
        dm = jnp.where((gidx >= lo) & (gidx < hi), 0.0, dm)
    pm = (gidx < pool.layout.raw_len).astype(jnp.float32)
    return dm, pm


def _apply_boundary_approx(plan, comm, model, topo, oc, state, grads,
                           denom, seed, offload_opt):
    """The approximate-clip software pipeline (module docstring).

    Per plan-order bucket *i*: issue bucket *i*'s hop-2, then (while it is
    in flight) run bucket *i−1*'s AdamW with the clip factor from the
    running squared norm through bucket *i−2*, then fold bucket *i−1*'s
    psum into the running norm.  The drain folds the final bucket's psum
    *before* its update, so the last bucket uses the complete norm, and a
    one-bucket plan reduces to the exact path's ordering.  The returned
    ``grad_norm`` metric is accumulated by the exact path's canonical
    local left-fold + single psum, so the metric is bitwise identical to
    the exact schedule's at any bucket count — only the *applied* clip
    factors are stale.
    """
    flat_grads = {name: grads[name].reshape(-1) for name in plan.shard_elems}
    shard_coord = comm.partition_coord()
    pools = {p.name: p for p in model.all_pools()}
    norm_axes = topo.partition_axes + (MODEL_AXIS,)
    stash = comm.host_stash if offload_opt else None

    flat_state = {}
    for name in plan.shard_elems:
        flat_state[name] = {
            "p": state["params"][name].reshape(-1),
            "m": None if offload_opt else state["m"][name].reshape(-1),
            "v": None if offload_opt else state["v"][name].reshape(-1),
            "shard_len": grads[name].shape[-1],
        }
    out = {name: {"p": [], "m": [], "v": []} for name in plan.shard_elems}

    def update(i, ref, g_bucket, running_sq):
        """Bucket ``ref``'s AdamW with the clip factor from ``running_sq``
        (the stale prefix norm — or the complete one at the drain)."""
        fs = flat_state[ref.pool]
        gnorm_i = jnp.sqrt(running_sq) / denom
        clip = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm_i, 1e-12))
        grad_scale = clip / denom
        p_in = lax.slice_in_dim(fs["p"], ref.lo, ref.hi, axis=0)
        if offload_opt:
            m_in = stash.get(TAG_M, i, (ref.elems,), jnp.float32,
                             or_zeros=True, ordered=False)
            v_in = stash.get(TAG_V, i, (ref.elems,), jnp.float32,
                             or_zeros=True, ordered=False)
        else:
            m_in = lax.slice_in_dim(fs["m"], ref.lo, ref.hi, axis=0)
            v_in = lax.slice_in_dim(fs["v"], ref.lo, ref.hi, axis=0)
        dm, pm = _bucket_masks(pools[ref.pool], ref, shard_coord,
                               fs["shard_len"])
        p_new, m_new, v_new = adamw_shard_update(
            p_in, g_bucket, m_in, v_in, state["step"], oc,
            decay_mask=dm, pad_mask=pm, grad_scale=grad_scale)
        out[ref.pool]["p"].append(p_new)
        if offload_opt:
            # Unordered: the put operand depends on the get via the AdamW
            # update, so dataflow already sequences the pair; ordered
            # callbacks here deadlock against the hop-2 psum rendezvous on
            # the multi-device CPU runtime.  The tokens MUST reach the
            # computation's outputs (folded into gnorm below): a put whose
            # token is dropped stalls the runtime the same way.
            put_toks.append(stash.put(TAG_M, i, m_new, ordered=False))
            put_toks.append(stash.put(TAG_V, i, v_new, ordered=False))
        else:
            out[ref.pool]["m"].append(m_new)
            out[ref.pool]["v"].append(v_new)

    running_sq = jnp.float32(0.0)
    sq_local = jnp.float32(0.0)   # exact path's canonical left-fold — the
    #                               returned metric is bitwise identical to
    #                               the exact schedule's grad_norm
    put_toks = []
    pending = None  # (bucket index, BucketRef, in-flight reduced bucket)
    for i, ref in enumerate(plan.buckets):
        raw = lax.slice_in_dim(flat_grads[ref.pool], ref.lo, ref.hi, axis=0)
        in_flight = comm.hop2_bucketed(raw, salt=i, seed=seed)
        if pending is not None:
            j, pref, pbucket = pending
            update(j, pref, pbucket, running_sq)   # stale: through bucket j-1
            running_sq = running_sq + lax.psum(_sq(pbucket), norm_axes)
            sq_local = sq_local + _sq(pbucket)
        pending = (i, ref, in_flight)
    if pending is not None:  # drain: complete norm for the final bucket
        j, pref, pbucket = pending
        running_sq = running_sq + lax.psum(_sq(pbucket), norm_axes)
        sq_local = sq_local + _sq(pbucket)
        update(j, pref, pbucket, running_sq)

    gnorm = jnp.sqrt(lax.psum(sq_local, norm_axes)) / denom
    if put_toks:    # keep the d2h puts live (value is always 0)
        gnorm = gnorm + sum(put_toks).astype(jnp.float32) * 0.0

    new_params, new_m, new_v = {}, {}, {}
    for name in plan.shard_elems:
        shape = grads[name].shape

        def cat(bufs, shape=shape):
            return (jnp.concatenate(bufs) if len(bufs) > 1
                    else bufs[0]).reshape(shape)

        if not out[name]["p"]:         # empty pool: nothing to update
            new_params[name] = state["params"][name]
            if not offload_opt:
                new_m[name] = state["m"][name]
                new_v[name] = state["v"][name]
            continue
        new_params[name] = cat(out[name]["p"])
        if not offload_opt:
            new_m[name] = cat(out[name]["m"])
            new_v[name] = cat(out[name]["v"])
    return new_params, new_m, new_v, gnorm


def apply_boundary(
    plan: BoundaryPlan,
    comm,
    model,
    topo: MiCSTopology,
    oc: OptConfig,
    state: dict,
    grads: dict,
    denom: float,
    seed=None,
    offload_opt: bool = False,
):
    """Run one gradient-accumulation boundary under ``plan``.

    ``grads`` holds per-pool fp32 accumulated gradient *sums* (local shards,
    ``[stack, 1, shard_len]``); ``denom`` is the mean divisor
    (``micro_steps * data_parallel``).  Returns
    ``(new_params, new_m, new_v, grad_norm)``.  Under
    ``plan.clip_mode='exact'`` the global-norm clip is a barrier — the norm
    is reduced from every bucket's partial before any shard update issues;
    ``'approx'`` pipelines each bucket's update under the next bucket's
    hop-2 with a one-bucket-stale clip factor (module docstring).  ``seed``
    (the traced step counter) feeds the int8 hop-2 wire's stochastic-
    rounding dither; float wires ignore it.  ``offload_opt=True`` streams
    the AdamW ``m``/``v`` shards through the host stash (lazy zero-init)
    instead of the state dict — ``new_m``/``new_v`` come back empty and the
    params trajectory is bitwise unchanged.
    """
    if plan.mode == "bucketed" and plan.clip_mode == "approx":
        return _apply_boundary_approx(plan, comm, model, topo, oc, state,
                                      grads, denom, seed, offload_opt)
    flat_grads = {
        name: grads[name].reshape(-1) for name in plan.shard_elems
    }
    if plan.mode == "bucketed":
        reduced, sq_parts = _reduce_bucketed(plan, comm, flat_grads, seed)
    else:
        reduced, sq_parts = _reduce_serial(plan, comm, flat_grads, seed)

    # ---- exact global-norm clip, denominator folded -----------------------
    sq_local = jnp.float32(0.0)
    for part in sq_parts:               # fixed left-fold, canonical order
        sq_local = sq_local + part
    sq = lax.psum(sq_local, topo.partition_axes + (MODEL_AXIS,))
    gnorm = jnp.sqrt(sq) / denom
    clip = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
    grad_scale = clip / denom           # mean + clip in one fused factor

    # ---- AdamW on fp32 shards, clip scale folded in -----------------------
    shard_coord = comm.partition_coord()
    stash = comm.host_stash if offload_opt else None
    new_params, new_m, new_v = {}, {}, {}
    put_toks = []
    for pool_idx, pool in enumerate(model.all_pools()):
        name = pool.name
        g = reduced[name].reshape(grads[name].shape)
        shard_len = g.shape[-1]
        start = shard_coord * shard_len
        dm = pool.layout.decay_mask_for_shard(start, shard_len)
        pm = pool.layout.padding_mask_for_shard(start, shard_len)
        if offload_opt:
            m_in = stash.get(TAG_M, pool_idx, g.shape, jnp.float32,
                             or_zeros=True, ordered=False)
            v_in = stash.get(TAG_V, pool_idx, g.shape, jnp.float32,
                             or_zeros=True, ordered=False)
        else:
            m_in, v_in = state["m"][name], state["v"][name]
        p, m, v = adamw_shard_update(
            state["params"][name], g, m_in, v_in,
            state["step"], oc, decay_mask=dm, pad_mask=pm,
            grad_scale=grad_scale)
        new_params[name] = p
        if offload_opt:
            # Unordered: dataflow (get -> AdamW -> put) sequences the pair;
            # tokens fold into gnorm to stay live (_apply_boundary_approx).
            put_toks.append(stash.put(TAG_M, pool_idx, m, ordered=False))
            put_toks.append(stash.put(TAG_V, pool_idx, v, ordered=False))
        else:
            new_m[name], new_v[name] = m, v
    if put_toks:
        gnorm = gnorm + sum(put_toks).astype(jnp.float32) * 0.0
    return new_params, new_m, new_v, gnorm
