"""Host-memory stash: the d2h/h2d leg of ``carry_offload="host"`` and
``offload_opt=True`` (ROADMAP "Fully-overlapped step").

MiCS §3.1 sizes the partition group from what must *reside* in HBM.  Two
of the largest residents are pure between-use storage: the prefetch
carry's gathered flat buffers (written in the forward, read once in the
backward) and the AdamW ``m``/``v`` shards (read/written once per
boundary).  Neither is touched by compute between those points, so both
can live in host memory, streamed down/up around their single use — the
memory planner (core/memplan.py) then subtracts them from the HBM
footprint and ``autotune.resolve_scale`` can fit a larger model per
device than remat alone.

The stash is a host-side keyed store driven by **ordered
``io_callback``s**: ``put`` copies a device array into a process-global
dict, ``get`` streams it back (optionally popping, optionally
zero-filling on miss — the lazy ``m``/``v`` init).  Keys are
``(namespace, tag, slot, device_index)`` so concurrent engines, pools,
layers and devices never collide; ``device_index`` is folded from the
mesh axis indices *inside* shard_map, so each device owns its slice.
``ordered=True`` serializes the callbacks within a step, which is what
makes put-then-get across the forward/backward boundary well-defined.

On an accelerator backend the same structure would be expressed with
``jax.device_put`` to a ``pinned_host``-memory-kind sharding (zero-copy
DMA streams); the CPU backend used by the harnesses exposes only
``unpinned_host``, so the io_callback form is the portable mechanism —
the *pricing* (the link model's host tier, core/linkmodel.py) is the
same either way.

Checkpointing: with ``offload_opt=True`` the optimizer moments live here,
not in the on-device state dict — :func:`export_stash` /
:func:`import_stash` round-trip them for save/restore.
"""

from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import io_callback

# process-global store: (namespace, tag, slot, device_index) -> np.ndarray
_STASH: dict = {}
_NAMESPACES = itertools.count()

# well-known tags (slots are mode-defined: pool index, bucket index, layer)
TAG_M = 1              # AdamW first moment shards (offload_opt)
TAG_V = 2              # AdamW second moment shards (offload_opt)
TAG_CARRY_BASE = 16    # + per-pool ordinal: prefetch-carry buffers

# Restored-checkpoint sentinel namespace: live namespaces are a process-local
# counter, so entries imported from a checkpoint land under -1 and ``get``
# falls back to it on a miss (migrating the entry into the live key).  This
# makes restore namespace-agnostic — no coordination between the
# checkpointer and whichever CommEngine the restored step uses.
CKPT_NAMESPACE = -1


class HostStash:
    """One namespace of the process-global host store, bound to a mesh.

    ``mesh_axes`` is a tuple of ``(axis_name, size)`` pairs in mesh order;
    :meth:`device_index` linearizes this device's coordinate from them
    (must be called inside shard_map over that mesh).
    """

    def __init__(self, mesh_axes):
        self.namespace = next(_NAMESPACES)
        self.axes = tuple((str(n), int(s)) for n, s in mesh_axes)

    def device_index(self) -> jax.Array:
        idx = jnp.int32(0)
        for name, size in self.axes:
            idx = idx * jnp.int32(size) + lax.axis_index(name).astype(jnp.int32)
        return idx

    def _key(self, tag, slot) -> jax.Array:
        return jnp.stack([
            jnp.int32(self.namespace), jnp.int32(tag),
            jnp.asarray(slot, jnp.int32), self.device_index(),
        ])

    def put(self, tag: int, slot, x: jax.Array, *,
            ordered: bool = True) -> jax.Array:
        """Store ``x`` at (tag, slot) for this device (the d2h stream).

        Returns an int32 token.  ``ordered=True`` sequences the call on
        the per-device effect token — required when a later ``get`` has no
        data dependency on this put (the carry path's forward-put /
        backward-get pair).  Pass ``ordered=False`` when dataflow already
        orders the pair (the boundary's get -> update -> put chain):
        ordered callbacks serialize against the step's collectives and can
        rendezvous-deadlock the multi-device CPU runtime when interleaved
        with psums at the boundary.
        """

        def cb(key, val):
            # Store WITHOUT forcing materialization: jax's io_callback impl
            # hands ``val`` over as a (possibly still-pending) CPU-device
            # array, and ``np.asarray`` here would wait for it *inside* the
            # callback — on a thread-starved host runtime that wait
            # deadlocks against the step's collective rendezvous (every
            # other device is parked in ITS put callback).  Conversion
            # happens at get/export time, when the value has long
            # materialized.
            _STASH[tuple(int(k) for k in np.asarray(key))] = val
            return np.int32(0)

        return io_callback(cb, jax.ShapeDtypeStruct((), jnp.int32),
                           self._key(tag, slot), x, ordered=ordered)

    def get(self, tag: int, slot, shape, dtype, *, or_zeros: bool = False,
            pop: bool = True, ordered: bool = True) -> jax.Array:
        """Fetch the array at (tag, slot) back to device (the h2d stream).

        ``pop=True`` releases the host copy (single-use carries);
        ``or_zeros=True`` returns zeros on a missing key — the lazy
        zero-init of offloaded optimizer moments on step 0.  See
        :meth:`put` for the ``ordered`` contract.
        """
        shape = tuple(int(d) for d in shape)
        np_dtype = np.dtype(jnp.dtype(dtype).name)

        def cb(key):
            k = tuple(int(v) for v in np.asarray(key))
            val = _STASH.pop(k, None) if pop else _STASH.get(k)
            if val is None:        # checkpoint-restored entry?
                kk = (CKPT_NAMESPACE,) + k[1:]
                val = _STASH.pop(kk, None) if pop else _STASH.get(kk)
            if val is None:
                if or_zeros:
                    return np.zeros(shape, np_dtype)
                raise KeyError(f"host stash miss: {k}")
            return np.asarray(val)    # materializes lazily-stored puts

        return io_callback(cb, jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)),
                           self._key(tag, slot), ordered=ordered)


# ---------------------------------------------------------------------------
# host-side management (tests, checkpointing)
# ---------------------------------------------------------------------------

def stash_size() -> int:
    return len(_STASH)

def stash_clear() -> None:
    _STASH.clear()


def clear_namespace(namespace: int) -> None:
    """Drop every entry of one namespace (e.g. stale CKPT_NAMESPACE imports
    before a cross-topology restore declines to re-import)."""
    for k in [k for k in _STASH if k[0] == namespace]:
        del _STASH[k]


def export_stash(namespace: int | None = None) -> dict:
    """Snapshot (a namespace of) the stash — the offloaded-moment half of a
    checkpoint when ``offload_opt=True``."""
    return {k: np.asarray(v).copy() for k, v in _STASH.items()
            if namespace is None or k[0] == namespace}


def import_stash(entries: dict, *, as_checkpoint: bool = False) -> None:
    """Load entries back into the stash.  ``as_checkpoint=True`` rewrites
    every key's namespace to :data:`CKPT_NAMESPACE` so the restored step's
    engine finds them through ``get``'s fallback regardless of which live
    namespace it was assigned."""
    for k, v in entries.items():
        k = tuple(int(x) for x in k)
        if as_checkpoint:
            k = (CKPT_NAMESPACE,) + k[1:]
        _STASH[k] = np.asarray(v).copy()
