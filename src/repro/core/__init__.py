"""MiCS core: the paper's system layer.

``topology`` (partition/replication groups as mesh axes), ``flat_param``
(flat parameter pools), ``collectives`` (staged gathers + exact adjoints),
``comm`` (the CommEngine — single construction point for every
collective), ``linkmodel`` (the one link-bandwidth table of the tree),
``autotune`` (bandwidth-aware GatherPolicy/SyncPolicy tuner), ``quant``
(int8 blockwise wire), ``mics`` (the 2-hop training step).
"""
