"""The single link-bandwidth model of the tree: named profiles + α-β algebra.

MiCS's central claim is that the *right* communication scale depends on the
network (paper §3): heterogeneous bandwidth — fast intra-node links (NVLink,
ICI) vs slow inter-node links (EFA, DCI) — decides whether a flat, 2-stage
inner-first, or paper-faithful 3-stage outer-first gather wins.  Every
component that reasons about the network reads the SAME table:

* ``core/topology.py`` re-exports the v5e chip/link constants from here
  (its partition-size heuristic and the roofline use them);
* ``core/autotune.py`` costs candidate ``GatherPolicy``/``SyncPolicy``
  combinations with :meth:`LinkProfile.ring_time` over the table;
* ``roofline/analysis.py`` turns HLO census bytes into seconds with the
  same per-tier bandwidths;
* ``benchmarks/paper_model.py`` builds its calibrated ``Net`` from the
  EFA profiles (the paper's measured p3dn/p4d anchors live here).

A profile is a two-tier model: ``intra`` (the fast tier every group of up
to ``node_size`` consecutive ranks shares) and ``inter`` (the slow tier any
larger or node-crossing group pays), each an (α, β) pair — per-hop startup
latency plus per-participant ring bandwidth.  Two tiers are exactly what
the paper's analysis uses (§2.3, Fig 2) and enough to reproduce its
flat-vs-hierarchical crossovers; finer hierarchies can be expressed by
registering custom profiles per pool.

A third, non-network tier prices the device<->host link (PCIe / DMA): the
``host`` Link costs the d2h/h2d streams of ``carry_offload='host'`` and
``offload_opt=True`` (core/hostoffload.py) in the same α-β units, so the
autotuner can weigh "offload the carry and shrink the partition group"
against the network cost of the bigger group — the §3.1 scale-aware trade
extended to host memory.  Host transfers are point-to-point, not rings:
cost one stream of n bytes as ``alpha + n / bandwidth`` (``xfer_time``).

This module is dependency-free (no jax) so every layer of the tree can
import it without cycles.

Units: bandwidths are bytes/second, latencies seconds.  Network-style
"Gbps" figures (EFA 100/400 Gbps) convert via :func:`gbps`.
"""

from __future__ import annotations

import dataclasses

GB = 1e9
GIB = 1024**3


def gbps(gigabits_per_second: float) -> float:
    """Network-convention Gbit/s -> bytes/s (100 Gbps EFA = 12.5 GB/s)."""
    return gigabits_per_second * 1e9 / 8


@dataclasses.dataclass(frozen=True)
class Link:
    """One tier of the network: per-participant ring bandwidth + startup.

    ``bandwidth`` is the sustained bytes/s each participant of a ring
    collective moves on this tier; ``alpha`` is the per-hop startup latency
    (the (g-1)·α term of the standard α-β collective model).
    """

    bandwidth: float
    alpha: float


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Named two-tier link table + the chip roofline constants.

    intra:      fast tier (ICI / NVLink) — groups within one "node"
    inter:      slow tier (DCI / EFA)    — any group crossing node boundaries
    node_size:  consecutive ranks sharing the fast tier (paper's k)
    local_copy_bw: device-local copy bandwidth (the outer-first reorder stage)
    peak_flops / hbm_bw / hbm_bytes: chip constants for roofline synthesis
    """

    name: str
    intra: Link
    inter: Link
    node_size: int
    local_copy_bw: float
    peak_flops: float
    hbm_bw: float
    hbm_bytes: int
    description: str = ""
    # device<->host (PCIe/DMA) tier; None falls back to DEFAULT_HOST_LINK so
    # profiles predating the host tier keep working unchanged.
    host: Link | None = None

    def __post_init__(self):
        if self.node_size < 1:
            raise ValueError(f"node_size must be >= 1, got {self.node_size}")
        tiers = (self.intra, self.inter) + (
            (self.host,) if self.host is not None else ())
        for tier in tiers:
            if tier.bandwidth <= 0:
                raise ValueError(f"{self.name}: non-positive bandwidth")

    # -- tier lookup --------------------------------------------------------
    def link(self, tier: str) -> Link:
        if tier == "intra":
            return self.intra
        if tier == "inter":
            return self.inter
        if tier == "host":
            return self.host if self.host is not None else DEFAULT_HOST_LINK
        raise ValueError(f"unknown tier {tier!r}")

    def group_tier(self, positions) -> str:
        """Tier of a ring over partition-group linear ``positions``: 'intra'
        iff every participant lies in the same node_size-aligned island."""
        islands = {p // self.node_size for p in positions}
        return "intra" if len(islands) <= 1 else "inter"

    # -- alpha-beta algebra -------------------------------------------------
    def ring_time(self, tier: str, group_size: int, wire_bytes: float) -> float:
        """Time of one ring collective stage that moves ``wire_bytes`` per
        participant over ``tier`` in ``group_size - 1`` hops.

        ``wire_bytes`` is the census convention (roofline/hlo_stats.py):
        (g-1)/g of the full buffer for an all-gather / reduce-scatter stage,
        2(g-1)/g for an all-reduce — so model and measurement share units.
        """
        if group_size <= 1 or wire_bytes <= 0:
            return 0.0
        link = self.link(tier)
        return (group_size - 1) * link.alpha + wire_bytes / link.bandwidth

    def copy_time(self, nbytes: float) -> float:
        """Device-local copy (the paper's Fig-5 chunk-reorder stage)."""
        return nbytes / self.local_copy_bw

    def xfer_time(self, tier: str, nbytes: float, events: int = 1) -> float:
        """Point-to-point stream time: ``events`` transfers totalling
        ``nbytes`` over ``tier`` — the host-tier unit (one α per d2h/h2d
        issue, no ring factor; each device owns its own PCIe lane)."""
        if nbytes <= 0 and events <= 0:
            return 0.0
        link = self.link(tier)
        return events * link.alpha + nbytes / link.bandwidth

    def hbm_time(self, nbytes: float) -> float:
        """Time to stream ``nbytes`` through HBM — the unit the cost model
        prices memory-bound boundary compute in: the hop-2 pipeline's
        hideable norm/decompress work (``autotune.cost_hop2_schedule``) and
        the int8 wire's per-stage quantize/dequantize overhead
        (``autotune.QGZ_COMPUTE_BYTES_PER_ELEM``)."""
        return nbytes / self.hbm_bw


# ---------------------------------------------------------------------------
# named profiles
# ---------------------------------------------------------------------------

# Fallback device<->host link for profiles that do not pin one: one PCIe3
# x16-class lane per device (~16 GB/s sustained), ~5 µs per DMA issue.
DEFAULT_HOST_LINK = Link(bandwidth=16 * GB, alpha=5e-6)

# TPU v5e: 50 GB/s ICI per link within a pod; the inter-pod DCI modeled as a
# scarce 6.25 GB/s link per pod boundary (assignment constants, previously
# hard-coded in core/topology.py and roofline/analysis.py).
V5E = LinkProfile(
    name="v5e",
    intra=Link(bandwidth=50 * GB, alpha=1e-6),
    inter=Link(bandwidth=6.25 * GB, alpha=10e-6),
    node_size=16,                      # one pod's data-axis extent
    local_copy_bw=819 * GB,            # HBM-speed on-chip copies
    peak_flops=197e12,                 # bf16 peak
    hbm_bw=819 * GB,
    hbm_bytes=16 * GIB,
    description="TPU v5e pod: 50 GB/s ICI per link, 6.25 GB/s DCI per pod hop",
    host=Link(bandwidth=32 * GB, alpha=5e-6),   # PCIe4-class host DMA
)

# AWS p3dn.24xlarge (the paper's measured cluster): 8 V100s per node on
# NVLink (B_part ~= 128 GB/s aggregate -> 16 GB/s per GPU rail), 100 Gbps
# EFA between nodes.  Alphas are the paper_model.py calibration anchors.
EFA_100G = LinkProfile(
    name="efa-100g",
    intra=Link(bandwidth=16 * GB, alpha=8e-6),
    inter=Link(bandwidth=gbps(100), alpha=30e-6),
    node_size=8,
    local_copy_bw=900 * GB,
    peak_flops=125e12,                 # V100 fp16 tensor-core peak
    hbm_bw=900 * GB,
    hbm_bytes=32 * GIB,
    description="AWS p3dn: 8xV100 NVLink nodes, 100 Gbps EFA (paper anchor)",
    host=Link(bandwidth=16 * GB, alpha=5e-6),   # PCIe3 x16 per GPU
)

# AWS p4d.24xlarge-style follow-on: same node shape, 400 Gbps EFA.
EFA_400G = LinkProfile(
    name="efa-400g",
    intra=Link(bandwidth=16 * GB, alpha=8e-6),
    inter=Link(bandwidth=gbps(400), alpha=30e-6),
    node_size=8,
    local_copy_bw=900 * GB,
    peak_flops=312e12,                 # A100 bf16 peak
    hbm_bw=1555 * GB,
    hbm_bytes=40 * GIB,
    description="AWS p4d-style: NVLink nodes, 400 Gbps EFA",
    host=Link(bandwidth=32 * GB, alpha=5e-6),   # PCIe4 x16 per GPU
)

PROFILES: dict[str, LinkProfile] = {
    p.name: p for p in (V5E, EFA_100G, EFA_400G)
}


def register_profile(profile: LinkProfile) -> LinkProfile:
    """Add a profile to the named table (tests, site-specific clusters)."""
    PROFILES[profile.name] = profile
    return profile


def get_profile(profile: str | LinkProfile) -> LinkProfile:
    """Resolve a profile by name or pass an instance through."""
    if isinstance(profile, LinkProfile):
        return profile
    try:
        return PROFILES[profile]
    except KeyError:
        raise KeyError(
            f"unknown link profile {profile!r}; known: {sorted(PROFILES)} "
            f"(register_profile() adds custom tables)"
        ) from None


def custom_profile(
    name: str,
    *,
    intra_bw: float,
    inter_bw: float,
    node_size: int,
    alpha_intra: float = 1e-6,
    alpha_inter: float = 10e-6,
    host_bw: float | None = None,
    alpha_host: float = 5e-6,
    local_copy_bw: float = 819 * GB,
    peak_flops: float = V5E.peak_flops,
    hbm_bw: float = V5E.hbm_bw,
    hbm_bytes: int = V5E.hbm_bytes,
    description: str = "",
    register: bool = False,
) -> LinkProfile:
    """Custom link-table constructor (bandwidths in bytes/s; use
    :func:`gbps` for network-style Gbit/s figures)."""
    p = LinkProfile(
        name=name,
        intra=Link(bandwidth=intra_bw, alpha=alpha_intra),
        inter=Link(bandwidth=inter_bw, alpha=alpha_inter),
        host=(Link(bandwidth=host_bw, alpha=alpha_host)
              if host_bw is not None else None),
        node_size=node_size,
        local_copy_bw=local_copy_bw,
        peak_flops=peak_flops,
        hbm_bw=hbm_bw,
        hbm_bytes=hbm_bytes,
        description=description,
    )
    if register:
        register_profile(p)
    return p
