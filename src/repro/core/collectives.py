"""MiCS collectives: flat and hierarchical all-gather / reduce-scatter.

Implements the paper's §3.3 three-stage hierarchical all-gather, adapted to
TPU mesh axes, plus a beyond-paper *reorder-free* variant.

Paper-faithful ("outer_first", 3 stages), partition group p = outer×inner
(outer = "p/k nodes" over the slow links, inner = "k devices per node"):

  stage 1: ``inner`` parallel all-gathers over the *outer* (slow) dimension
           among same-local-rank devices  (paper Fig 5, inter-node)
  stage 2: chunk reorder to fix memory contiguity (paper Fig 5, middle)
  stage 3: batched all-gathers over the *inner* (fast) dimension

Beyond-paper ("inner_first", 2 stages): gathering over the fast dimension
first makes each device hold a *contiguous* block of chunks, so the outer
gather concatenates blocks already in canonical order — the reorder stage
vanishes and the slow-link stage moves k×-larger messages (better effective
bandwidth per the paper's own Fig 2 argument) while transferring the same
(p−k)M/p volume over the slow links.

All functions are pure jnp/lax and differentiate correctly: the VJP of a
hierarchical all-gather is the matching hierarchical reduce-scatter, which is
how hop-1 gradient synchronization (§3.4) materializes from plain `jax.grad`.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import quant as Q
from repro.core.topology import MiCSTopology, default_hierarchy_inner


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stage_groups(p: int, inner: int) -> tuple[list[list[int]], list[list[int]]]:
    """axis_index_groups for the two stages within a single mesh axis.

    outer groups: same local rank r, strided by ``inner``  (size p/inner)
    inner groups: contiguous runs of ``inner`` indices      (size inner)
    """
    outer_groups = [list(range(r, p, inner)) for r in range(inner)]
    inner_groups = [list(range(o * inner, (o + 1) * inner)) for o in range(p // inner)]
    return outer_groups, inner_groups


def flat_all_gather(x: jax.Array, axes: Sequence[str], axis: int = 0) -> jax.Array:
    """Vanilla single-collective all-gather over the product of ``axes``."""
    axes = tuple(axes)
    if not axes:
        return x
    return lax.all_gather(x, axes, axis=axis, tiled=True)


# ---------------------------------------------------------------------------
# hierarchical all-gather
# ---------------------------------------------------------------------------

def hierarchical_all_gather(
    x: jax.Array,
    topo: MiCSTopology,
    *,
    axis: int = 0,
    order: str = "inner_first",
    inner: int | None = None,
) -> jax.Array:
    """All-gather ``x`` over the partition group, staged over the hierarchy.

    ``x`` is this device's shard (1/p of the full buffer along ``axis``).
    Returns the full buffer, identical to ``flat_all_gather`` over the
    partition axes.
    """
    p = topo.partition_size
    if p == 1:
        return x

    if len(topo.partition_axes) > 1:
        return _hierarchical_multi_axis(x, topo, axis=axis, order=order)
    return _hierarchical_single_axis(
        x, topo.partition_axes[0], p, axis=axis, order=order, inner=inner
    )


def _hierarchical_single_axis(
    x: jax.Array,
    axis_name: str,
    p: int,
    *,
    axis: int,
    order: str,
    inner: int | None,
) -> jax.Array:
    # factor p = outer * inner
    if inner is None:
        inner = default_hierarchy_inner(p)
    if p % inner != 0:
        raise ValueError(f"inner={inner} does not divide p={p}")
    outer = p // inner
    if inner == 1 or outer == 1:
        return lax.all_gather(x, axis_name, axis=axis, tiled=True)

    outer_groups, inner_groups = _stage_groups(p, inner)

    if order == "outer_first":
        # Paper-faithful: stage 1 over slow/outer links, stage 2 reorder,
        # stage 3 over fast/inner links.
        g1 = lax.all_gather(
            x, axis_name, axis=axis, tiled=True, axis_index_groups=outer_groups
        )
        g2 = lax.all_gather(
            g1, axis_name, axis=axis, tiled=True, axis_index_groups=inner_groups
        )
        # g2 chunk order along ``axis`` is (local_rank r, node o); canonical
        # ownership (device i = o*inner + r owns chunk i) wants (o, r).
        return _reorder_chunks(g2, axis, inner, outer)
    elif order == "inner_first":
        # Beyond-paper: fast links first -> contiguous blocks -> no reorder.
        g1 = lax.all_gather(
            x, axis_name, axis=axis, tiled=True, axis_index_groups=inner_groups
        )
        g2 = lax.all_gather(
            g1, axis_name, axis=axis, tiled=True, axis_index_groups=outer_groups
        )
        return g2
    raise ValueError(f"unknown order {order!r}")


def _hierarchical_multi_axis(
    x: jax.Array, topo: MiCSTopology, *, axis: int, order: str
) -> jax.Array:
    """Partition group spans mesh axes (e.g. ('pod','shard')).

    Canonical chunk ownership follows PartitionSpec axis order: the first
    (slowest) axis is major.  Gathering minor-axis-first yields contiguous
    blocks, so concatenating over the major axis needs no reorder
    (inner_first).  Major-axis-first is the paper's schedule and needs the
    reorder stage.
    """
    axes = topo.partition_axes  # slowest first, major in chunk order
    if order == "inner_first":
        out = x
        for name in reversed(axes):  # fast axes first
            out = lax.all_gather(out, name, axis=axis, tiled=True)
        return out
    elif order == "outer_first":
        out = x
        sizes = [topo.axis_size(a) for a in axes]
        for name in axes:  # slow axes first
            out = lax.all_gather(out, name, axis=axis, tiled=True)
        # chunk order is reversed-major; fix to canonical (major=axes[0]).
        # After gathering slow-first, ordering along ``axis`` is
        # (minor..major); canonical is (major..minor).
        inner = 1
        for s in sizes[1:]:
            inner *= s
        return _reorder_chunks(out, axis, inner, sizes[0])
    raise ValueError(f"unknown order {order!r}")


def _reorder_chunks(buf: jax.Array, axis: int, inner: int, outer: int) -> jax.Array:
    """Paper stage 2: [r, o, chunk] -> [o, r, chunk] along ``axis``."""
    shape = buf.shape
    n = shape[axis]
    chunk = n // (inner * outer)
    new_shape = shape[:axis] + (inner, outer, chunk) + shape[axis + 1 :]
    resh = buf.reshape(new_shape)
    perm = list(range(resh.ndim))
    perm[axis], perm[axis + 1] = perm[axis + 1], perm[axis]
    return jnp.transpose(resh, perm).reshape(shape[:axis] + (n,) + shape[axis + 1 :])


# ---------------------------------------------------------------------------
# hierarchical reduce-scatter (the exact adjoint of the staged gather)
# ---------------------------------------------------------------------------

def hierarchical_reduce_scatter(
    g: jax.Array,
    topo: MiCSTopology,
    *,
    axis: int = 0,
    order: str = "inner_first",
    inner: int | None = None,
) -> jax.Array:
    """Reduce-scatter ``g`` over the partition group, staged over the
    hierarchy — the linear transpose of ``hierarchical_all_gather`` with the
    same ``order``/``inner`` (stages run in reverse, each all-gather becomes
    a ``psum_scatter`` over the same ``axis_index_groups``, the paper's
    reorder stage becomes its inverse permutation).

    This is what makes every gather policy's adjoint *exact*: hop-1 gradient
    synchronization (§3.4) is this function, whether reached implicitly via
    autodiff or through the CommEngine's centralized ``custom_vjp``.
    """
    p = topo.partition_size
    if p == 1:
        return g
    if len(topo.partition_axes) > 1:
        return _hier_rs_multi_axis(g, topo, axis=axis, order=order)
    return _hier_rs_single_axis(
        g, topo.partition_axes[0], p, axis=axis, order=order, inner=inner
    )


def _hier_rs_single_axis(
    g: jax.Array,
    axis_name: str,
    p: int,
    *,
    axis: int,
    order: str,
    inner: int | None,
) -> jax.Array:
    if inner is None:
        inner = default_hierarchy_inner(p)
    if p % inner != 0:
        raise ValueError(f"inner={inner} does not divide p={p}")
    outer = p // inner
    if inner == 1 or outer == 1:
        return lax.psum_scatter(g, axis_name, scatter_dimension=axis, tiled=True)

    outer_groups, inner_groups = _stage_groups(p, inner)

    if order == "outer_first":
        # forward: AG(outer) -> AG(inner) -> reorder [r,o]->[o,r]
        # adjoint: reorder [o,r]->[r,o] -> RS(inner) -> RS(outer)
        g = _reorder_chunks(g, axis, outer, inner)
        g = lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                             tiled=True, axis_index_groups=inner_groups)
        return lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                                tiled=True, axis_index_groups=outer_groups)
    elif order == "inner_first":
        # forward: AG(inner) -> AG(outer);  adjoint: RS(outer) -> RS(inner)
        g = lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                             tiled=True, axis_index_groups=outer_groups)
        return lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                                tiled=True, axis_index_groups=inner_groups)
    raise ValueError(f"unknown order {order!r}")


def _hier_rs_multi_axis(
    g: jax.Array, topo: MiCSTopology, *, axis: int, order: str
) -> jax.Array:
    axes = topo.partition_axes
    if order == "inner_first":
        # forward applied gathers fast->slow, so the last-applied gather is
        # axes[0]; the adjoint scatters slow->fast.
        out = g
        for name in axes:
            out = lax.psum_scatter(out, name, scatter_dimension=axis, tiled=True)
        return out
    elif order == "outer_first":
        sizes = [topo.axis_size(a) for a in axes]
        inner = 1
        for s in sizes[1:]:
            inner *= s
        out = _reorder_chunks(g, axis, sizes[0], inner)  # inverse of forward
        for name in reversed(axes):
            out = lax.psum_scatter(out, name, scatter_dimension=axis, tiled=True)
        return out
    raise ValueError(f"unknown order {order!r}")


# ---------------------------------------------------------------------------
# block-quantized staged reduce-scatter (ZeRO++ qgZ on the MiCS hierarchy)
# ---------------------------------------------------------------------------
#
# The float reduce-scatter above ships full-width payloads; the quantized
# variant ships (int8 q, f32 per-128-block absmax scales) on every hop.  A
# ``psum_scatter`` cannot carry int8 (the wire op *is* the sum), so each
# stage becomes the all-to-all decomposition of a reduce-scatter:
#
#   quantize local buffer  ->  all-to-all(q), all-to-all(s) within the
#   stage group  ->  dequantize  ->  accumulate the group's chunks in fp32
#
# and the fp32 partial sum is what the *next* stage quantizes — error is
# injected once per hop on the wire and never compounds through a chain of
# int8 summations (ZeRO++'s qgZ, adapted from its single all-to-all to this
# repo's staged hierarchy).  Rounding is stochastic by default (unbiased in
# expectation, core/quant.py); the dither key is a deterministic function of
# (salt, stage, device, step) — the training step counter threaded from the
# step function (``seed=``), so runs are reproducible while distinct
# training steps draw distinct dither (a fixed key would re-inject the
# *same* rounding error into every call, accumulating coherently on
# slowly-varying gradients).  Callers with no step counter in scope
# (standalone collectives, serving) fall back to a payload fingerprint (bit
# pattern of the buffer sum) as the step component — value-dependent but
# equally decorrelating.

_QGZ_SEED = 0x9f2c


def _dither_key(salt: int, stage: int, coord, fingerprint) -> jax.Array:
    key = jax.random.fold_in(jax.random.key(_QGZ_SEED), salt)
    key = jax.random.fold_in(key, stage)
    key = jax.random.fold_in(key, coord)
    return jax.random.fold_in(key, fingerprint)


def _payload_fingerprint(g: jax.Array):
    """int32 fingerprint of a payload (bit pattern of its sum) — folds the
    data into the dither key so repeated calls on different gradients never
    share rounding noise.  The fallback step component: the train step
    threads its real step counter instead (``seed=`` below), which makes
    the dither value-independent as well."""
    return lax.bitcast_convert_type(jnp.sum(g), jnp.int32)


def _step_component(g: jax.Array, seed, stochastic: bool):
    """The dither key's step component: the threaded step counter when the
    caller has one, else the payload fingerprint (legacy fallback)."""
    if not stochastic:
        return None
    if seed is not None:
        return seed
    return _payload_fingerprint(g)


def _device_coord(topo: MiCSTopology):
    """Linearized global device index (dither decorrelation across devices)."""
    idx = 0
    for name in topo.mesh.axis_names:
        idx = idx * topo.axis_size(name) + lax.axis_index(name)
    return idx


def _quant_exchange_stage(g, axis_names, *, group_size, groups, key):
    """One qgZ stage: blockwise-quantize, ship int8+scales, fp32-accumulate.

    ``g`` is this device's fp32 buffer ``[N]`` (``N % group_size == 0``);
    returns the group-reduced chunk ``[N / group_size]`` in fp32.
    """
    k = group_size
    if k == 1:
        return g
    n = g.shape[0]
    if n % k:
        raise ValueError(f"buffer length {n} not divisible by group {k}")
    chunks = g.reshape(k, n // k)
    q, s = Q.quantize_flat(chunks, key=key)
    qx = lax.all_to_all(q, axis_names, 0, 0, axis_index_groups=groups)
    sx = lax.all_to_all(s, axis_names, 0, 0, axis_index_groups=groups)
    return jnp.sum(Q.dequantize_flat(qx, sx, dtype=jnp.float32), axis=0)


def _quant_stage_plan(topo: MiCSTopology, topology: str, inner: int | None):
    """The stage sequence of the quantized adjoint, mirroring the float
    reduce-scatter of the same ``topology``: ``(axis_names, group_size,
    axis_index_groups)`` per stage, plus the outer_first pre-reorder factors.

    COUPLED to ``_hier_rs_single_axis``/``_hier_rs_multi_axis`` above: the
    stage order, group construction and reorder factors must stay in
    lockstep or the quantized adjoint scatters chunks to the wrong owners.
    The equivalence is pinned by ``tests/qgz_harness.py::quant_rs_routing``
    (grid-exact data makes the quantizer lossless, so any routing drift is
    a hard mismatch against ``psum_scatter``).
    """
    p = topo.partition_size
    reorder = None  # (outer, inner) reorder factors for outer_first
    if topology == "flat":
        return [(topo.partition_axes, p, None)], reorder
    if len(topo.partition_axes) > 1:
        axes = topo.partition_axes
        sizes = [topo.axis_size(a) for a in axes]
        if topology == "inner_first":
            # forward gathered fast->slow; adjoint scatters slow->fast
            stages = [((a,), topo.axis_size(a), None) for a in axes]
        else:  # outer_first
            inner_f = 1
            for s_ in sizes[1:]:
                inner_f *= s_
            reorder = (sizes[0], inner_f)
            stages = [((a,), topo.axis_size(a), None) for a in reversed(axes)]
        return stages, reorder
    axis_name = topo.partition_axes[0]
    if inner is None:
        inner = default_hierarchy_inner(p)
    if p % inner:
        raise ValueError(f"inner={inner} does not divide p={p}")
    outer = p // inner
    if inner == 1 or outer == 1:
        return [((axis_name,), p, None)], reorder
    outer_groups, inner_groups = _stage_groups(p, inner)
    if topology == "inner_first":
        stages = [((axis_name,), outer, outer_groups),
                  ((axis_name,), inner, inner_groups)]
    elif topology == "outer_first":
        reorder = (outer, inner)
        stages = [((axis_name,), inner, inner_groups),
                  ((axis_name,), outer, outer_groups)]
    else:
        raise ValueError(f"unknown topology {topology!r}")
    return stages, reorder


def quantized_reduce_scatter(
    g: jax.Array,
    topo: MiCSTopology,
    *,
    topology: str = "inner_first",
    inner: int | None = None,
    salt: int = 0,
    stochastic: bool = True,
    seed=None,
) -> jax.Array:
    """Block-quantized hop-1 reduce-scatter over the partition group (qgZ).

    Numerically this approximates ``hierarchical_reduce_scatter`` /
    ``hop1_reduce_scatter`` of the same staging while shipping int8 (+ f32
    block scales) on every hop; the result is always fp32.  Per-stage error
    is bounded by one quantization step of that stage's fp32 partial sums
    (additive across hops, never compounding), and with ``stochastic=True``
    each stage is unbiased in expectation.  ``seed`` (a traced int32, the
    training step) replaces the payload-fingerprint component of the dither
    key — value-independent, step-varying rounding noise.
    """
    g = g.astype(jnp.float32)
    if topo.partition_size == 1:
        return g
    if g.ndim != 1:
        raise ValueError(f"quantized_reduce_scatter expects a flat [N] "
                         f"buffer, got shape {g.shape}")
    stages, reorder = _quant_stage_plan(topo, topology, inner)
    if reorder is not None:
        g = _reorder_chunks(g, 0, reorder[0], reorder[1])
    coord = _device_coord(topo)
    fp = _step_component(g, seed, stochastic)
    for i, (axis_names, group_size, groups) in enumerate(stages):
        key = _dither_key(salt, i, coord, fp) if stochastic else None
        g = _quant_exchange_stage(
            g, axis_names if len(axis_names) > 1 else axis_names[0],
            group_size=group_size, groups=groups, key=key)
    return g


def quantized_all_reduce(
    g: jax.Array,
    topo: MiCSTopology,
    *,
    salt: int = 0,
    stochastic: bool = True,
    seed=None,
) -> jax.Array:
    """Block-quantized replication-group all-reduce (the int8 hop-2 leg).

    An all-reduce is a reduce-scatter + all-gather; both legs ship (int8 q,
    f32 block scales) with an fp32 accumulation between them: quantize ->
    all-to-all -> dequant -> fp32 sum -> re-quantize -> all-gather ->
    dequant.  Payload lengths need not divide the group (zero-padded to
    ``r`` chunks; chunk tails are ragged blocks, core/quant.py).  Unlike
    the elementwise bf16 hop-2 cast, the block structure follows the
    *payload*, so results depend on hop-2 granularity: the serial and
    bucketed boundary schedules are close but not bitwise equal under int8
    hop-2 (they are under fp32/bf16).
    """
    axes = topo.replication_axes
    r = topo.replication_degree
    g = g.astype(jnp.float32)
    if not axes or r == 1:
        return g
    if g.ndim != 1:
        raise ValueError(f"quantized_all_reduce expects a flat [N] buffer, "
                         f"got shape {g.shape}")
    n = g.shape[0]
    m = -(-n // r)
    pad = r * m - n
    x = jnp.pad(g, (0, pad)) if pad else g
    coord = _device_coord(topo)
    fp = _step_component(g, seed, stochastic)
    # reduce-scatter leg
    q, s = Q.quantize_flat(
        x.reshape(r, m),
        key=_dither_key(salt, 0, coord, fp) if stochastic else None)
    qx = lax.all_to_all(q, axes, 0, 0)
    sx = lax.all_to_all(s, axes, 0, 0)
    red = jnp.sum(Q.dequantize_flat(qx, sx, dtype=jnp.float32), axis=0)
    # all-gather leg (each replica owns — and re-quantizes — one chunk)
    q2, s2 = Q.quantize_flat(
        red, key=_dither_key(salt, 1, coord, fp) if stochastic else None)
    qg = lax.all_gather(q2, axes, axis=0, tiled=True)
    sg = lax.all_gather(s2, axes, axis=0, tiled=True)
    nb = s2.shape[-1]
    out = Q.dequantize_flat(qg.reshape(r, m), sg.reshape(r, nb),
                            dtype=jnp.float32).reshape(r * m)
    return out[:n] if pad else out


# ---------------------------------------------------------------------------
# partition-group gather front-end (what comm.py builds policies from)
# ---------------------------------------------------------------------------

def partition_all_gather(
    x: jax.Array,
    topo: MiCSTopology,
    *,
    axis: int = 0,
    hierarchical: bool = True,
    order: str = "inner_first",
    inner: int | None = None,
) -> jax.Array:
    """Gather a model-state shard across its partition group (paper §3.2).

    One call per layer on the layer's *flat* buffer — the coalesced
    communication API of paper §4 is satisfied by construction.
    """
    if topo.partition_size == 1:
        return x
    if hierarchical:
        return hierarchical_all_gather(
            x, topo, axis=axis, order=order, inner=inner
        )
    return flat_all_gather(x, topo.partition_axes, axis=axis)


# ---------------------------------------------------------------------------
# gradient synchronization primitives (§3.4)
# ---------------------------------------------------------------------------

def hop1_reduce_scatter(g: jax.Array, topo: MiCSTopology, *, axis: int = 0) -> jax.Array:
    """Reduce-scatter a full gradient across the partition group (hop 1).

    Normally this arises implicitly as the VJP of ``partition_all_gather``;
    the explicit form is used by the alternative-schedule ablation and tests.
    """
    if topo.partition_size == 1:
        return g
    return lax.psum_scatter(
        g, topo.partition_axes, scatter_dimension=axis, tiled=True
    )


def hop2_all_reduce(g: jax.Array, topo: MiCSTopology) -> jax.Array:
    """All-reduce shard gradients across replication groups (hop 2).

    Runs once per gradient-accumulation boundary, over the replication axes
    only — the paper's amortized global synchronization.
    """
    if not topo.replication_axes or topo.replication_degree == 1:
        return g
    return lax.psum(g, topo.replication_axes)


def alternative_sync(g_full: jax.Array, topo: MiCSTopology, *, axis: int = 0) -> jax.Array:
    """DeepSpeed's default schedule (paper §3.4 "alternative"): all-reduce the
    *full* gradient over every data device each micro-step, then keep only the
    local shard.  Implemented for the Fig 14 ablation; strictly redundant.
    """
    summed = lax.psum(g_full, topo.partition_axes + topo.replication_axes)
    p = topo.partition_size
    if p == 1:
        return summed
    idx = _partition_coord(topo)
    size = summed.shape[axis] // p
    return lax.dynamic_slice_in_dim(summed, idx * size, size, axis=axis)


def _partition_coord(topo: MiCSTopology):
    """Linearized index of this device within its partition group."""
    idx = 0
    for name in topo.partition_axes:
        idx = idx * topo.axis_size(name) + lax.axis_index(name)
    return idx


def replica_mean(x: jax.Array, topo: MiCSTopology) -> jax.Array:
    """Mean over every data-parallel device (for loss logging)."""
    return lax.pmean(x, topo.data_axes)
