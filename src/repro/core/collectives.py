"""MiCS collectives: flat and hierarchical all-gather / reduce-scatter.

Implements the paper's §3.3 three-stage hierarchical all-gather, adapted to
TPU mesh axes, plus a beyond-paper *reorder-free* variant.

Paper-faithful ("outer_first", 3 stages), partition group p = outer×inner
(outer = "p/k nodes" over the slow links, inner = "k devices per node"):

  stage 1: ``inner`` parallel all-gathers over the *outer* (slow) dimension
           among same-local-rank devices  (paper Fig 5, inter-node)
  stage 2: chunk reorder to fix memory contiguity (paper Fig 5, middle)
  stage 3: batched all-gathers over the *inner* (fast) dimension

Beyond-paper ("inner_first", 2 stages): gathering over the fast dimension
first makes each device hold a *contiguous* block of chunks, so the outer
gather concatenates blocks already in canonical order — the reorder stage
vanishes and the slow-link stage moves k×-larger messages (better effective
bandwidth per the paper's own Fig 2 argument) while transferring the same
(p−k)M/p volume over the slow links.

All functions are pure jnp/lax and differentiate correctly: the VJP of a
hierarchical all-gather is the matching hierarchical reduce-scatter, which is
how hop-1 gradient synchronization (§3.4) materializes from plain `jax.grad`.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.topology import MiCSTopology, default_hierarchy_inner


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stage_groups(p: int, inner: int) -> tuple[list[list[int]], list[list[int]]]:
    """axis_index_groups for the two stages within a single mesh axis.

    outer groups: same local rank r, strided by ``inner``  (size p/inner)
    inner groups: contiguous runs of ``inner`` indices      (size inner)
    """
    outer_groups = [list(range(r, p, inner)) for r in range(inner)]
    inner_groups = [list(range(o * inner, (o + 1) * inner)) for o in range(p // inner)]
    return outer_groups, inner_groups


def flat_all_gather(x: jax.Array, axes: Sequence[str], axis: int = 0) -> jax.Array:
    """Vanilla single-collective all-gather over the product of ``axes``."""
    axes = tuple(axes)
    if not axes:
        return x
    return lax.all_gather(x, axes, axis=axis, tiled=True)


# ---------------------------------------------------------------------------
# hierarchical all-gather
# ---------------------------------------------------------------------------

def hierarchical_all_gather(
    x: jax.Array,
    topo: MiCSTopology,
    *,
    axis: int = 0,
    order: str = "inner_first",
    inner: int | None = None,
) -> jax.Array:
    """All-gather ``x`` over the partition group, staged over the hierarchy.

    ``x`` is this device's shard (1/p of the full buffer along ``axis``).
    Returns the full buffer, identical to ``flat_all_gather`` over the
    partition axes.
    """
    p = topo.partition_size
    if p == 1:
        return x

    if len(topo.partition_axes) > 1:
        return _hierarchical_multi_axis(x, topo, axis=axis, order=order)
    return _hierarchical_single_axis(
        x, topo.partition_axes[0], p, axis=axis, order=order, inner=inner
    )


def _hierarchical_single_axis(
    x: jax.Array,
    axis_name: str,
    p: int,
    *,
    axis: int,
    order: str,
    inner: int | None,
) -> jax.Array:
    # factor p = outer * inner
    if inner is None:
        inner = default_hierarchy_inner(p)
    if p % inner != 0:
        raise ValueError(f"inner={inner} does not divide p={p}")
    outer = p // inner
    if inner == 1 or outer == 1:
        return lax.all_gather(x, axis_name, axis=axis, tiled=True)

    outer_groups, inner_groups = _stage_groups(p, inner)

    if order == "outer_first":
        # Paper-faithful: stage 1 over slow/outer links, stage 2 reorder,
        # stage 3 over fast/inner links.
        g1 = lax.all_gather(
            x, axis_name, axis=axis, tiled=True, axis_index_groups=outer_groups
        )
        g2 = lax.all_gather(
            g1, axis_name, axis=axis, tiled=True, axis_index_groups=inner_groups
        )
        # g2 chunk order along ``axis`` is (local_rank r, node o); canonical
        # ownership (device i = o*inner + r owns chunk i) wants (o, r).
        return _reorder_chunks(g2, axis, inner, outer)
    elif order == "inner_first":
        # Beyond-paper: fast links first -> contiguous blocks -> no reorder.
        g1 = lax.all_gather(
            x, axis_name, axis=axis, tiled=True, axis_index_groups=inner_groups
        )
        g2 = lax.all_gather(
            g1, axis_name, axis=axis, tiled=True, axis_index_groups=outer_groups
        )
        return g2
    raise ValueError(f"unknown order {order!r}")


def _hierarchical_multi_axis(
    x: jax.Array, topo: MiCSTopology, *, axis: int, order: str
) -> jax.Array:
    """Partition group spans mesh axes (e.g. ('pod','shard')).

    Canonical chunk ownership follows PartitionSpec axis order: the first
    (slowest) axis is major.  Gathering minor-axis-first yields contiguous
    blocks, so concatenating over the major axis needs no reorder
    (inner_first).  Major-axis-first is the paper's schedule and needs the
    reorder stage.
    """
    axes = topo.partition_axes  # slowest first, major in chunk order
    if order == "inner_first":
        out = x
        for name in reversed(axes):  # fast axes first
            out = lax.all_gather(out, name, axis=axis, tiled=True)
        return out
    elif order == "outer_first":
        out = x
        sizes = [topo.axis_size(a) for a in axes]
        for name in axes:  # slow axes first
            out = lax.all_gather(out, name, axis=axis, tiled=True)
        # chunk order is reversed-major; fix to canonical (major=axes[0]).
        # After gathering slow-first, ordering along ``axis`` is
        # (minor..major); canonical is (major..minor).
        inner = 1
        for s in sizes[1:]:
            inner *= s
        return _reorder_chunks(out, axis, inner, sizes[0])
    raise ValueError(f"unknown order {order!r}")


def _reorder_chunks(buf: jax.Array, axis: int, inner: int, outer: int) -> jax.Array:
    """Paper stage 2: [r, o, chunk] -> [o, r, chunk] along ``axis``."""
    shape = buf.shape
    n = shape[axis]
    chunk = n // (inner * outer)
    new_shape = shape[:axis] + (inner, outer, chunk) + shape[axis + 1 :]
    resh = buf.reshape(new_shape)
    perm = list(range(resh.ndim))
    perm[axis], perm[axis + 1] = perm[axis + 1], perm[axis]
    return jnp.transpose(resh, perm).reshape(shape[:axis] + (n,) + shape[axis + 1 :])


# ---------------------------------------------------------------------------
# hierarchical reduce-scatter (the exact adjoint of the staged gather)
# ---------------------------------------------------------------------------

def hierarchical_reduce_scatter(
    g: jax.Array,
    topo: MiCSTopology,
    *,
    axis: int = 0,
    order: str = "inner_first",
    inner: int | None = None,
) -> jax.Array:
    """Reduce-scatter ``g`` over the partition group, staged over the
    hierarchy — the linear transpose of ``hierarchical_all_gather`` with the
    same ``order``/``inner`` (stages run in reverse, each all-gather becomes
    a ``psum_scatter`` over the same ``axis_index_groups``, the paper's
    reorder stage becomes its inverse permutation).

    This is what makes every gather policy's adjoint *exact*: hop-1 gradient
    synchronization (§3.4) is this function, whether reached implicitly via
    autodiff or through the CommEngine's centralized ``custom_vjp``.
    """
    p = topo.partition_size
    if p == 1:
        return g
    if len(topo.partition_axes) > 1:
        return _hier_rs_multi_axis(g, topo, axis=axis, order=order)
    return _hier_rs_single_axis(
        g, topo.partition_axes[0], p, axis=axis, order=order, inner=inner
    )


def _hier_rs_single_axis(
    g: jax.Array,
    axis_name: str,
    p: int,
    *,
    axis: int,
    order: str,
    inner: int | None,
) -> jax.Array:
    if inner is None:
        inner = default_hierarchy_inner(p)
    if p % inner != 0:
        raise ValueError(f"inner={inner} does not divide p={p}")
    outer = p // inner
    if inner == 1 or outer == 1:
        return lax.psum_scatter(g, axis_name, scatter_dimension=axis, tiled=True)

    outer_groups, inner_groups = _stage_groups(p, inner)

    if order == "outer_first":
        # forward: AG(outer) -> AG(inner) -> reorder [r,o]->[o,r]
        # adjoint: reorder [o,r]->[r,o] -> RS(inner) -> RS(outer)
        g = _reorder_chunks(g, axis, outer, inner)
        g = lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                             tiled=True, axis_index_groups=inner_groups)
        return lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                                tiled=True, axis_index_groups=outer_groups)
    elif order == "inner_first":
        # forward: AG(inner) -> AG(outer);  adjoint: RS(outer) -> RS(inner)
        g = lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                             tiled=True, axis_index_groups=outer_groups)
        return lax.psum_scatter(g, axis_name, scatter_dimension=axis,
                                tiled=True, axis_index_groups=inner_groups)
    raise ValueError(f"unknown order {order!r}")


def _hier_rs_multi_axis(
    g: jax.Array, topo: MiCSTopology, *, axis: int, order: str
) -> jax.Array:
    axes = topo.partition_axes
    if order == "inner_first":
        # forward applied gathers fast->slow, so the last-applied gather is
        # axes[0]; the adjoint scatters slow->fast.
        out = g
        for name in axes:
            out = lax.psum_scatter(out, name, scatter_dimension=axis, tiled=True)
        return out
    elif order == "outer_first":
        sizes = [topo.axis_size(a) for a in axes]
        inner = 1
        for s in sizes[1:]:
            inner *= s
        out = _reorder_chunks(g, axis, sizes[0], inner)  # inverse of forward
        for name in reversed(axes):
            out = lax.psum_scatter(out, name, scatter_dimension=axis, tiled=True)
        return out
    raise ValueError(f"unknown order {order!r}")


# ---------------------------------------------------------------------------
# partition-group gather front-end (what comm.py builds policies from)
# ---------------------------------------------------------------------------

def partition_all_gather(
    x: jax.Array,
    topo: MiCSTopology,
    *,
    axis: int = 0,
    hierarchical: bool = True,
    order: str = "inner_first",
    inner: int | None = None,
) -> jax.Array:
    """Gather a model-state shard across its partition group (paper §3.2).

    One call per layer on the layer's *flat* buffer — the coalesced
    communication API of paper §4 is satisfied by construction.
    """
    if topo.partition_size == 1:
        return x
    if hierarchical:
        return hierarchical_all_gather(
            x, topo, axis=axis, order=order, inner=inner
        )
    return flat_all_gather(x, topo.partition_axes, axis=axis)


# ---------------------------------------------------------------------------
# gradient synchronization primitives (§3.4)
# ---------------------------------------------------------------------------

def hop1_reduce_scatter(g: jax.Array, topo: MiCSTopology, *, axis: int = 0) -> jax.Array:
    """Reduce-scatter a full gradient across the partition group (hop 1).

    Normally this arises implicitly as the VJP of ``partition_all_gather``;
    the explicit form is used by the alternative-schedule ablation and tests.
    """
    if topo.partition_size == 1:
        return g
    return lax.psum_scatter(
        g, topo.partition_axes, scatter_dimension=axis, tiled=True
    )


def hop2_all_reduce(g: jax.Array, topo: MiCSTopology) -> jax.Array:
    """All-reduce shard gradients across replication groups (hop 2).

    Runs once per gradient-accumulation boundary, over the replication axes
    only — the paper's amortized global synchronization.
    """
    if not topo.replication_axes or topo.replication_degree == 1:
        return g
    return lax.psum(g, topo.replication_axes)


def alternative_sync(g_full: jax.Array, topo: MiCSTopology, *, axis: int = 0) -> jax.Array:
    """DeepSpeed's default schedule (paper §3.4 "alternative"): all-reduce the
    *full* gradient over every data device each micro-step, then keep only the
    local shard.  Implemented for the Fig 14 ablation; strictly redundant.
    """
    summed = lax.psum(g_full, topo.partition_axes + topo.replication_axes)
    p = topo.partition_size
    if p == 1:
        return summed
    idx = _partition_coord(topo)
    size = summed.shape[axis] // p
    return lax.dynamic_slice_in_dim(summed, idx * size, size, axis=axis)


def _partition_coord(topo: MiCSTopology):
    """Linearized index of this device within its partition group."""
    idx = 0
    for name in topo.partition_axes:
        idx = idx * topo.axis_size(name) + lax.axis_index(name)
    return idx


def replica_mean(x: jax.Array, topo: MiCSTopology) -> jax.Array:
    """Mean over every data-parallel device (for loss logging)."""
    return lax.pmean(x, topo.data_axes)
