"""Mesh topology for MiCS: partition groups and replication groups as mesh axes.

The paper divides ``n`` devices into *partition groups* of size ``p`` (each
holding one complete, internally partitioned replica of the model states) and
*replication groups* (same-local-rank devices across partition groups, holding
identical shards).  On TPU we realize this by factoring the ``data`` axis of
the production mesh ``(pod, data, model)`` into ``(repl, shard)`` with
``shard == p``:

    all-gather over 'shard'            = intra-partition-group gather
    psum_scatter over 'shard'          = hop-1 gradient reduce-scatter
    psum over ('pod', 'repl')          = hop-2 replication-group all-reduce

ZeRO-3 is the degenerate case ``partition_axes == all data-like axes`` with
no replication axes; the same code path covers both (§3.2 of the paper).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import AxisType, make_mesh, mesh_from_devices
from repro.core.linkmodel import V5E

# Mesh axis names, fixed across the framework.
POD_AXIS = "pod"
REPL_AXIS = "repl"
SHARD_AXIS = "shard"
DP2_AXIS = "dp2"     # leftover of the model axis donated to data parallelism
MODEL_AXIS = "model"

MICS_AXES = (POD_AXIS, REPL_AXIS, SHARD_AXIS, DP2_AXIS, MODEL_AXIS)

# v5e-class hardware constants (roofline + partition-size heuristic).
# The single source of truth is the link-profile table
# (core/linkmodel.py); these aliases keep the historical names alive for
# the heuristics below and tests.
HBM_BYTES_PER_CHIP = V5E.hbm_bytes
PEAK_BF16_FLOPS = V5E.peak_flops
HBM_BW = V5E.hbm_bw
ICI_BW_PER_LINK = V5E.intra.bandwidth
# DCI (inter-pod) modeled as a scarce slow link per pod boundary.
DCI_BW_PER_LINK = V5E.inter.bandwidth

# Adam mixed precision footprint: fp32 master + fp32 m + fp32 v + fp32 grad
# accumulator (the transient bf16 gathered copy is per-layer, not persistent).
MODEL_STATE_BYTES_PER_PARAM = 16


def _auto(n: int):
    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The assignment's production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (POD_AXIS, "data", MODEL_AXIS) if multi_pod else ("data", MODEL_AXIS)
    return make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mics_mesh(base: Mesh, partition_size: int, tp: int | None = None) -> Mesh:
    """Refactor the production mesh into the MiCS mesh.

    ``data`` index ``d`` maps to ``(repl, shard) = divmod(d, partition_size)``
    so a partition group is a contiguous run of data-axis neighbours (fast
    ICI ring segment) — the paper's "consecutive ranks form a partition
    group".  Optionally the ``model`` axis is factored into ``(dp2, tp)``:
    architectures too small to exploit 16-way tensor parallelism donate the
    leftover factor to data parallelism (beyond-paper optimization,
    EXPERIMENTS.md §Perf).
    """
    names = base.axis_names
    devices = base.devices  # ndarray shaped like base
    if POD_AXIS in names:
        pods, data, model = devices.shape
    else:
        pods = 1
        data, model = devices.shape
        devices = devices.reshape(pods, data, model)
    if data % partition_size != 0:
        raise ValueError(
            f"partition size {partition_size} does not divide data axis {data}"
        )
    tp = model if tp is None else tp
    if model % tp != 0:
        raise ValueError(f"tp {tp} does not divide model axis {model}")
    repl = data // partition_size
    devs = devices.reshape(pods, repl, partition_size, model // tp, tp)
    return mesh_from_devices(devs, MICS_AXES, axis_types=_auto(5))


def make_host_mesh(
    pods: int = 1, repl: int = 1, shard: int = 1, model: int = 1, dp2: int = 1
) -> Mesh:
    """Small mesh over however many (virtual) devices exist — for tests."""
    n = pods * repl * shard * dp2 * model
    devs = np.array(jax.devices()[:n]).reshape(pods, repl, shard, dp2, model)
    return mesh_from_devices(devs, MICS_AXES, axis_types=_auto(5))


def elastic_host_topology(n_devices: int, partition_size: int,
                          tp: int = 1) -> MiCSTopology:
    """MiCSTopology over the first ``n_devices`` surviving (virtual) devices.

    The elastic train loop's mesh half (the policy half is
    ``autotune.resolve_world``): after a world change the survivors are
    re-factored as ``(pod=1, repl=n/(p·tp), shard=p, dp2=1, model=tp)`` —
    partition groups stay contiguous runs (the paper's consecutive-rank
    rule), the TP degree is pinned (flat layouts are TP-local, the
    checkpointer's one resharding invariant), and everything else reshards
    freely on restore.
    """
    if n_devices <= 0:
        raise ValueError(f"need at least one device, got {n_devices}")
    if n_devices % (partition_size * tp):
        raise ValueError(
            f"world of {n_devices} devices does not factor as "
            f"partition_size={partition_size} x tp={tp}")
    if n_devices > len(jax.devices()):
        raise ValueError(
            f"world of {n_devices} devices exceeds the {len(jax.devices())} "
            f"available")
    repl = n_devices // (partition_size * tp)
    return MiCSTopology(make_host_mesh(1, repl, partition_size, tp))


@dataclasses.dataclass(frozen=True)
class MiCSTopology:
    """Static description of how model states map onto a MiCS mesh.

    partition_axes: mesh axes whose product is the partition group (the ``p``
      devices jointly holding one model-state replica).  Ordered slowest
      link first — hierarchical gathers stage over them in order.
    replication_axes: mesh axes across which shards are replicated (hop-2
      all-reduce runs over these at the gradient-accumulation boundary).
    """

    mesh: Mesh
    partition_axes: tuple[str, ...] = (SHARD_AXIS,)
    replication_axes: tuple[str, ...] = (POD_AXIS, REPL_AXIS, DP2_AXIS)

    def __post_init__(self):
        names = set(self.mesh.axis_names)
        for ax in self.partition_axes + self.replication_axes:
            if ax not in names:
                raise ValueError(f"axis {ax!r} not in mesh {self.mesh.axis_names}")
        overlap = set(self.partition_axes) & set(self.replication_axes)
        if overlap:
            raise ValueError(f"axes {overlap} both partition and replication")

    # -- sizes ------------------------------------------------------------
    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name]

    @property
    def partition_size(self) -> int:  # p
        return math.prod(self.axis_size(a) for a in self.partition_axes)

    @property
    def replication_degree(self) -> int:  # n / p
        return math.prod(self.axis_size(a) for a in self.replication_axes)

    @property
    def model_size(self) -> int:
        return self.axis_size(MODEL_AXIS) if MODEL_AXIS in self.mesh.axis_names else 1

    @property
    def data_axes(self) -> tuple[str, ...]:
        """All axes that carry data parallelism (batch is sharded over these)."""
        return tuple(
            a for a in self.mesh.axis_names if a != MODEL_AXIS
        )

    @property
    def data_parallel_size(self) -> int:
        return math.prod(self.axis_size(a) for a in self.data_axes)

    @property
    def world_size(self) -> int:
        return self.mesh.size

    # -- shardings ---------------------------------------------------------
    def flat_param_sharding(self) -> NamedSharding:
        """[L, shard_len] flat pool: sharded over partition axes only."""
        return NamedSharding(self.mesh, P(None, self.partition_axes))

    def scalar_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def batch_sharding(self, extra_dims: int = 1) -> NamedSharding:
        """Batch dim sharded over every data axis; trailing dims replicated."""
        return NamedSharding(self.mesh, P(self.data_axes, *([None] * extra_dims)))

    def batch_spec(self, extra_dims: int = 1) -> P:
        return P(self.data_axes, *([None] * extra_dims))

    def flat_param_spec(self) -> P:
        return P(None, self.partition_axes)

    # -- group tables (for diagnostics / axis_index_groups) ----------------
    def partition_groups(self) -> list[list[int]]:
        """Global device ids of each partition group (paper Fig 3)."""
        mesh_devs = self.mesh.devices
        ids = np.vectorize(lambda d: d.id)(mesh_devs)
        # Move partition axes last, flatten the rest.
        names = list(self.mesh.axis_names)
        part_idx = [names.index(a) for a in self.partition_axes]
        other_idx = [i for i in range(len(names)) if i not in part_idx]
        perm = other_idx + part_idx
        arr = np.transpose(ids, perm).reshape(-1, self.partition_size)
        return [list(map(int, row)) for row in arr]

    def replication_groups(self) -> list[list[int]]:
        """Devices holding the same shard (paper's replication groups)."""
        mesh_devs = self.mesh.devices
        ids = np.vectorize(lambda d: d.id)(mesh_devs)
        names = list(self.mesh.axis_names)
        repl_idx = [names.index(a) for a in self.replication_axes]
        other_idx = [i for i in range(len(names)) if i not in repl_idx]
        perm = other_idx + repl_idx
        arr = np.transpose(ids, perm).reshape(-1, self.replication_degree)
        return [list(map(int, row)) for row in arr]


def choose_partition_size(
    param_count: int,
    *,
    data_axis: int = 16,
    model_axis: int = 16,
    hbm_bytes: int = HBM_BYTES_PER_CHIP,
    state_bytes_per_param: int = MODEL_STATE_BYTES_PER_PARAM,
    reserve_fraction: float = 0.35,
) -> int:
    """Paper §5.1.1 heuristic: the smallest partition group that fits.

    Model states are already divided by the tensor-parallel degree; the
    partition group then divides the remainder.  ``reserve_fraction`` of HBM
    is left for activations, KV caches and collective staging buffers.
    """
    budget = hbm_bytes * (1.0 - reserve_fraction)
    per_device_full = param_count * state_bytes_per_param / model_axis
    p = 1
    while p <= data_axis:
        if per_device_full / p <= budget:
            return p
        p *= 2
    raise ValueError(
        f"model with {param_count/1e9:.1f}B params does not fit even with "
        f"p={data_axis} (needs {per_device_full/data_axis/1e9:.1f} GB/device)"
    )


def default_hierarchy_inner(p: int) -> int:
    """Default intra-"node" factor: the largest power-of-two ≤ sqrt(p) that
    divides p — the 2-D analogue of the paper's (p/k nodes) × (k per node).
    The single source of truth for the staged gather, its adjoint
    reduce-scatter, and ``hierarchy_factors``."""
    inner = 1
    while inner * inner <= p // 2 and p % (inner * 2) == 0:
        inner *= 2
    return inner


def hierarchy_factors(topo: MiCSTopology, inner: int | None = None) -> tuple[int, int]:
    """Factor the partition group as (outer, inner) for hierarchical comm.

    When the partition group spans multiple mesh axes, the factorization is
    the axis split itself (slow axis = outer).  Within a single axis, the
    default inner factor is the largest power-of-two ≤ sqrt(p) — the 2-D
    analogue of the paper's (p/k nodes) × (k per node).
    """
    p = topo.partition_size
    if len(topo.partition_axes) > 1:
        outer = topo.axis_size(topo.partition_axes[0])
        return outer, p // outer
    if inner is None:
        inner = default_hierarchy_inner(p)
    if p % inner != 0:
        raise ValueError(f"inner factor {inner} does not divide p={p}")
    return p // inner, inner
