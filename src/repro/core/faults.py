"""Deterministic fault injection for elastic training (DESIGN.md §6).

MiCS's premise is training gigantic models on *public cloud*, where gigantic
capacity is bought as preemptible/spot instances: devices disappear mid-run
(sometimes with a notice window, sometimes abruptly), come back later, run
slow, or die halfway through a checkpoint write.  The train loop's survival
of those events (runtime/train_loop.py) is only trustworthy if the exact
failure timeline can be scripted and replayed — this module is that script.

A :class:`FaultPlan` is an ordered set of :class:`FaultEvent`\\ s, each firing
exactly once at its scripted step:

* ``preempt(at_step, devices)`` — raise :class:`PreemptionError` before the
  step runs: ``devices`` leave the world.  ``notice=True`` (the spot
  two-minute-warning model) lets the loop take an emergency checkpoint of
  the still-intact state; ``notice=False`` is the abrupt kill — the loop
  rolls back to the last *complete* checkpoint and recomputes.
* ``grow(at_step, devices)`` — raise :class:`GrowthError`: capacity came
  back, the loop re-resolves scale and resumes on the larger world.
* ``slow(at_step, device, factor)`` — stretch the step's wall time by
  sleeping, so the loop's EWMA straggler detector fires; ``evict=True``
  instead raises :class:`StragglerError` (the production "evict the slow
  host" decision), which the loop treats as a rollback-and-retry failure.
* ``crash_during_save(step)`` — kill the checkpoint writer *mid-write*
  (after the state blob, before the manifest is complete), leaving a
  ``step_*.tmp`` dir plus a truncated manifest behind — the atomicity
  scenario ``Checkpointer.latest_step`` must survive.
* ``crash(at_step)`` — raise :class:`EngineCrashError`: the process died
  but the world did not change.  The serving loop
  (runtime/resilient.py) retries in place — fresh pools, in-flight
  requests replayed from their prompts.

The same step-indexed plan drives serving: the resilient serve loop calls
it with the scheduler *tick* (``FaultPlan.parse`` builds one from the
``launch/serve.py --fault-plan`` spec), so a preemption can land mid-decode
and the bitwise replay contract is checked by tests/serve_chaos_harness.py.

The plan is callable with the step index, which is exactly the
``fault_injector`` hook ``runtime/train_loop.train`` already had; the
checkpoint-writer leg attaches via :meth:`FaultPlan.bind` (the loop does
this automatically when it is handed a plan).  Everything is driven by step
indices and fires once, so timelines replay identically across runs — the
8-virtual-device harness (tests/elastic_harness.py) scripts pod losses and
proves the resumed trajectory bitwise against a cold restore.
"""

from __future__ import annotations

import dataclasses
import json
import time


class FaultError(RuntimeError):
    """Base of every injected fault."""


class WorldChangeError(FaultError):
    """The device world changed: ``lost`` devices left, ``gained`` joined.

    ``notice=True`` means the event was announced while the old world was
    still intact (spot preemption notice / scheduler grow notification), so
    the loop may take an emergency checkpoint before rebuilding.
    """

    def __init__(self, msg: str, *, lost: int = 0, gained: int = 0,
                 notice: bool = True):
        super().__init__(msg)
        self.lost = int(lost)
        self.gained = int(gained)
        self.notice = bool(notice)


class PreemptionError(WorldChangeError):
    """Devices were (or are about to be) preempted."""

    def __init__(self, msg: str, *, lost: int, notice: bool = True):
        super().__init__(msg, lost=lost, notice=notice)


class GrowthError(WorldChangeError):
    """Preempted capacity returned; the world grew back."""

    def __init__(self, msg: str, *, gained: int):
        super().__init__(msg, gained=gained, notice=True)


class StragglerError(FaultError):
    """A device is slow enough that the scheduler decided to evict it."""


class CrashDuringSaveError(FaultError):
    """The checkpoint writer died mid-write (simulated process kill)."""


class EngineCrashError(FaultError):
    """The serving engine died without the world changing (process crash,
    XLA runtime abort).  The resilient serve loop treats it as retryable:
    same world, fresh KV pools, every in-flight request replayed from its
    prompt — bounded by ``ServeLoopConfig.max_crash_retries``."""


@dataclasses.dataclass
class FaultEvent:
    """One scripted event.  ``fired`` keeps every event one-shot, so the
    post-rollback replay of a step does not re-raise its fault."""

    kind: str                # 'preempt' | 'grow' | 'slow' | 'crash_during_save'
    at_step: int
    devices: int = 0         # lost (preempt) / gained (grow) device count
    factor: float = 1.0      # slow-down multiple for 'slow'
    notice: bool = True      # preemption announced before devices vanish
    evict: bool = False      # 'slow' escalates to StragglerError
    fired: bool = False

    def describe(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()}


class FaultPlan:
    """A deterministic, scripted failure timeline.

    Builders chain: ``FaultPlan().preempt(5, devices=4).grow(12, devices=4)``.
    ``slow_base_s`` scales the synthetic straggler delay (``factor`` times
    it); keep it small in tests — the *values* of the run never depend on
    wall time, only the loop's straggler EWMA does.
    """

    def __init__(self, *, slow_base_s: float = 0.05):
        self.events: list[FaultEvent] = []
        self.slow_base_s = float(slow_base_s)
        self.log: list[dict] = []      # fired events, in firing order

    # -- builders -----------------------------------------------------------
    def preempt(self, at_step: int, devices: int = 1, *,
                notice: bool = True) -> "FaultPlan":
        self.events.append(FaultEvent("preempt", at_step, devices=devices,
                                      notice=notice))
        return self

    def grow(self, at_step: int, devices: int) -> "FaultPlan":
        self.events.append(FaultEvent("grow", at_step, devices=devices))
        return self

    def slow(self, at_step: int, device: int = 0, factor: float = 3.0, *,
             evict: bool = False) -> "FaultPlan":
        # `device` is advisory on the SPMD harness (a slow device stalls the
        # whole collective, so the delay is global either way).
        self.events.append(FaultEvent("slow", at_step, devices=device,
                                      factor=factor, evict=evict))
        return self

    def crash_during_save(self, step: int) -> "FaultPlan":
        self.events.append(FaultEvent("crash_during_save", step))
        return self

    def crash(self, at_step: int) -> "FaultPlan":
        """Engine crash with the world intact (serve-loop retry path)."""
        self.events.append(FaultEvent("crash", at_step))
        return self

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from a compact CLI spec (``launch/serve.py
        --fault-plan``).

        Comma-separated one-shot events, each ``kind@tick`` with an
        optional ``xN`` device count (default 1):

        - ``preempt@T[xN]`` — abrupt loss of N devices at tick T;
        - ``notice@T[xN]`` — preemption announced with notice;
        - ``grow@T[xN]`` — N devices return;
        - ``slow@T[xF]`` — straggling tick (F = slowdown factor);
        - ``evict@T`` — straggler escalated to eviction;
        - ``crash@T`` — engine crash, world intact.

        Example: ``"preempt@20x4,grow@40x4,crash@60"``.
        """
        plan = cls()
        for part in filter(None, (p.strip() for p in spec.split(","))):
            try:
                kind, rest = part.split("@", 1)
                at, _, arg = rest.partition("x")
                at = int(at)
                n = float(arg) if arg else 1.0
            except ValueError:
                raise ValueError(f"bad fault spec {part!r} "
                                 "(want kind@tick[xN])") from None
            if kind == "preempt":
                plan.preempt(at, devices=int(n), notice=False)
            elif kind == "notice":
                plan.preempt(at, devices=int(n), notice=True)
            elif kind == "grow":
                plan.grow(at, devices=int(n))
            elif kind == "slow":
                plan.slow(at, factor=n)
            elif kind == "evict":
                plan.slow(at, factor=n, evict=True)
            elif kind == "crash":
                plan.crash(at)
            else:
                raise ValueError(f"unknown fault kind {kind!r} in {part!r}")
        return plan

    # -- the train-loop hook ------------------------------------------------
    def __call__(self, step: int) -> None:
        """Fire this step's scripted events (the loop's ``fault_injector``)."""
        for ev in self.events:
            if ev.fired or ev.at_step != int(step) \
                    or ev.kind == "crash_during_save":
                continue
            ev.fired = True
            self.log.append(ev.describe())
            if ev.kind == "preempt":
                raise PreemptionError(
                    f"preemption at step {step}: {ev.devices} device(s) "
                    f"{'announced leaving' if ev.notice else 'lost abruptly'}",
                    lost=ev.devices, notice=ev.notice)
            if ev.kind == "grow":
                raise GrowthError(
                    f"world grew at step {step}: {ev.devices} device(s) "
                    f"returned", gained=ev.devices)
            if ev.kind == "slow":
                time.sleep(self.slow_base_s * max(ev.factor - 1.0, 0.0))
                if ev.evict:
                    raise StragglerError(
                        f"device {ev.devices} {ev.factor:g}x slow at step "
                        f"{step}: evicted")
            if ev.kind == "crash":
                raise EngineCrashError(
                    f"engine crashed at step {step} (world intact)")

    # -- the checkpoint-writer hook ----------------------------------------
    def bind(self, checkpointer) -> "FaultPlan":
        """Attach the crash-during-save leg to a ``Checkpointer``."""
        checkpointer.fault_hook = self._save_hook
        return self

    def _save_hook(self, phase: str, tmp_dir, meta: dict) -> None:
        """Checkpointer ``fault_hook``: kill the writer mid-write.

        Runs on the writer thread after the state blob is on disk but
        before the manifest is complete; leaves a truncated manifest in the
        ``.tmp`` dir (what a real mid-``write_text`` kill leaves) so the
        atomicity scan has something adversarial to skip.
        """
        if phase != "pre_manifest":
            return
        for ev in self.events:
            if ev.fired or ev.kind != "crash_during_save" \
                    or ev.at_step != int(meta.get("step", -1)):
                continue
            ev.fired = True
            self.log.append(ev.describe())
            from repro.checkpoint.checkpointer import MANIFEST

            (tmp_dir / MANIFEST).write_text(json.dumps(meta)[:24])
            raise CrashDuringSaveError(
                f"checkpoint writer killed mid-save at step {meta['step']}")

    # -- introspection ------------------------------------------------------
    def pending(self) -> list[FaultEvent]:
        return [ev for ev in self.events if not ev.fired]

    def describe(self) -> dict:
        return {"events": [ev.describe() for ev in self.events],
                "fired": list(self.log)}
