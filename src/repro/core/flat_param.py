"""Flat parameter pools: ZeRO-3 / MiCS uniform model-state partitioning.

DeepSpeed (and therefore MiCS) shards each layer's parameters as one flat,
contiguous, uniformly divided buffer — which is also what makes the paper's
"coalesced communication" and "memory defragmentation" optimizations natural.
We reproduce that layout directly:

* every block's TP-local tensors are flattened and concatenated into one
  fp32 vector, padded so any partition-group size divides it;
* the vector (plus Adam's m/v, same shape) is what MiCS shards over the
  partition group — gathering a layer is ONE collective (coalesced by
  construction, paper §4), and XLA's static allocation of the pool is the
  analogue of the paper's preallocated contiguous buffers;
* segment metadata records how to rebuild tensors, which elements receive
  weight decay, and which segments must be re-assembled across the tensor-
  parallel axis at use time (norm scales, d_model biases, grouped-KV
  projections) — those are stored model-sharded and all-gathered over
  'model' sub-groups on use, so **no parameter is ever stored replicated**
  and no gradient fix-ups are needed: every collective's adjoint is exact.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Mapping

import jax
import jax.numpy as jnp
from jax import lax

# Any partition-group size we ever use (<= 32 data-parallel participants in
# ZeRO-3 multi-pod mode) times the 128-lane TPU alignment.
PAD_MULTIPLE = 32 * 128


@dataclasses.dataclass(frozen=True)
class Segment:
    """One logical tensor inside a flat pool (shapes are TP-local)."""

    name: str
    shape: tuple[int, ...]
    offset: int            # element offset into the flat vector
    decay: bool            # weight decay applies to this segment
    init: str              # 'normal' | 'zeros' | 'ones'
    std: float             # stddev for 'normal'
    model_gather: int = 1  # all-gather group size over the model axis at use
    model_gather_dim: int = 0

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    @property
    def end(self) -> int:
        return self.offset + self.size


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static description of a flat pool; shared by every layer in a stack."""

    segments: tuple[Segment, ...]
    raw_len: int
    flat_len: int

    # -- construction -------------------------------------------------------
    @staticmethod
    def build(segments: Iterable[Segment]) -> "FlatLayout":
        segs = tuple(segments)
        raw = segs[-1].end if segs else 0
        flat = ((raw + PAD_MULTIPLE - 1) // PAD_MULTIPLE) * PAD_MULTIPLE
        flat = max(flat, PAD_MULTIPLE)
        return FlatLayout(segs, raw, flat)

    def seg(self, name: str) -> Segment:
        for s in self.segments:
            if s.name == name:
                return s
        raise KeyError(name)

    @property
    def param_count(self) -> int:
        return self.raw_len

    # -- tensor <-> flat -----------------------------------------------------
    def unflatten(
        self,
        flat: jax.Array,
        *,
        model_gather_fn: Callable[[Segment, jax.Array], jax.Array] | None = None,
    ) -> dict[str, jax.Array]:
        """Rebuild tensors from a gathered flat vector.

        ``model_gather_fn`` reassembles model-axis-sharded segments (identity
        outside shard_map / at tp=1).
        """
        out = {}
        for s in self.segments:
            t = lax.slice_in_dim(flat, s.offset, s.end, axis=0).reshape(s.shape)
            if s.model_gather > 1 and model_gather_fn is not None:
                t = model_gather_fn(s, t)
            out[s.name] = t
        return out

    def flatten(self, tensors: Mapping[str, jax.Array], dtype=jnp.float32) -> jax.Array:
        parts = []
        cursor = 0
        for s in self.segments:
            if s.offset != cursor:
                raise ValueError("segments are not contiguous")
            parts.append(tensors[s.name].reshape(-1).astype(dtype))
            cursor = s.end
        pad = self.flat_len - self.raw_len
        if pad:
            parts.append(jnp.zeros((pad,), dtype))
        return jnp.concatenate(parts) if parts else jnp.zeros((self.flat_len,), dtype)

    # -- init ----------------------------------------------------------------
    def init_flat(self, key: jax.Array, dtype=jnp.float32) -> jax.Array:
        """Full flat vector init (used under jit with sharded out_shardings)."""
        tensors = {}
        for s in self.segments:
            key, sub = jax.random.split(key)
            if s.init == "normal":
                t = jax.random.normal(sub, s.shape, dtype) * jnp.asarray(s.std, dtype)
            elif s.init == "zeros":
                t = jnp.zeros(s.shape, dtype)
            elif s.init == "ones":
                t = jnp.ones(s.shape, dtype)
            elif s.init == "lru":
                # RG-LRU Λ such that the per-channel decay a = sigmoid(Λ) is
                # uniform in [0.9, 0.999] (Griffin appendix initialization).
                u = jax.random.uniform(sub, s.shape, dtype, 0.9, 0.999)
                t = jnp.log(u) - jnp.log1p(-u)
            else:
                raise ValueError(f"unknown init {s.init!r}")
            tensors[s.name] = t
        return self.flatten(tensors, dtype)

    # -- masks ----------------------------------------------------------------
    def nodecay_ranges(self) -> list[tuple[int, int]]:
        rng = [(s.offset, s.end) for s in self.segments if not s.decay]
        rng.append((self.raw_len, self.flat_len))  # padding never decays
        return rng

    def decay_mask_for_shard(self, shard_start, shard_len: int) -> jax.Array:
        """Decay mask for the local shard [shard_start, shard_start+shard_len).

        Built from static ranges + dynamic shard offset so no device ever
        materializes the full-length mask.
        """
        gidx = shard_start + jnp.arange(shard_len, dtype=jnp.int32)
        mask = jnp.ones((shard_len,), jnp.float32)
        for lo, hi in self.nodecay_ranges():
            if lo >= hi:
                continue
            inside = (gidx >= lo) & (gidx < hi)
            mask = jnp.where(inside, 0.0, mask)
        return mask

    def padding_mask_for_shard(self, shard_start, shard_len: int) -> jax.Array:
        """1.0 for real parameters, 0.0 for the padded tail."""
        gidx = shard_start + jnp.arange(shard_len, dtype=jnp.int32)
        return (gidx < self.raw_len).astype(jnp.float32)


# ---------------------------------------------------------------------------
# fixed-byte bucketization (the boundary scheduler's unit of pipelining)
# ---------------------------------------------------------------------------

def bucket_elems(bucket_mb: float, itemsize: int = 4) -> int:
    """Elements per fixed-byte bucket (>= 1 even for degenerate sizes)."""
    if bucket_mb <= 0:
        raise ValueError(f"bucket_mb must be > 0, got {bucket_mb}")
    return max(1, int(bucket_mb * 1e6) // itemsize)


def partition_buckets(
    n_elems: int, bucket_mb: float, itemsize: int = 4
) -> tuple[tuple[int, int], ...]:
    """Split ``[0, n_elems)`` into contiguous ``(lo, hi)`` buckets of at most
    ``bucket_mb`` megabytes each (``itemsize`` bytes per element).

    Static Python ints — the boundary scheduler (core/schedule.py) unrolls
    over these, so bucket count is a compile-time property.  Degenerate
    cases: ``bucket_mb`` larger than the whole buffer yields one bucket;
    every element is covered exactly once in order.
    """
    if n_elems <= 0:
        return ()
    per = bucket_elems(bucket_mb, itemsize)
    return tuple(
        (lo, min(lo + per, n_elems)) for lo in range(0, n_elems, per)
    )


class LayoutBuilder:
    """Accumulates segments with automatic offsets."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self._segments: list[Segment] = []
        self._cursor = 0

    def add(
        self,
        name: str,
        shape: tuple[int, ...],
        *,
        decay: bool = True,
        init: str = "normal",
        std: float | None = None,
        model_gather: int = 1,
        model_gather_dim: int = 0,
    ) -> None:
        if std is None:
            fan_in = shape[0] if len(shape) > 1 else max(shape[-1], 1)
            std = 1.0 / math.sqrt(max(fan_in, 1))
        seg = Segment(
            name=self.prefix + name,
            shape=tuple(int(d) for d in shape),
            offset=self._cursor,
            decay=decay,
            init=init,
            std=float(std),
            model_gather=int(model_gather),
            model_gather_dim=int(model_gather_dim),
        )
        self._segments.append(seg)
        self._cursor += seg.size

    def extend(self, other: "LayoutBuilder") -> None:
        """Inline another builder's segments (namespaced) after ours."""
        for s in other._segments:
            self._segments.append(dataclasses.replace(s, offset=self._cursor))
            self._cursor += s.size

    def build(self) -> FlatLayout:
        return FlatLayout.build(self._segments)


# ---------------------------------------------------------------------------
# model-axis gathering of sharded small segments
# ---------------------------------------------------------------------------

def model_gather_fn_for(axis_name: str, axis_size: int):
    """Returns the gather fn used inside shard_map to reassemble segments that
    are stored sharded over the model axis (norm scales, grouped-KV
    projections).  Group size g < axis_size gathers over contiguous sub-groups
    (ranks sharing the same KV head); g == axis_size gathers fully.
    The adjoint (psum_scatter over the same groups) is exact, so these
    parameters need no gradient fix-up.
    """

    def fn(seg: Segment, t: jax.Array) -> jax.Array:
        g = seg.model_gather
        if g <= 1 or axis_size == 1:
            return t
        if g == axis_size:
            return lax.all_gather(t, axis_name, axis=seg.model_gather_dim, tiled=True)
        groups = [list(range(i * g, (i + 1) * g)) for i in range(axis_size // g)]
        return lax.all_gather(
            t, axis_name, axis=seg.model_gather_dim, tiled=True,
            axis_index_groups=groups,
        )

    return fn


def identity_gather_fn(seg: Segment, t: jax.Array) -> jax.Array:
    return t
