"""The MiCS engine: scale-aware partitioned training step with 2-hop
gradient synchronization (paper §3), plus the ZeRO-3 and alternative-schedule
baselines used in the ablations.

Schedule (one jitted step = one gradient-accumulation boundary, s micro-steps):

  for each micro-step (lax.scan):
      per layer (lax.scan inside the model):
          all-gather the layer's flat shard across the partition group
          (policy topology + wire dtype, §3.3) — issued one layer AHEAD of
          its compute under the default double-buffered prefetch schedule;
          compute under jax.checkpoint (ZeRO-3 semantics + activation
          checkpointing)
      backward: the gather's custom-VJP adjoint reduce-scatters gradients
          across the partition group -> hop 1 (§3.4), accumulated in fp32
  at the boundary (core/schedule.py, the boundary scheduler):
      psum over replication axes                 -> hop 2 (§3.4)
      global-norm clip, AdamW on fp32 shards (optimizer states partitioned)
      — run serially (reference) or as a bucketed software pipeline that
      issues bucket k's hop-2 while bucket k-1's norm/decompress compute
      runs, bitwise identical to the serial path
      (MiCSConfig(boundary_schedule=..., hop2_bucket_mb=...))

Every collective above is owned by ONE ``CommEngine`` (core/comm.py, see
DESIGN.md §4) built from (MiCSTopology, MiCSConfig).  ZeRO-3 baseline =
partition_axes spanning every data axis (hop 2 vanishes).  Alternative
schedule (Fig 14) = all-reduce full gradient each micro-step then slice —
selected by SyncPolicy, realized in the gather's custom_vjp.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.autotune import resolve_config
from repro.core.comm import CommEngine
from repro.core.schedule import (
    BOUNDARY_SCHEDULES, CLIP_MODES, apply_boundary, plan_boundary,
)
from repro.core.topology import MODEL_AXIS, MiCSTopology
from repro.models import layers as L
from repro.models import lm
from repro.models.lm import ModelDef
from repro.optim.adamw import OptConfig


@dataclasses.dataclass(frozen=True)
class MiCSConfig:
    """Knobs of the paper's three mechanisms + beyond-paper options.

    ``policy="auto"`` hands the communication knobs (``hierarchical``,
    ``gather_order``, ``hierarchy_inner``, the wire dtype, hop-2
    compression) to the bandwidth-aware autotuner (core/autotune.py), which
    ranks every candidate over the named ``link_profile``
    (core/linkmodel.py) and rewrites this config with the winner before the
    CommEngine is built.  Auto never changes numerics you did not opt into,
    per mechanism: the int8 gather wire needs ``quant_gather=True`` (its
    gradient adjoint stays exact), the compressed hop-2 wires need
    ``compress_hop2=True``/``"bf16"``/``"int8"``, and the lossy int8 qgZ
    hop-1 needs ``hop1_wire_dtype="int8"``; under ``policy="auto"`` those
    flags turn from orders into permissions.
    """

    micro_steps: int = 1
    hierarchical: bool = True
    gather_order: str = "inner_first"   # 'outer_first' = paper-faithful 3-stage
    gather_dtype: Any = jnp.bfloat16
    sync_mode: str = "2hop"             # '2hop' | 'allreduce_slice' (ablation)
    hierarchy_inner: int | None = None  # intra-"node" factor for staged gather
    compress_hop2: Any = False          # hop-2 wire: False/'fp32' | True/'bf16'
    #                                     | 'int8' (quantized all-reduce leg)
    scores_bf16: bool = False           # bf16 attention scores (§Perf)
    mlstm_chunk: int = 0                # chunkwise-parallel mLSTM (§Perf)
    quant_gather: bool = False          # int8 wire / serving-weight gathers
    hop1_wire_dtype: str = "fp32"       # 'fp32' | 'bf16' | 'int8' (ZeRO++ qgZ
    #                                     block-quantized hop-1 reduce-scatter)
    grad_rounding: str = "stochastic"   # int8 gradient quantizer rounding
    prefetch: bool = True               # double-buffered lookahead gathers
    prefetch_carry: str = "stored"      # 'stored' carry residual | 'remat'
    #                                     backward re-gather (memplan knob)
    policy: str = "manual"              # 'manual' | 'auto' (link-model tuner)
    link_profile: Any = "v5e"           # profile name or LinkProfile instance
    boundary_schedule: str = "bucketed"  # 'serial' (reference) | 'bucketed'
    hop2_bucket_mb: float = 32.0        # fixed-byte hop-2 pipeline bucket
    clip_mode: str = "exact"            # 'exact' global-norm barrier |
    #                                     'approx' one-bucket-stale pipeline
    carry_offload: str = "none"         # 'none' | 'host' prefetch-carry
    #                                     d2h/h2d stream (core/hostoffload.py)
    offload_opt: bool = False           # AdamW m/v shards live in host memory
    hbm_budget_gb: float | None = None  # per-device HBM budget (GiB) the
    #                                     memory planner gates policies on
    kv_dtype: str = "bf16"              # paged-KV block dtype: 'fp32' | 'bf16'
    #                                     | 'int8' (core/quant.py block scales;
    #                                     a permission under policy='auto')
    kv_block_size: int = 16             # tokens per paged-KV block
    max_resident_requests: int = 0      # serving residency cap per device;
    #                                     0 = derive from the memory planner

    def __post_init__(self):
        from repro.core.comm import (
            CARRY_OFFLOADS, GRAD_ROUNDINGS, HOP1_WIRE_DTYPES,
            HOP2_WIRE_DTYPES, PREFETCH_CARRIES,
        )

        if self.policy not in ("manual", "auto"):
            raise ValueError(f"unknown policy {self.policy!r} "
                             "(expected 'manual' or 'auto')")
        if self.boundary_schedule not in BOUNDARY_SCHEDULES:
            raise ValueError(
                f"unknown boundary_schedule {self.boundary_schedule!r} "
                f"(expected one of {BOUNDARY_SCHEDULES})")
        if self.hop2_bucket_mb <= 0:
            raise ValueError(
                f"hop2_bucket_mb must be > 0, got {self.hop2_bucket_mb}")
        if self.clip_mode not in CLIP_MODES:
            raise ValueError(f"unknown clip_mode {self.clip_mode!r} "
                             f"(expected one of {CLIP_MODES})")
        if self.clip_mode == "approx" and self.boundary_schedule != "bucketed":
            raise ValueError(
                "clip_mode='approx' requires boundary_schedule='bucketed' "
                "(the approximate clip is a property of the bucket pipeline)")
        if self.carry_offload not in CARRY_OFFLOADS:
            raise ValueError(
                f"unknown carry_offload {self.carry_offload!r} "
                f"(expected one of {CARRY_OFFLOADS})")
        if self.carry_offload == "host" and not (
                self.prefetch and self.prefetch_carry == "stored"):
            raise ValueError(
                "carry_offload='host' requires prefetch=True and "
                "prefetch_carry='stored' (it offloads the stored carry)")
        if self.prefetch_carry not in PREFETCH_CARRIES:
            raise ValueError(
                f"unknown prefetch_carry {self.prefetch_carry!r} "
                f"(expected one of {PREFETCH_CARRIES})")
        if self.hbm_budget_gb is not None and self.hbm_budget_gb <= 0:
            raise ValueError(
                f"hbm_budget_gb must be > 0, got {self.hbm_budget_gb}")
        if self.hop1_wire_dtype not in HOP1_WIRE_DTYPES:
            raise ValueError(
                f"unknown hop1_wire_dtype {self.hop1_wire_dtype!r} "
                f"(expected one of {HOP1_WIRE_DTYPES})")
        if self.grad_rounding not in GRAD_ROUNDINGS:
            raise ValueError(
                f"unknown grad_rounding {self.grad_rounding!r} "
                f"(expected one of {GRAD_ROUNDINGS})")
        if not (self.compress_hop2 in (False, True)
                or self.compress_hop2 in HOP2_WIRE_DTYPES):
            raise ValueError(
                f"compress_hop2 must be a bool or one of {HOP2_WIRE_DTYPES}, "
                f"got {self.compress_hop2!r}")
        if self.kv_dtype not in ("fp32", "bf16", "int8"):
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r} "
                "(expected 'fp32', 'bf16' or 'int8')")
        if self.kv_block_size < 1:
            raise ValueError(
                f"kv_block_size must be >= 1, got {self.kv_block_size}")
        if self.max_resident_requests < 0:
            raise ValueError(
                "max_resident_requests must be >= 0 (0 = planner-derived), "
                f"got {self.max_resident_requests}")


# ---------------------------------------------------------------------------
# state containers + shardings
# ---------------------------------------------------------------------------

def init_state_shapes(model: ModelDef, *,
                      offload_opt: bool = False) -> dict[str, Any]:
    """Global ShapeDtypeStructs for params/m/v/step (no allocation).

    With ``offload_opt=True`` the AdamW moments live in the host stash
    (core/hostoffload.py), not the device state: ``m``/``v`` are absent.
    """
    shapes = model.global_flat_shapes()
    flat = {
        name: jax.ShapeDtypeStruct(shape, jnp.float32)
        for name, shape in shapes.items()
    }
    out = {
        "params": flat,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if not offload_opt:
        out["m"], out["v"] = dict(flat), dict(flat)
    return out


def state_pspecs(model: ModelDef, topo: MiCSTopology, *,
                 offload_opt: bool = False) -> dict[str, Any]:
    pool_spec = P(None, MODEL_AXIS, topo.partition_axes)
    flat = {name: pool_spec for name in model.global_flat_shapes()}
    out = {"params": flat, "step": P()}
    if not offload_opt:
        out["m"], out["v"] = dict(flat), dict(flat)
    return out


def state_shardings(model: ModelDef, topo: MiCSTopology, *,
                    offload_opt: bool = False):
    return jax.tree.map(
        lambda spec: NamedSharding(topo.mesh, spec),
        state_pspecs(model, topo, offload_opt=offload_opt),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_pspecs(model: ModelDef, topo: MiCSTopology, *, micro: bool = True):
    """PartitionSpecs for a training batch dict."""
    lead = (None,) if micro else ()
    base = {
        "tokens": P(*lead, topo.data_axes, None),
        "targets": P(*lead, topo.data_axes, None),
        "mask": P(*lead, topo.data_axes, None),
    }
    if model.cfg.family == "vlm":
        base["vision"] = P(*lead, topo.data_axes, None, None)
    if model.cfg.family == "encdec":
        base["audio"] = P(*lead, topo.data_axes, None, None)
    return base


def init_state(model: ModelDef, topo: MiCSTopology, seed: int = 0, *,
               offload_opt: bool = False):
    """Materialize sharded fp32 state (for runnable-scale models).

    The init is computed on a single device and distributed with
    ``device_put``.  Jitting it with sharded+replicated ``out_shardings``
    is NOT equivalent: XLA's SPMD partitioner may establish the replicated
    axes by all-reducing identical per-replica contributions, which *sums*
    them — observed doubling every parameter on CPU meshes with a
    replication axis (pod/repl > 1).  device_put is exact and makes the
    initial state a pure function of (model, seed), independent of topology.
    """
    shapes = model.global_flat_shapes()
    shardings = state_shardings(model, topo, offload_opt=offload_opt)

    def _init(key):
        import zlib

        flat = {}
        for pool in model.all_pools():
            stack, tp, _ = shapes[pool.name]
            pool_key = jax.random.fold_in(
                key, zlib.crc32(pool.name.encode()) % (2**31))
            keys = jax.random.split(pool_key, stack * tp).reshape(stack, tp)
            rows = jax.vmap(jax.vmap(pool.layout.init_flat))(keys)
            flat[pool.name] = rows
        out = {"params": flat, "step": jnp.int32(0)}
        if not offload_opt:
            # Offloaded moments zero-init lazily in the host stash instead
            # (HostStash.get(..., or_zeros=True) on first boundary).
            out["m"] = jax.tree.map(jnp.zeros_like, flat)
            out["v"] = jax.tree.map(jnp.zeros_like, flat)
        return out

    state = jax.jit(_init)(jax.random.key(seed))
    return jax.device_put(state, shardings)


# ---------------------------------------------------------------------------
# the training step
# ---------------------------------------------------------------------------

def build_train_step(
    model: ModelDef,
    topo: MiCSTopology,
    mcfg: MiCSConfig,
    oc: OptConfig,
):
    """Returns a jitted (state, batch) -> (state, metrics) step function.

    All collectives — the per-layer hop-1 gathers and their adjoint
    reduce-scatters, and the boundary hop-2 all-reduce — are owned by one
    ``CommEngine`` constructed from (topo, mcfg).  ``policy="auto"``
    configs are first resolved by the link-model autotuner
    (core/autotune.py); pass the resolved config around if you also need
    the ranked plan.
    """
    mcfg, _ = resolve_config(mcfg, model, topo, mode="train")
    comm = CommEngine.from_config(topo, mcfg)
    boundary = plan_boundary(model, topo, mode=mcfg.boundary_schedule,
                             bucket_mb=mcfg.hop2_bucket_mb,
                             clip_mode=mcfg.clip_mode)
    ctx = L.Ctx(mode="train", tp=topo.model_size, tp_axis=MODEL_AXIS,
                compute_dtype=jnp.dtype(mcfg.gather_dtype),
                scores_bf16=mcfg.scores_bf16, mlstm_chunk=mcfg.mlstm_chunk)
    s = mcfg.micro_steps
    denom = float(s * topo.data_parallel_size)

    def loss_of(flat, micro_batch, step_ctx):
        return lm.loss_fn(model, flat, comm, step_ctx, micro_batch)

    def sharded_step(state, batch):
        params = state["params"]
        # The step counter rides the context into every gather's VJP: the
        # int8 qgZ wires fold it into their stochastic-rounding dither key
        # (step-varying, value-independent); float wires never read it.
        step_ctx = dataclasses.replace(ctx, step_seed=state["step"])

        def micro(carry, mb):
            grads_acc, loss_acc, aux_acc = carry
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, mb, step_ctx)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (grads_acc, loss_acc + metrics["loss"],
                    aux_acc + metrics["aux"]), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (grads, loss_sum, aux_sum), _ = lax.scan(
            micro, (zeros, jnp.float32(0.0), jnp.float32(0.0)), batch)

        # ---- boundary: hop 2 + exact clip + AdamW (core/schedule.py) ------
        # Serial reference or the bucketed software pipeline; bitwise
        # identical either way (tests/schedule_harness.py).
        new_params, new_m, new_v, gnorm = apply_boundary(
            boundary, comm, model, topo, oc, state, grads, denom,
            seed=state["step"], offload_opt=mcfg.offload_opt)
        step = state["step"]

        metrics = {
            "loss": lax.pmean(loss_sum / s, topo.data_axes),
            "aux": lax.pmean(aux_sum / s, topo.data_axes),
            "grad_norm": gnorm,
        }
        new_state = {"params": new_params, "step": step + 1}
        if not mcfg.offload_opt:
            new_state["m"], new_state["v"] = new_m, new_v
        return new_state, metrics

    st_specs = state_pspecs(model, topo, offload_opt=mcfg.offload_opt)
    b_specs = batch_pspecs(model, topo)
    sharded = shard_map(
        sharded_step, mesh=topo.mesh,
        in_specs=(st_specs, b_specs),
        out_specs=(st_specs, {"loss": P(), "aux": P(), "grad_norm": P()}),
        check_vma=False,
    )
    ns = lambda spec: jax.tree.map(
        lambda s_: NamedSharding(topo.mesh, s_), spec,
        is_leaf=lambda x: isinstance(x, P))
    step_fn = jax.jit(
        sharded,
        in_shardings=(ns(st_specs), ns(b_specs)),
        out_shardings=(ns(st_specs),
                       ns({"loss": P(), "aux": P(), "grad_norm": P()})),
        donate_argnums=(0,),
    )
    return step_fn


def make_batch_shapes(model: ModelDef, global_batch: int, seq: int,
                      micro_steps: int) -> dict[str, jax.ShapeDtypeStruct]:
    """Global abstract shapes of one training batch (for the dry-run)."""
    if global_batch % micro_steps:
        raise ValueError("global_batch must divide by micro_steps")
    b = global_batch // micro_steps
    sds = jax.ShapeDtypeStruct
    out = {
        "tokens": sds((micro_steps, b, seq), jnp.int32),
        "targets": sds((micro_steps, b, seq), jnp.int32),
        "mask": sds((micro_steps, b, seq), jnp.float32),
    }
    cfg = model.cfg
    if cfg.family == "vlm":
        out["vision"] = sds(
            (micro_steps, b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["audio"] = sds(
            (micro_steps, b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return out
