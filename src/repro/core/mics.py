"""The MiCS engine: scale-aware partitioned training step with 2-hop
gradient synchronization (paper §3), plus the ZeRO-3 and alternative-schedule
baselines used in the ablations.

Schedule (one jitted step = one gradient-accumulation boundary, s micro-steps):

  for each micro-step (lax.scan):
      per layer (lax.scan inside the model):
          all-gather the layer's bf16 flat shard across the partition group
          (hierarchical, §3.3); compute under jax.checkpoint (backward
          re-gathers — ZeRO-3 semantics + activation checkpointing)
      backward: the gather's adjoint reduce-scatters gradients across the
          partition group  -> hop 1 (§3.4), accumulated in fp32 shards
  at the boundary:
      psum over replication axes                 -> hop 2 (§3.4)
      global-norm clip, AdamW on fp32 shards (optimizer states partitioned)

ZeRO-3 baseline = partition_axes spanning every data axis (hop 2 vanishes).
Alternative schedule (Fig 14) = all-reduce full gradient each micro-step then
slice — implemented by overriding the gather's custom_vjp.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as C
from repro.core.flat_param import model_gather_fn_for
from repro.core.topology import MODEL_AXIS, MiCSTopology
from repro.models import layers as L
from repro.models import lm
from repro.models.lm import ModelDef, Pool
from repro.optim.adamw import OptConfig, adamw_shard_update


@dataclasses.dataclass(frozen=True)
class MiCSConfig:
    """Knobs of the paper's three mechanisms + beyond-paper options."""

    micro_steps: int = 1
    hierarchical: bool = True
    gather_order: str = "inner_first"   # 'outer_first' = paper-faithful 3-stage
    gather_dtype: Any = jnp.bfloat16
    sync_mode: str = "2hop"             # '2hop' | 'allreduce_slice' (ablation)
    hierarchy_inner: int | None = None  # intra-"node" factor for staged gather
    compress_hop2: bool = False         # bf16-compressed cross-replica hop 2
    scores_bf16: bool = False           # bf16 attention scores (§Perf)
    mlstm_chunk: int = 0                # chunkwise-parallel mLSTM (§Perf)
    quant_gather: bool = False          # int8 serving-weight gathers (§Perf)


# ---------------------------------------------------------------------------
# parameter gathering
# ---------------------------------------------------------------------------

def make_gather_fn(topo: MiCSTopology, mcfg: MiCSConfig) -> Callable:
    """Returns gather(pool, flat_shard_row) -> dict of layer tensors."""
    mg = model_gather_fn_for(MODEL_AXIS, topo.model_size)

    def ag(row):
        return C.partition_all_gather(
            row, topo, hierarchical=mcfg.hierarchical,
            order=mcfg.gather_order, inner=mcfg.hierarchy_inner,
        )

    if mcfg.sync_mode == "allreduce_slice":
        # DeepSpeed's default schedule (paper §3.4 "alternative"): the gather
        # adjoint all-reduces the *full* gradient over every data device each
        # micro-step and keeps the local slice.  Numerically identical to
        # 2-hop, strictly more communication — the Fig 14 ablation.
        @jax.custom_vjp
        def gather_full(row):
            return ag(row)

        def fwd(row):
            return ag(row), None

        def bwd(_, ct):
            return (C.alternative_sync(ct, topo),)

        gather_full.defvjp(fwd, bwd)
    else:
        gather_full = ag

    def gather(pool: Pool, row) -> dict[str, jax.Array]:
        if isinstance(row, dict):  # int8 serving weights: {'q':…, 's':…}
            from repro.core.quant import dequantize_flat

            q = gather_full(row["q"])
            s = gather_full(row["s"])
            full = dequantize_flat(q, s, dtype=mcfg.gather_dtype)
        else:
            full = gather_full(row.astype(mcfg.gather_dtype))
        return pool.layout.unflatten(full, model_gather_fn=mg)

    return gather


# ---------------------------------------------------------------------------
# state containers + shardings
# ---------------------------------------------------------------------------

def init_state_shapes(model: ModelDef) -> dict[str, Any]:
    """Global ShapeDtypeStructs for params/m/v/step (no allocation)."""
    shapes = model.global_flat_shapes()
    flat = {
        name: jax.ShapeDtypeStruct(shape, jnp.float32)
        for name, shape in shapes.items()
    }
    return {
        "params": flat,
        "m": dict(flat),
        "v": dict(flat),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_pspecs(model: ModelDef, topo: MiCSTopology) -> dict[str, Any]:
    pool_spec = P(None, MODEL_AXIS, topo.partition_axes)
    flat = {name: pool_spec for name in model.global_flat_shapes()}
    return {"params": flat, "m": dict(flat), "v": dict(flat), "step": P()}


def state_shardings(model: ModelDef, topo: MiCSTopology):
    return jax.tree.map(
        lambda spec: NamedSharding(topo.mesh, spec),
        state_pspecs(model, topo),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_pspecs(model: ModelDef, topo: MiCSTopology, *, micro: bool = True):
    """PartitionSpecs for a training batch dict."""
    lead = (None,) if micro else ()
    base = {
        "tokens": P(*lead, topo.data_axes, None),
        "targets": P(*lead, topo.data_axes, None),
        "mask": P(*lead, topo.data_axes, None),
    }
    if model.cfg.family == "vlm":
        base["vision"] = P(*lead, topo.data_axes, None, None)
    if model.cfg.family == "encdec":
        base["audio"] = P(*lead, topo.data_axes, None, None)
    return base


def init_state(model: ModelDef, topo: MiCSTopology, seed: int = 0):
    """Materialize sharded fp32 state (for runnable-scale models)."""
    shapes = model.global_flat_shapes()
    shardings = state_shardings(model, topo)

    def _init(key):
        import zlib

        flat = {}
        for pool in model.all_pools():
            stack, tp, _ = shapes[pool.name]
            pool_key = jax.random.fold_in(
                key, zlib.crc32(pool.name.encode()) % (2**31))
            keys = jax.random.split(pool_key, stack * tp).reshape(stack, tp)
            rows = jax.vmap(jax.vmap(pool.layout.init_flat))(keys)
            flat[pool.name] = rows
        zeros = jax.tree.map(jnp.zeros_like, flat)
        return {
            "params": flat,
            "m": zeros,
            "v": jax.tree.map(jnp.zeros_like, flat),
            "step": jnp.int32(0),
        }

    with topo.mesh:
        return jax.jit(_init, out_shardings=shardings)(jax.random.key(seed))


# ---------------------------------------------------------------------------
# the training step
# ---------------------------------------------------------------------------

def build_train_step(
    model: ModelDef,
    topo: MiCSTopology,
    mcfg: MiCSConfig,
    oc: OptConfig,
):
    """Returns a jitted (state, batch) -> (state, metrics) step function."""
    gather = make_gather_fn(topo, mcfg)
    ctx = L.Ctx(mode="train", tp=topo.model_size, tp_axis=MODEL_AXIS,
                scores_bf16=mcfg.scores_bf16, mlstm_chunk=mcfg.mlstm_chunk)
    s = mcfg.micro_steps
    denom = float(s * topo.data_parallel_size)
    shard_coord = functools.partial(C._partition_coord, topo)

    def loss_of(flat, micro_batch):
        return lm.loss_fn(model, flat, gather, ctx, micro_batch)

    def sharded_step(state, batch):
        params = state["params"]

        def micro(carry, mb):
            grads_acc, loss_acc, aux_acc = carry
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, mb)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
            return (grads_acc, loss_acc + metrics["loss"],
                    aux_acc + metrics["aux"]), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (grads, loss_sum, aux_sum), _ = lax.scan(
            micro, (zeros, jnp.float32(0.0), jnp.float32(0.0)), batch)

        # ---- hop 2: replication-group all-reduce at the boundary ----------
        if mcfg.sync_mode == "2hop":
            def hop2(g):
                if mcfg.compress_hop2:
                    g = g.astype(jnp.bfloat16)
                g = C.hop2_all_reduce(g, topo)
                return g.astype(jnp.float32)
            grads = jax.tree.map(hop2, grads)
        grads = jax.tree.map(lambda g: g / denom, grads)

        # ---- global-norm clip ---------------------------------------------
        sq_local = sum(
            jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        sq = lax.psum(sq_local, topo.partition_axes + (MODEL_AXIS,))
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

        # ---- AdamW on fp32 shards ------------------------------------------
        step = state["step"]
        new_params, new_m, new_v = {}, {}, {}
        for pool in model.all_pools():
            name = pool.name
            g = grads[name]
            shard_len = g.shape[-1]
            start = shard_coord() * shard_len
            dm = pool.layout.decay_mask_for_shard(start, shard_len)
            pm = pool.layout.padding_mask_for_shard(start, shard_len)
            p, m, v = adamw_shard_update(
                state["params"][name], g, state["m"][name], state["v"][name],
                step, oc, decay_mask=dm, pad_mask=pm)
            new_params[name], new_m[name], new_v[name] = p, m, v

        metrics = {
            "loss": lax.pmean(loss_sum / s, topo.data_axes),
            "aux": lax.pmean(aux_sum / s, topo.data_axes),
            "grad_norm": gnorm,
        }
        new_state = {
            "params": new_params, "m": new_m, "v": new_v, "step": step + 1,
        }
        return new_state, metrics

    st_specs = state_pspecs(model, topo)
    b_specs = batch_pspecs(model, topo)
    sharded = shard_map(
        sharded_step, mesh=topo.mesh,
        in_specs=(st_specs, b_specs),
        out_specs=(st_specs, {"loss": P(), "aux": P(), "grad_norm": P()}),
        check_vma=False,
    )
    ns = lambda spec: jax.tree.map(
        lambda s_: NamedSharding(topo.mesh, s_), spec,
        is_leaf=lambda x: isinstance(x, P))
    step_fn = jax.jit(
        sharded,
        in_shardings=(ns(st_specs), ns(b_specs)),
        out_shardings=(ns(st_specs),
                       ns({"loss": P(), "aux": P(), "grad_norm": P()})),
        donate_argnums=(0,),
    )
    return step_fn


def make_batch_shapes(model: ModelDef, global_batch: int, seq: int,
                      micro_steps: int) -> dict[str, jax.ShapeDtypeStruct]:
    """Global abstract shapes of one training batch (for the dry-run)."""
    if global_batch % micro_steps:
        raise ValueError("global_batch must divide by micro_steps")
    b = global_batch // micro_steps
    sds = jax.ShapeDtypeStruct
    out = {
        "tokens": sds((micro_steps, b, seq), jnp.int32),
        "targets": sds((micro_steps, b, seq), jnp.int32),
        "mask": sds((micro_steps, b, seq), jnp.float32),
    }
    cfg = model.cfg
    if cfg.family == "vlm":
        out["vision"] = sds(
            (micro_steps, b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["audio"] = sds(
            (micro_steps, b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return out
