"""Deterministic synthetic LM data pipeline with sharded host loading.

Real corpora are out of scope for a CPU container, but the pipeline has the
structure a production loader needs: deterministic per-step sampling (so
restarts resume mid-epoch without replaying or skipping data), per-host
sharding (each host materializes only its slice of the global batch), and
double-buffered prefetch onto device.

The synthetic stream is a fixed-seed Zipf-ish token process with enough
autocorrelation that models visibly learn (loss drops below the uniform
entropy floor quickly) — used by the fidelity benchmark (paper Fig 16).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    micro_steps: int
    seed: int = 1234
    # Markov-chain synthetic text knobs
    branch: int = 32          # successors per state
    skew: float = 1.3         # Zipf skew of the successor distribution


class SyntheticLM:
    """Deterministic, seekable synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse Markov transition structure: each token has `branch`
        # plausible successors with Zipf weights
        self._succ = rng.integers(0, cfg.vocab, (cfg.vocab, cfg.branch))
        w = 1.0 / np.arange(1, cfg.branch + 1) ** cfg.skew
        self._w = w / w.sum()

    def sequence(self, index: int) -> np.ndarray:
        """The `index`-th sequence (stateless — seekable for elastic resume)."""
        rng = np.random.default_rng((self.cfg.seed, index))
        toks = np.empty(self.cfg.seq + 1, np.int32)
        toks[0] = rng.integers(self.cfg.vocab)
        choices = rng.choice(self.cfg.branch, size=self.cfg.seq, p=self._w)
        noise = rng.random(self.cfg.seq)
        for t in range(self.cfg.seq):
            if noise[t] < 0.05:  # 5% resets keep entropy > 0
                toks[t + 1] = rng.integers(self.cfg.vocab)
            else:
                toks[t + 1] = self._succ[toks[t], choices[t]]
        return toks

    def global_step_batch(self, step: int) -> dict[str, np.ndarray]:
        """Full global batch for a step (tests / single host)."""
        return self.host_step_batch(step, host_index=0, host_count=1)

    def host_step_batch(self, step: int, host_index: int, host_count: int):
        """This host's slice: [micro, local_b, seq] per field."""
        cfg = self.cfg
        if cfg.global_batch % (cfg.micro_steps * host_count):
            raise ValueError("global batch must divide by micro_steps*hosts")
        per_micro = cfg.global_batch // cfg.micro_steps
        local_b = per_micro // host_count
        toks = np.empty((cfg.micro_steps, local_b, cfg.seq + 1), np.int32)
        for m in range(cfg.micro_steps):
            for i in range(local_b):
                seq_index = (
                    step * cfg.global_batch + m * per_micro
                    + host_index * local_b + i
                )
                toks[m, i] = self.sequence(seq_index)
        return {
            "tokens": toks[:, :, :-1],
            "targets": toks[:, :, 1:],
            "mask": np.ones((cfg.micro_steps, local_b, cfg.seq), np.float32),
        }


class PrefetchLoader:
    """Background-thread prefetch of host batches onto device."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 host_index: int = 0, host_count: int = 1, depth: int = 2,
                 extras: dict | None = None):
        self.source = source
        self.extras = extras or {}
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._host = (host_index, host_count)
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.host_step_batch(step, *self._host)
            batch.update({k: v(step) if callable(v) else v
                          for k, v in self.extras.items()})
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
