"""Sharded checkpointing with elastic resharding.

Fault-tolerance model (DESIGN.md; targets 1000+ nodes):

* **Sharded save** — each host writes only the shards it owns (here: the
  process-local addressable shards) as one .npz per pool plus a JSON
  manifest carrying the step, mesh descriptor, partition-group size and data
  -pipeline cursor.  No host ever materializes the full model.
* **Atomicity** — writes go to ``step_XXXXXX.tmp/`` and are renamed into
  place only after the manifest is fsync'd; a crashed save can never corrupt
  the latest valid checkpoint (restart scans for the newest complete one).
* **Elastic resharding** — restore may target a *different* topology
  (partition-group size, replication degree, or pod count).  Because model
  states are flat vectors, resharding is pure index arithmetic: the global
  [stack, tp, flat_len] array is reassembled logically and re-partitioned
  under the new topology's NamedShardings.  This is what lets the framework
  resume after losing a pod (512 -> 256 chips) or growing back.
* **Async save** — serialization happens on a worker thread; the train loop
  only blocks if a second save is requested before the first lands.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mics import state_shardings
from repro.core.topology import MiCSTopology
from repro.models.lm import ModelDef

MANIFEST = "manifest.json"


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._worker: threading.Thread | None = None

    # -- save ---------------------------------------------------------------
    def save(self, state, step: int, *, topo: MiCSTopology,
             data_cursor: int = 0, blocking: bool = True,
             host_stash: dict | None = None):
        """Snapshot `state` at `step`.  Arrays are fetched to host first (so
        the device buffers donate-rotate freely) and written by a worker.
        ``host_stash`` (core/hostoffload.export_stash) carries the
        host-offloaded optimizer moments when ``offload_opt=True`` — the
        half of the training state that is not in ``state``."""
        host_state = jax.tree.map(np.asarray, state)
        meta = {
            "step": int(step),
            "data_cursor": int(data_cursor),
            "time": time.time(),
            "mesh_axes": dict(zip(topo.mesh.axis_names, topo.mesh.devices.shape)),
            "partition_axes": list(topo.partition_axes),
            "replication_axes": list(topo.replication_axes),
        }
        self.wait()
        self._worker = threading.Thread(
            target=self._write, args=(host_state, meta, host_stash),
            daemon=True)
        self._worker.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, host_state, meta, host_stash=None):
        step = meta["step"]
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat, treedef = jax.tree_util.tree_flatten_with_path(host_state)
        names = []
        arrays = {}
        for i, (path, leaf) in enumerate(flat):
            key = f"leaf_{i:04d}"
            names.append("/".join(str(getattr(p, "key", p)) for p in path))
            arrays[key] = leaf
        np.savez(tmp / "state.npz", **arrays)
        meta["leaves"] = names
        if host_stash:
            # offloaded-moment shards, keyed "k_<ns>_<tag>_<slot>_<device>"
            np.savez(tmp / "stash.npz",
                     **{"k_" + "_".join(str(int(x)) for x in k): v
                        for k, v in host_stash.items()})
        (tmp / MANIFEST).write_text(json.dumps(meta, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp") and (p / MANIFEST).exists()
        )
        return steps[-1] if steps else None

    def restore(self, model: ModelDef, topo: MiCSTopology,
                step: int | None = None, *, offload_opt: bool = False):
        """Load a checkpoint onto (possibly different) `topo`.

        Returns (state, meta).  Cross-topology restores reshard via the flat
        layout — the on-disk representation is topology-agnostic global
        arrays, so nothing special is needed beyond new out-shardings.
        ``offload_opt=True`` additionally imports the checkpoint's host-stash
        shards (the offloaded AdamW moments) under the sentinel namespace
        (core/hostoffload.CKPT_NAMESPACE); the stash keys are per-device, so
        that leg of the restore is same-topology only — a cross-topology
        restore starts the moments from the lazy zero-init instead.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        meta = json.loads((path / MANIFEST).read_text())
        data = np.load(path / "state.npz")
        leaves = [data[f"leaf_{i:04d}"] for i in range(len(meta["leaves"]))]

        if offload_opt and (path / "stash.npz").exists():
            from repro.core.hostoffload import import_stash

            blob = np.load(path / "stash.npz")
            import_stash(
                {tuple(int(x) for x in name[2:].split("_")): blob[name]
                 for name in blob.files},
                as_checkpoint=True)

        # rebuild the pytree structure from a template
        from repro.core.mics import init_state_shapes

        template = init_state_shapes(model, offload_opt=offload_opt)
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        if len(flat_t) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, model needs {len(flat_t)}")
        for want, got in zip(flat_t, leaves):
            if tuple(want.shape) != tuple(got.shape):
                raise ValueError(
                    f"leaf shape mismatch {got.shape} vs {want.shape}: elastic "
                    f"restore reshards pods/partition/replication freely but "
                    f"the TP degree is fixed (flat layouts are TP-local)")
        state_host = jax.tree_util.tree_unflatten(treedef, leaves)

        shardings = state_shardings(model, topo, offload_opt=offload_opt)
        with topo.mesh:
            state = jax.tree.map(
                lambda a, s: jax.device_put(jnp.asarray(a), s),
                state_host, shardings,
                is_leaf=lambda x: isinstance(x, np.ndarray),
            )
        return state, meta
