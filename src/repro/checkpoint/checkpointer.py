"""Sharded checkpointing with elastic resharding.

Fault-tolerance model (DESIGN.md §6; targets 1000+ nodes):

* **Sharded save** — each host writes only the shards it owns (here: the
  process-local addressable shards) as one .npz per pool plus a JSON
  manifest carrying the step, mesh descriptor, partition-group size and data
  -pipeline cursor.  No host ever materializes the full model.
* **Atomicity** — writes go to ``step_XXXXXX.tmp/`` and are renamed into
  place only after the manifest is fsync'd; a crashed save can never corrupt
  the latest valid checkpoint.  Restart scans for the newest *complete* one:
  ``latest_step`` skips ``.tmp`` dirs, stray non-numeric ``step_*`` names,
  and dirs whose manifest/state blob is missing or truncated (the
  kill-the-writer scenarios tests/test_checkpoint.py +
  tests/elastic_harness.py script via ``fault_hook``).
* **Elastic resharding** — restore may target a *different* topology
  (partition-group size, replication degree, or pod count).  Because model
  states are flat vectors, resharding is pure index arithmetic: the global
  [stack, tp, flat_len] array is reassembled logically and re-partitioned
  under the new topology's NamedShardings.  This is what lets the framework
  resume after losing a pod (512 -> 256 chips) or growing back.
* **Emergency save** — a preemption notice (runtime/train_loop.py elastic
  path) triggers a blocking ``save(..., emergency=True)`` of the still-
  intact state, tagged in the manifest, so a world change with notice loses
  zero steps.
* **Async save** — serialization happens on a worker thread; the train loop
  only blocks if a second save is requested before the first lands.  A
  writer-thread failure is held and re-raised from the next ``wait()`` /
  ``save()`` — never silently swallowed.
"""

from __future__ import annotations

import json
import logging
import os
import pathlib
import shutil
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mics import state_shardings
from repro.core.topology import MiCSTopology
from repro.models.lm import ModelDef

log = logging.getLogger("repro.checkpoint")

MANIFEST = "manifest.json"
STATE_BLOB = "state.npz"
STASH_BLOB = "stash.npz"


def _fsync(path: pathlib.Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._worker: threading.Thread | None = None
        self._exc: BaseException | None = None
        # Test/fault-injection hook (core/faults.FaultPlan.bind): called as
        # fault_hook(phase, tmp_dir, meta) from the writer thread; raising
        # simulates the writer dying mid-save.
        self.fault_hook: Callable[[str, pathlib.Path, dict], None] | None = None

    # -- save ---------------------------------------------------------------
    def save(self, state, step: int, *, topo: MiCSTopology,
             data_cursor: int = 0, blocking: bool = True,
             host_stash: dict | None = None, emergency: bool = False):
        """Snapshot `state` at `step`.  Arrays are fetched to host first (so
        the device buffers donate-rotate freely) and written by a worker.
        ``host_stash`` (core/hostoffload.export_stash) carries the
        host-offloaded optimizer moments when ``offload_opt=True`` — the
        half of the training state that is not in ``state``.
        ``emergency=True`` tags a preemption-triggered save in the manifest
        (the train loop's response to a world-change notice)."""
        host_state = jax.tree.map(np.asarray, state)
        meta = {
            "step": int(step),
            "data_cursor": int(data_cursor),
            "time": time.time(),
            "mesh_axes": dict(zip(topo.mesh.axis_names, topo.mesh.devices.shape)),
            "partition_axes": list(topo.partition_axes),
            "replication_axes": list(topo.replication_axes),
            "emergency": bool(emergency),
        }
        self.wait()
        self._worker = threading.Thread(
            target=self._write_guarded, args=(host_state, meta, host_stash),
            daemon=True)
        self._worker.start()
        if blocking:
            self.wait()

    def wait(self):
        """Join the in-flight save; re-raise its failure, if any."""
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def _write_guarded(self, host_state, meta, host_stash=None):
        try:
            self._write(host_state, meta, host_stash)
        except BaseException as e:  # noqa: BLE001 - held for wait()
            self._exc = e

    def _write(self, host_state, meta, host_stash=None):
        step = meta["step"]
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat, treedef = jax.tree_util.tree_flatten_with_path(host_state)
        names = []
        arrays = {}
        for i, (path, leaf) in enumerate(flat):
            key = f"leaf_{i:04d}"
            names.append("/".join(str(getattr(p, "key", p)) for p in path))
            arrays[key] = leaf
        np.savez(tmp / STATE_BLOB, **arrays)
        meta["leaves"] = names
        if host_stash:
            # offloaded-moment shards, keyed "k_<ns>_<tag>_<slot>_<device>"
            np.savez(tmp / STASH_BLOB,
                     **{"k_" + "_".join(str(int(x)) for x in k): v
                        for k, v in host_stash.items()})
        if self.fault_hook is not None:
            # state blob is on disk, manifest is not: the mid-save kill
            # window the atomicity contract is tested against.
            self.fault_hook("pre_manifest", tmp, meta)
        mpath = tmp / MANIFEST
        mpath.write_text(json.dumps(meta, indent=1))
        _fsync(mpath)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)

    # -- restore --------------------------------------------------------------
    def _complete(self, path: pathlib.Path) -> bool:
        """True iff `path` is a fully-written ``step_<N>`` checkpoint dir."""
        if path.name.endswith(".tmp") or not path.name[len("step_"):].isdigit():
            return False
        if not (path / STATE_BLOB).exists():
            return False
        try:
            json.loads((path / MANIFEST).read_text())
        except (OSError, ValueError):
            return False   # missing or truncated manifest (crashed writer)
        return True

    def latest_step(self) -> int | None:
        """Newest *complete* checkpoint step (None if there is none).

        Skips ``.tmp`` dirs, malformed names (a stray ``step_old`` must not
        crash the scan), and dirs with a missing/truncated manifest or
        state blob — everything a crashed writer can leave behind.
        """
        steps = sorted(
            int(p.name[len("step_"):]) for p in self.dir.glob("step_*")
            if self._complete(p)
        )
        return steps[-1] if steps else None

    def restore(self, model: ModelDef, topo: MiCSTopology,
                step: int | None = None, *, offload_opt: bool = False):
        """Load a checkpoint onto (possibly different) `topo`.

        Returns (state, meta).  Cross-topology restores reshard via the flat
        layout — the on-disk representation is topology-agnostic global
        arrays, so nothing special is needed beyond new out-shardings.

        ``offload_opt=True`` additionally imports the checkpoint's host-stash
        shards (the offloaded AdamW moments) under the sentinel namespace
        (core/hostoffload.CKPT_NAMESPACE).  The stash keys are per-device
        (the mesh-linearized device index), so that leg of the restore is
        same-topology only; a cross-topology restore restarts the moments
        from the lazy zero-init — EXPLICITLY: a warning is logged and
        ``meta["host_stash"]`` records ``{present, restored, reset}`` so
        callers (and tests) see exactly which half of the optimizer state
        survived the world change.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        if not self._complete(path):
            raise FileNotFoundError(
                f"checkpoint {path} is missing or incomplete "
                f"(newest complete step: {self.latest_step()})")
        meta = json.loads((path / MANIFEST).read_text())
        data = np.load(path / STATE_BLOB)
        leaves = [data[f"leaf_{i:04d}"] for i in range(len(meta["leaves"]))]

        if offload_opt:
            meta["host_stash"] = self._restore_stash(path, meta, topo)

        # rebuild the pytree structure from a template
        from repro.core.mics import init_state_shapes

        template = init_state_shapes(model, offload_opt=offload_opt)
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        if len(flat_t) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, model needs {len(flat_t)}")
        for want, got in zip(flat_t, leaves):
            if tuple(want.shape) != tuple(got.shape):
                raise ValueError(
                    f"leaf shape mismatch {got.shape} vs {want.shape}: elastic "
                    f"restore reshards pods/partition/replication freely but "
                    f"the TP degree is fixed (flat layouts are TP-local)")
        state_host = jax.tree_util.tree_unflatten(treedef, leaves)

        shardings = state_shardings(model, topo, offload_opt=offload_opt)
        with topo.mesh:
            state = jax.tree.map(
                lambda a, s: jax.device_put(jnp.asarray(a), s),
                state_host, shardings,
                is_leaf=lambda x: isinstance(x, np.ndarray),
            )
        return state, meta

    def _restore_stash(self, path: pathlib.Path, meta: dict,
                       topo: MiCSTopology) -> dict:
        """Import the host-stash leg of a checkpoint (offload_opt=True).

        Same-topology only: the stash is keyed by the mesh-linearized
        device index, and the shard *shapes* are per-topology, so a
        cross-topology import would collide wrong-shaped arrays into the
        live engine's reads.  On a topology mismatch the import is skipped,
        stale sentinel entries are purged, and the reset is surfaced."""
        from repro.core.hostoffload import (
            CKPT_NAMESPACE, clear_namespace, import_stash,
        )

        info = {"present": (path / STASH_BLOB).exists(),
                "restored": False, "reset": None}
        here = {
            "mesh_axes": dict(zip(topo.mesh.axis_names,
                                  (int(s) for s in topo.mesh.devices.shape))),
            "partition_axes": list(topo.partition_axes),
        }
        same_topo = (
            {k: int(v) for k, v in meta.get("mesh_axes", {}).items()} ==
            here["mesh_axes"]
            and list(meta.get("partition_axes", [])) == here["partition_axes"])
        if not info["present"]:
            info["reset"] = "missing"
            log.warning(
                "offload_opt restore from %s: checkpoint has no host stash; "
                "optimizer moments restart from zero", path.name)
        elif not same_topo:
            clear_namespace(CKPT_NAMESPACE)   # no stale wrong-shape entries
            info["reset"] = "cross-topology"
            log.warning(
                "offload_opt restore from %s onto a different topology "
                "(%s -> %s): host-stash optimizer moments are per-device and "
                "do not reshard; restarting m/v from zero (params/step are "
                "restored exactly)", path.name,
                meta.get("mesh_axes"), here["mesh_axes"])
        else:
            blob = np.load(path / STASH_BLOB)
            import_stash(
                {tuple(int(x) for x in name[2:].split("_")): blob[name]
                 for name in blob.files},
                as_checkpoint=True)
            info["restored"] = True
        return info
