"""Continuous-batching request scheduler for the paged serving engine.

Host-side and jax-free: the :class:`ContinuousBatcher` owns one
:class:`~repro.runtime.paged.PagedKVAllocator` per data rank and turns a
ragged arrival queue into fixed-shape step plans for
:func:`repro.runtime.paged.build_paged_step`.  Each *tick* produces one
``StepPlan`` whose rows are the ``dp * slots_local`` resident request slots:

- **prefill rows** feed up to ``chunk`` prompt tokens (``n_new > 1`` allowed),
  so long prompts are streamed in chunks interleaved with decode traffic
  instead of stalling the whole batch (bounded TTFT *and* bounded
  tokens/s);
- **decode rows** feed the previously sampled token (``n_new == 1``);
- **idle rows** carry ``n_new == 0`` — the engine drops their cache writes
  and the scheduler ignores their sampled token.

Admission is FIFO, gated on a free slot *and* a free-block budget of
``blocks_for(len(prompt) + 1)`` on the target rank.  Requests grow their
block allocation lazily, one tick ahead of the write frontier; when a rank
runs out of blocks the youngest resident request on that rank is evicted —
its blocks are freed and it is requeued at the *front* of the waiting queue
to restart from scratch (sampling is seeded per (seed, position), so a
restarted request regenerates the same tokens).

Overload protection (all off by default, so an unconfigured batcher keeps
the PR-8 semantics exactly):

- **bounded queue** — ``max_queue`` rejects submissions once the waiting
  queue is full (:class:`ShedError`, reason :data:`SHED_QUEUE_FULL`);
- **deadlines / TTLs** — per-request ``deadline_tick`` (absolute completion
  deadline) and ``ttl_ticks`` (max queue wait).  Admission is
  deadline-aware: a request that cannot possibly finish in time is rejected
  at submit (:data:`SHED_DEADLINE_SUBMIT`); queued requests are swept every
  tick and shed the moment their deadline becomes unreachable or their TTL
  expires (:data:`SHED_DEADLINE`, :data:`SHED_TTL`).  Shedding is always
  typed and ledgered — never a silent drop;
- **seeded-jitter backoff** — with ``backoff_base > 0`` an evicted or
  replayed request is requeued with a ``retry_at_tick`` gate computed by
  :func:`backoff_ticks` (exponential in the attempt count, jitter keyed by
  ``(backoff_seed, rid, attempt)`` so schedules replay deterministically);
  admission scans past gated entries without violating FIFO among the
  eligible;
- **eviction cap with aging** — evict-youngest + front-of-queue requeue can
  livelock: under sustained overload the youngest resident is always the
  freshest readmission of the same request, which is evicted again before
  it can finish (tests/test_batching_faults.py reproduces the schedule).
  ``evict_cap`` bounds that: a request evicted ``evict_cap`` times gains
  priority — it is requeued at the queue front with no backoff gate and
  becomes ineligible as an eviction victim, so its next admission sticks.

Tick counts double as the latency clock: the bench maps ticks to wall time
after the fact, so the scheduler itself stays deterministic — including
every shed/backoff/degradation decision.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any

import numpy as np

from repro.runtime.paged import PagedKVAllocator, blocks_for

# -- typed load-shedding reasons (the ledger's vocabulary) -------------------
SHED_QUEUE_FULL = "queue_full"            # bounded queue rejected the submit
SHED_DEADLINE_SUBMIT = "deadline_unreachable"   # could never finish in time
SHED_DEADLINE = "deadline_expired"        # became unreachable while queued
SHED_TTL = "ttl_expired"                  # waited longer than its TTL


class ShedError(RuntimeError):
    """A request was load-shed; ``reason`` is one of the ``SHED_*`` strings.

    Raised from :meth:`ContinuousBatcher.submit` (reject-on-submit: the
    caller learns immediately, and the request is already accounted in the
    batcher's shed ledger — never a silent drop)."""

    def __init__(self, reason: str, request: "Request"):
        super().__init__(f"request {request.rid} shed: {reason}")
        self.reason = reason
        self.request = request


def backoff_ticks(base: int, attempt: int, *, rid: int = 0,
                  seed: int = 0) -> int:
    """Deterministic seeded-jitter exponential backoff, in scheduler ticks.

    ``base * 2^(attempt-1)`` plus a jitter drawn from a splitmix-style hash
    of ``(seed, rid, attempt)`` — the result lies in ``[window, 2*window)``
    and is a pure function of its arguments, so retry schedules replay
    identically across runs (the same discipline as the per-(seed,
    position) sampler)."""
    if base <= 0:
        return 0
    window = base * (1 << min(max(attempt - 1, 0), 16))
    h = (seed * 0x9E3779B97F4A7C15 + rid * 0xBF58476D1CE4E5B9
         + attempt * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    h ^= h >> 31
    h = (h * 0xD6E8FEB86659FD93) & 0xFFFFFFFFFFFFFFFF
    h ^= h >> 27
    return window + h % window


@dataclasses.dataclass
class Request:
    """One serving request plus its scheduler-side bookkeeping."""

    rid: int
    prompt: list[int]
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    eos: int | None = None
    arrival: int = 0
    deadline_tick: int | None = None   # absolute finish-by tick (None = no SLO)
    ttl_ticks: int | None = None       # max ticks waiting unadmitted

    # -- mutable scheduler state ------------------------------------------
    generated: list[int] = dataclasses.field(default_factory=list)
    prefill_done: int = 0
    next_pos: int = 0          # cache positions written so far
    blocks: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1             # global slot id, -1 while waiting
    rank: int = -1
    admit_tick: int = -1
    first_admit_tick: int = -1  # first-ever admission (survives evictions)
    first_token_tick: int = -1
    finish_tick: int = -1
    submit_tick: int = -1
    evictions: int = 0
    replays: int = 0           # world-change replays (full restart from prompt)
    retry_at_tick: int = 0     # backoff gate: not admissible before this tick
    shed_reason: str | None = None
    shed_tick: int = -1
    events: list[tuple[str, int]] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return self.eos is not None and self.eos in self.generated

    def positions_needed(self) -> int:
        # The final sampled token is returned but never written back.
        return len(self.prompt) + self.max_new_tokens - 1

    def min_ticks_left(self, chunk: int) -> int:
        """Ticks to completion under the best possible schedule.

        ``ceil(remaining_prompt / chunk)`` prefill ticks (the first token
        lands on the last of them) plus one tick per remaining token.  The
        deadline math: a request planned at tick ``t`` can finish no
        earlier than tick ``t + min_ticks_left - 1``."""
        pre = len(self.prompt) - self.prefill_done
        rem = self.max_new_tokens - len(self.generated)
        if pre > 0:
            return -(-pre // chunk) + rem - 1
        return rem

    def record(self, kind: str, tick: int) -> None:
        self.events.append((kind, tick))

    def reset(self) -> None:
        self.generated = []
        self.prefill_done = 0
        self.next_pos = 0
        self.blocks = []
        self.slot = -1
        self.rank = -1
        self.first_token_tick = -1


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """Fixed-shape arrays for one engine step plus the slot -> request map."""

    tokens: np.ndarray       # [B, chunk] int32
    pos: np.ndarray          # [B] int32 first-token positions
    n_new: np.ndarray        # [B] int32 (0 = idle row)
    tables: np.ndarray       # [B, max_blocks] int32 rank-local block ids
    seeds: np.ndarray        # [B] int32
    temps: np.ndarray        # [B] float32
    requests: dict[int, Request]   # slot -> resident request this tick

    @property
    def active_rows(self) -> int:
        return int((self.n_new > 0).sum())


class DegradationLadder:
    """Graceful-degradation state machine over priced serve levels.

    ``levels`` is an ordered list of ``{"kv_dtype", "resident_cap",
    "label"}`` dicts, level 0 being the configured operating point and each
    later level a cheaper one (typically from
    :func:`repro.core.memplan.degradation_levels`, which prices residency
    per KV dtype with ``max_resident_requests``).  :meth:`update` walks the
    ladder with hysteresis: pressure above ``high_water`` for ``dwell``
    consecutive ticks downshifts one level; pressure below ``low_water``
    for ``dwell`` ticks restores one level.  Transitions are recorded in
    ``transitions`` and the whole machine is a pure function of the
    pressure series — deterministic and unit-testable device-free.

    Note the numerics caveat: a level that changes ``kv_dtype`` changes
    decode numerics by design (that is the degradation), so the serve
    loop's bitwise-replay guarantee holds per operating level, not across
    a downshift.
    """

    def __init__(self, levels: list[dict], *, high_water: float = 0.75,
                 low_water: float = 0.25, dwell: int = 8):
        if not levels:
            raise ValueError("ladder needs at least one level")
        if not (0.0 <= low_water < high_water):
            raise ValueError("need 0 <= low_water < high_water")
        self.levels = [dict(lv) for lv in levels]
        self.high_water = float(high_water)
        self.low_water = float(low_water)
        self.dwell = max(int(dwell), 1)
        self.level = 0
        self.max_level_seen = 0
        self.transitions: list[dict] = []
        self._hot = 0   # consecutive ticks above high_water
        self._cool = 0  # consecutive ticks below low_water

    def current(self) -> dict:
        return self.levels[self.level]

    def update(self, tick: int, pressure: float) -> bool:
        """Feed one tick's pressure sample; True iff the level changed."""
        self._hot = self._hot + 1 if pressure >= self.high_water else 0
        self._cool = self._cool + 1 if pressure <= self.low_water else 0
        new = self.level
        if self._hot >= self.dwell and self.level + 1 < len(self.levels):
            new = self.level + 1
        elif self._cool >= self.dwell and self.level > 0:
            new = self.level - 1
        if new == self.level:
            return False
        self.transitions.append({
            "tick": int(tick), "from": self.level, "to": new,
            "pressure": float(pressure),
            "label": self.levels[new].get("label", str(new))})
        self.level = new
        self.max_level_seen = max(self.max_level_seen, new)
        self._hot = self._cool = 0
        return True


class ContinuousBatcher:
    """FIFO admission + chunked-prefill/decode interleaving over paged KV.

    Parameters mirror the engine: ``dp`` data ranks of ``slots_local``
    resident slots each, ``nb_local`` KV blocks per rank (block 0 is the
    engine's garbage block and never allocated), ``max_blocks`` table width
    per request and ``chunk`` tokens fed per prefill row per tick.

    ``reserve`` picks the admission discipline: ``"min"`` admits as soon
    as the first prompt chunk fits (``blocks_for(len(prompt) + 1)``) and
    relies on eviction + front-of-queue requeue when later growth finds
    the rank exhausted — maximum occupancy, but under sustained overload
    the evicted replays waste work; ``"full"`` admits only when the
    request's worst-case block count fits after subtracting every
    resident's unclaimed reservation, so growth can never fail and
    nothing is ever evicted (vLLM's conservative watermark, the right
    default for throughput benchmarks).

    Overload controls (see the module docstring; zero disables each):
    ``max_queue`` bounds the waiting queue, ``evict_cap`` is the
    per-request eviction budget before priority aging kicks in,
    ``backoff_base``/``backoff_seed`` drive the seeded-jitter retry gate,
    and ``resident_cap`` caps admitted requests per rank below
    ``slots_local`` (the degradation ladder's tightening lever, priced by
    ``memplan.max_resident_requests``).
    """

    def __init__(self, *, dp: int, slots_local: int, nb_local: int,
                 block_size: int, max_blocks: int, chunk: int = 1,
                 reserve: str = "min", max_queue: int = 0,
                 evict_cap: int = 4, backoff_base: int = 0,
                 backoff_seed: int = 0, resident_cap: int = 0):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        if reserve not in ("min", "full"):
            raise ValueError("reserve must be 'min' or 'full'")
        self.reserve = reserve
        self.dp = dp
        self.slots_local = slots_local
        self.batch = dp * slots_local
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.chunk = chunk
        self.nb_local = nb_local
        self.max_queue = int(max_queue)
        self.evict_cap = int(evict_cap)
        self.backoff_base = int(backoff_base)
        self.backoff_seed = int(backoff_seed)
        self.resident_cap = int(resident_cap)
        self.allocators = [PagedKVAllocator(nb_local, block_size)
                           for _ in range(dp)]
        self.waiting: list[Request] = []
        self.resident: dict[int, Request] = {}   # slot -> request
        self.finished: list[Request] = []
        self.shed_requests: list[Request] = []
        self.tick = 0
        self.evicted = 0
        self.replayed = 0
        self.submitted = 0
        self._queue_depth: list[int] = []   # one sample per planned tick
        self._wait_ages: list[int] = []     # per waiting request per tick

    # -- queue management -------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue ``req``, or reject it with a typed :class:`ShedError`.

        Structural problems (prompt too long for the table, empty prompt)
        stay ``ValueError`` — those are caller bugs, not load.  Overload
        rejections (queue full, deadline unreachable even if admitted now)
        raise :class:`ShedError` *after* recording the request in the shed
        ledger, so every submission is accounted."""
        need = blocks_for(req.positions_needed(), self.block_size)
        if need > self.max_blocks:
            raise ValueError(
                f"request {req.rid} needs {need} blocks > max_blocks="
                f"{self.max_blocks}")
        if not req.prompt:
            raise ValueError("empty prompt")
        self.submitted += 1
        req.submit_tick = self.tick
        req.record("submit", self.tick)
        if self.max_queue and len(self.waiting) >= self.max_queue:
            self._shed(req, SHED_QUEUE_FULL)
            raise ShedError(SHED_QUEUE_FULL, req)
        if self._deadline_unreachable(req):
            self._shed(req, SHED_DEADLINE_SUBMIT)
            raise ShedError(SHED_DEADLINE_SUBMIT, req)
        self.waiting.append(req)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.resident

    def pressure(self) -> float:
        """Queue occupancy in [0, inf): the degradation ladder's signal.

        Waiting requests over the queue bound (or over the slot count when
        the queue is unbounded) — 1.0 means the backlog equals capacity."""
        cap = self.max_queue if self.max_queue else self.batch
        return len(self.waiting) / float(max(cap, 1))

    def _deadline_unreachable(self, req: Request) -> bool:
        return (req.deadline_tick is not None
                and self.tick + req.min_ticks_left(self.chunk) - 1
                > req.deadline_tick)

    def _shed(self, req: Request, reason: str) -> None:
        """Typed removal: ledger the request, free anything it held."""
        if req.slot >= 0 and self.resident.get(req.slot) is req:
            self.allocators[req.rank].free(req.blocks)
            del self.resident[req.slot]
            req.blocks = []
            req.slot = -1
        req.shed_reason = reason
        req.shed_tick = self.tick
        req.record("shed", self.tick)
        self.shed_requests.append(req)

    def _expire_waiting(self) -> None:
        """Sweep the queue for deadline/TTL expiries (typed, never silent)."""
        keep = []
        for r in self.waiting:
            if r.ttl_ticks is not None \
                    and self.tick - r.submit_tick > r.ttl_ticks:
                self._shed(r, SHED_TTL)
            elif self._deadline_unreachable(r):
                self._shed(r, SHED_DEADLINE)
            else:
                keep.append(r)
        self.waiting = keep

    def _free_slots(self, rank: int) -> list[int]:
        lo = rank * self.slots_local
        return [s for s in range(lo, lo + self.slots_local)
                if s not in self.resident]

    def _reserved_extra(self, rank: int) -> int:
        """Blocks promised to residents on ``rank`` but not yet allocated."""
        if self.reserve != "full":
            return 0
        return sum(
            max(0, blocks_for(r.positions_needed(), self.block_size)
                - len(r.blocks))
            for r in self.resident.values() if r.rank == rank)

    def _residents_on(self, rank: int) -> int:
        return sum(1 for r in self.resident.values() if r.rank == rank)

    def _try_place(self, req: Request) -> bool:
        """Place ``req`` on some rank if slot + block budget allow."""
        if self.reserve == "full":
            budget = blocks_for(req.positions_needed(), self.block_size)
        else:
            budget = blocks_for(len(req.prompt) + 1, self.block_size)
        for rank in range(self.dp):
            slots = self._free_slots(rank)
            if self.resident_cap \
                    and self._residents_on(rank) >= self.resident_cap:
                continue
            avail = (self.allocators[rank].free_blocks
                     - self._reserved_extra(rank))
            if not slots or avail < budget:
                continue
            req.slot, req.rank = slots[0], rank
            req.admit_tick = self.tick
            if req.first_admit_tick < 0:
                req.first_admit_tick = self.tick
            req.record("admit", self.tick)
            self.resident[req.slot] = req
            return True
        return False

    def _admit(self) -> None:
        """FIFO-admit waiting requests into free slots under block budget.

        Strict FIFO among the *eligible*: the scan skips entries whose
        backoff gate (``retry_at_tick``) has not elapsed — a backing-off
        request must not head-block the queue — but stops at the first
        eligible request that does not fit, so capacity is still granted
        in arrival order."""
        self._expire_waiting()
        while True:
            admitted = False
            for qi, req in enumerate(self.waiting):
                if req.retry_at_tick > self.tick:
                    continue
                if self._try_place(req):
                    self.waiting.pop(qi)
                    admitted = True
                break
            if not admitted:
                break

    def _requeue(self, victim: Request) -> None:
        """Evicted: front-of-queue requeue with backoff, cap and aging."""
        victim.reset()
        victim.evictions += 1
        self.evicted += 1
        victim.record("evict", self.tick)
        if self.evict_cap and victim.evictions >= self.evict_cap:
            # aging: priority admission, no backoff gate — and from here on
            # the victim-selection filter protects it from further eviction
            victim.retry_at_tick = self.tick
            self.waiting.insert(0, victim)
            return
        victim.retry_at_tick = self.tick + backoff_ticks(
            self.backoff_base, victim.evictions, rid=victim.rid,
            seed=self.backoff_seed)
        self.waiting.insert(0, victim)

    def _evict(self, rank: int, keep: Request | None) -> bool:
        """Evict the youngest evictable resident on ``rank`` (not ``keep``).

        Requests at their eviction cap are not eligible victims — that,
        plus their priority readmission, is what breaks the
        evict-youngest/readmit/evict-again livelock under sustained
        overload."""
        victims = [r for r in self.resident.values()
                   if r.rank == rank and r is not keep
                   and not (self.evict_cap
                            and r.evictions >= self.evict_cap)]
        if not victims:
            return False
        victim = max(victims, key=lambda r: (r.admit_tick, r.slot))
        self.allocators[rank].free(victim.blocks)
        del self.resident[victim.slot]
        self._requeue(victim)
        return True

    def _ensure_blocks(self, req: Request, n_new: int) -> bool:
        """Grow ``req.blocks`` to cover ``next_pos + n_new`` positions."""
        need = blocks_for(req.next_pos + n_new, self.block_size)
        while len(req.blocks) < need:
            got = self.allocators[req.rank].alloc(need - len(req.blocks))
            if got is not None:
                req.blocks.extend(got)
                return True
            if not self._evict(req.rank, keep=req):
                return False
        return True

    # -- world changes ----------------------------------------------------

    def rebuild_world(self, dp: int, *, nb_local: int | None = None
                      ) -> list[Request]:
        """Re-key the scheduler to a changed device world; replay in-flight.

        The serving half of a :class:`repro.core.faults.WorldChangeError`
        (and of a KV-dtype degradation rebuild): every resident request
        loses its KV blocks with the old pools, so each is reset to its
        prompt and requeued *ahead* of the waiting queue in original
        admission order — per-(seed, position) sampling regenerates the
        identical completion (the chaos harness's bitwise contract).  The
        tick clock, finished/shed ledgers and counters all survive, so
        latency accounting spans the fault.  Allocators are reset in place
        for surviving ranks and created for grown ones.  Returns the
        replayed requests."""
        nb = self.nb_local if nb_local is None else nb_local
        survivors = sorted(self.resident.values(),
                           key=lambda r: (r.admit_tick, r.slot))
        for r in survivors:
            r.reset()
            r.replays += 1
            self.replayed += 1
            r.record("replay", self.tick)
            r.retry_at_tick = self.tick + backoff_ticks(
                self.backoff_base, r.evictions + r.replays, rid=r.rid,
                seed=self.backoff_seed)
        self.resident = {}
        self.waiting[:0] = survivors
        self.dp = dp
        self.batch = dp * self.slots_local
        if nb == self.nb_local:
            allocs = self.allocators[:dp]
            for a in allocs:
                a.reset()
        else:
            self.nb_local, allocs = nb, []
        allocs += [PagedKVAllocator(nb, self.block_size)
                   for _ in range(dp - len(allocs))]
        self.allocators = allocs
        return survivors

    # -- planning / commit ------------------------------------------------

    def plan_step(self) -> StepPlan:
        # shed residents whose deadline became unreachable mid-flight:
        # finishing late is worthless under an SLO, and their blocks are
        # exactly what the queue behind them is starved of
        for req in list(self.resident.values()):
            if self._deadline_unreachable(req):
                self._shed(req, SHED_DEADLINE)
        self._admit()
        self._queue_depth.append(len(self.waiting))
        self._wait_ages.extend(
            self.tick - r.submit_tick for r in self.waiting)
        B, C = self.batch, self.chunk
        tokens = np.zeros((B, C), np.int32)
        pos = np.zeros(B, np.int32)
        n_new = np.zeros(B, np.int32)
        tables = np.zeros((B, self.max_blocks), np.int32)
        seeds = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        live: dict[int, Request] = {}
        for slot in sorted(self.resident):
            req = self.resident.get(slot)
            if req is None:   # evicted earlier this same planning pass
                continue
            P = len(req.prompt)
            if req.prefill_done < P:
                n = min(C, P - req.prefill_done)
                row = req.prompt[req.prefill_done:req.prefill_done + n]
            else:
                n = 1
                row = [req.generated[-1] if req.generated
                       else req.prompt[-1]]
            if not self._ensure_blocks(req, n):
                # rank exhausted and nothing else to evict: self-evict
                self.allocators[req.rank].free(req.blocks)
                del self.resident[slot]
                self._requeue(req)
                continue
            tokens[slot, :n] = row
            pos[slot] = req.next_pos
            n_new[slot] = n
            tables[slot, :len(req.blocks)] = req.blocks
            seeds[slot] = req.seed
            temps[slot] = req.temperature
            live[slot] = req
        # A mid-pass eviction may have reclaimed the blocks of a request
        # planned earlier in this same tick; idle such rows out so nothing
        # writes into blocks it no longer owns.
        for slot in list(live):
            if self.resident.get(slot) is not live[slot]:
                tokens[slot] = 0
                pos[slot] = 0
                n_new[slot] = 0
                tables[slot] = 0
                seeds[slot] = 0
                temps[slot] = 0.0
                del live[slot]
        return StepPlan(tokens=tokens, pos=pos, n_new=n_new, tables=tables,
                        seeds=seeds, temps=temps, requests=live)

    def commit(self, plan: StepPlan, sampled: np.ndarray) -> list[Request]:
        """Advance request state with the engine's sampled tokens.

        Returns the requests that completed on this tick (their blocks and
        slots are already released).
        """
        completed = []
        for slot, req in plan.requests.items():
            n = int(plan.n_new[slot])
            if n == 0:
                continue
            req.next_pos += n
            if req.prefill_done < len(req.prompt):
                req.prefill_done += n
                if req.prefill_done < len(req.prompt):
                    continue           # mid-prefill: sampled token is noise
                req.first_token_tick = self.tick
            req.generated.append(int(sampled[slot]))
            if req.done:
                req.finish_tick = self.tick
                req.record("complete", self.tick)
                self.allocators[req.rank].free(req.blocks)
                req.blocks = []
                del self.resident[req.slot]
                req.slot = -1
                self.finished.append(req)
                completed.append(req)
        self.tick += 1
        return completed

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict[str, Any]:
        done = self.finished
        ttft = [r.first_token_tick - r.arrival for r in done
                if r.first_token_tick >= 0]
        lat = [r.finish_tick - r.arrival for r in done]
        return {
            "finished": len(done),
            "waiting": len(self.waiting),
            "resident": len(self.resident),
            "evictions": self.evicted,
            "replays": self.replayed,
            "shed": len(self.shed_requests),
            "submitted": self.submitted,
            "ticks": self.tick,
            "tokens_generated": sum(len(r.generated) for r in done),
            "ttft_ticks_p50": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_ticks_p99": float(np.percentile(ttft, 99)) if ttft else 0.0,
            "latency_ticks_p50": float(np.percentile(lat, 50)) if lat else 0.0,
            "latency_ticks_p99": float(np.percentile(lat, 99)) if lat else 0.0,
        }

    def ledger(self) -> dict[str, Any]:
        """Request-lifecycle ledger: where every submission ended up.

        ``accounted`` is the no-loss invariant — completed + shed +
        still-in-flight covers 100% of submissions (the chaos harness and
        the bench burst cell both gate on it).  Percentile roll-ups cover
        end-to-end latency, TTFT, queue depth per tick and per-tick
        request wait ages, all in deterministic scheduler ticks."""
        done, shed = self.finished, self.shed_requests
        in_flight = len(self.waiting) + len(self.resident)
        lat = [r.finish_tick - r.arrival for r in done]
        ttft = [r.first_token_tick - r.arrival for r in done
                if r.first_token_tick >= 0]
        qd, ages = self._queue_depth, self._wait_ages

        def pct(xs, q):
            return float(np.percentile(xs, q)) if xs else 0.0

        return {
            "submitted": self.submitted,
            "completed": len(done),
            "shed": len(shed),
            "in_flight": in_flight,
            "accounted": self.submitted == len(done) + len(shed) + in_flight,
            "shed_by_reason": dict(Counter(
                r.shed_reason for r in shed)),
            "evictions": self.evicted,
            "replays": self.replayed,
            "max_evictions_per_request": max(
                (r.evictions for r in done + shed + self.waiting
                 + list(self.resident.values())), default=0),
            "ticks": self.tick,
            "latency_ticks_p50": pct(lat, 50),
            "latency_ticks_p99": pct(lat, 99),
            "ttft_ticks_p50": pct(ttft, 50),
            "ttft_ticks_p99": pct(ttft, 99),
            "queue_depth_p50": pct(qd, 50),
            "queue_depth_p99": pct(qd, 99),
            "queue_depth_max": max(qd, default=0),
            "wait_age_ticks_p50": pct(ages, 50),
            "wait_age_ticks_p99": pct(ages, 99),
        }
