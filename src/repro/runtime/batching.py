"""Continuous-batching request scheduler for the paged serving engine.

Host-side and jax-free: the :class:`ContinuousBatcher` owns one
:class:`~repro.runtime.paged.PagedKVAllocator` per data rank and turns a
ragged arrival queue into fixed-shape step plans for
:func:`repro.runtime.paged.build_paged_step`.  Each *tick* produces one
``StepPlan`` whose rows are the ``dp * slots_local`` resident request slots:

- **prefill rows** feed up to ``chunk`` prompt tokens (``n_new > 1`` allowed),
  so long prompts are streamed in chunks interleaved with decode traffic
  instead of stalling the whole batch (bounded TTFT *and* bounded
  tokens/s);
- **decode rows** feed the previously sampled token (``n_new == 1``);
- **idle rows** carry ``n_new == 0`` — the engine drops their cache writes
  and the scheduler ignores their sampled token.

Admission is FIFO, gated on a free slot *and* a free-block budget of
``blocks_for(len(prompt) + 1)`` on the target rank.  Requests grow their
block allocation lazily, one tick ahead of the write frontier; when a rank
runs out of blocks the youngest resident request on that rank is evicted —
its blocks are freed and it is requeued at the *front* of the waiting queue
to restart from scratch (sampling is seeded per (seed, position), so a
restarted request regenerates the same tokens).

Tick counts double as the latency clock: the bench maps ticks to wall time
after the fact, so the scheduler itself stays deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.runtime.paged import PagedKVAllocator, blocks_for


@dataclasses.dataclass
class Request:
    """One serving request plus its scheduler-side bookkeeping."""

    rid: int
    prompt: list[int]
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    eos: int | None = None
    arrival: int = 0

    # -- mutable scheduler state ------------------------------------------
    generated: list[int] = dataclasses.field(default_factory=list)
    prefill_done: int = 0
    next_pos: int = 0          # cache positions written so far
    blocks: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1             # global slot id, -1 while waiting
    rank: int = -1
    admit_tick: int = -1
    first_token_tick: int = -1
    finish_tick: int = -1
    evictions: int = 0

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return self.eos is not None and self.eos in self.generated

    def positions_needed(self) -> int:
        # The final sampled token is returned but never written back.
        return len(self.prompt) + self.max_new_tokens - 1

    def reset(self) -> None:
        self.generated = []
        self.prefill_done = 0
        self.next_pos = 0
        self.blocks = []
        self.slot = -1
        self.rank = -1
        self.first_token_tick = -1


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """Fixed-shape arrays for one engine step plus the slot -> request map."""

    tokens: np.ndarray       # [B, chunk] int32
    pos: np.ndarray          # [B] int32 first-token positions
    n_new: np.ndarray        # [B] int32 (0 = idle row)
    tables: np.ndarray       # [B, max_blocks] int32 rank-local block ids
    seeds: np.ndarray        # [B] int32
    temps: np.ndarray        # [B] float32
    requests: dict[int, Request]   # slot -> resident request this tick

    @property
    def active_rows(self) -> int:
        return int((self.n_new > 0).sum())


class ContinuousBatcher:
    """FIFO admission + chunked-prefill/decode interleaving over paged KV.

    Parameters mirror the engine: ``dp`` data ranks of ``slots_local``
    resident slots each, ``nb_local`` KV blocks per rank (block 0 is the
    engine's garbage block and never allocated), ``max_blocks`` table width
    per request and ``chunk`` tokens fed per prefill row per tick.

    ``reserve`` picks the admission discipline: ``"min"`` admits as soon
    as the first prompt chunk fits (``blocks_for(len(prompt) + 1)``) and
    relies on eviction + front-of-queue requeue when later growth finds
    the rank exhausted — maximum occupancy, but under sustained overload
    the evicted replays waste work; ``"full"`` admits only when the
    request's worst-case block count fits after subtracting every
    resident's unclaimed reservation, so growth can never fail and
    nothing is ever evicted (vLLM's conservative watermark, the right
    default for throughput benchmarks).
    """

    def __init__(self, *, dp: int, slots_local: int, nb_local: int,
                 block_size: int, max_blocks: int, chunk: int = 1,
                 reserve: str = "min"):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        if reserve not in ("min", "full"):
            raise ValueError("reserve must be 'min' or 'full'")
        self.reserve = reserve
        self.dp = dp
        self.slots_local = slots_local
        self.batch = dp * slots_local
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.chunk = chunk
        self.allocators = [PagedKVAllocator(nb_local, block_size)
                           for _ in range(dp)]
        self.waiting: list[Request] = []
        self.resident: dict[int, Request] = {}   # slot -> request
        self.finished: list[Request] = []
        self.tick = 0
        self.evicted = 0

    # -- queue management -------------------------------------------------

    def submit(self, req: Request) -> None:
        need = blocks_for(req.positions_needed(), self.block_size)
        if need > self.max_blocks:
            raise ValueError(
                f"request {req.rid} needs {need} blocks > max_blocks="
                f"{self.max_blocks}")
        if not req.prompt:
            raise ValueError("empty prompt")
        self.waiting.append(req)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.resident

    def _free_slots(self, rank: int) -> list[int]:
        lo = rank * self.slots_local
        return [s for s in range(lo, lo + self.slots_local)
                if s not in self.resident]

    def _reserved_extra(self, rank: int) -> int:
        """Blocks promised to residents on ``rank`` but not yet allocated."""
        if self.reserve != "full":
            return 0
        return sum(
            max(0, blocks_for(r.positions_needed(), self.block_size)
                - len(r.blocks))
            for r in self.resident.values() if r.rank == rank)

    def _admit(self) -> None:
        """FIFO-admit waiting requests into free slots under block budget."""
        progress = True
        while self.waiting and progress:
            progress = False
            req = self.waiting[0]
            if self.reserve == "full":
                budget = blocks_for(req.positions_needed(), self.block_size)
            else:
                budget = blocks_for(len(req.prompt) + 1, self.block_size)
            for rank in range(self.dp):
                slots = self._free_slots(rank)
                avail = (self.allocators[rank].free_blocks
                         - self._reserved_extra(rank))
                if not slots or avail < budget:
                    continue
                req = self.waiting.pop(0)
                req.slot, req.rank = slots[0], rank
                req.admit_tick = self.tick
                self.resident[req.slot] = req
                progress = True
                break

    def _evict(self, rank: int, keep: Request | None) -> bool:
        """Evict the youngest resident request on ``rank`` (not ``keep``)."""
        victims = [r for r in self.resident.values()
                   if r.rank == rank and r is not keep]
        if not victims:
            return False
        victim = max(victims, key=lambda r: (r.admit_tick, r.slot))
        self.allocators[rank].free(victim.blocks)
        del self.resident[victim.slot]
        victim.reset()
        victim.evictions += 1
        self.evicted += 1
        self.waiting.insert(0, victim)
        return True

    def _ensure_blocks(self, req: Request, n_new: int) -> bool:
        """Grow ``req.blocks`` to cover ``next_pos + n_new`` positions."""
        need = blocks_for(req.next_pos + n_new, self.block_size)
        while len(req.blocks) < need:
            got = self.allocators[req.rank].alloc(need - len(req.blocks))
            if got is not None:
                req.blocks.extend(got)
                return True
            if not self._evict(req.rank, keep=req):
                return False
        return True

    # -- planning / commit ------------------------------------------------

    def plan_step(self) -> StepPlan:
        self._admit()
        B, C = self.batch, self.chunk
        tokens = np.zeros((B, C), np.int32)
        pos = np.zeros(B, np.int32)
        n_new = np.zeros(B, np.int32)
        tables = np.zeros((B, self.max_blocks), np.int32)
        seeds = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        live: dict[int, Request] = {}
        for slot in sorted(self.resident):
            req = self.resident.get(slot)
            if req is None:   # evicted earlier this same planning pass
                continue
            P = len(req.prompt)
            if req.prefill_done < P:
                n = min(C, P - req.prefill_done)
                row = req.prompt[req.prefill_done:req.prefill_done + n]
            else:
                n = 1
                row = [req.generated[-1] if req.generated
                       else req.prompt[-1]]
            if not self._ensure_blocks(req, n):
                # rank exhausted and nothing else to evict: self-evict
                self.allocators[req.rank].free(req.blocks)
                del self.resident[slot]
                req.reset()
                req.evictions += 1
                self.evicted += 1
                self.waiting.insert(0, req)
                continue
            tokens[slot, :n] = row
            pos[slot] = req.next_pos
            n_new[slot] = n
            tables[slot, :len(req.blocks)] = req.blocks
            seeds[slot] = req.seed
            temps[slot] = req.temperature
            live[slot] = req
        # A mid-pass eviction may have reclaimed the blocks of a request
        # planned earlier in this same tick; idle such rows out so nothing
        # writes into blocks it no longer owns.
        for slot in list(live):
            if self.resident.get(slot) is not live[slot]:
                tokens[slot] = 0
                pos[slot] = 0
                n_new[slot] = 0
                tables[slot] = 0
                seeds[slot] = 0
                temps[slot] = 0.0
                del live[slot]
        return StepPlan(tokens=tokens, pos=pos, n_new=n_new, tables=tables,
                        seeds=seeds, temps=temps, requests=live)

    def commit(self, plan: StepPlan, sampled: np.ndarray) -> list[Request]:
        """Advance request state with the engine's sampled tokens.

        Returns the requests that completed on this tick (their blocks and
        slots are already released).
        """
        completed = []
        for slot, req in plan.requests.items():
            n = int(plan.n_new[slot])
            if n == 0:
                continue
            req.next_pos += n
            if req.prefill_done < len(req.prompt):
                req.prefill_done += n
                if req.prefill_done < len(req.prompt):
                    continue           # mid-prefill: sampled token is noise
                req.first_token_tick = self.tick
            req.generated.append(int(sampled[slot]))
            if req.done:
                req.finish_tick = self.tick
                self.allocators[req.rank].free(req.blocks)
                req.blocks = []
                del self.resident[req.slot]
                req.slot = -1
                self.finished.append(req)
                completed.append(req)
        self.tick += 1
        return completed

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict[str, Any]:
        done = self.finished
        ttft = [r.first_token_tick - r.arrival for r in done
                if r.first_token_tick >= 0]
        lat = [r.finish_tick - r.arrival for r in done]
        return {
            "finished": len(done),
            "waiting": len(self.waiting),
            "resident": len(self.resident),
            "evictions": self.evicted,
            "ticks": self.tick,
            "tokens_generated": sum(len(r.generated) for r in done),
            "ttft_ticks_p50": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_ticks_p99": float(np.percentile(ttft, 99)) if ttft else 0.0,
            "latency_ticks_p50": float(np.percentile(lat, 50)) if lat else 0.0,
            "latency_ticks_p99": float(np.percentile(lat, 99)) if lat else 0.0,
        }
