"""Paged KV-cache allocator + paged decode step (vLLM/MaxText-style).

Contiguous serving caches reserve ``cache_len`` slots per request up front —
ragged traffic at wildly different sequence lengths turns most of that HBM
into dead slots.  The paged layout replaces the per-request axis with a
shared pool of fixed-size blocks:

    contiguous:  k [stack, batch, cache_len, heads, dh]
    paged:       k [stack, n_blocks, block_size, heads, dh]
                 block_tables [batch, max_blocks] int32  (rank-local ids)

Each request owns a list of blocks; table entry ``i`` maps token positions
``[i*block_size, (i+1)*block_size)`` to a physical block.  Blocks return to
the free list the moment a request completes, so resident batch is bounded
by *live tokens*, not worst-case length.  Sharding is unchanged from the
contiguous layout: the block pool is sharded over the data axes (each data
rank owns its own allocator and ``n_blocks_local`` blocks — table entries
are rank-local ids) and heads over the model axis, including the GQA
head-slot replication of DESIGN.md §3.

Physical block 0 of every rank is reserved as the *garbage block*: it is
never allocated, unset table entries point at it, and the decode step
redirects writes from padding rows out of range (dropped), so reads through
an unset table entry are deterministic zeros that the per-request
``kv_valid_len`` mask excludes from the softmax.

Bitwise discipline: the paged decode step gathers the pool back into a
contiguous ``[b, max_blocks*block_size, heads, dh]`` view with the *same*
key-axis length as a contiguous cache of that capacity, so the fp32 softmax
reduction tree is identical and paged decode is **bitwise-equal** to the
contiguous reference (tests/serve_harness.py pins this for fp32 and bf16
KV across block sizes).

Int8 KV blocks (``kv_dtype='int8'``) reuse ``core/quant.py``'s absmax
block quantizer — the serving-side analogue of the qgZ gradient wire.  Each
token row is quantized once on write, per (token, head, 128-block of
head_dim), so scale pages shard over the model axis exactly like k/v and
blocks are never re-quantized.  Documented error bound: per-element relative
error ≤ 1/254 of the row's per-block absmax (round-to-nearest at 127 levels);
end-to-end logits stay within a few percent of the fp32 reference
(serve_harness ``int8_kv_error``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import quant as Q
from repro.core.autotune import resolve_config
from repro.core.comm import CommEngine
from repro.core.mics import MiCSConfig, state_pspecs
from repro.core.topology import MODEL_AXIS, MiCSTopology
from repro.models import layers as L
from repro.models import lm
from repro.models.lm import ModelDef

KV_DTYPES = ("fp32", "bf16", "int8")
_KV_JNP = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class PageState:
    """Per-step paged-cache state threaded through ``Ctx.pages``.

    block_tables: [b, max_blocks] int32 rank-local block ids (traced).
    block_size:   static tokens per block.
    n_new:        [b] int32 tokens consumed per slot this tick (traced), or
                  None (all ``tq`` rows valid — plain decode).
    """

    block_tables: Any
    block_size: int
    n_new: Any = None


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Physical blocks needed to hold ``n_tokens`` cache positions."""
    return -(-max(n_tokens, 0) // block_size)


class PagedKVAllocator:
    """Host-side free-list allocator for one data rank's block pool.

    Block 0 is the reserved garbage block (never handed out).  Allocation
    is lowest-id-first so refilled slots reuse just-freed blocks — the
    pool's steady-state working set stays compact.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free = list(range(n_blocks - 1, 0, -1))  # pop() -> lowest id

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """n blocks, or None (and no change) if the pool can't supply them."""
        if n < 0:
            raise ValueError("negative block count")
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        return got

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if not 0 < b < self.n_blocks:
                raise ValueError(f"bad block id {b}")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
        self._free.extend(sorted(blocks, reverse=True))
        self._free.sort(reverse=True)

    def reset(self) -> None:
        """Return every block to the pool (outstanding tables invalid).

        The world-change rebuild path: after a preemption/degradation the
        KV pool arrays are re-initialized and every resident request
        replays from its prompt, so the allocator forgets all outstanding
        allocations in one step instead of requiring each to be freed."""
        self._free = list(range(self.n_blocks - 1, 0, -1))


# ---------------------------------------------------------------------------
# paged cache pytree (global arrays + pspecs)
# ---------------------------------------------------------------------------

def _check_paged_support(model: ModelDef) -> None:
    if model.cfg.window:
        raise NotImplementedError(
            "paged KV serving requires window == 0 (no rolling caches)")
    for pool in model.pools:
        if pool.make_cache is None:
            raise NotImplementedError(
                f"pool {pool.name!r} has no KV cache (family "
                f"{model.cfg.family!r} is not paged-servable)")
        one = pool.make_cache(1, 8)
        if set(one) != {"k", "v"} or one["k"].ndim != 4:
            raise NotImplementedError(
                f"pool {pool.name!r} cache is not a plain k/v dict "
                f"(family {model.cfg.family!r} is not paged-servable)")


def paged_cache_local(model: ModelDef, n_blocks_local: int, block_size: int,
                      kv_dtype: str = "bf16"):
    """One data rank's paged cache pytree (stacked over each pool's layers).

    Leaves per pool: k/v [stack, n_blocks, block_size, h_local, dh]
    (+ f32 scale pages ks/vs [stack, n_blocks, block_size, h_local, n_scale]
    when ``kv_dtype='int8'``).
    """
    _check_paged_support(model)
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}")
    caches = {}
    for pool in model.pools:
        one = pool.make_cache(n_blocks_local, block_size)
        shape = one["k"].shape  # [n_blocks, block_size, h_local, dh]
        if kv_dtype == "int8":
            nsc = Q.n_blocks(shape[-1])
            one = {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros((*shape[:-1], nsc), jnp.float32),
                "vs": jnp.zeros((*shape[:-1], nsc), jnp.float32),
            }
        else:
            dt = _KV_JNP[kv_dtype]
            one = {"k": one["k"].astype(dt), "v": one["v"].astype(dt)}
        caches[pool.name] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (pool.stack, *a.shape)), one)
    return caches


def paged_cache_pspecs(model: ModelDef, topo: MiCSTopology, batch_axes=None,
                       *, kv_dtype: str = "bf16"):
    """[stack, blocks, block_size, heads, ...]: blocks over the data axes
    (each rank owns its pool), heads over model — same placement rules as
    the contiguous cache; int8 scale pages shard identically."""
    baxes = topo.data_axes if batch_axes is None else batch_axes
    spec = P(None, baxes, None, MODEL_AXIS, None)
    names = ("k", "v", "ks", "vs") if kv_dtype == "int8" else ("k", "v")
    return {pool.name: {n: spec for n in names} for pool in model.pools}


def init_paged_caches(model: ModelDef, topo: MiCSTopology,
                      n_blocks_local: int, block_size: int,
                      kv_dtype: str = "bf16", batch_axes=None):
    """Global zero-filled paged caches + their pspecs.

    ``n_blocks_local`` is per data rank (allocators are rank-local); the
    global blocks axis is ``n_blocks_local * dp``.
    """
    baxes = topo.data_axes if batch_axes is None else batch_axes
    local = paged_cache_local(model, n_blocks_local, block_size, kv_dtype)
    specs = paged_cache_pspecs(model, topo, baxes, kv_dtype=kv_dtype)

    def globalize(leaf, ps):
        shape = list(leaf.shape)
        for i, ax in enumerate(ps):
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shape[i] *= topo.axis_size(a)
        sharding = NamedSharding(topo.mesh, ps)
        return jax.device_put(jnp.zeros(tuple(shape), leaf.dtype), sharding)

    caches = jax.tree.map(globalize, local, specs,
                          is_leaf=lambda x: isinstance(x, jnp.ndarray))
    return caches, specs


# ---------------------------------------------------------------------------
# host-side cache conversion (prefill once, then serve paged)
# ---------------------------------------------------------------------------

def pages_from_contiguous(model: ModelDef, topo: MiCSTopology, contig,
                          paged, tables, lengths, *, block_size: int,
                          kv_dtype: str = "bf16", batch_axes=None):
    """Copy a contiguous prefill cache into an allocated paged pool.

    contig: the ``lm.prefill`` cache pytree (k/v [stack, B, cap, H, dh],
    slot of position a == a for window-free archs); paged: global paged
    caches from :func:`init_paged_caches`; tables [B, max_blocks] rank-local
    block ids; lengths [B] prompt lengths.  Returns updated paged caches.
    Host-side (numpy) — runs once per admission wave, not per step.
    """
    import numpy as np

    baxes = topo.data_axes if batch_axes is None else batch_axes
    dp = 1
    for a in baxes:
        dp *= topo.axis_size(a)
    tables = np.asarray(tables)
    lengths = np.asarray(lengths)
    bsz = tables.shape[0]
    b_local = bsz // dp
    out = {}
    specs = paged_cache_pspecs(model, topo, baxes, kv_dtype=kv_dtype)
    for pool in model.pools:
        src_k = np.asarray(contig[pool.name]["k"], dtype=np.float32)
        src_v = np.asarray(contig[pool.name]["v"], dtype=np.float32)
        dst = {name: np.array(leaf) for name, leaf in paged[pool.name].items()}
        nb_local = dst["k"].shape[1] // dp
        for b in range(bsz):
            n = int(lengths[b])
            if n == 0:
                continue
            rank = b // b_local
            posn = np.arange(n)
            gblk = rank * nb_local + tables[b, posn // block_size]
            off = posn % block_size
            if kv_dtype == "int8":
                qk, sk = Q.quantize_flat(jnp.asarray(src_k[:, b, :n]))
                qv, sv = Q.quantize_flat(jnp.asarray(src_v[:, b, :n]))
                dst["k"][:, gblk, off] = np.asarray(qk)
                dst["v"][:, gblk, off] = np.asarray(qv)
                dst["ks"][:, gblk, off] = np.asarray(sk)
                dst["vs"][:, gblk, off] = np.asarray(sv)
            else:
                dst["k"][:, gblk, off] = src_k[:, b, :n].astype(dst["k"].dtype)
                dst["v"][:, gblk, off] = src_v[:, b, :n].astype(dst["v"].dtype)
        out[pool.name] = {
            name: jax.device_put(
                jnp.asarray(leaf),
                NamedSharding(topo.mesh, specs[pool.name][name]))
            for name, leaf in dst.items()}
    return out


# ---------------------------------------------------------------------------
# the paged decode/chunk step
# ---------------------------------------------------------------------------

def build_paged_step(model: ModelDef, topo: MiCSTopology, mcfg: MiCSConfig,
                     *, max_blocks: int, block_size: int | None = None,
                     chunk: int = 1, kv_dtype: str | None = None,
                     top_k: int = 0, batch_axes=None):
    """Jitted continuous-batching step over a paged KV pool.

    step(params, caches, tokens [B, chunk], pos [B], n_new [B],
         tables [B, max_blocks], seeds [B], temps [B])
      -> (next_tok [B], logits_row [B, vocab_padded], new_caches)

    One call advances every slot by up to ``chunk`` tokens: decode slots
    consume 1 (``n_new=1``), prefill slots up to ``chunk`` (chunked prefill
    interleaved into decode ticks — TTFT and steady-state tokens/s both
    bounded), idle slots 0.  The sampled token comes from the logit row of
    each slot's last consumed token; the scheduler ignores it mid-prompt.
    The key-axis length of every attention is ``max_blocks * block_size``
    regardless of the chunking, so a request's hidden states — and its
    sampled tokens — are bitwise-independent of where its chunk boundaries
    fall for a fixed ``chunk`` width (one compiled executable).  Across
    *different* chunk widths the kernels tile the token matmuls
    differently, so equality is only up to last-ulp rounding — the serve
    harness checks both regimes.
    """
    mcfg, plan = resolve_config(mcfg, model, topo, mode="serve")
    block_size = block_size if block_size is not None else mcfg.kv_block_size
    kv_dtype = kv_dtype if kv_dtype is not None else mcfg.kv_dtype
    _check_paged_support(model)
    comm = CommEngine.from_config(topo, mcfg)
    cache_len = max_blocks * block_size
    ctx = L.Ctx(mode="decode", tp=topo.model_size, tp_axis=MODEL_AXIS,
                cache_len=cache_len, window=0,
                compute_dtype=jnp.dtype(mcfg.gather_dtype),
                scores_bf16=mcfg.scores_bf16, mlstm_chunk=mcfg.mlstm_chunk)
    baxes = topo.data_axes if batch_axes is None else batch_axes
    flat_specs = state_pspecs(model, topo)["params"]
    if mcfg.quant_gather:
        flat_specs = {name: {"q": spec, "s": spec}
                      for name, spec in flat_specs.items()}
    kv_spec = P(None, baxes, None, MODEL_AXIS, None)
    names = ("k", "v", "ks", "vs") if kv_dtype == "int8" else ("k", "v")
    c_specs = {pool.name: {n: kv_spec for n in names} for pool in model.pools}
    tok_spec = P(baxes, None)
    row_spec = P(baxes)
    tbl_spec = P(baxes, None)
    logit_spec = P(baxes, MODEL_AXIS)

    def sharded_step(params, caches, tokens, pos, n_new, tables, seeds, temps):
        pages = PageState(block_tables=tables, block_size=block_size,
                          n_new=n_new)
        logits, new_caches = lm.decode_step(
            model, params, comm, ctx, tokens, pos, caches, pages=pages)
        b = tokens.shape[0]
        row = jnp.maximum(n_new - 1, 0)
        lgt = logits[jnp.arange(b), row]            # [b, V/tp] last-consumed row
        next_tok = lm.sample_tokens(
            lgt, ctx, model.cfg.vocab, seed=seeds, pos=pos + n_new,
            temperature=temps, top_k=top_k)
        return next_tok, lgt, new_caches

    ns = lambda spec: jax.tree.map(
        lambda s_: NamedSharding(topo.mesh, s_), spec,
        is_leaf=lambda x: isinstance(x, P))

    step_sm = shard_map(
        sharded_step, mesh=topo.mesh,
        in_specs=(flat_specs, c_specs, tok_spec, row_spec, row_spec,
                  tbl_spec, row_spec, row_spec),
        out_specs=(row_spec, logit_spec, c_specs),
        check_vma=False,
    )
    step_fn = jax.jit(
        step_sm,
        in_shardings=(ns(flat_specs), ns(c_specs), ns(tok_spec), ns(row_spec),
                      ns(row_spec), ns(tbl_spec), ns(row_spec), ns(row_spec)),
        out_shardings=(ns(row_spec), ns(logit_spec), ns(c_specs)),
        donate_argnums=(1,),
    )
    return step_fn


def build_contiguous_step(model: ModelDef, topo: MiCSTopology,
                          mcfg: MiCSConfig, cache_len: int, *,
                          top_k: int = 0, batch_axes=None):
    """Vector-position contiguous-cache decode step: the bitwise reference
    for the paged engine (same per-request positions and sampler, regular
    [stack, b, cache_len, h, dh] caches, one token per slot per call).

    step(params, caches, tokens [B, 1], pos [B], seeds [B], temps [B])
      -> (next_tok [B], logits_row [B, vocab_padded], new_caches)
    """
    from repro.runtime.serving import cache_pspecs

    mcfg, _ = resolve_config(mcfg, model, topo, mode="serve")
    comm = CommEngine.from_config(topo, mcfg)
    ctx = L.Ctx(mode="decode", tp=topo.model_size, tp_axis=MODEL_AXIS,
                cache_len=cache_len, window=model.cfg.window,
                compute_dtype=jnp.dtype(mcfg.gather_dtype),
                scores_bf16=mcfg.scores_bf16, mlstm_chunk=mcfg.mlstm_chunk)
    baxes = topo.data_axes if batch_axes is None else batch_axes
    flat_specs = state_pspecs(model, topo)["params"]
    if mcfg.quant_gather:
        flat_specs = {name: {"q": spec, "s": spec}
                      for name, spec in flat_specs.items()}
    c_specs = cache_pspecs(model, topo, baxes)
    tok_spec = P(baxes, None)
    row_spec = P(baxes)
    logit_spec = P(baxes, MODEL_AXIS)

    def sharded_step(params, caches, tokens, pos, seeds, temps):
        logits, new_caches = lm.decode_step(
            model, params, comm, ctx, tokens, pos, caches)
        lgt = logits[:, 0]
        next_tok = lm.sample_tokens(
            lgt, ctx, model.cfg.vocab, seed=seeds, pos=pos + 1,
            temperature=temps, top_k=top_k)
        return next_tok, lgt, new_caches

    ns = lambda spec: jax.tree.map(
        lambda s_: NamedSharding(topo.mesh, s_), spec,
        is_leaf=lambda x: isinstance(x, P))

    step_sm = shard_map(
        sharded_step, mesh=topo.mesh,
        in_specs=(flat_specs, c_specs, tok_spec, row_spec, row_spec, row_spec),
        out_specs=(row_spec, logit_spec, c_specs),
        check_vma=False,
    )
    return jax.jit(
        step_sm,
        in_shardings=(ns(flat_specs), ns(c_specs), ns(tok_spec), ns(row_spec),
                      ns(row_spec), ns(row_spec)),
        out_shardings=(ns(row_spec), ns(logit_spec), ns(c_specs)),
        donate_argnums=(1,),
    )
