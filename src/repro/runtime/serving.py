"""Serving runtime: prefill + KV-cache decode steps under MiCS sharding.

Inference uses the same flat-pool parameter gathering as training (memory
scales 1/p like ZeRO-3 inference) minus optimizer state.  The KV cache is
sharded batch-over-data and heads-over-model; for GQA archs whose KV head
count is below the model-axis width, each rank caches the one head its Q
group attends to (global cache carries tp "head slots" — the vLLM-style
replication documented in DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.autotune import resolve_config
from repro.core.comm import CommEngine
from repro.core.mics import MiCSConfig, state_pspecs
from repro.core.topology import MODEL_AXIS, MiCSTopology
from repro.models import layers as L
from repro.models import lm
from repro.models.lm import ModelDef


def _cache_pspec_for(leaf_path: str, leaf) -> P:
    """PartitionSpec for one cache leaf (stack, batch, ...) by convention.

    kv/cross caches: [stack, b, len, heads, dh]   -> heads over model
    rec conv:        [stack, b, cw-1, channels]   -> channels over model
    rec h:           [stack, b, channels]         -> channels over model
    xlstm leaves (replicated compute): batch only.
    """
    name = leaf_path.split("/")[-1]
    nd = leaf.ndim
    if name in ("k", "v") and nd == 5:
        return P(None, "data_all", None, MODEL_AXIS, None)
    if name == "conv" and nd == 4:
        return P(None, "data_all", None, MODEL_AXIS)
    if name == "h" and nd == 3:
        return P(None, "data_all", MODEL_AXIS)
    return P(None, "data_all", *([None] * (nd - 2)))


def batch_axes_for(topo: MiCSTopology, global_batch: int):
    """Data axes the batch shards over.

    Ragged batches (``global_batch`` not a multiple of the data-parallel
    size) are padded up to the next multiple with masked dummy rows by
    :func:`pad_ragged_batch` — they used to fall back to replicating the
    whole batch on every data rank, which made a 5-row batch on dp=4 cost
    as much as 20 rows.
    """
    del global_batch  # padding, not replication, handles raggedness now
    return topo.data_axes


def pad_ragged_batch(topo: MiCSTopology, batch: dict):
    """Pad every batch leaf to the next multiple of dp with dummy rows.

    Returns ``(padded_batch, row_mask)`` where ``row_mask`` is a bool [B]
    marking real rows; dummy rows must be masked out of sampling (the
    serve decode step emits token ``-1`` for them).
    """
    dp = topo.data_parallel_size
    b = batch["tokens"].shape[0]
    pad = (-b) % dp
    mask = jnp.arange(b + pad) < b
    if pad:
        batch = {k: jnp.concatenate(
            [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)], axis=0)
            for k, v in batch.items()}
    return batch, mask


def cache_pspecs(model: ModelDef, topo: MiCSTopology, batch_axes=None):
    """Specs for the full cache pytree (built from a tiny local template)."""
    template = lm.init_caches(model, batch=1, cache_len=max(model.cfg.window, 8))
    xlstm = model.cfg.family == "xlstm"
    baxes = topo.data_axes if batch_axes is None else batch_axes

    def spec(path, leaf):
        pathstr = "/".join(str(getattr(p, "key", p)) for p in path)
        ps = _cache_pspec_for(pathstr, leaf)
        if xlstm:  # replicated-compute states: batch sharding only
            ps = P(None, "data_all", *([None] * (leaf.ndim - 2)))
        # replace the placeholder with the real batch axes tuple
        parts = [baxes if p == "data_all" else p for p in ps]
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, template)


def global_cache_shapes(model: ModelDef, topo: MiCSTopology,
                        global_batch: int, cache_len: int, batch_axes=None):
    """Global ShapeDtypeStructs for the cache pytree (no allocation)."""
    baxes = topo.data_axes if batch_axes is None else batch_axes
    dp = 1
    for a in baxes:
        dp *= topo.axis_size(a)
    local_b = global_batch // dp
    template = lm.init_caches(model, batch=local_b, cache_len=cache_len)
    specs = cache_pspecs(model, topo, baxes)

    def scale(leaf, ps):
        shape = list(leaf.shape)
        for i, ax in enumerate(ps):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                shape[i] *= topo.axis_size(a)
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)

    return jax.tree.map(scale, template, specs,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray)), specs


def build_serve_steps(model: ModelDef, topo: MiCSTopology, mcfg: MiCSConfig,
                      cache_len: int, batch_axes=None, *, top_k: int = 0):
    """Returns (prefill_fn, decode_fn) jitted for the topo's mesh.

    Weight gathers (bf16 or int8-quantized, serial or prefetched) run
    through the same CommEngine as training — decode re-gathers every
    layer each step, so the prefetch schedule matters most here.
    ``policy="auto"`` configs are resolved by the link-model autotuner
    first (serving mode: forward gathers only, no gradient sync).

    ``decode_fn(params, caches, tokens, pos, seeds, temps, row_mask)``
    samples with per-request seeded Gumbel noise (``lm.sample_tokens``):
    ``temps == 0`` rows take the noiseless argmax (exact greedy), masked
    rows (``row_mask`` False — :func:`pad_ragged_batch` padding) emit -1.
    """
    mcfg, _ = resolve_config(mcfg, model, topo, mode="serve")
    comm = CommEngine.from_config(topo, mcfg)
    ctx = L.Ctx(mode="decode", tp=topo.model_size, tp_axis=MODEL_AXIS,
                cache_len=cache_len, window=model.cfg.window,
                compute_dtype=jnp.dtype(mcfg.gather_dtype),
                scores_bf16=mcfg.scores_bf16, mlstm_chunk=mcfg.mlstm_chunk)
    baxes = topo.data_axes if batch_axes is None else batch_axes
    flat_specs = state_pspecs(model, topo)["params"]
    if mcfg.quant_gather:  # int8 weights + per-block scales, same sharding
        flat_specs = {name: {"q": spec, "s": spec}
                      for name, spec in flat_specs.items()}
    c_specs = cache_pspecs(model, topo, baxes)
    tok_spec = P(baxes, None)
    logit_spec = P(baxes, None, MODEL_AXIS)

    def sharded_prefill(params, batch):
        pctx = dataclasses.replace(ctx, mode="prefill")
        logits, caches = lm.prefill(model, params, comm, pctx, batch)
        return logits, caches

    def sharded_decode(params, caches, tokens, pos, seeds, temps, row_mask):
        logits, new_caches = lm.decode_step(
            model, params, comm, ctx, tokens, pos, caches)
        b = tokens.shape[0]
        pos_b = jnp.broadcast_to(pos, (b,)).astype(jnp.int32)
        nxt = lm.sample_tokens(logits[:, -1], ctx, model.cfg.vocab,
                               seed=seeds, pos=pos_b + 1,
                               temperature=temps, top_k=top_k)
        nxt = jnp.where(row_mask, nxt, -1)
        return logits, nxt[:, None], new_caches

    ns = lambda spec: jax.tree.map(
        lambda s_: NamedSharding(topo.mesh, s_), spec,
        is_leaf=lambda x: isinstance(x, P))

    batch_specs = {"tokens": tok_spec}
    if model.cfg.family == "vlm":
        batch_specs["vision"] = P(baxes, None, None)
    if model.cfg.family == "encdec":
        batch_specs["audio"] = P(baxes, None, None)

    prefill_sm = shard_map(
        sharded_prefill, mesh=topo.mesh,
        in_specs=(flat_specs, batch_specs),
        out_specs=(logit_spec, c_specs),
        check_vma=False,
    )
    prefill_fn = jax.jit(
        prefill_sm,
        in_shardings=(ns(flat_specs), ns(batch_specs)),
        out_shardings=(ns(logit_spec), ns(c_specs)),
    )

    row_spec = P(baxes)
    decode_sm = shard_map(
        sharded_decode, mesh=topo.mesh,
        in_specs=(flat_specs, c_specs, tok_spec, P(), row_spec, row_spec,
                  row_spec),
        out_specs=(logit_spec, tok_spec, c_specs),
        check_vma=False,
    )
    decode_jit = jax.jit(
        decode_sm,
        in_shardings=(ns(flat_specs), ns(c_specs), ns(tok_spec),
                      NamedSharding(topo.mesh, P()), ns(row_spec),
                      ns(row_spec), ns(row_spec)),
        out_shardings=(ns(logit_spec), ns(tok_spec), ns(c_specs)),
        donate_argnums=(1,),
    )

    def decode_fn(params, caches, tokens, pos, seeds=None, temps=None,
                  row_mask=None):
        b = tokens.shape[0]
        if seeds is None:
            seeds = jnp.zeros((b,), jnp.int32)
        if temps is None:
            temps = jnp.zeros((b,), jnp.float32)  # greedy
        if row_mask is None:
            row_mask = jnp.ones((b,), bool)
        return decode_jit(params, caches, tokens, pos, seeds, temps, row_mask)

    decode_fn.lower = decode_jit.lower  # AOT path (launch/dryrun.py)
    return prefill_fn, decode_fn


def resize_for_serve_world(model, mcfg: MiCSConfig, n_devices: int, *,
                           tp: int = 1, partition_size: int | None = None,
                           seq: int = 0, arrival_rate: float = 0.0
                           ) -> tuple[MiCSTopology, MiCSConfig, dict]:
    """(topology, config, ledger info) for serving on an ``n_devices`` world.

    The serving analog of ``train_loop.resize_for_world``, and the one
    rebuild path the resilient serve loop (runtime/resilient.py) uses on
    every :class:`repro.core.faults.WorldChangeError`:

    1. ``autotune.resolve_world(mode="serve")`` re-picks the partition
       group for the survivors (the paper's §3.1 rule under
       ``mcfg.hbm_budget_gb``; the keep rule without a budget);
    2. ``topology.elastic_host_topology`` re-meshes them contiguously
       (TP stays pinned — flat layouts are TP-local);
    3. ``autotune.rerank_serve_world`` re-ranks the serve decode grid on
       the new link geometry with numerics pinned, so the re-ranked
       policy cannot break the bitwise replay contract.

    ``info`` is ledger-friendly: the §3.1 decision plus the re-ranked
    serve policy summary.
    """
    from repro.core.autotune import rerank_serve_world, resolve_world
    from repro.core.topology import elastic_host_topology

    p, mcfg2, info = resolve_world(
        model, mcfg, n_devices=n_devices, tp=tp,
        partition_size=partition_size, mode="serve", seq=seq)
    topo = elastic_host_topology(n_devices, p, tp)
    mcfg3, plan = rerank_serve_world(model, topo, mcfg2, seq=seq,
                                     arrival_rate=arrival_rate)
    chosen = plan.chosen
    info = dict(info, serve_rerank={
        "gather": chosen.gather.topology,
        "wire": chosen.gather.wire_dtype,
        "prefetch": chosen.gather.prefetch,
        "kv_dtype": mcfg3.kv_dtype,            # pinned, not chosen.kv_dtype
        "max_resident_requests": mcfg3.max_resident_requests,
        "t_decode_s": chosen.t_decode_s,
        "tokens_per_s": chosen.tokens_per_s,
    })
    return topo, mcfg3, info
