"""Fault-tolerant, elastic training driver.

Production posture (DESIGN.md §6; scales the same way at 1000+ nodes):

* **Checkpoint/restart** — periodic async sharded checkpoints; on start the
  loop resumes from the newest complete checkpoint, including the data
  cursor (the synthetic pipeline is seekable, so no sample is replayed or
  skipped).
* **Failure handling** — any step raising a device/runtime error triggers
  rollback-and-retry from the last checkpoint; repeated failures of the same
  step re-raise (poison-step guard).  On real clusters the same hook is
  where a missing-heartbeat / SPMD barrier timeout lands.
* **Elastic world changes** — with an :class:`ElasticConfig`, a
  :class:`repro.core.faults.WorldChangeError` (preemption / grow-back) is
  survived in-loop: an emergency checkpoint is taken while the old world is
  still intact (when the event came with notice), the surviving device set
  is re-factored into a fresh ``MiCSTopology``
  (``core/topology.elastic_host_topology``), ``autotune.resolve_world``
  re-picks partition-group size + carry for the new world (the paper's
  §3.1 rule re-run on the survivors, when ``hbm_budget_gb`` is set), the
  step function is rebuilt, and the newest complete checkpoint is restored
  cross-topology.  Every change lands in the ``LoopStats.world_changes``
  ledger; the retry budget and backoff are bounded
  (``ElasticConfig.max_world_changes`` / ``backoff_s``).  The resumed
  trajectory is bitwise identical to a cold restore of the same checkpoint
  on the same surviving topology (tests/elastic_harness.py).
* **Straggler mitigation** — on TPU SPMD a straggler stalls the collective,
  so mitigation happens at the *input* layer: the loader prefetches ahead on
  a worker thread and the loop tracks a step-time EWMA, flagging steps
  slower than `straggler_factor` x the EWMA; an injected
  :class:`repro.core.faults.StragglerError` (the production evict decision)
  rides the rollback-and-retry path.

Deterministic fault injection: pass a ``core/faults.FaultPlan`` as
``fault_injector`` — the loop binds its crash-during-save leg to the
checkpointer automatically, and every scripted event fires exactly once.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.autotune import resolve_world
from repro.core.faults import FaultError, WorldChangeError
from repro.core.mics import MiCSConfig, build_train_step, init_state
from repro.core.topology import MiCSTopology, elastic_host_topology
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.build import build_model
from repro.models.lm import ModelDef
from repro.optim.adamw import OptConfig

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    log_every: int = 10
    straggler_factor: float = 3.0
    max_step_retries: int = 2
    seed: int = 0


@dataclasses.dataclass
class ElasticConfig:
    """How the loop survives world changes (preemptible/spot capacity).

    ``max_world_changes`` bounds the rebuild budget — a flapping cluster
    re-raises rather than thrashing forever.  ``backoff_s`` sleeps
    ``backoff_s * attempt`` before each rebuild (keep 0 in tests; on a real
    cluster this is where the coordinator's membership settles)."""

    max_world_changes: int = 8
    backoff_s: float = 0.0


@dataclasses.dataclass
class LoopStats:
    losses: list
    step_times: list
    straggler_steps: list
    restarts: int = 0
    world_changes: list = dataclasses.field(default_factory=list)
    emergency_saves: int = 0
    save_failures: int = 0


def train(model: ModelDef, topo: MiCSTopology, mcfg: MiCSConfig,
          oc: OptConfig, dc: DataConfig, lc: LoopConfig,
          fault_injector: Callable[[int], None] | None = None,
          elastic: ElasticConfig | None = None) -> LoopStats:
    ckpt = Checkpointer(lc.checkpoint_dir)
    if hasattr(fault_injector, "bind"):   # a core/faults.FaultPlan
        fault_injector.bind(ckpt)
    source = SyntheticLM(dc)
    stats = LoopStats([], [], [], 0)

    topo_cur, mcfg_cur = topo, mcfg
    tp = topo.model_size
    world = topo.world_size
    step_fn = build_train_step(model, topo_cur, mcfg_cur, oc)

    def _try_save(state, step, cursor, *, blocking, emergency=False) -> bool:
        """Checkpoint, absorbing writer crashes into the stats ledger.

        A held failure from a previous async save surfaces here too (the
        checkpointer re-raises it from ``save``'s internal ``wait``); one
        retry keeps the checkpoint cadence after a crashed writer."""
        for attempt in (0, 1):
            try:
                ckpt.save(state, step, topo=topo_cur, data_cursor=cursor,
                          blocking=blocking, emergency=emergency,
                          host_stash=_stash_snapshot(mcfg_cur))
                return True
            except Exception as e:  # noqa: BLE001 - failure domain boundary
                stats.save_failures += 1
                log.warning("checkpoint save at step %d failed (%s)%s",
                            step, e, "; retrying" if attempt == 0 else "")
        return False

    start = ckpt.latest_step()
    if start is not None:
        state, meta = ckpt.restore(model, topo_cur,
                                   offload_opt=mcfg_cur.offload_opt)
        cursor = meta["data_cursor"]
        log.info("resumed from step %d", start)
    else:
        state = init_state(model, topo_cur, seed=lc.seed,
                           offload_opt=mcfg_cur.offload_opt)
        cursor = 0

    ewma = None
    measured = 0   # steps timed since the last (re)compile
    step = int(np.asarray(state["step"]))
    retries = 0
    while step < lc.total_steps:
        batch = jax.tree.map(
            jax.numpy.asarray, source.global_step_batch(cursor))
        t0 = time.time()
        try:
            if fault_injector is not None:
                fault_injector(step)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # blocks; surfaces device errors
        except WorldChangeError as e:
            stats.restarts += 1
            if elastic is None:
                raise
            if len(stats.world_changes) >= elastic.max_world_changes:
                log.error("world changed %d times; giving up",
                          len(stats.world_changes))
                raise
            new_world = world - e.lost + e.gained
            fired_step = step
            log.warning("world change at step %d (%s): %d -> %d devices",
                        step, e, world, new_world)
            if e.notice:
                # the old world is still intact (preemption notice / grow
                # announcement): emergency-save so zero steps are lost.
                if _try_save(state, step, cursor, blocking=True,
                             emergency=True):
                    stats.emergency_saves += 1
            else:
                try:
                    ckpt.wait()   # let an in-flight periodic save land
                except FaultError as we:
                    stats.save_failures += 1
                    log.warning("in-flight save lost to the crash (%s)", we)
            if elastic.backoff_s:
                time.sleep(elastic.backoff_s * (len(stats.world_changes) + 1))
            topo_cur, mcfg_cur, info = resize_for_world(
                model, mcfg, new_world, tp=tp,
                partition_size=topo_cur.partition_size)
            step_fn = build_train_step(model, topo_cur, mcfg_cur, oc)
            if ckpt.latest_step() is not None:
                state, meta = ckpt.restore(model, topo_cur,
                                           offload_opt=mcfg_cur.offload_opt)
                cursor = meta["data_cursor"]
            else:
                state = init_state(model, topo_cur, seed=lc.seed,
                                   offload_opt=mcfg_cur.offload_opt)
                cursor = 0
            step = int(np.asarray(state["step"]))
            world = new_world
            stats.world_changes.append({
                "at_step": int(fired_step),
                "kind": "grow" if e.gained else "preempt",
                "lost": e.lost, "gained": e.gained, "notice": e.notice,
                "world": new_world, "resumed_step": step, **info,
            })
            log.warning("resumed at step %d on %d devices (p=%d, %s)",
                        step, new_world, topo_cur.partition_size,
                        info["rule"])
            ewma = None
            measured = 0   # the rebuilt step_fn recompiles on first use
            retries = 0
            continue
        except Exception as e:  # noqa: BLE001 - failure domain boundary
            stats.restarts += 1
            retries += 1
            if retries > lc.max_step_retries:
                raise
            log.warning("step %d failed (%s); rolling back", step, e)
            prev = ckpt.latest_step()
            if prev is not None:
                state, meta = ckpt.restore(model, topo_cur,
                                           offload_opt=mcfg_cur.offload_opt)
                cursor = meta["data_cursor"]
                step = int(np.asarray(state["step"]))
            else:
                state = init_state(model, topo_cur, seed=lc.seed,
                                   offload_opt=mcfg_cur.offload_opt)
                cursor = 0
                step = 0
            continue
        retries = 0
        dt = time.time() - t0
        measured += 1
        if measured > 1:
            # the first step after a (re)compile pays tracing+compilation;
            # seeding the EWMA with it would mask real stragglers for many
            # steps, so the detector warms up from the second step on.
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if ewma is not None and dt > lc.straggler_factor * ewma \
                and len(stats.step_times) > 3:
            stats.straggler_steps.append(step)
            log.warning("straggler: step %d took %.2fs (ewma %.2fs)",
                        step, dt, ewma)
        stats.losses.append(loss)
        stats.step_times.append(dt)
        cursor += 1
        step += 1
        if lc.log_every and step % lc.log_every == 0:
            log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
        if lc.checkpoint_every and step % lc.checkpoint_every == 0:
            _try_save(state, step, cursor, blocking=False)
    try:
        ckpt.wait()
    except Exception as e:  # noqa: BLE001
        stats.save_failures += 1
        log.warning("final wait surfaced a crashed save (%s)", e)
    _try_save(state, step, cursor, blocking=True)
    return stats


def _stash_snapshot(mcfg: MiCSConfig):
    """The offloaded-moment half of the state when ``offload_opt=True``."""
    if not mcfg.offload_opt:
        return None
    from repro.core.hostoffload import export_stash

    return export_stash()


def resize_for_world(model, mcfg: MiCSConfig, n_devices: int, *, tp: int = 1,
                     partition_size: int | None = None,
                     local_batch: int = 0, seq: int = 0
                     ) -> tuple[MiCSTopology, MiCSConfig, dict]:
    """(topology, config, ledger info) for an ``n_devices`` world.

    The one rebuild path both the in-loop world-change handler and a cold
    :func:`elastic_restart` share, so the two are bitwise-interchangeable:
    ``autotune.resolve_world`` re-picks partition-group size + carry
    (§3.1 re-run on the survivors under ``mcfg.hbm_budget_gb``; without a
    budget the previous ``partition_size`` is kept where it divides), then
    the survivors are re-meshed contiguously
    (``core/topology.elastic_host_topology``).
    """
    p, mcfg2, info = resolve_world(
        model, mcfg, n_devices=n_devices, tp=tp,
        partition_size=partition_size, local_batch=local_batch, seq=seq)
    return elastic_host_topology(n_devices, p, tp), mcfg2, info


def elastic_restart(checkpoint_dir: str, cfg, new_topo: MiCSTopology,
                    mcfg: MiCSConfig, oc: OptConfig, step: int | None = None):
    """Resume a run on a different topology (pod loss / regrowth).

    Returns (model, state, step_fn, meta) resharded for `new_topo`.
    ``step=None`` restores the newest complete checkpoint; pass an explicit
    step to cold-restore the exact checkpoint an in-loop world change
    resumed from (the bitwise-equivalence reference of the kill-a-device
    test).  Pair with :func:`resize_for_world` to pick ``new_topo`` and the
    matching config the in-loop path would have chosen.
    """
    model = build_model(cfg, tp=new_topo.model_size)
    ckpt = Checkpointer(checkpoint_dir)
    state, meta = ckpt.restore(model, new_topo, step,
                               offload_opt=mcfg.offload_opt)
    step_fn = build_train_step(model, new_topo, mcfg, oc)
    return model, state, step_fn, meta
