"""Fault-tolerant training driver.

Production posture (DESIGN.md; scales the same way at 1000+ nodes):

* **Checkpoint/restart** — periodic async sharded checkpoints; on start the
  loop resumes from the newest complete checkpoint, including the data
  cursor (the synthetic pipeline is seekable, so no sample is replayed or
  skipped).
* **Failure handling** — any step raising a device/runtime error triggers
  rollback-and-retry from the last checkpoint; repeated failures of the same
  step re-raise (poison-step guard).  On real clusters the same hook is
  where a missing-heartbeat / SPMD barrier timeout lands.
* **Elastic scaling** — `elastic_restart` rebuilds topology + step function
  for a different mesh/partition size and reshards the checkpoint onto it
  (e.g. 512 -> 256 chips after losing a pod).
* **Straggler mitigation** — on TPU SPMD a straggler stalls the collective,
  so mitigation happens at the *input* layer: the loader prefetches ahead on
  a worker thread and the loop tracks a step-time EWMA, flagging steps
  slower than `straggler_factor` x the EWMA (the production hook would evict
  or re-route the slow host; here we surface the signal + count).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.mics import MiCSConfig, build_train_step, init_state
from repro.core.topology import MiCSTopology
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.build import build_model
from repro.models.lm import ModelDef
from repro.optim.adamw import OptConfig

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    log_every: int = 10
    straggler_factor: float = 3.0
    max_step_retries: int = 2
    seed: int = 0


@dataclasses.dataclass
class LoopStats:
    losses: list
    step_times: list
    straggler_steps: list
    restarts: int


def train(model: ModelDef, topo: MiCSTopology, mcfg: MiCSConfig,
          oc: OptConfig, dc: DataConfig, lc: LoopConfig,
          fault_injector: Callable[[int], None] | None = None) -> LoopStats:
    ckpt = Checkpointer(lc.checkpoint_dir)
    step_fn = build_train_step(model, topo, mcfg, oc)
    source = SyntheticLM(dc)

    start = ckpt.latest_step()
    if start is not None:
        state, meta = ckpt.restore(model, topo, offload_opt=mcfg.offload_opt)
        cursor = meta["data_cursor"]
        log.info("resumed from step %d", start)
    else:
        state = init_state(model, topo, seed=lc.seed,
                           offload_opt=mcfg.offload_opt)
        cursor = 0

    stats = LoopStats([], [], [], 0)
    ewma = None
    step = int(np.asarray(state["step"]))
    retries = 0
    while step < lc.total_steps:
        batch = jax.tree.map(
            jax.numpy.asarray, source.global_step_batch(cursor))
        t0 = time.time()
        try:
            if fault_injector is not None:
                fault_injector(step)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # blocks; surfaces device errors
        except Exception as e:  # noqa: BLE001 - failure domain boundary
            stats.restarts += 1
            retries += 1
            if retries > lc.max_step_retries:
                raise
            log.warning("step %d failed (%s); rolling back", step, e)
            prev = ckpt.latest_step()
            if prev is not None:
                state, meta = ckpt.restore(model, topo,
                                           offload_opt=mcfg.offload_opt)
                cursor = meta["data_cursor"]
                step = int(np.asarray(state["step"]))
            else:
                state = init_state(model, topo, seed=lc.seed,
                                   offload_opt=mcfg.offload_opt)
                cursor = 0
                step = 0
            continue
        retries = 0
        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > lc.straggler_factor * ewma and len(stats.step_times) > 3:
            stats.straggler_steps.append(step)
            log.warning("straggler: step %d took %.2fs (ewma %.2fs)",
                        step, dt, ewma)
        stats.losses.append(loss)
        stats.step_times.append(dt)
        cursor += 1
        step += 1
        if lc.log_every and step % lc.log_every == 0:
            log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
        if lc.checkpoint_every and step % lc.checkpoint_every == 0:
            ckpt.save(state, step, topo=topo, data_cursor=cursor,
                      blocking=False, host_stash=_stash_snapshot(mcfg))
    ckpt.wait()
    ckpt.save(state, step, topo=topo, data_cursor=cursor, blocking=True,
              host_stash=_stash_snapshot(mcfg))
    return stats


def _stash_snapshot(mcfg: MiCSConfig):
    """The offloaded-moment half of the state when ``offload_opt=True``."""
    if not mcfg.offload_opt:
        return None
    from repro.core.hostoffload import export_stash

    return export_stash()


def elastic_restart(checkpoint_dir: str, cfg, new_topo: MiCSTopology,
                    mcfg: MiCSConfig, oc: OptConfig):
    """Resume a run on a different topology (pod loss / regrowth).

    Returns (model, state, step_fn, meta) resharded for `new_topo`.
    """
    model = build_model(cfg, tp=new_topo.model_size)
    ckpt = Checkpointer(checkpoint_dir)
    state, meta = ckpt.restore(model, new_topo,
                               offload_opt=mcfg.offload_opt)
    step_fn = build_train_step(model, new_topo, mcfg, oc)
    return model, state, step_fn, meta
