"""Fault-tolerant serving driver: the world-change-aware serve loop.

The training loop survives preemption by checkpoint + rollback
(runtime/train_loop.py).  Serving has a cheaper durable state: the
*prompts*.  Because the paged engine samples per (seed, position)
(models/lm.sample_tokens) and paged attention is bitwise-invariant to
block-table layout and gather staging (tests/serve_harness.py), a request
replayed from its prompt on any surviving topology regenerates exactly the
completion it would have produced — so the loop's recovery story is simply
**re-mesh, rebuild, replay**:

1. a scripted :class:`repro.core.faults.FaultPlan` (or a real preemption
   signal) raises a typed fault at a scheduler tick;
2. on :class:`~repro.core.faults.WorldChangeError` the survivors are
   re-meshed and the serve policy grid re-ranked for the new link geometry
   under the same HBM budget — with numerics pinned —
   (``runtime/serving.resize_for_serve_world``), the paged step and pools
   are rebuilt, and every in-flight request is requeued from its prompt
   (``ContinuousBatcher.rebuild_world``) ahead of the waiting queue;
3. :class:`~repro.core.faults.StragglerError` is the "evict the slow
   host" decision: the world shrinks by one (rounded to a TP multiple)
   and the same rebuild runs;
4. :class:`~repro.core.faults.EngineCrashError` retries in place — same
   world, fresh pools, bounded by ``max_crash_retries``.

``notice`` on a preemption is advisory here: training uses it to take an
emergency checkpoint, but serving's checkpoint *is* the prompt queue, so
both paths replay identically and the ledger just records which kind
fired.

Overload control rides on the batcher (deadlines/TTL, bounded queue,
typed shedding, seeded backoff) and on an optional
:class:`~repro.runtime.batching.DegradationLadder`: each tick the queue
pressure feeds the ladder, and a level change tightens the per-rank
residency cap (priced by ``memplan.max_resident_requests``) or downshifts
the KV dtype — the latter rebuilds the engine in place and replays, the
one recovery path numerics are *allowed* to change on (that is the
degradation), restoring automatically when pressure clears.

The chaos harness (tests/serve_chaos_harness.py) proves the headline
guarantee on 8 virtual devices: kill half the mesh mid-decode and every
surviving request completes bitwise-identical to the fault-free run, with
the lifecycle ledger accounting for 100% of submissions.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.faults import (
    EngineCrashError, StragglerError, WorldChangeError,
)
from repro.core.mics import MiCSConfig, init_state
from repro.core.topology import MiCSTopology
from repro.runtime import paged as PG
from repro.runtime.batching import (
    ContinuousBatcher, DegradationLadder, Request, ShedError,
)
from repro.runtime.serving import resize_for_serve_world

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class ServeLoopConfig:
    """Engine geometry + robustness budgets for :class:`ResilientServeLoop`.

    The engine half mirrors ``benchmarks/serve_bench.py`` (``slots_local``
    resident slots and ``nb_local`` KV blocks per data rank, ``max_blocks``
    table width, ``chunk`` prefill tokens per tick).  The robustness half:
    ``max_world_changes``/``max_crash_retries`` bound the rebuild budget
    (a flapping cluster re-raises rather than thrashing), ``max_ticks`` is
    the deadlock guard, and the ``reserve``/``max_queue``/``evict_cap``/
    ``backoff_*``/``resident_cap`` knobs pass through to the batcher's
    overload control."""

    slots_local: int
    nb_local: int
    block_size: int
    max_blocks: int
    chunk: int = 8
    top_k: int = 0
    reserve: str = "full"
    max_queue: int = 0
    evict_cap: int = 4
    backoff_base: int = 0
    backoff_seed: int = 0
    resident_cap: int = 0
    max_world_changes: int = 8
    max_crash_retries: int = 2
    max_ticks: int = 100_000
    seed: int = 7              # default params provider: init_state(seed)
    arrival_rate: float = 0.0  # offered load the world re-rank prices


def _default_params(model, topo: MiCSTopology, seed: int):
    """Reload weights onto a (possibly new) topology.

    Serving weights are read-only, so production reloads them from the
    checkpoint/object store after a world change; the deterministic stand-in
    is a seeded re-init — ``init_state`` materializes identical global
    values on any mesh, which the chaos harness's bitwise contract
    implicitly verifies."""
    return init_state(model, topo, seed=seed)["params"]


class ResilientServeLoop:
    """Continuous-batching serve loop that survives faults and overload.

    ``fault_injector`` is called with every scheduler tick (a
    ``core/faults.FaultPlan`` fits directly); ``params_for(model, topo)``
    reloads weights after a rebuild (default: seeded re-init);
    ``ladder`` enables graceful degradation.
    """

    def __init__(self, model, topo: MiCSTopology, mcfg: MiCSConfig,
                 sc: ServeLoopConfig, *,
                 params_for: Callable | None = None,
                 fault_injector: Callable[[int], None] | None = None,
                 ladder: DegradationLadder | None = None):
        self.model = model
        self.topo = topo
        self.mcfg0 = mcfg          # numerics source for every re-rank
        self.mcfg = mcfg
        self.sc = sc
        self.tp = topo.model_size
        self.world = topo.world_size
        self.ctx_len = sc.max_blocks * sc.block_size
        self.fault = fault_injector
        self.ladder = ladder
        self.params_for = params_for or (
            lambda model, topo: _default_params(model, topo, sc.seed))
        self.kv_dtype = (ladder.current()["kv_dtype"] if ladder
                         else mcfg.kv_dtype)
        self.world_changes: list[dict] = []
        self.crash_retries = 0
        self.batcher = ContinuousBatcher(
            dp=topo.data_parallel_size, slots_local=sc.slots_local,
            nb_local=sc.nb_local, block_size=sc.block_size,
            max_blocks=sc.max_blocks, chunk=sc.chunk, reserve=sc.reserve,
            max_queue=sc.max_queue, evict_cap=sc.evict_cap,
            backoff_base=sc.backoff_base, backoff_seed=sc.backoff_seed,
            resident_cap=(ladder.current()["resident_cap"] if ladder
                          else sc.resident_cap))
        self._build_engine()

    # -- engine (re)construction ------------------------------------------

    def _build_engine(self) -> None:
        sc = self.sc
        self.step_chunk = PG.build_paged_step(
            self.model, self.topo, self.mcfg, max_blocks=sc.max_blocks,
            block_size=sc.block_size, chunk=sc.chunk,
            kv_dtype=self.kv_dtype, top_k=sc.top_k)
        self.step_one = PG.build_paged_step(
            self.model, self.topo, self.mcfg, max_blocks=sc.max_blocks,
            block_size=sc.block_size, chunk=1,
            kv_dtype=self.kv_dtype, top_k=sc.top_k)
        self.caches, _ = PG.init_paged_caches(
            self.model, self.topo, sc.nb_local, sc.block_size,
            self.kv_dtype)
        self.params = self.params_for(self.model, self.topo)

    def _rebuild(self, n_devices: int) -> dict:
        """Re-mesh + numerics-pinned re-rank + rebuild + replay."""
        self.topo, self.mcfg, info = resize_for_serve_world(
            self.model, self.mcfg0, n_devices, tp=self.tp,
            partition_size=self.topo.partition_size, seq=self.ctx_len,
            arrival_rate=self.sc.arrival_rate)
        if self.ladder is None:       # ladder levels own kv_dtype otherwise
            self.kv_dtype = self.mcfg.kv_dtype
        self._build_engine()
        replayed = self.batcher.rebuild_world(
            dp=self.topo.data_parallel_size)
        self.world = n_devices
        return dict(info, replayed=len(replayed))

    def _shrink_to_tp_multiple(self, n: int) -> int:
        n -= n % self.tp
        if n < self.tp:
            raise WorldChangeError(
                f"world of {n} devices cannot carry tp={self.tp}", lost=0)
        return n

    # -- fault handlers ----------------------------------------------------

    def _on_world_change(self, e: WorldChangeError, tick: int) -> None:
        if len(self.world_changes) >= self.sc.max_world_changes:
            log.error("world changed %d times; giving up",
                      len(self.world_changes))
            raise e
        new_world = self._shrink_to_tp_multiple(
            self.world - e.lost + e.gained)
        log.warning("world change at tick %d (%s): %d -> %d devices",
                    tick, e, self.world, new_world)
        info = self._rebuild(new_world)
        self.world_changes.append({
            "at_tick": int(tick),
            "kind": "grow" if e.gained else "preempt",
            "lost": e.lost, "gained": e.gained, "notice": e.notice,
            "world": new_world, **info})

    def _on_straggler(self, e: StragglerError, tick: int) -> None:
        if len(self.world_changes) >= self.sc.max_world_changes:
            raise e
        new_world = self._shrink_to_tp_multiple(self.world - 1)
        log.warning("straggler evicted at tick %d (%s): %d -> %d devices",
                    tick, e, self.world, new_world)
        info = self._rebuild(new_world)
        self.world_changes.append({
            "at_tick": int(tick), "kind": "straggler_evict",
            "lost": 1, "gained": 0, "notice": False,
            "world": new_world, **info})

    def _on_crash(self, e: EngineCrashError, tick: int) -> None:
        self.crash_retries += 1
        if self.crash_retries > self.sc.max_crash_retries:
            raise e
        log.warning("engine crash at tick %d (%s): retrying in place",
                    tick, e)
        # same world: fresh pools + params, replay in-flight from prompts
        self.caches, _ = PG.init_paged_caches(
            self.model, self.topo, self.sc.nb_local, self.sc.block_size,
            self.kv_dtype)
        self.params = self.params_for(self.model, self.topo)
        replayed = self.batcher.rebuild_world(
            dp=self.topo.data_parallel_size)
        self.world_changes.append({
            "at_tick": int(tick), "kind": "crash", "lost": 0, "gained": 0,
            "notice": False, "world": self.world,
            "replayed": len(replayed)})

    def _on_ladder(self, tick: int) -> None:
        if not self.ladder.update(tick, self.batcher.pressure()):
            return
        lv = self.ladder.current()
        self.batcher.resident_cap = lv["resident_cap"]
        if lv["kv_dtype"] != self.kv_dtype:
            # dtype downshift/restore: pools change layout, so this is a
            # same-world rebuild + replay (numerics change by design here)
            self.kv_dtype = lv["kv_dtype"]
            self._build_engine()
            self.batcher.rebuild_world(dp=self.topo.data_parallel_size)
        log.warning("degradation ladder -> level %d (%s) at tick %d",
                    self.ladder.level, lv.get("label", ""), tick)

    # -- the loop ----------------------------------------------------------

    def _engine_step(self, plan) -> np.ndarray:
        decode_only = int(plan.n_new.max()) <= 1
        step = self.step_one if decode_only else self.step_chunk
        tokens = plan.tokens[:, :1] if decode_only else plan.tokens
        tok, _logits, self.caches = step(
            self.params, self.caches,
            jnp.asarray(tokens), jnp.asarray(plan.pos),
            jnp.asarray(plan.n_new), jnp.asarray(plan.tables),
            jnp.asarray(plan.seeds), jnp.asarray(plan.temps))
        return np.asarray(tok)

    def run(self, requests: list[Request],
            arrival_ticks: list[int] | None = None) -> dict:
        """Serve ``requests`` to completion (or typed shed); return report.

        ``arrival_ticks[i]`` is the tick request ``i`` is offered at
        (default: all at tick 0).  The report carries the completions, the
        lifecycle ledger, the world-change ledger and the ladder
        transitions — everything the chaos harness and the launcher
        print."""
        if arrival_ticks is None:
            arrival_ticks = [0] * len(requests)
        pending = sorted(zip(arrival_ticks, requests),
                         key=lambda p: (p[0], p[1].rid))
        b = self.batcher
        while pending or not b.idle:
            if b.tick > self.sc.max_ticks:
                raise RuntimeError(
                    f"serve loop exceeded max_ticks={self.sc.max_ticks} "
                    f"(queue deadlock?)")
            tick = b.tick
            try:
                if self.fault is not None:
                    self.fault(tick)
            except WorldChangeError as e:
                self._on_world_change(e, tick)
                continue
            except StragglerError as e:
                self._on_straggler(e, tick)
                continue
            except EngineCrashError as e:
                self._on_crash(e, tick)
                continue
            while pending and pending[0][0] <= tick:
                _, req = pending.pop(0)
                req.arrival = tick
                try:
                    b.submit(req)
                except ShedError:
                    pass    # typed + already in the batcher's shed ledger
            plan = b.plan_step()
            if plan.active_rows == 0:
                b.commit(plan, np.zeros(b.batch, np.int64))
            else:
                b.commit(plan, self._engine_step(plan))
            if self.ladder is not None:
                self._on_ladder(tick)
        return self.report()

    def report(self) -> dict:
        return {
            "completions": {r.rid: list(r.generated) for r in
                            self.batcher.finished},
            "shed": {r.rid: r.shed_reason for r in
                     self.batcher.shed_requests},
            "ledger": self.batcher.ledger(),
            "world_changes": list(self.world_changes),
            "ladder_transitions": (list(self.ladder.transitions)
                                   if self.ladder else []),
            "ladder_max_level": (self.ladder.max_level_seen
                                 if self.ladder else 0),
            "ladder_level": self.ladder.level if self.ladder else 0,
            "crash_retries": self.crash_retries,
            "world": self.world,
            "kv_dtype": self.kv_dtype,
            "ticks": self.batcher.tick,
        }


def serve_resilient(model, topo, mcfg, sc: ServeLoopConfig,
                    requests: list[Request],
                    arrival_ticks: list[int] | None = None, **kw) -> dict:
    """One-shot convenience wrapper around :class:`ResilientServeLoop`."""
    return ResilientServeLoop(model, topo, mcfg, sc, **kw).run(
        requests, arrival_ticks)
