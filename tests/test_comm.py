"""CommEngine tests: policy construction / config mapping units on one
device, plus the 8-virtual-device correctness harness (tests/comm_harness.py)
covering gather-policy equivalence, exact VJP adjoints, int8 wire gathers,
and the double-buffered prefetch schedule (bitwise loss equality + HLO
census evidence of one-layer-ahead gathers)."""

import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from harness_util import run_harness
from repro.core.comm import (
    GATHER_TOPOLOGIES, CommEngine, GatherPolicy, SyncPolicy,
)
from repro.core.mics import MiCSConfig

HARNESS = pathlib.Path(__file__).parent / "comm_harness.py"


# ---------------------------------------------------------------------------
# policy construction units (single device)
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        GatherPolicy(topology="ring")
    with pytest.raises(ValueError):
        GatherPolicy(wire_dtype="fp8")
    with pytest.raises(ValueError):
        SyncPolicy(mode="3hop")
    with pytest.raises(ValueError):
        SyncPolicy(hop2_wire_dtype="fp8")
    # int8 hop-2 is the qgZ decompress leg, a legal wire since ISSUE 4
    assert SyncPolicy(hop2_wire_dtype="int8").hop2_wire_dtype == "int8"


@pytest.mark.parametrize("mcfg,topology,wire,mode,hop2_wire", [
    (MiCSConfig(), "inner_first", "bf16", "2hop", "fp32"),
    (MiCSConfig(hierarchical=False), "flat", "bf16", "2hop", "fp32"),
    (MiCSConfig(gather_order="outer_first"), "outer_first", "bf16",
     "2hop", "fp32"),
    (MiCSConfig(gather_dtype=jnp.float32), "inner_first", "fp32",
     "2hop", "fp32"),
    (MiCSConfig(quant_gather=True), "inner_first", "int8", "2hop", "fp32"),
    (MiCSConfig(sync_mode="allreduce_slice", compress_hop2=True),
     "inner_first", "bf16", "allreduce_slice", "bf16"),
])
def test_from_config_mapping(topo1, mcfg, topology, wire, mode, hop2_wire):
    eng = CommEngine.from_config(topo1, mcfg)
    assert eng.gather_policy.topology == topology
    assert eng.gather_policy.wire_dtype == wire
    assert eng.sync_policy.mode == mode
    assert eng.sync_policy.hop2_wire_dtype == hop2_wire
    assert eng.prefetch == mcfg.prefetch


def test_describe_is_json_serializable(topo1):
    for pol in GATHER_TOPOLOGIES:
        eng = CommEngine(topo1, GatherPolicy(topology=pol))
        json.dumps(eng.describe())


def test_gather_identity_at_p1(topo1):
    """partition_size == 1: the gather is a pure dtype cast, hop-1 a no-op."""
    eng = CommEngine.from_config(topo1, MiCSConfig())
    row = jnp.arange(8.0, dtype=jnp.float32)
    full = eng.gather_flat(row)
    assert full.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(full, np.float32),
                                  np.asarray(row))
    np.testing.assert_array_equal(np.asarray(eng.hop1_reduce_scatter(row)),
                                  np.asarray(row))
    np.testing.assert_array_equal(np.asarray(eng.hop2(row)), np.asarray(row))


def test_stored_int8_dict_gather(topo1):
    from repro.core.quant import quantize_flat

    eng = CommEngine.from_config(topo1, MiCSConfig())
    row = jnp.asarray(np.random.default_rng(0).normal(size=(512,)) * 0.05,
                      jnp.float32)
    q, s = quantize_flat(row)
    full = eng.gather_flat({"q": q, "s": s})
    assert full.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(row), atol=2e-3)


# ---------------------------------------------------------------------------
# multi-device harness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def harness_results():
    return run_harness(HARNESS)


CHECKS = [
    "policy_equiv", "vjp_matches_rs", "int8_wire_gather",
    "prefetch_bitwise", "prefetch_decode", "prefetch_census",
]


@pytest.mark.parametrize("name", CHECKS)
def test_comm_check(harness_results, name):
    res = harness_results.get(name)
    assert res is not None, f"harness did not run {name}"
    assert res["ok"], f"{name}: {res.get('err')}\n{res.get('tb', '')}"


def test_prefetch_census_counts(harness_results):
    detail = harness_results.get("prefetch_census_detail")
    assert detail is not None
    assert detail["serial"]["carried_all_gathers"] == 0
    assert detail["prefetch"]["carried_all_gathers"] >= 1
