"""Int8 block-quantized serving-weight gathers (§Perf B3): roundtrip error
bound and end-to-end decode consistency against fp32 weights — plus the
qgZ-supporting primitives: ragged tails (arbitrary bucket/chunk lengths)
and stochastic-rounding unbiasedness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.mics import MiCSConfig, init_state
from repro.core.quant import (
    BLOCK, dequantize_flat, n_blocks, quantize_flat, quantize_state,
)
from repro.models.build import build_model
from repro.runtime.serving import build_serve_steps

RNG = np.random.default_rng(17)


@pytest.mark.parametrize("shape", [(4096,), (3, 1, 4096), (2, 131072)])
def test_quant_roundtrip_error_bound(shape):
    x = jnp.asarray(RNG.normal(size=shape) * 0.05, jnp.float32)
    q, s = quantize_flat(x)
    back = dequantize_flat(q, s, dtype=jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x))
    # absmax int8: error <= scale/2 = absmax/254 per block
    blocks = np.asarray(x).reshape(-1, BLOCK)
    bound = np.abs(blocks).max(-1) / 254 + 1e-8
    assert np.all(err.reshape(-1, BLOCK) <= bound[:, None] * 1.01)


def test_quant_zeros_exact():
    x = jnp.zeros((2, BLOCK * 4), jnp.float32)
    q, s = quantize_flat(x)
    np.testing.assert_array_equal(np.asarray(dequantize_flat(q, s)), 0)


# ---------------------------------------------------------------------------
# ragged tails (qgZ bucket/chunk lengths need not divide BLOCK)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length", [1, 100, BLOCK - 1, BLOCK + 1,
                                    3 * BLOCK + 17, 1000])
def test_quant_ragged_roundtrip(length):
    x = jnp.asarray(RNG.normal(size=(length,)) * 0.1, jnp.float32)
    q, s = quantize_flat(x)
    assert q.shape == (length,)
    assert s.shape == (n_blocks(length),) == (-(-length // BLOCK),)
    back = np.asarray(dequantize_flat(q, s, dtype=jnp.float32))
    # per-block bound, short final block included (its own absmax)
    for b in range(n_blocks(length)):
        lo, hi = b * BLOCK, min((b + 1) * BLOCK, length)
        blk = np.asarray(x)[lo:hi]
        bound = np.abs(blk).max() / 254 + 1e-8
        assert np.all(np.abs(back[lo:hi] - blk) <= bound * 1.01), (b, lo, hi)


def test_quant_ragged_matches_aligned_prefix():
    """The short final block must not perturb earlier (aligned) blocks."""
    x = jnp.asarray(RNG.normal(size=(2 * BLOCK,)) * 0.05, jnp.float32)
    q_full, s_full = quantize_flat(x)
    q_rag, s_rag = quantize_flat(x[: BLOCK + 7])
    np.testing.assert_array_equal(np.asarray(q_full[:BLOCK]),
                                  np.asarray(q_rag[:BLOCK]))
    np.testing.assert_array_equal(np.asarray(s_full[:1]),
                                  np.asarray(s_rag[:1]))


def test_quant_ragged_leading_dims():
    x = jnp.asarray(RNG.normal(size=(3, 2, 200)) * 0.05, jnp.float32)
    q, s = quantize_flat(x)
    assert q.shape == (3, 2, 200) and s.shape == (3, 2, 2)
    back = dequantize_flat(q, s, dtype=jnp.float32)
    assert np.abs(np.asarray(back) - np.asarray(x)).max() < 0.05


# ---------------------------------------------------------------------------
# stochastic rounding (the qgZ gradient-wire mode)
# ---------------------------------------------------------------------------

def test_stochastic_rounding_unbiased():
    """Mean over keys of dequant(quant(x, key)) converges to x — well below
    one deterministic-rounding step of systematic error."""
    x = jnp.asarray(RNG.normal(size=(256,)) * 0.02, jnp.float32)
    keys = jax.random.split(jax.random.key(3), 4000)

    def trial(k):
        q, s = quantize_flat(x, key=k)
        return dequantize_flat(q, s, dtype=jnp.float32)

    mean = np.asarray(jnp.mean(jax.vmap(trial)(keys), axis=0))
    _, s0 = quantize_flat(x)
    step = float(np.asarray(s0).max())          # one quantization step
    bias = np.abs(mean - np.asarray(x)).max()
    # nearest rounding has bias up to step/2; the stochastic mean must sit
    # an order of magnitude closer to the true value
    assert bias < 0.05 * step, (bias, step)


def test_stochastic_rounding_error_bound():
    """A single stochastic draw errs by at most one full step per element
    (vs half a step for nearest) and stays inside the int8 range."""
    x = jnp.asarray(RNG.normal(size=(4 * BLOCK,)) * 0.1, jnp.float32)
    q, s = quantize_flat(x, key=jax.random.key(11))
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127
    back = np.asarray(dequantize_flat(q, s, dtype=jnp.float32))
    step = np.repeat(np.asarray(s), BLOCK)
    assert np.all(np.abs(back - np.asarray(x)) <= step * 1.01)


def test_stochastic_rounding_exact_on_grid():
    """Values already on the quantization grid are reproduced exactly for
    every key (floor(v + u) == v for integer v, u < 1)."""
    ints = jnp.asarray(RNG.integers(-127, 128, size=(BLOCK,)), jnp.float32)
    ints = ints.at[0].set(127.0)                # pin absmax -> scale == 1
    for seed in (0, 1, 2):
        q, s = quantize_flat(ints, key=jax.random.key(seed))
        np.testing.assert_array_equal(
            np.asarray(dequantize_flat(q, s, dtype=jnp.float32)),
            np.asarray(ints))


def test_quantized_decode_matches_fp32(topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, seed=8)
    params = state["params"]
    qparams = quantize_state(params)

    pre_f, dec_f = build_serve_steps(model, topo1, MiCSConfig(), cache_len=24)
    pre_q, dec_q = build_serve_steps(
        model, topo1, MiCSConfig(quant_gather=True), cache_len=24)

    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    logits_f, caches_f = pre_f(params, {"tokens": toks})
    logits_q, caches_q = pre_q(qparams, {"tokens": toks})
    lf = np.asarray(logits_f, np.float32)
    lq = np.asarray(logits_q, np.float32)
    # int8 weights perturb logits slightly; ranking must agree at the top
    assert np.abs(lf - lq).max() < 0.6
    assert (np.argmax(lf, -1) == np.argmax(lq, -1)).mean() > 0.9

    tok = jnp.argmax(logits_f[:, -1:], axis=-1).astype(jnp.int32)
    lgf, _, caches_f = dec_f(params, caches_f, tok, jnp.int32(16))
    lgq, _, caches_q = dec_q(qparams, caches_q, tok, jnp.int32(16))
    assert np.abs(np.asarray(lgf, np.float32)
                  - np.asarray(lgq, np.float32)).max() < 0.6
