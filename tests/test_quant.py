"""Int8 block-quantized serving-weight gathers (§Perf B3): roundtrip error
bound and end-to-end decode consistency against fp32 weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.mics import MiCSConfig, init_state
from repro.core.quant import BLOCK, dequantize_flat, quantize_flat, quantize_state
from repro.models.build import build_model
from repro.runtime.serving import build_serve_steps

RNG = np.random.default_rng(17)


@pytest.mark.parametrize("shape", [(4096,), (3, 1, 4096), (2, 131072)])
def test_quant_roundtrip_error_bound(shape):
    x = jnp.asarray(RNG.normal(size=shape) * 0.05, jnp.float32)
    q, s = quantize_flat(x)
    back = dequantize_flat(q, s, dtype=jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x))
    # absmax int8: error <= scale/2 = absmax/254 per block
    blocks = np.asarray(x).reshape(-1, BLOCK)
    bound = np.abs(blocks).max(-1) / 254 + 1e-8
    assert np.all(err.reshape(-1, BLOCK) <= bound[:, None] * 1.01)


def test_quant_zeros_exact():
    x = jnp.zeros((2, BLOCK * 4), jnp.float32)
    q, s = quantize_flat(x)
    np.testing.assert_array_equal(np.asarray(dequantize_flat(q, s)), 0)


def test_quantized_decode_matches_fp32(topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, seed=8)
    params = state["params"]
    qparams = quantize_state(params)

    pre_f, dec_f = build_serve_steps(model, topo1, MiCSConfig(), cache_len=24)
    pre_q, dec_q = build_serve_steps(
        model, topo1, MiCSConfig(quant_gather=True), cache_len=24)

    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    logits_f, caches_f = pre_f(params, {"tokens": toks})
    logits_q, caches_q = pre_q(qparams, {"tokens": toks})
    lf = np.asarray(logits_f, np.float32)
    lq = np.asarray(logits_q, np.float32)
    # int8 weights perturb logits slightly; ranking must agree at the top
    assert np.abs(lf - lq).max() < 0.6
    assert (np.argmax(lf, -1) == np.argmax(lq, -1)).mean() > 0.9

    tok = jnp.argmax(logits_f[:, -1:], axis=-1).astype(jnp.int32)
    lgf, _, caches_f = dec_f(params, caches_f, tok, jnp.int32(16))
    lgq, _, caches_q = dec_q(qparams, caches_q, tok, jnp.int32(16))
    assert np.abs(np.asarray(lgf, np.float32)
                  - np.asarray(lgq, np.float32)).max() < 0.6
