"""CommEngine correctness harness, run in a subprocess with 8 virtual CPU
devices (same pattern as dist_harness.py).  Prints one JSON object with named
check results; tests/test_comm.py asserts on them.  Checks:

  policy_equiv       flat / inner_first / outer_first gather policies produce
                     bitwise-identical full buffers, on single- and
                     multi-axis partition groups
  vjp_matches_rs     every policy's VJP equals the explicit
                     hop1_reduce_scatter of the upstream cotangent
  int8_wire_gather   ZeRO++-style int8 wire gathers stay within the blockwise
                     quantization error bound and still train (grads flow
                     through the straight-through adjoint)
  prefetch_bitwise   double-buffered prefetch training losses are *bitwise*
                     equal to the serial schedule's
  prefetch_decode    prefill+decode logits bitwise equal across schedules
  prefetch_census    compiled HLO of the prefetch schedule shows all-gathers
                     carried into the layer-scan loop carry (issued one layer
                     ahead); the serial schedule shows none
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs import get_config, smoke_variant
from repro.core import collectives as C
from repro.core.comm import CommEngine, GatherPolicy, SyncPolicy
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

RESULTS = {}

POLICIES = ("flat", "inner_first", "outer_first")


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {
                "ok": False,
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc()[-2000:],
            }
        return fn
    return deco


def _topos():
    """(label, topo, in_spec) for single- and multi-axis partition groups."""
    single = MiCSTopology(make_host_mesh(1, 2, 4, 1),
                          partition_axes=("shard",),
                          replication_axes=("pod", "repl"))
    multi = MiCSTopology(make_host_mesh(2, 1, 4, 1),
                         partition_axes=("pod", "shard"),
                         replication_axes=("repl",))
    return [("single", single, P("shard", None)),
            ("multi", multi, P(("pod", "shard"), None))]


def _engine(topo, policy, **kw):
    gp = GatherPolicy(topology=policy, wire_dtype=kw.pop("wire", "fp32"),
                      prefetch=kw.pop("prefetch", False),
                      inner=kw.pop("inner", None))
    return CommEngine(topo, gp, SyncPolicy(**kw))


# ---------------------------------------------------------------------------
@check("policy_equiv")
def _policy_equiv():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(32, 4)), jnp.float32)
    for label, topo, in_spec in _topos():
        mesh = topo.mesh

        def run(engine):
            return shard_map(engine.gather_flat, mesh=mesh, in_specs=in_spec,
                             out_specs=P(None, None), check_vma=False)(x)

        ref = run(_engine(topo, "flat"))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(x),
                                      err_msg=f"{label} flat != input")
        for pol in POLICIES[1:]:
            got = run(_engine(topo, pol))
            assert np.array_equal(np.asarray(got), np.asarray(ref)), \
                f"{label}/{pol}: staged gather != flat gather"
        # explicit inner factor on the single-axis group
        if label == "single":
            for pol in POLICIES[1:]:
                got = run(_engine(topo, pol, inner=2))
                assert np.array_equal(np.asarray(got), np.asarray(ref)), \
                    f"{label}/{pol}/inner=2"


# ---------------------------------------------------------------------------
@check("vjp_matches_rs")
def _vjp_matches_rs():
    """Each policy's VJP == the explicit hop-1 reduce-scatter, compared
    inside one shard_map body so no ambient cotangent scaling interferes."""
    rng = np.random.default_rng(1)
    for label, topo, in_spec in _topos():
        mesh = topo.mesh
        x = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
        # ct varies per device so the reduction is non-trivial
        ct = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)

        for pol in POLICIES:
            eng = _engine(topo, pol)

            def body(xs, cs):
                _, vjp = jax.vjp(eng.gather_flat, xs)
                (got,) = vjp(cs)
                want = C.hop1_reduce_scatter(cs, topo)  # flat reference
                want_policy = eng.hop1_reduce_scatter(cs)
                return got, want, want_policy

            got, want, want_policy = shard_map(
                body, mesh=mesh, in_specs=(in_spec, P(None, None)),
                out_specs=(in_spec, in_spec, in_spec), check_vma=False)(x, ct)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6,
                err_msg=f"{label}/{pol}: VJP != flat hop1_reduce_scatter")
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want_policy),
                err_msg=f"{label}/{pol}: VJP != staged hop1_reduce_scatter")


# ---------------------------------------------------------------------------
@check("int8_wire_gather")
def _int8_wire():
    from repro.core.quant import BLOCK

    topo = MiCSTopology(make_host_mesh(1, 1, 4, 1))
    mesh = topo.mesh
    n = 4 * BLOCK * 2
    x = jnp.asarray(np.random.default_rng(2).normal(size=(n,)) * 0.05,
                    jnp.float32)
    eng = _engine(topo, "inner_first", wire="int8")
    got = shard_map(eng.gather_flat, mesh=mesh, in_specs=P(("shard",)),
                    out_specs=P(None), check_vma=False)(x)
    err = np.abs(np.asarray(got, np.float32) - np.asarray(x))
    blocks = np.asarray(x).reshape(-1, BLOCK)
    bound = np.abs(blocks).max(-1) / 254 + 1e-8
    # bf16 dequant output adds ~2^-8 relative rounding on top of int8 error
    assert np.all(err.reshape(-1, BLOCK) <= bound[:, None] * 1.05 + 2e-3), \
        err.max()

    # straight-through adjoint: grads flow and match the exact reduce-scatter
    ct = jnp.asarray(np.random.default_rng(3).normal(size=(n,)), jnp.float32)

    def body(xs, cs):
        _, vjp = jax.vjp(lambda v: eng.gather_flat(v).astype(jnp.float32), xs)
        (got,) = vjp(cs)
        want = C.hop1_reduce_scatter(cs, topo)
        return got, want

    got, want = shard_map(body, mesh=mesh, in_specs=(P(("shard",)), P(None)),
                          out_specs=(P(("shard",)), P(("shard",))),
                          check_vma=False)(x, ct)
    # the upstream cotangent passes through the bf16 compute-dtype cast
    # before the (fp32) reduce-scatter, so compare at bf16 resolution
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
def _train_losses(mcfg, steps=3, seed=0):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 4, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    state = init_state(model, topo, seed=seed)
    step = build_train_step(
        model, topo, mcfg,
        OptConfig(total_steps=50, warmup_steps=0, lr_max=3e-3))
    rng = np.random.default_rng(7)
    s, b, t = 2, 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (s, b, t)), jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (s, b, t)), jnp.int32),
        "mask": jnp.ones((s, b, t), jnp.float32),
    }
    losses = []
    for _ in range(steps):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return losses


@check("prefetch_bitwise")
def _prefetch_bitwise():
    serial = _train_losses(MiCSConfig(micro_steps=2, prefetch=False))
    pre = _train_losses(MiCSConfig(micro_steps=2, prefetch=True))
    assert all(np.isfinite(serial)) and all(np.isfinite(pre))
    assert serial == pre, f"prefetch diverged: {serial} vs {pre}"
    # and with the paper-faithful 3-stage gather order
    serial3 = _train_losses(
        MiCSConfig(micro_steps=2, prefetch=False, gather_order="outer_first"))
    pre3 = _train_losses(
        MiCSConfig(micro_steps=2, prefetch=True, gather_order="outer_first"))
    assert serial3 == pre3, f"outer_first prefetch diverged: {serial3} vs {pre3}"


# ---------------------------------------------------------------------------
@check("prefetch_decode")
def _prefetch_decode():
    from repro.runtime.serving import build_serve_steps

    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    state = init_state(model, topo, seed=3)
    params = state["params"]
    rng = np.random.default_rng(11)
    toks = jnp.array(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    outs = {}
    for label, prefetch in (("serial", False), ("prefetch", True)):
        pre_fn, dec_fn = build_serve_steps(
            model, topo, MiCSConfig(prefetch=prefetch), cache_len=32)
        logits, caches = pre_fn(params, {"tokens": toks})
        tok = jnp.argmax(jnp.asarray(logits[:, -1:]), -1).astype(jnp.int32)
        lg2, tok2, _ = dec_fn(params, caches, tok, jnp.int32(16))
        outs[label] = (np.asarray(logits, np.float32),
                       np.asarray(lg2, np.float32), np.asarray(tok2))
    assert np.array_equal(outs["serial"][0], outs["prefetch"][0]), "prefill"
    assert np.array_equal(outs["serial"][1], outs["prefetch"][1]), "decode"
    assert np.array_equal(outs["serial"][2], outs["prefetch"][2]), "token"


# ---------------------------------------------------------------------------
@check("prefetch_census")
def _prefetch_census():
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 4, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    counts = {}
    for label, prefetch in (("serial", False), ("prefetch", True)):
        step = build_train_step(
            model, topo, MiCSConfig(micro_steps=2, prefetch=prefetch),
            OptConfig(total_steps=10))
        lowered = step.lower(init_state_shapes(model),
                             make_batch_shapes(model, 8, 32, 2))
        stats = analyze(lowered.compile().as_text(), mesh_shape,
                        partition_axes=topo.partition_axes,
                        replication_axes=topo.replication_axes)
        counts[label] = stats["prefetch"]
        # stage attribution sees the staged hop-1 gathers
        stages = stats["by_stage"]
        assert any(k.startswith("param_gather") for k in stages), stages
    assert counts["serial"]["carried_all_gathers"] == 0, counts
    assert counts["prefetch"]["carried_all_gathers"] > 0, counts
    RESULTS["prefetch_census_detail"] = counts


print(json.dumps(RESULTS, indent=1, default=str))
