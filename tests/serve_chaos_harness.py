"""Serving chaos harness: kill devices mid-decode, prove bitwise replay.

Runs in a subprocess with 8 virtual CPU devices (same pattern as
elastic_harness.py).  Prints one JSON object with named check results;
tests/test_batching_faults.py asserts on them, and ``--check`` mode is the
CI chaos smoke gate (artifact BENCH_serve_chaos_smoke.json).

The system under test is :class:`repro.runtime.resilient.ResilientServeLoop`
— the world-change-aware serve loop.  Serving's durable state is the
prompt queue: because sampling is keyed per (seed, position) and paged
attention is bitwise-invariant to block-table layout and gather staging
(tests/serve_harness.py), a request replayed from its prompt on ANY
surviving topology regenerates exactly its fault-free completion.  Every
fault check below therefore asserts the strongest possible property:
the faulted run's completions are BITWISE identical to the fault-free
baseline's, not merely "recovered".

Checks:

  preempt_replay_bitwise  8 devices (dp=4, tp=2) lose half the mesh
                          abruptly mid-decode (no notice).  The loop
                          re-meshes the 4 survivors, re-ranks the serve
                          policy grid with numerics pinned, rebuilds the
                          paged engine and replays all in-flight requests
                          from their prompts — completions bitwise equal
                          to the fault-free run, ledger accounts 100% of
                          submissions, replay counters populated.
  grow_back_readmission   start on 4 devices, the preempted capacity
                          returns mid-run (grow 4 -> 8): resident
                          requests replay onto the larger world and the
                          completions still match the 8-device fault-free
                          baseline (the topology-invariance contract).
  straggler_evict         a straggling host is evicted (8 -> 7, rounded
                          down to 6 = a tp multiple).  resolve_world's
                          keep rule re-picks the partition group (2 does
                          not divide the new extent 3, so p drops to 1)
                          — the §3.1 decision exercised by serving —
                          and completions stay bitwise.
  crash_retry             the engine dies with the world intact: the loop
                          retries in place (fresh pools, same mesh, replay
                          from prompts), bounded by max_crash_retries;
                          bitwise completions, crash ledgered.
  shed_under_burst        overload: a tick-0 burst over a bounded queue
                          with tight deadlines, seeded backoff and the
                          degradation ladder.  Some requests complete,
                          some shed with TYPED reasons (queue_full /
                          deadline_unreachable); the ladder engages under
                          pressure and restores when it clears; the
                          lifecycle ledger accounts every submission; and
                          the whole overload trajectory is deterministic
                          — a second identical run sheds the same rids
                          for the same reasons and completes the same
                          tokens.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import sys

import numpy as np

from repro.bench import measure as MS
from repro.configs import get_config, smoke_variant
from repro.core.faults import FaultPlan
from repro.core.mics import MiCSConfig
from repro.core.topology import elastic_host_topology
from repro.models.build import build_model
from repro.runtime.batching import DegradationLadder, Request
from repro.runtime.resilient import ResilientServeLoop, ServeLoopConfig

RESULTS = {}
CTX = {}

BLOCK_SIZE = 8
MAX_BLOCKS = 4
CHUNK = 8
SLOTS_LOCAL = 4
NB_LOCAL = 17          # +1 for the garbage block 0
N_REQUESTS = 8

CFG = smoke_variant(get_config("llama3.2-1b"))
TP = 2
MODEL = build_model(CFG, tp=TP)
MCFG = MiCSConfig(kv_dtype="bf16", kv_block_size=BLOCK_SIZE)
SC = ServeLoopConfig(slots_local=SLOTS_LOCAL, nb_local=NB_LOCAL,
                     block_size=BLOCK_SIZE, max_blocks=MAX_BLOCKS,
                     chunk=CHUNK, top_k=8, reserve="full", seed=7)


check = MS.make_check(RESULTS)


def make_trace(n: int) -> list[Request]:
    """Seeded chat-shaped trace; every run builds a FRESH copy (requests
    carry mutable scheduling state)."""
    rng = np.random.default_rng(42)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 9))
        max_new = int(rng.integers(10, 25))
        reqs.append(Request(
            rid=i, prompt=rng.integers(1, CFG.vocab, plen).astype(int).tolist(),
            max_new_tokens=max_new, temperature=0.7, seed=1000 + i))
    return reqs


def run_loop(n_devices: int, *, fault=None, ladder=None, sc=SC,
             reqs=None, arrivals=None):
    topo = elastic_host_topology(n_devices, 2, tp=TP)
    loop = ResilientServeLoop(MODEL, topo, MCFG, sc,
                              fault_injector=fault, ladder=ladder)
    return loop.run(reqs if reqs is not None else make_trace(N_REQUESTS),
                    arrivals)


def assert_bitwise(report, baseline, what):
    assert set(report["completions"]) == set(baseline["completions"]), \
        (what, sorted(report["completions"]), sorted(baseline["completions"]))
    for rid, toks in baseline["completions"].items():
        assert report["completions"][rid] == toks, \
            f"{what}: rid {rid} diverged from the fault-free run"


def assert_accounted(report, what):
    led = report["ledger"]
    assert led["accounted"], (what, led)
    assert led["in_flight"] == 0, (what, led)


# ---------------------------------------------------------------------------
# fault-free baseline on the full 8-device mesh: the bitwise reference
BASE = run_loop(8)
assert BASE["ledger"]["completed"] == N_REQUESTS, BASE["ledger"]


@check("preempt_replay_bitwise")
def _preempt():
    plan = FaultPlan().preempt(6, devices=4, notice=False)
    rep = run_loop(8, fault=plan)
    CTX["preempt"] = rep
    assert len(rep["world_changes"]) == 1, rep["world_changes"]
    wc = rep["world_changes"][0]
    assert wc["kind"] == "preempt" and wc["lost"] == 4 and not wc["notice"]
    assert wc["at_tick"] == 6 and wc["world"] == 4, wc
    assert wc["replayed"] > 0, "nothing was in flight at the kill tick"
    # the re-rank ledger is present and numerics stayed pinned
    assert wc["serve_rerank"]["kv_dtype"] == MCFG.kv_dtype, wc
    assert rep["ledger"]["replays"] == wc["replayed"], rep["ledger"]
    assert_bitwise(rep, BASE, "preempt 8->4")
    assert_accounted(rep, "preempt 8->4")
    RESULTS["preempt_detail"] = {
        "ledger": wc, "ticks": rep["ticks"], "bitwise": True}


@check("grow_back_readmission")
def _grow_back():
    base4 = run_loop(4)                      # fault-free on the small world
    assert_bitwise(base4, BASE, "4-device fault-free (topology invariance)")
    plan = FaultPlan().grow(5, devices=4)
    rep = run_loop(4, fault=plan)
    wc = rep["world_changes"][0]
    assert wc["kind"] == "grow" and wc["gained"] == 4 and wc["world"] == 8, wc
    assert wc["replayed"] > 0, wc
    assert_bitwise(rep, BASE, "grow 4->8")
    assert_accounted(rep, "grow 4->8")
    RESULTS["grow_detail"] = {"ledger": wc, "ticks": rep["ticks"],
                              "bitwise": True}


@check("straggler_evict")
def _straggler():
    plan = FaultPlan(slow_base_s=0.01).slow(4, factor=3, evict=True)
    rep = run_loop(8, fault=plan)
    wc = rep["world_changes"][0]
    assert wc["kind"] == "straggler_evict", wc
    assert wc["world"] == 6, wc              # 8 - 1 rounded down to tp=2
    # extent 3 is not divisible by the old p=2: the keep rule re-picks p=1
    assert wc["partition_size"] == 1, wc
    assert_bitwise(rep, BASE, "straggler 8->6")
    assert_accounted(rep, "straggler 8->6")
    RESULTS["straggler_detail"] = {"ledger": wc, "fired": plan.log,
                                   "bitwise": True}


@check("crash_retry")
def _crash():
    plan = FaultPlan().crash(7)
    rep = run_loop(8, fault=plan)
    assert rep["crash_retries"] == 1, rep["crash_retries"]
    wc = rep["world_changes"][0]
    assert wc["kind"] == "crash" and wc["world"] == 8, wc
    assert wc["replayed"] > 0, wc
    assert_bitwise(rep, BASE, "crash retry")
    assert_accounted(rep, "crash retry")
    RESULTS["crash_detail"] = {"ledger": wc, "bitwise": True}


# ---------------------------------------------------------------------------
def _burst_once():
    """One overloaded run: 16 requests at tick 0 over a bounded queue with
    tight deadlines, backoff and a residency-tightening ladder level.

    Geometry: dp=2 x slots_local=2 = 4 resident rows against a 12-deep
    queue, so the tick-0 burst leaves ~8 waiting (pressure 0.67 > the 0.6
    high water) — the ladder engages after its dwell, tightens residency
    to 1/rank, and restores once the queue drains below 0.2."""
    sc = ServeLoopConfig(
        slots_local=2, nb_local=NB_LOCAL, block_size=BLOCK_SIZE,
        max_blocks=MAX_BLOCKS, chunk=CHUNK, top_k=8, reserve="full",
        max_queue=12, evict_cap=2, backoff_base=2, backoff_seed=11, seed=7)
    ladder = DegradationLadder(
        [{"kv_dtype": MCFG.kv_dtype, "resident_cap": 0,
          "label": "configured"},
         {"kv_dtype": MCFG.kv_dtype, "resident_cap": 1,
          "label": "tightened"}],
        high_water=0.6, low_water=0.2, dwell=2)
    reqs = make_trace(16)
    for r in reqs[2:5]:
        r.deadline_tick = 4                  # unreachable: typed shed
    for r in reqs[8:12]:
        r.deadline_tick = 200                # generous: must complete
    return run_loop(4, ladder=ladder, sc=sc, reqs=reqs,
                    arrivals=[0] * len(reqs))


@check("shed_under_burst")
def _burst():
    rep = _burst_once()
    led = rep["ledger"]
    assert_accounted(rep, "burst")
    assert led["shed"] > 0 and led["completed"] > 0, led
    by = led["shed_by_reason"]
    assert by.get("queue_full", 0) > 0, by          # bounded-queue rejection
    assert by.get("deadline_unreachable", 0) > 0, by  # typed deadline shed
    # every shed is typed — no silent drops
    assert sum(by.values()) == led["shed"], (by, led["shed"])
    # the ladder engaged under pressure and restored when it cleared
    assert rep["ladder_max_level"] >= 1, rep["ladder_transitions"]
    assert rep["ladder_level"] == 0, rep["ladder_transitions"]
    # completed requests decoded their full budget (no silent truncation)
    done = {r: len(t) for r, t in rep["completions"].items()}
    assert all(n > 0 for n in done.values()), done
    # the generous-deadline cohort rode out the overload and completed
    assert all(r in rep["completions"] for r in range(8, 12)), sorted(done)

    # determinism: an identical second run sheds the same rids for the
    # same reasons and completes the same tokens
    rep2 = _burst_once()
    assert rep["shed"] == rep2["shed"], (rep["shed"], rep2["shed"])
    assert rep["completions"] == rep2["completions"]
    assert led["shed_by_reason"] == rep2["ledger"]["shed_by_reason"]
    RESULTS["burst_detail"] = {
        "completed": led["completed"], "shed": led["shed"],
        "shed_by_reason": by, "ladder": rep["ladder_transitions"],
        "queue_depth_p99": led.get("queue_depth_p99"),
        "deterministic": True}
    CTX["burst"] = rep


# ---------------------------------------------------------------------------
# summary ledger for the CI chaos smoke artifact
_bit = {name: RESULTS.get(name, {}).get("ok", False)
        for name in ("preempt_replay_bitwise", "grow_back_readmission",
                     "straggler_evict", "crash_retry")}
_burst_res = CTX.get("burst")
RESULTS["summary"] = {
    "replay_bitwise": _bit,
    "baseline_ticks": BASE["ticks"],
    "shed_under_burst": ({
        "completed": _burst_res["ledger"]["completed"],
        "shed": _burst_res["ledger"]["shed"],
        "accounted": _burst_res["ledger"]["accounted"],
        "ladder_engaged": _burst_res["ladder_max_level"] >= 1,
    } if _burst_res else None),
}

# the chaos suite's matrix cells (one contract cell per named check)
RESULTS["cells"] = MS.contract_cells(
    "chaos", RESULTS,
    dict(model=CFG.name, tp=TP, block_size=BLOCK_SIZE,
         slots_local=SLOTS_LOCAL, n_requests=N_REQUESTS))
print(json.dumps(RESULTS, indent=1, default=str))
if "--check" in sys.argv:
    MS.exit_check(RESULTS, "serve chaos smoke gate")
