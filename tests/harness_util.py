"""Shared runner for the subprocess correctness harnesses.

The multi-device checks (dist_harness.py, comm_harness.py) run in child
processes so the main pytest process keeps its own device configuration;
this is the one place the child environment and JSON-output parsing live.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys


def run_harness(script: pathlib.Path, timeout: int = 1500) -> dict:
    """Execute a harness script and return its parsed JSON result dict."""
    proc = subprocess.run(
        [sys.executable, str(script)],
        capture_output=True, text=True, timeout=timeout,
        cwd=str(script.parent.parent),
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": str(pathlib.Path.home()), "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = proc.stdout
    return json.loads(out[out.index("{"):])
