"""Device-free tests for the perf-matrix core (repro.bench).

Synthetic timing draws exercise the variance estimator and the
significance-aware regression gate end to end: an injected 1.5x slowdown
must fail the 1.2x gate decisively, noise-level jitter must pass, and the
config-hash provenance must be stable across key order and serialization.
The BENCH_matrix.json schema round-trips through json, and the declared
matrix itself is checked for internal consistency (every reference cell
exists, every suite is runnable, the standalone shims' gates are the
matrix's own).
"""

import json

import pytest

from repro.bench import gates as G
from repro.bench import matrixdef as MD
from repro.bench import measure as MS
from repro.bench import runner as R

US = 1e-6


def t_cell(cid, samples_us, rows=None, ok=None):
    """A synthetic timing cell record keyed like the runner keys them."""
    stats = MS.TimingStats(tuple(s * US for s in samples_us))
    cell = MS.timing_cell({"cell": cid, "steps": len(samples_us)}, stats,
                          metrics={"rows": rows} if rows else {}, ok=ok)
    return dict(cell, id=cid)


# ---------------------------------------------------------------------------
# TimingStats: the variance estimator
# ---------------------------------------------------------------------------

def test_timing_stats_robust_summary():
    t = MS.TimingStats((10.0, 12.0, 11.0, 100.0, 11.5))
    assert t.median_s == 11.5          # the outlier does not move the median
    assert t.mad_s == 0.5
    assert t.min_s == 10.0
    assert t.n == 5
    # MAD-based standard error of the median
    assert t.sigma_s == pytest.approx(MS.MEDIAN_SE_FACTOR * 0.5 / 5 ** 0.5)


def test_timing_stats_warmup_discard_and_roundtrip():
    t = MS.TimingStats.from_samples([999.0, 999.0, 1.0, 2.0, 3.0], warmup=2)
    assert t.samples_s == (1.0, 2.0, 3.0)
    assert t.warmup == 2 and t.median_s == 2.0
    t2 = MS.TimingStats.from_dict(json.loads(json.dumps(t.to_dict())))
    assert t2 == t
    with pytest.raises(ValueError):
        MS.TimingStats.from_samples([1.0], warmup=1)


def test_sigma_falls_back_to_iqr_then_zero():
    # MAD degenerates (quantized clock: most samples identical) but the
    # IQR still sees the spread
    t = MS.TimingStats((10.0, 10.0, 10.0, 20.0, 20.0))
    assert t.mad_s == 0.0 and t.iqr_s > 0.0 and t.sigma_s > 0.0
    # all-identical samples: zero spread means any excess is significant
    assert MS.TimingStats((5.0, 5.0, 5.0)).sigma_s == 0.0


def test_measure_collects_warmup_and_repeats():
    calls = []
    stats = MS.measure(lambda: calls.append(1), warmup=2, repeats=5)
    assert len(calls) == 7 and stats.n == 5 and stats.warmup == 2


# ---------------------------------------------------------------------------
# config-hash provenance
# ---------------------------------------------------------------------------

def test_config_hash_stable_across_key_order_and_json():
    a = {"mesh": {"p": 4, "tp": 2}, "steps": 8, "cell": "x"}
    b = json.loads(json.dumps({"cell": "x", "steps": 8,
                               "mesh": {"tp": 2, "p": 4}}))
    assert MS.config_hash(a) == MS.config_hash(b)
    assert MS.config_hash(a) != MS.config_hash(dict(a, steps=9))
    assert len(MS.config_hash(a)) == 12


# ---------------------------------------------------------------------------
# the variance-aware regression gate
# ---------------------------------------------------------------------------

def _ratio_gate(cell, ref, threshold=1.2, normalize_by=None):
    spec = G.GateSpec(kind="ratio_vs_ref", reference=ref["id"],
                      threshold=threshold, normalize_by=normalize_by)
    return G.gate_ratio_vs_ref(spec, cell, {ref["id"]: ref})


def test_injected_slowdown_fails_gate():
    """A genuine 1.5x slowdown on a quiet machine fails the 1.2x gate."""
    ref = t_cell("ref", [99.9, 100.0, 100.1, 99.95, 100.05])
    slow = t_cell("slow", [149.8, 150.0, 150.2, 149.9, 150.1])
    res = _ratio_gate(slow, ref)
    assert not res.ok and res.data["significant"]
    assert res.data["ratio"] == pytest.approx(1.5, rel=1e-3)


def test_noise_level_jitter_passes_gate():
    """A 1.25x median blip inside a wide measured noise band passes."""
    ref = t_cell("ref", [90.0, 95.0, 100.0, 105.0, 110.0])
    jit = t_cell("jit", [s * 1.25 for s in (90.0, 95.0, 100.0, 105.0, 110.0)])
    res = _ratio_gate(jit, ref)
    assert res.ok and not res.data["significant"]
    assert res.data["ratio"] == pytest.approx(1.25)
    # the same 1.25x on a quiet machine IS significant: tiny sigmas
    # tighten the gate automatically
    ref_q = t_cell("ref", [99.9, 100.0, 100.1, 99.95, 100.05])
    jit_q = t_cell("jit", [124.9, 125.0, 125.1, 124.95, 125.05])
    assert not _ratio_gate(jit_q, ref_q).ok


def test_per_row_normalization():
    """Paged pushes 1.5x the rows; per-row the same gate passes."""
    ref = t_cell("fixed", [99.9, 100.0, 100.1, 99.95, 100.05], rows=8)
    paged = t_cell("paged", [149.8, 150.0, 150.2, 149.9, 150.1], rows=12)
    assert not _ratio_gate(paged, ref).ok            # raw: 1.5x, fails
    res = _ratio_gate(paged, ref, normalize_by="rows")
    assert res.ok                                    # per-row: 1.0x
    assert res.data["ratio"] == pytest.approx(1.0, rel=1e-3)


def test_missing_reference_fails_loudly():
    res = G.gate_ratio_vs_ref(
        G.GateSpec(kind="ratio_vs_ref", reference="nope", threshold=1.2),
        t_cell("c", [1.0]), {})
    assert not res.ok and "missing" in res.detail


def test_contract_gate_requires_a_verdict():
    spec = G.GateSpec(kind="contract")
    assert G.gate_contract(spec, {"ok": True}).ok
    assert not G.gate_contract(spec, {"ok": False}).ok
    res = G.gate_contract(spec, {"ok": None})       # no verdict => fail
    assert not res.ok and "no verdict" in res.detail


def test_metric_bound_gate():
    cell = {"metrics": {"normalized_ratio": 1.3}}
    spec = G.GateSpec(kind="metric_bound", metric="normalized_ratio",
                      min_value=1.0)
    assert G.gate_metric_bound(spec, cell).ok
    cell["metrics"]["normalized_ratio"] = 0.9
    assert not G.gate_metric_bound(spec, cell).ok
    assert not G.gate_metric_bound(spec, {"metrics": {}}).ok


def test_enforce_smoke_downgrade():
    spec = G.GateSpec(kind="metric_bound", metric="x", min_value=1.0,
                      enforce_smoke=False)
    cell = {"metrics": {"x": 0.5}}
    smoke = G.evaluate_gates((spec,), cell, {}, None, smoke=True)[0]
    full = G.evaluate_gates((spec,), cell, {}, None, smoke=False)[0]
    assert not smoke.ok and not smoke.enforced       # recorded, not gating
    assert not full.ok and full.enforced


# ---------------------------------------------------------------------------
# baselines: missing / stale / advisory / enforced
# ---------------------------------------------------------------------------

def _baseline(cells):
    return {"schema": G.BASELINE_SCHEMA, "cells": cells}


def test_missing_baseline_is_never_pass_by_default():
    """No baseline => the baseline gate is advisory, but the in-run
    reference gate still fails the injected slowdown."""
    ref = t_cell("ref", [99.9, 100.0, 100.1, 99.95, 100.05])
    slow = t_cell("slow", [149.8, 150.0, 150.2, 149.9, 150.1])
    bres = G.gate_ratio_vs_baseline(
        G.GateSpec(kind="ratio_vs_baseline", threshold=1.5), slow, None)
    assert bres.ok and not bres.enforced             # recorded only
    assert not _ratio_gate(slow, ref).ok             # still gated in-run


def test_stale_baseline_treated_as_missing():
    cell = t_cell("c", [100.0, 100.1, 99.9])
    entry = {"median_s": 50 * US, "sigma_s": 0.1 * US,
             "config_hash": "000000000000", "enforce": True}
    res = G.gate_ratio_vs_baseline(
        G.GateSpec(kind="ratio_vs_baseline", threshold=1.2), cell,
        _baseline({"c": entry}))
    assert res.ok and not res.enforced and "stale" in res.detail
    # matching hash: the 2x regression over baseline now hard-fails
    entry2 = dict(entry, config_hash=cell["config_hash"])
    res2 = G.gate_ratio_vs_baseline(
        G.GateSpec(kind="ratio_vs_baseline", threshold=1.2), cell,
        _baseline({"c": entry2}))
    assert not res2.ok and res2.enforced


def test_advisory_baseline_records_but_does_not_gate():
    cell = t_cell("c", [100.0, 100.1, 99.9])
    entry = {"median_s": 50 * US, "sigma_s": 0.1 * US,
             "config_hash": cell["config_hash"], "enforce": False}
    res = G.gate_ratio_vs_baseline(
        G.GateSpec(kind="ratio_vs_baseline", threshold=1.2), cell,
        _baseline({"c": entry}))
    assert not res.ok and not res.enforced


def test_exact_baseline_gate():
    cell = dict(MS.exact_cell({"cell": "fig"}, "abc123"), id="f")
    spec = G.GateSpec(kind="exact_vs_baseline")
    missing = G.gate_exact_vs_baseline(spec, cell, None)
    assert missing.ok and not missing.enforced       # recorded, not compared
    entry = {"hash": "abc123", "config_hash": cell["config_hash"]}
    assert G.gate_exact_vs_baseline(spec, cell, _baseline({"f": entry})).ok
    bad = G.gate_exact_vs_baseline(
        spec, cell, _baseline({"f": dict(entry, hash="def456")}))
    assert not bad.ok and bad.enforced               # exact defaults enforced


# ---------------------------------------------------------------------------
# the runner's central gate pass + report schema round-trip
# ---------------------------------------------------------------------------

def _tiny_matrix(smoke=True):
    cells = {
        "t/ref": MD.CellSpec(id="t/ref", suite="t", gates=()),
        "t/fast": MD.CellSpec(
            id="t/fast", suite="t",
            gates=(G.GateSpec(kind="ratio_vs_ref", reference="t/ref",
                              threshold=1.2),)),
        "t/contract": MD.CellSpec(
            id="t/contract", suite="t",
            gates=(G.GateSpec(kind="contract"),)),
        "t/never_emitted": MD.CellSpec(
            id="t/never_emitted", suite="t",
            gates=(G.GateSpec(kind="contract"),)),
    }
    suites = {"t": MD.SuiteSpec("t", "tests/nonexistent.py")}
    return MD.MatrixSpec(suites=suites, cells=cells, smoke=smoke)


def _tiny_suite_cells():
    return {"t": {
        "t/ref": t_cell("t/ref", [100.0, 100.1, 99.9]),
        "t/fast": t_cell("t/fast", [101.0, 101.1, 100.9]),
        "t/contract": dict(MS.contract_cell({"c": 1}, True), id="t/contract"),
        "t/extra": dict(MS.contract_cell({"c": 2}, True), id="t/extra"),
    }}


def test_gate_cells_missing_declared_cell_fails():
    matrix = _tiny_matrix()
    report_cells, failures = R.gate_cells(matrix, _tiny_suite_cells(), None)
    assert report_cells["t/fast"]["ok"]
    assert report_cells["t/contract"]["ok"]
    # the declared-but-never-emitted cell is a loud failure (one entry
    # per gate: the synthetic "present" gate plus its declared gates)...
    assert not report_cells["t/never_emitted"]["ok"]
    assert {f["cell"] for f in failures} == {"t/never_emitted"}
    # ...and the undeclared extra cell is carried through ungated
    assert report_cells["t/extra"]["declared"] is False
    assert report_cells["t/extra"]["gates"] == []


def test_report_schema_roundtrip():
    matrix = _tiny_matrix()
    suite_runs = {"t": {
        "status": {"script": "x.py", "argv": [], "status": "ok",
                   "wall_s": 0.1, "returncode": 0},
        "out": {"cells": _tiny_suite_cells()["t"]},
    }}
    report = R.assemble_report(matrix, suite_runs, None, "benchmarks/b.json")
    assert G.validate_report(report) == []
    rt = json.loads(json.dumps(report, default=str))
    assert G.validate_report(rt) == []
    assert rt["schema"] == G.SCHEMA
    assert rt["matrix_config_hash"] == matrix.config_hash
    # only the declared-but-missing cell fails; everything else gated ok
    assert {f["cell"] for f in rt["failures"]} == {"t/never_emitted"}
    assert rt["ok"] is False


def test_validate_report_catches_malformed_cells():
    bad = {"schema": G.SCHEMA, "smoke": True, "matrix_config_hash": "x",
           "suites": {}, "ok": True, "failures": [],
           "cells": {"c": {"kind": "banana"}}}
    errs = G.validate_report(bad)
    assert any("bad kind" in e for e in errs)
    assert any("config_hash" in e for e in errs)


# ---------------------------------------------------------------------------
# the declared matrix is internally consistent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("smoke", [True, False])
def test_declared_matrix_consistency(smoke):
    matrix = MD.build_matrix(smoke)
    for cid, spec in matrix.cells.items():
        assert spec.suite in matrix.suites, cid
        for gate in spec.gates:
            if gate.kind == "ratio_vs_ref":
                assert gate.reference in matrix.cells, (cid, gate.reference)
                assert gate.threshold and gate.threshold > 1.0
    # every historical gate surface is declared
    have = set(matrix.cells)
    for name in MD.MEMPLAN_CHECKS:
        assert f"memplan/{name}" in have
    for name in MD.ELASTIC_CHECKS:
        assert f"elastic/{name}" in have
    for name in MD.CHAOS_CHECKS:
        assert f"chaos/{name}" in have
    for label in MD.COMM_POLICY_LABELS:
        assert f"comm/policy/{label}" in have
    for name in MD.FIGURE_CELLS:
        assert f"figures/{name}" in have
    rates = MD.SERVE_RATES_SMOKE if smoke else MD.SERVE_RATES_FULL
    for rate in rates:
        assert f"serve/rate/{rate}" in have
    # smoke and full declare different matrices (provenance hash differs)
    assert MD.build_matrix(True).config_hash != \
        MD.build_matrix(False).config_hash


def test_check_suite_slices_one_suite():
    """The standalone shims gate exactly their own declared slice."""
    out = {"cells": {}}   # a suite that emitted nothing
    failures = R.check_suite("memplan", out, smoke=True)
    # every declared memplan cell is reported missing, nothing else
    assert all(f.startswith("memplan/") for f in failures)
    missing = {f.split(":")[0] for f in failures}
    assert missing == {f"memplan/{n}" for n in MD.MEMPLAN_CHECKS}


# ---------------------------------------------------------------------------
# the harness verdict registry
# ---------------------------------------------------------------------------

def test_make_check_and_contract_cells():
    results = {}
    check = MS.make_check(results)

    @check("passes")
    def _a():
        pass

    @check("fails")
    def _b():
        raise ValueError("boom")

    results["fails_detail"] = {"extra": "not a verdict"}
    assert results["passes"] == {"ok": True}
    assert not results["fails"]["ok"] and "boom" in results["fails"]["err"]
    assert MS.failed_checks(results) == ["fails"]
    cells = MS.contract_cells("h", results, {"mesh": 8})
    assert set(cells) == {"h/passes", "h/fails"}     # details skipped
    assert cells["h/passes"]["ok"] and not cells["h/fails"]["ok"]
    assert cells["h/fails"]["detail"].startswith("ValueError")
    assert cells["h/passes"]["config"]["mesh"] == 8
