"""Recurrent-block numerics: chunkwise-parallel mLSTM == sequential cell,
RG-LRU decode step == scan prefix, conv1d causal state handoff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recurrent as R

RNG = np.random.default_rng(5)


def _mlstm_inputs(b=2, t=64, nh=2, dh=16):
    q = jnp.asarray(RNG.normal(size=(b, t, nh, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, t, nh, dh)), jnp.float32) / np.sqrt(dh)
    v = jnp.asarray(RNG.normal(size=(b, t, nh, dh)), jnp.float32)
    ilog = jnp.asarray(RNG.normal(size=(b, t, nh)), jnp.float32)
    flog = jax.nn.log_sigmoid(
        jnp.asarray(RNG.normal(size=(b, t, nh)) + 2.0, jnp.float32))
    return q, k, v, ilog, flog


def _sequential(q, k, v, ilog, flog):
    b, t, nh, dh = q.shape
    carry = (
        jnp.zeros((b, nh, dh, dh), jnp.float32),
        jnp.zeros((b, nh, dh), jnp.float32),
        jnp.full((b, nh), -1e30, jnp.float32),
    )
    hs = []
    for i in range(t):
        carry, h = R._mlstm_cell(q[:, i], k[:, i], v[:, i],
                                 ilog[:, i], flog[:, i], carry)
        hs.append(h)
    return jnp.stack(hs, axis=1), carry


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_mlstm_chunkwise_matches_sequential(chunk):
    q, k, v, ilog, flog = _mlstm_inputs()
    want_h, want_state = _sequential(q, k, v, ilog, flog)
    got_h, got_state = R.mlstm_chunkwise(q, k, v, ilog, flog, chunk)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_state[0]),
                               np.asarray(want_state[0]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_state[1]),
                               np.asarray(want_state[1]), rtol=2e-3, atol=2e-3)


def test_mlstm_chunkwise_grads_finite():
    q, k, v, ilog, flog = _mlstm_inputs(b=1, t=32, nh=1, dh=8)

    def loss(q):
        h, _ = R.mlstm_chunkwise(q, k, v, ilog, flog, 8)
        return jnp.sum(h ** 2)

    g = jax.grad(loss)(q)
    assert np.all(np.isfinite(np.asarray(g)))


def test_rglru_decode_matches_scan():
    from repro.configs import get_config, smoke_variant
    from repro.core.flat_param import LayoutBuilder
    from repro.models import layers as L

    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    b = LayoutBuilder()
    R.griffin_rec_layout(cfg, 1, b)
    layout = b.build()
    flat = layout.init_flat(jax.random.key(0))
    t = layout.unflatten(flat)

    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    ctx_train = L.Ctx(mode="train", tp=1)
    full, _ = R.griffin_rec_apply(cfg, t, x, ctx_train)

    # prefill on the first 15 tokens, then one decode step
    ctx_prefill = L.Ctx(mode="prefill", tp=1, cache_len=16)
    _, cache = R.griffin_rec_apply(cfg, t, x[:, :15], ctx_prefill)
    ctx_dec = L.Ctx(mode="decode", tp=1, pos=jnp.int32(15), cache_len=16)
    last, _ = R.griffin_rec_apply(cfg, t, x[:, 15:16], ctx_dec, cache)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, 15], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_causal_conv_state_handoff():
    w = jnp.asarray(RNG.normal(size=(4, 8)), jnp.float32)
    bias = jnp.zeros((8,), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(1, 12, 8)), jnp.float32)
    full, _ = R._causal_conv1d(x, w, bias)
    y1, state = R._causal_conv1d(x[:, :9], w, bias)
    y2, _ = R._causal_conv1d(x[:, 9:], w, bias, state)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(full), rtol=1e-5, atol=1e-6)
