"""Autotuner census-match harness, run in a subprocess with 8 virtual CPU
devices (same pattern as comm_harness.py).  Prints one JSON object with named
check results; tests/test_autotune.py asserts on them.

The property under test is the tentpole contract of core/autotune.py: the
*analytical* per-stage census (``predict_traffic``) equals the *measured*
census (``hlo_stats.analyze(...)['by_stage']``) of the actually-compiled
train step, stage by stage, for every (gather topology x wire dtype) — so
the cost model ranks policies from the same traffic the HLO really has.

Checks:

  census_match_single   3 topologies x 3 wire dtypes on a single-axis
                        partition group (p=4, repl=2 -> hop2 present):
                        per-stage wire bytes within 2% (padding is already
                        in flat_len, so in practice they match exactly),
                        collective counts exactly equal
  census_match_prefetch the double-buffered schedule's counts
                        (s*stack + 1 gathers, s*(stack+1) adjoints)
  census_match_multi    multi-axis ('pod','shard') partition group: the
                        outer stage is the pod hop, bytes match both stage
                        orders
  census_match_qgz      the int8 qgZ hop-1 wire under all 3 topologies:
                        the grad_rs stages become per-stage all-to-all
                        pairs (int8 q + f32 scales) whose predicted wire
                        bytes and instruction counts match the compiled
                        HLO exactly (ISSUE 4 acceptance)
  auto_plan_census      policy="auto" end to end: resolve_config picks a
                        plan, the step compiled from the resolved config
                        measures the bytes the plan predicted

The prediction side passes ``upcast_float_collectives=True`` because the
XLA CPU backend widens bf16 collectives to f32 on the wire; on TPU the
flag stays False and the same formulas describe the real traffic.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import traceback

import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.autotune import compare_census, predict_traffic, resolve_config
from repro.core.comm import GatherPolicy, SyncPolicy
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state_shapes, make_batch_shapes,
)
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

RESULTS = {}
MICRO = 2
RTOL = 0.02     # "padding tolerance": flat_len is pre-padded, so ~exact

_WIRE_MCFG = {
    "fp32": dict(gather_dtype=jnp.float32),
    "bf16": dict(gather_dtype=jnp.bfloat16),
    "int8": dict(gather_dtype=jnp.bfloat16, quant_gather=True),
}


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {
                "ok": False,
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc()[-2000:],
            }
        return fn
    return deco


def _mcfg(topology: str, wire: str, prefetch: bool = False,
          hop1: str = "fp32") -> MiCSConfig:
    return MiCSConfig(
        micro_steps=MICRO,
        hierarchical=topology != "flat",
        gather_order=topology if topology != "flat" else "inner_first",
        prefetch=prefetch,
        hop1_wire_dtype=hop1,
        **_WIRE_MCFG[wire],
    )


def _measure(model, topo, mcfg, *, global_batch=16, seq=16):
    step = build_train_step(model, topo, mcfg, OptConfig(total_steps=10))
    text = step.lower(
        init_state_shapes(model),
        make_batch_shapes(model, global_batch, seq, MICRO),
    ).compile().as_text()
    mesh_shape = dict(zip(topo.mesh.axis_names, topo.mesh.devices.shape))
    return analyze(text, mesh_shape,
                   partition_axes=topo.partition_axes,
                   replication_axes=topo.replication_axes)


def _assert_match(model, topo, topology, wire, *, prefetch=False,
                  hop1="fp32", tag=""):
    mcfg = _mcfg(topology, wire, prefetch, hop1)
    measured = _measure(model, topo, mcfg)["by_stage"]
    pred = predict_traffic(
        model, topo,
        GatherPolicy(topology, wire, None, prefetch),
        SyncPolicy(hop1_wire_dtype=hop1),
        micro_steps=MICRO, upcast_float_collectives=True,
    )["by_stage"]
    cmp = compare_census(pred, measured)
    detail = {}
    for stage, row in cmp.items():
        p, m = row["predicted_wire_bytes"], row["measured_wire_bytes"]
        assert p > 0 and m > 0, f"{tag}/{stage}: empty side {row}"
        assert abs(m - p) <= RTOL * p, \
            f"{tag}/{stage}: predicted {p} != measured {m}"
        pc, mc = pred[stage]["count"], measured[stage]["count"]
        assert pc == mc, f"{tag}/{stage}: count predicted {pc} != {mc}"
        detail[stage] = {"bytes": m, "ratio": row["ratio"], "count": mc}
    return detail


def _single_axis():
    cfg = smoke_variant(get_config("llama3.2-1b"))
    topo = MiCSTopology(make_host_mesh(1, 2, 4, 1),
                        partition_axes=("shard",),
                        replication_axes=("pod", "repl"))
    return build_model(cfg, tp=1), topo


# ---------------------------------------------------------------------------
@check("census_match_single")
def _census_single():
    model, topo = _single_axis()
    detail = {}
    for topology in ("flat", "inner_first", "outer_first"):
        for wire in ("fp32", "bf16", "int8"):
            detail[f"{topology}/{wire}"] = _assert_match(
                model, topo, topology, wire, tag=f"{topology}/{wire}")
    RESULTS["census_match_single_detail"] = detail


# ---------------------------------------------------------------------------
@check("census_match_prefetch")
def _census_prefetch():
    model, topo = _single_axis()
    detail = _assert_match(model, topo, "inner_first", "bf16",
                           prefetch=True, tag="prefetch")
    RESULTS["census_match_prefetch_detail"] = detail


# ---------------------------------------------------------------------------
@check("census_match_multi")
def _census_multi():
    cfg = smoke_variant(get_config("llama3.2-1b"))
    topo = MiCSTopology(make_host_mesh(2, 1, 4, 1),
                        partition_axes=("pod", "shard"),
                        replication_axes=("repl",))
    model = build_model(cfg, tp=1)
    detail = {}
    for topology in ("inner_first", "outer_first"):
        detail[topology] = _assert_match(
            model, topo, topology, "bf16", tag=f"multi/{topology}")
    # the slow-axis hop exists and is the outer stage
    for topology, d in detail.items():
        assert "param_gather.outer" in d, (topology, d)
    RESULTS["census_match_multi_detail"] = detail


# ---------------------------------------------------------------------------
@check("census_match_qgz")
def _census_qgz():
    """int8 qgZ hop-1: per-stage all-to-all wire bytes and counts are
    instruction-exact for every topology (the ISSUE 4 acceptance check)."""
    model, topo = _single_axis()
    detail = {}
    for topology in ("flat", "inner_first", "outer_first"):
        detail[topology] = _assert_match(
            model, topo, topology, "bf16", hop1="int8",
            tag=f"qgz/{topology}")
        assert any(k.startswith("grad_rs") for k in detail[topology])
    RESULTS["census_match_qgz_detail"] = detail


# ---------------------------------------------------------------------------
@check("auto_plan_census")
def _auto_plan_census():
    model, topo = _single_axis()
    mcfg = MiCSConfig(micro_steps=MICRO, policy="auto", link_profile="v5e",
                      prefetch=False)
    resolved, plan = resolve_config(mcfg, model, topo)
    assert plan is not None and resolved.policy == "manual"
    g = plan.chosen.gather
    measured = _measure(model, topo, resolved)["by_stage"]
    pred = predict_traffic(
        model, topo, g, plan.chosen.sync, micro_steps=MICRO,
        upcast_float_collectives=True)["by_stage"]
    cmp = compare_census(pred, measured)
    for stage, row in cmp.items():
        p, m = row["predicted_wire_bytes"], row["measured_wire_bytes"]
        assert abs(m - p) <= RTOL * max(p, 1.0), (stage, row)
    RESULTS["auto_plan_census_detail"] = {
        "chosen": plan.chosen.describe()["gather"],
        "stages": {k: v["measured_wire_bytes"] for k, v in cmp.items()},
    }


print(json.dumps(RESULTS, indent=1, default=str))
