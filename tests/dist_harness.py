"""Distributed-correctness harness, run in a subprocess with 8 virtual CPU
devices (keeps the main pytest process at 1 device, per the dry-run rules).

Prints one JSON object with named check results; tests/test_distributed.py
asserts on them.  Checks:

  hier_gather        hierarchical all-gather (both stage orders, single- and
                     multi-axis partition groups) == flat all-gather, values
                     and gradients
  mics_fidelity      MiCS (p=2, repl/pod=2, tp=2) training == single-device
                     training (paper Fig 16 analogue)
  zero3_equiv        ZeRO-3 configuration (partition = all data axes) matches
  alt_sync_equiv     alternative schedule (Fig 14) is numerically identical
  hier_train_equiv   hierarchical gather on == off, same losses
  compress_hop2      bf16-compressed hop 2 stays close
  decode_consistency prefill+decode logits == teacher-forced forward
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs import get_config, smoke_variant
from repro.core import collectives as C
from repro.core.mics import MiCSConfig, build_train_step, init_state, state_pspecs
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig

RESULTS = {}


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {
                "ok": False,
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc()[-2000:],
            }
        return fn
    return deco


# ---------------------------------------------------------------------------
@check("hier_gather")
def _hier_gather():
    mesh = make_host_mesh(2, 1, 4, 1)  # pod=2, shard=4
    x = jnp.arange(64.0).reshape(16, 4)

    def run(fn, in_spec):
        return shard_map(fn, mesh=mesh, in_specs=in_spec,
                         out_specs=P(None, None), check_vma=False)(x)

    # single-axis partition group (p=4), both orders, values
    topo = MiCSTopology(mesh, partition_axes=("shard",),
                        replication_axes=("pod", "repl"))
    ref = run(lambda xs: C.flat_all_gather(xs, ("shard",)), P("shard", None))
    for order in ("inner_first", "outer_first"):
        got = run(
            lambda xs: C.hierarchical_all_gather(xs, topo, order=order, inner=2),
            P("shard", None))
        np.testing.assert_allclose(got, ref, err_msg=order)

    # gradients flow identically through flat and both staged orders
    w = jnp.arange(64.0).reshape(16, 4) / 64.0

    def make_loss(gather):
        def f(xv):
            def body(xs):
                full = gather(xs)
                return jnp.sum(full ** 2) / mesh.size
            return jnp.sum(
                shard_map(body, mesh=mesh, in_specs=P("shard", None),
                          out_specs=P(), check_vma=False)(xv))
        return f

    gref = jax.grad(make_loss(lambda xs: C.flat_all_gather(xs, ("shard",))))(w)
    for order in ("inner_first", "outer_first"):
        g = jax.grad(make_loss(
            lambda xs: C.hierarchical_all_gather(xs, topo, order=order, inner=2)
        ))(w)
        np.testing.assert_allclose(g, gref, rtol=1e-6, err_msg=f"grad {order}")

    # multi-axis partition group (pod x shard), both orders
    topo2 = MiCSTopology(mesh, partition_axes=("pod", "shard"),
                         replication_axes=("repl",))
    ref2 = run(lambda xs: C.flat_all_gather(xs, ("pod", "shard")),
               P(("pod", "shard"), None))
    for order in ("inner_first", "outer_first"):
        got = run(lambda xs: C.hierarchical_all_gather(xs, topo2, order=order),
                  P(("pod", "shard"), None))
        np.testing.assert_allclose(got, ref2, err_msg=f"multiaxis {order}")


# ---------------------------------------------------------------------------
def _train_losses(mesh_dims, mcfg, partition_axes=("shard",), steps=4, seed=0,
                  arch="llama3.2-1b"):
    cfg = smoke_variant(get_config(arch))
    mesh = make_host_mesh(*mesh_dims)
    repl_axes = tuple(a for a in ("pod", "repl") if a not in partition_axes)
    topo = MiCSTopology(mesh, partition_axes=partition_axes,
                        replication_axes=repl_axes)
    tp = mesh_dims[3]
    model = build_model(cfg, tp=tp)
    state = init_state(model, topo, seed=seed)
    step = build_train_step(
        model, topo, mcfg,
        OptConfig(total_steps=50, warmup_steps=0, lr_max=3e-3))
    rng = np.random.default_rng(7)
    s, b, t = 2, 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (s, b, t)), jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (s, b, t)), jnp.int32),
        "mask": jnp.ones((s, b, t), jnp.float32),
    }
    losses = []
    for _ in range(steps):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return np.array(losses)


REF = {}


@check("mics_fidelity")
def _fidelity():
    """Paper Fig 16 analogue.  Note: tp=2 and tp=1 initialize TP-local
    shards from different RNG streams, so this is a *convergence-curve*
    comparison (like the paper's DeepSpeed-vs-MiCS loss overlay), not a
    bitwise one — the bitwise checks are the fixed-tp partition/schedule
    equivalences below."""
    REF["single"] = _train_losses((1, 1, 1, 1), MiCSConfig(micro_steps=2))
    REF["mics"] = _train_losses((2, 1, 2, 2), MiCSConfig(micro_steps=2))
    assert np.all(np.isfinite(REF["mics"]))
    np.testing.assert_allclose(REF["mics"], REF["single"], rtol=0.02, atol=0.03)


@check("zero3_equiv")
def _zero3():
    z3 = _train_losses((2, 1, 2, 2), MiCSConfig(micro_steps=2),
                       partition_axes=("pod", "shard"))
    np.testing.assert_allclose(z3, REF["mics"], rtol=0.02, atol=0.03)


@check("alt_sync_equiv")
def _alt():
    alt = _train_losses((2, 1, 2, 2),
                        MiCSConfig(micro_steps=2, sync_mode="allreduce_slice"))
    np.testing.assert_allclose(alt, REF["mics"], rtol=2e-3, atol=2e-3)


@check("hier_train_equiv")
def _hier_train():
    flat = _train_losses((1, 1, 4, 2),
                         MiCSConfig(micro_steps=2, hierarchical=False))
    hier = _train_losses((1, 1, 4, 2),
                         MiCSConfig(micro_steps=2, hierarchical=True,
                                    gather_order="outer_first"))
    # first step is bit-identical; later steps drift only via bf16
    # reduction order in the staged backward reduce-scatter
    np.testing.assert_allclose(hier[0], flat[0], rtol=1e-6)
    np.testing.assert_allclose(hier, flat, rtol=2e-3, atol=5e-3)


@check("compress_hop2")
def _compress():
    comp = _train_losses((2, 1, 2, 2),
                         MiCSConfig(micro_steps=2, compress_hop2=True))
    np.testing.assert_allclose(comp, REF["mics"], rtol=0.05, atol=0.05)


@check("moe_tp_equiv")
def _moe_tp():
    """Token-sharded expert-parallel MoE (tp=4) == single-device model."""
    one = _train_losses((1, 1, 1, 1), MiCSConfig(micro_steps=2),
                        arch="deepseek-moe-16b", seed=2)
    ep = _train_losses((1, 1, 2, 4), MiCSConfig(micro_steps=2),
                       arch="deepseek-moe-16b", seed=2)
    np.testing.assert_allclose(ep, one, rtol=0.03, atol=0.05)


@check("griffin_partition_equiv")
def _griffin_partition():
    """Griffin (RG-LRU + MQA kv-group gathers) under MiCS partitioning:
    p=2 vs p=1 at the same tp=2 (identical logical init — TP-local RNG
    streams depend only on (stack, tp)) must train identically."""
    p2 = _train_losses((1, 1, 2, 2), MiCSConfig(micro_steps=2),
                       arch="recurrentgemma-2b", seed=3)
    p1 = _train_losses((1, 2, 1, 2), MiCSConfig(micro_steps=2),
                       arch="recurrentgemma-2b", seed=3)
    np.testing.assert_allclose(p2, p1, rtol=2e-3, atol=5e-3)


@check("mlstm_chunk_train_equiv")
def _mlstm_chunk():
    """Chunkwise mLSTM training == sequential-scan training (xlstm)."""
    seq = _train_losses((1, 1, 2, 1), MiCSConfig(micro_steps=2),
                        arch="xlstm-125m", seed=4)
    chk = _train_losses((1, 1, 2, 1),
                        MiCSConfig(micro_steps=2, mlstm_chunk=8),
                        arch="xlstm-125m", seed=4)
    np.testing.assert_allclose(chk, seq, rtol=5e-3, atol=1e-2)


# ---------------------------------------------------------------------------
@check("decode_consistency")
def _decode():
    from repro.core.comm import CommEngine
    from repro.core.topology import MODEL_AXIS
    from repro.models import layers as L
    from repro.models import lm as lmmod
    from repro.runtime.serving import build_serve_steps

    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 1, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    state = init_state(model, topo, seed=3)
    params = state["params"]

    cache_len = 32
    prefill_fn, decode_fn = build_serve_steps(
        model, topo, MiCSConfig(), cache_len)

    rng = np.random.default_rng(11)
    b, t0 = 2, 16
    toks = jnp.array(rng.integers(0, cfg.vocab, (b, t0 + 4)), jnp.int32)
    logits0, caches = prefill_fn(params, {"tokens": toks[:, :t0]})

    comm = CommEngine.from_config(topo, MiCSConfig())
    ctx = L.Ctx(mode="train", tp=2, tp_axis=MODEL_AXIS)

    def fwd(p, tokens):
        hidden, _, _, t_head = lmmod.forward(
            model, p, comm, ctx, {"tokens": tokens})
        return lmmod.lm_logits(model, t_head, hidden, ctx)

    sm = shard_map(
        fwd, mesh=mesh,
        in_specs=(state_pspecs(model, topo)["params"],
                  P(topo.data_axes, None)),
        out_specs=P(topo.data_axes, None, MODEL_AXIS), check_vma=False)
    ref_logits = np.asarray(jax.jit(sm)(params, toks))

    errs = []
    for i in range(4):
        pos = jnp.int32(t0 + i)
        logits, next_tok, caches = decode_fn(
            params, caches, toks[:, t0 + i: t0 + i + 1], pos)
        got = np.asarray(logits)[:, 0]
        want = ref_logits[:, t0 + i]
        errs.append(float(np.max(np.abs(got - want))))
    errs.append(float(np.max(np.abs(
        np.asarray(logits0)[:, 0] - ref_logits[:, t0 - 1]))))
    assert max(errs) < 0.15, f"decode logits deviate: {errs}"


print(json.dumps(RESULTS, indent=1, default=str))
