"""Serving-layer units: cache partition specs, batch-axes fallback, the
serving-footprint partition heuristic, and windowed-cache roll semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, smoke_variant
from repro.core.topology import MiCSTopology, choose_partition_size, make_host_mesh
from repro.models.build import build_model, exact_param_count
from repro.runtime.serving import batch_axes_for, cache_pspecs, global_cache_shapes


def test_batch_axes_fallback(topo1):
    assert batch_axes_for(topo1, 4) == topo1.data_axes
    # a single stream cannot shard over >1 data ranks
    mesh = make_host_mesh(1, 1, 1, 1)
    topo = MiCSTopology(mesh)
    assert batch_axes_for(topo, 1) == topo.data_axes  # dp=1 divides
    assert batch_axes_for(topo, 3) == topo.data_axes


@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-2b",
                                  "whisper-large-v3", "xlstm-125m"])
def test_cache_pspecs_structure(arch, topo1):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg, tp=1)
    specs = cache_pspecs(model, topo1)
    shapes, specs2 = global_cache_shapes(model, topo1, global_batch=2,
                                         cache_len=16)
    # same tree structure, every leaf has a spec of matching rank
    leaves_sh = jax.tree.leaves(shapes)
    leaves_sp = jax.tree.leaves(specs2, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_sh) == len(leaves_sp)
    for sh, sp in zip(leaves_sh, leaves_sp):
        assert len(sp) <= len(sh.shape)


def test_serving_footprint_heuristic():
    n = exact_param_count(get_config("dbrx-132b"))
    p_train = choose_partition_size(n)                       # 16 B/param
    p_serve = choose_partition_size(n, state_bytes_per_param=2)
    assert p_train == 16
    assert p_serve == 2  # §Perf cell B: 1.86x collective-term win


def test_windowed_cache_roll_matches_decode_slots():
    """Prefill writes slot a%cap for absolute position a; decode continues."""
    import dataclasses

    from repro.core.flat_param import LayoutBuilder
    from repro.models import layers as L
    from repro.models.blocks import attn_layout, self_attention

    cfg = dataclasses.replace(smoke_variant(get_config("recurrentgemma-2b")),
                              window=8)
    b = LayoutBuilder()
    ad = attn_layout(cfg, 1, b)
    layout = b.build()
    t = layout.unflatten(layout.init_flat(jax.random.key(0)))

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 13, cfg.d_model)), jnp.float32)

    # full forward over 13 tokens (window 8)
    ctx = L.Ctx(mode="train", tp=1)
    full, _ = self_attention(t, x, ctx, ad, cfg, window=8)

    # prefill over 12 then decode token 13
    ctxp = L.Ctx(mode="prefill", tp=1, cache_len=8)
    _, cache = self_attention(t, x[:, :12], ctxp, ad, cfg, window=8)
    ctxd = L.Ctx(mode="decode", tp=1, pos=jnp.int32(12), cache_len=8)
    last, _ = self_attention(t, x[:, 12:13], ctxd, ad, cfg, window=8,
                             cache=cache)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, 12], np.float32),
                               rtol=2e-2, atol=2e-2)
