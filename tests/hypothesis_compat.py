"""Use real hypothesis when installed; otherwise a deterministic fallback.

The container image does not ship ``hypothesis`` and the repo rules forbid
installing packages, so the property-based tests run against this miniature
strategy sampler instead: each ``@given`` test is executed ``max_examples``
times with pseudo-random (seeded, reproducible) draws.  The strategy surface
implemented is exactly what the test-suite uses: ``integers``, ``tuples``,
``lists``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def sample(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Tuples(_Strategy):
        def __init__(self, *parts):
            self.parts = parts

        def sample(self, rng):
            return tuple(p.sample(rng) for p in self.parts)

    class _Lists(_Strategy):
        def __init__(self, elems, min_size=0, max_size=8):
            self.elems, self.min_size, self.max_size = elems, min_size, max_size

        def sample(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            return [self.elems.sample(rng) for _ in range(n)]

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def tuples(*parts):
            return _Tuples(*parts)

        @staticmethod
        def lists(elems, min_size=0, max_size=8):
            return _Lists(elems, min_size=min_size, max_size=max_size)

    class settings:  # noqa: N801
        _profiles: dict = {}
        _current = {"max_examples": 20}

        @classmethod
        def register_profile(cls, name, max_examples=20, **_ignored):
            cls._profiles[name] = {"max_examples": max_examples}

        @classmethod
        def load_profile(cls, name):
            cls._current = cls._profiles.get(name, cls._current)

    def given(*strats):
        def deco(fn):
            # NB: no functools.wraps — pytest must see the zero-arg
            # signature of the runner, not the wrapped test's draw params
            # (it would try to resolve them as fixtures).
            def runner():
                rng = random.Random(f"given:{fn.__name__}")
                for _ in range(settings._current["max_examples"]):
                    drawn = tuple(s.sample(rng) for s in strats)
                    fn(*drawn)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
