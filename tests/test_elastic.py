"""Elastic preemption survival: fault-injection mechanics (device-free) and
the kill-a-device resume matrix (8-virtual-device subprocess harness,
tests/elastic_harness.py — shared via the session-scoped ``elastic_results``
fixture so the subprocess runs once)."""

import json

import pytest

from repro.core.autotune import resolve_world
from repro.core.faults import (
    CrashDuringSaveError, FaultPlan, GrowthError, PreemptionError,
    StragglerError, WorldChangeError,
)
from repro.core.mics import MiCSConfig
from repro.core.topology import elastic_host_topology

# ---------------------------------------------------------------------------
# FaultPlan mechanics (no devices, no jax arrays)
# ---------------------------------------------------------------------------


def test_events_fire_exactly_once_at_their_step():
    plan = FaultPlan().preempt(3, devices=4, notice=False).grow(7, devices=4)
    plan(0)
    plan(1)   # nothing scheduled: no raise
    with pytest.raises(PreemptionError) as e:
        plan(3)
    assert e.value.lost == 4 and e.value.gained == 0 and not e.value.notice
    plan(3)   # one-shot: the replayed step does not re-raise
    with pytest.raises(GrowthError) as e:
        plan(7)
    assert e.value.gained == 4 and e.value.notice
    assert plan.pending() == []
    assert [ev["kind"] for ev in plan.log] == ["preempt", "grow"]


def test_world_change_hierarchy_and_slow_evict():
    assert issubclass(PreemptionError, WorldChangeError)
    assert issubclass(GrowthError, WorldChangeError)
    plan = FaultPlan(slow_base_s=0.0).slow(2, factor=5.0)    # flag-only
    plan(2)   # no eviction: just (zero, here) delay
    plan2 = FaultPlan(slow_base_s=0.0).slow(1, factor=2.0, evict=True)
    with pytest.raises(StragglerError):
        plan2(1)


def test_crash_during_save_hook_truncates_manifest(tmp_path):
    class FakeCkpt:
        fault_hook = None

    ck = FakeCkpt()
    plan = FaultPlan().crash_during_save(5).bind(ck)
    assert ck.fault_hook == plan._save_hook
    meta = {"step": 5, "data_cursor": 5, "mesh_axes": {"shard": 2}}
    with pytest.raises(CrashDuringSaveError):
        ck.fault_hook("pre_manifest", tmp_path, meta)
    # the corpse a mid-write kill leaves: a manifest that does not parse
    corpse = (tmp_path / "manifest.json").read_text()
    with pytest.raises(ValueError):
        json.loads(corpse)
    # other phases and other steps are untouched, and the event is one-shot
    ck.fault_hook("pre_manifest", tmp_path, {"step": 6})
    ck.fault_hook("pre_manifest", tmp_path, meta)
    assert plan.pending() == []


def test_describe_round_trips_the_timeline():
    plan = FaultPlan().preempt(2, devices=1).slow(4).crash_during_save(6)
    d = plan.describe()
    assert [e["kind"] for e in d["events"]] == \
        ["preempt", "slow", "crash_during_save"]
    assert d["fired"] == []


# ---------------------------------------------------------------------------
# resolve_world / elastic_host_topology (device-free policy half)
# ---------------------------------------------------------------------------


def test_resolve_world_keep_rule_shrinks_to_largest_divisor():
    # no budget: keep p where it divides, else the largest divisor below it
    p, mcfg2, info = resolve_world(None, MiCSConfig(), n_devices=6, tp=1,
                                   partition_size=4)
    assert p == 3 and info["rule"] == "keep"
    p, _, _ = resolve_world(None, MiCSConfig(), n_devices=8, tp=2,
                            partition_size=2)
    assert p == 2
    p, _, info = resolve_world(None, MiCSConfig(), n_devices=2, tp=1,
                               partition_size=4)
    assert p == 2 and info["data_extent"] == 2


def test_resolve_world_rejects_tp_nondivisible_world():
    with pytest.raises(ValueError, match="TP-local"):
        resolve_world(None, MiCSConfig(), n_devices=6, tp=4)
    with pytest.raises(ValueError):
        resolve_world(None, MiCSConfig(), n_devices=0, tp=1)


def test_elastic_host_topology_validates_factorization():
    with pytest.raises(ValueError, match="does not factor"):
        elastic_host_topology(3, 2, tp=1)
    with pytest.raises(ValueError, match="at least one"):
        elastic_host_topology(0, 1, tp=1)


# ---------------------------------------------------------------------------
# the kill-a-device matrix (subprocess harness; one run per session)
# ---------------------------------------------------------------------------

ELASTIC_CHECKS = [
    "kill_pod_resume_bitwise",
    "grow_back_resume_bitwise",
    "repick_keep_rule_bitwise",
    "resolve_scale_repick",
    "data_continuity",
    "straggler_flagged",
    "crash_mid_save",
    "reshard_roundtrip",
    "offload_cross_topology",
]


@pytest.mark.parametrize("name", ELASTIC_CHECKS)
def test_elastic_harness(elastic_results, name):
    res = elastic_results[name]
    assert res["ok"], f"{name}: {res.get('err')}\n{res.get('tb', '')}"


def test_elastic_summary_ledger(elastic_results):
    s = elastic_results["summary"]
    assert s["restarts"] == 1 and s["world_changes"] == 2
    assert s["emergency_saves"] == 1
    assert all(s["resume_bitwise"].values()), s["resume_bitwise"]
