"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel body on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    attention_ref, flash_attention, flash_attention_gqa)
from repro.kernels.rglru import rglru_ref, rglru_scan
from repro.kernels.rmsnorm import rmsnorm_nd, rmsnorm_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bh,t,d", [(2, 128, 32), (4, 256, 64), (1, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention(bh, t, d, dtype, causal, window):
    q = jnp.asarray(RNG.normal(size=(bh, t, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(bh, t, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(bh, t, d)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("g,hkv", [(2, 2), (4, 1), (1, 4)])
def test_flash_attention_gqa_layout(g, hkv):
    b, t, dh = 2, 128, 32
    q = jnp.asarray(RNG.normal(size=(b, t, hkv, g, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, t, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, t, hkv, dh)), jnp.float32)
    got = flash_attention_gqa(q, k, v, block_q=64, block_k=64)
    # oracle via the model-layer attention (same [b,t,hkv,g,dh] layout)
    from repro.models.layers import attention
    want = attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", [(64, 128), (4, 16, 256), (2, 8, 8, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    s = jnp.asarray(RNG.normal(size=shape[-1]) * 0.2, jnp.float32)
    got = rmsnorm_nd(x, s)
    want = rmsnorm_ref(x, s)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("b,t,c", [(2, 128, 128), (1, 512, 256), (3, 96, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru(b, t, c, dtype):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, size=(b, t, c)), dtype)
    bb = jnp.asarray(RNG.normal(size=(b, t, c)) * 0.1, dtype)
    got = rglru_scan(a, bb)
    want = rglru_ref(a, bb)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=5e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_rglru_ref_matches_sequential_loop():
    """The associative-scan oracle equals the plain sequential recurrence."""
    a = np.asarray(RNG.uniform(0.8, 0.99, size=(2, 64, 32)), np.float32)
    b = np.asarray(RNG.normal(size=(2, 64, 32)), np.float32)
    h = np.zeros((2, 32), np.float32)
    seq = np.empty_like(a)
    for t in range(a.shape[1]):
        h = a[:, t] * h + b[:, t]
        seq[:, t] = h
    # associative scan reorders the products -> fp32 rounding differences
    np.testing.assert_allclose(
        np.asarray(rglru_ref(jnp.asarray(a), jnp.asarray(b))), seq,
        rtol=1e-4, atol=1e-6)
