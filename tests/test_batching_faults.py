"""Overload-control and fault-tolerance tests for the serving scheduler.

Device-free units exercise the deadline/TTL math, the typed shed ledger,
seeded-jitter backoff reproducibility, the degradation-ladder hysteresis,
the eviction-cap livelock fix and the ``rebuild_world`` replay path —
everything in runtime/batching.py that PR 9 added is deterministic host
code, so it is all testable without a device.  The engine-level chaos
properties (bitwise replay across preemption/grow-back/straggler/crash,
shed-under-burst determinism on the real paged engine) run through the
8-virtual-device subprocess harness (tests/serve_chaos_harness.py).
"""

import numpy as np
import pytest

from repro.core.faults import EngineCrashError, FaultPlan
from repro.runtime.batching import (
    SHED_DEADLINE, SHED_DEADLINE_SUBMIT, SHED_QUEUE_FULL, SHED_TTL,
    ContinuousBatcher, DegradationLadder, Request, ShedError, backoff_ticks,
)


def _drive(b, max_ticks=500):
    """Fake engine keyed by (rid, position): replay regenerates streams."""
    for _ in range(max_ticks):
        if b.idle:
            return b
        plan = b.plan_step()
        tok = np.zeros(b.batch, np.int64)
        for slot, req in plan.requests.items():
            tok[slot] = (req.rid * 1000 + req.next_pos
                         + int(plan.n_new[slot])) % 97
        b.commit(plan, tok)
    raise AssertionError("scheduler did not drain")


def _batcher(**kw):
    cfg = dict(dp=1, slots_local=2, nb_local=9, block_size=4, max_blocks=4,
               chunk=4)
    cfg.update(kw)
    return ContinuousBatcher(**cfg)


# ---------------------------------------------------------------------------
# deadline / TTL math
# ---------------------------------------------------------------------------

def test_min_ticks_left():
    r = Request(rid=0, prompt=list(range(1, 8)), max_new_tokens=5)
    # ceil(7/4)=2 prefill ticks (first token lands on the last) + 4 decode
    assert r.min_ticks_left(chunk=4) == 6
    assert r.min_ticks_left(chunk=7) == 5
    assert r.min_ticks_left(chunk=1) == 11
    r.prefill_done = 7
    r.generated = [1, 2]
    assert r.min_ticks_left(chunk=4) == 3       # decode-only: one per token


def test_submit_rejects_unreachable_deadline():
    b = _batcher()
    req = Request(rid=0, prompt=[1, 2], max_new_tokens=8, deadline_tick=3)
    with pytest.raises(ShedError) as ei:
        b.submit(req)
    assert ei.value.reason == SHED_DEADLINE_SUBMIT
    # rejected, but never silently: the ledger accounts it
    led = b.ledger()
    assert led["submitted"] == 1 and led["shed"] == 1 and led["accounted"]
    assert led["shed_by_reason"] == {SHED_DEADLINE_SUBMIT: 1}
    assert req.shed_reason == SHED_DEADLINE_SUBMIT and req.shed_tick == 0


def test_exactly_reachable_deadline_admits_and_completes():
    b = _batcher()
    # min_ticks_left = 1 + 5 = 6 from tick 0 -> earliest finish tick 5
    req = Request(rid=0, prompt=[1, 2], max_new_tokens=6, deadline_tick=5)
    b.submit(req)
    _drive(b)
    assert req.finish_tick == 5 == req.deadline_tick
    assert b.ledger()["completed"] == 1


def test_queued_deadline_expires_typed():
    # one slot: the second request waits; its deadline becomes unreachable
    # while queued and the sweep sheds it with the *queued* reason
    b = _batcher(slots_local=1)
    b.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=6))
    late = Request(rid=1, prompt=[1, 2], max_new_tokens=6, deadline_tick=7)
    b.submit(late)
    _drive(b)
    assert late.shed_reason == SHED_DEADLINE
    led = b.ledger()
    assert led["completed"] == 1 and led["shed"] == 1 and led["accounted"]


def test_ttl_expires_while_waiting():
    b = _batcher(slots_local=1)
    b.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=8))
    aged = Request(rid=1, prompt=[1, 2], max_new_tokens=2, ttl_ticks=3)
    b.submit(aged)
    _drive(b)
    assert aged.shed_reason == SHED_TTL
    assert aged.shed_tick == 4          # first tick past submit_tick + ttl
    assert b.ledger()["accounted"]


def test_bounded_queue_rejects_on_submit():
    b = _batcher(slots_local=1, max_queue=2)
    b.submit(Request(rid=0, prompt=[1], max_new_tokens=4))
    b.plan_step()                        # admit rid 0: queue is empty again
    b.commit(b.plan_step(), np.zeros(1, np.int64))
    for rid in (1, 2):
        b.submit(Request(rid=rid, prompt=[1], max_new_tokens=4))
    with pytest.raises(ShedError) as ei:
        b.submit(Request(rid=3, prompt=[1], max_new_tokens=4))
    assert ei.value.reason == SHED_QUEUE_FULL
    led = b.ledger()
    assert led["submitted"] == 4 and led["shed"] == 1 and led["accounted"]


def test_structural_errors_stay_value_errors():
    b = _batcher(max_queue=1)
    with pytest.raises(ValueError):
        b.submit(Request(rid=0, prompt=[1], max_new_tokens=99))
    with pytest.raises(ValueError):
        b.submit(Request(rid=1, prompt=[], max_new_tokens=1))
    assert b.ledger()["submitted"] == 0   # caller bugs are not load


# ---------------------------------------------------------------------------
# seeded backoff
# ---------------------------------------------------------------------------

def test_backoff_is_reproducible_and_windowed():
    for attempt in (1, 2, 3, 7):
        window = 4 * (1 << (attempt - 1))
        got = backoff_ticks(4, attempt, rid=5, seed=9)
        assert got == backoff_ticks(4, attempt, rid=5, seed=9)
        assert window <= got < 2 * window, (attempt, got)
    assert backoff_ticks(0, 3, rid=5, seed=9) == 0    # disabled
    # jitter decorrelates requests retrying after the same attempt count
    draws = {backoff_ticks(4, 2, rid=r, seed=9) for r in range(16)}
    assert len(draws) > 1


def test_backoff_gate_skips_without_blocking_fifo():
    b = _batcher(slots_local=1, backoff_base=4, backoff_seed=1)
    gated = Request(rid=0, prompt=[1], max_new_tokens=2)
    ready = Request(rid=1, prompt=[1], max_new_tokens=2)
    b.submit(gated)
    b.submit(ready)
    gated.retry_at_tick = 3              # as a requeue would set it
    plan = b.plan_step()                 # rid 1 admitted past the gate
    assert plan.requests and next(iter(plan.requests.values())).rid == 1
    _drive(b)
    assert {r.rid for r in b.finished} == {0, 1}


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def _ladder(dwell=3):
    return DegradationLadder(
        [{"kv_dtype": "bf16", "resident_cap": 0, "label": "configured"},
         {"kv_dtype": "bf16", "resident_cap": 2, "label": "tightened"},
         {"kv_dtype": "int8", "resident_cap": 4, "label": "kv_int8"}],
        high_water=0.75, low_water=0.25, dwell=dwell)


def test_ladder_needs_consecutive_dwell():
    lad = _ladder(dwell=3)
    assert not lad.update(0, 0.9) and not lad.update(1, 0.9)
    assert not lad.update(2, 0.5)        # streak broken: counter resets
    assert not lad.update(3, 0.9) and not lad.update(4, 0.9)
    assert lad.update(5, 0.9)            # third consecutive hot tick
    assert lad.level == 1 and lad.current()["label"] == "tightened"


def test_ladder_walks_both_ways_and_clamps():
    lad = _ladder(dwell=1)
    assert lad.update(0, 1.0) and lad.level == 1
    assert lad.update(1, 1.0) and lad.level == 2
    assert not lad.update(2, 1.0)        # clamped at the last level
    assert not lad.update(3, 0.5)        # hysteresis band: no movement
    assert lad.update(4, 0.0) and lad.level == 1
    assert lad.update(5, 0.0) and lad.level == 0
    assert not lad.update(6, 0.0)        # clamped at the configured level
    assert lad.max_level_seen == 2
    assert [t["to"] for t in lad.transitions] == [1, 2, 1, 0]


def test_ladder_validates():
    with pytest.raises(ValueError):
        DegradationLadder([])
    with pytest.raises(ValueError):
        DegradationLadder([{"kv_dtype": "bf16", "resident_cap": 0}],
                          high_water=0.2, low_water=0.5)


def test_resident_cap_limits_admission():
    b = _batcher(slots_local=3, resident_cap=2)
    for rid in range(3):
        b.submit(Request(rid=rid, prompt=[1], max_new_tokens=4))
    plan = b.plan_step()
    assert plan.active_rows == 2         # cap 2 < 3 free slots
    _drive(b)                            # ...but nobody is starved
    assert len(b.finished) == 3


# ---------------------------------------------------------------------------
# eviction cap + aging: the livelock regression
# ---------------------------------------------------------------------------

def _sustained_stream(evict_cap, ticks=300):
    """reserve="min" under a never-ending one-request-per-tick stream.

    The pool (5 usable blocks) cannot hold two full requests (4 blocks
    each), so resident growth keeps evicting the youngest resident — and
    with a fresh arrival every tick, the evicted request is readmitted as
    the youngest again and re-evicted before it can finish."""
    b = ContinuousBatcher(dp=1, slots_local=2, nb_local=6, block_size=2,
                          max_blocks=4, chunk=2, reserve="min",
                          evict_cap=evict_cap)
    for t in range(ticks):
        b.submit(Request(rid=t, prompt=[1, 2], max_new_tokens=7, arrival=t))
        plan = b.plan_step()
        tok = np.zeros(b.batch, np.int64)
        for slot, req in plan.requests.items():
            tok[slot] = (req.rid * 1000 + req.next_pos
                         + int(plan.n_new[slot])) % 97
        b.commit(plan, tok)
    return b


def test_reserve_min_livelocks_without_eviction_cap():
    # the regression: with the cap disabled (legacy PR-8 semantics), the
    # first request is starved FOREVER — hundreds of ticks, dozens of
    # evictions, zero completions for a 9-tick job
    b = _sustained_stream(evict_cap=0)
    assert 0 not in {r.rid for r in b.finished}
    starved = next(r for r in b.waiting + list(b.resident.values())
                   if r.rid == 0)
    assert starved.evictions > 20, starved.evictions


def test_eviction_cap_with_aging_breaks_the_livelock():
    b = _sustained_stream(evict_cap=3)
    done = {r.rid: r for r in b.finished}
    assert 0 in done, "aging failed to rescue the starved request"
    assert done[0].evictions <= 3
    assert done[0].finish_tick < 30      # rescued promptly, not eventually
    led = b.ledger()
    assert led["max_evictions_per_request"] <= 3
    assert led["accounted"]


def test_capped_eviction_streams_stay_deterministic():
    # the cap changes the schedule, not the tokens: per-(rid, position)
    # streams still match an eviction-free run of the same requests
    def finished_streams(**kw):
        b = ContinuousBatcher(dp=1, slots_local=2, nb_local=6, block_size=4,
                              max_blocks=4, chunk=4, **kw)
        for i in range(3):
            b.submit(Request(rid=i, prompt=[1, 2, 3, 4], max_new_tokens=9))
        _drive(b)
        return {r.rid: r.generated for r in b.finished}

    want = finished_streams(reserve="full")
    got = finished_streams(reserve="min", evict_cap=2)
    assert got == want


# ---------------------------------------------------------------------------
# world-change replay (device-free half of the chaos contract)
# ---------------------------------------------------------------------------

def test_rebuild_world_replays_bitwise_and_keeps_ledger():
    def run(rebuild_at=None):
        b = ContinuousBatcher(dp=2, slots_local=2, nb_local=9, block_size=4,
                              max_blocks=4, chunk=4)
        for i in range(6):
            b.submit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=8))
        for _ in range(500):
            if b.idle:
                return b
            if rebuild_at is not None and b.tick == rebuild_at:
                replayed = b.rebuild_world(dp=1)
                assert replayed and all(r.next_pos == 0 for r in replayed)
                rebuild_at = None
            plan = b.plan_step()
            tok = np.zeros(b.batch, np.int64)
            for slot, req in plan.requests.items():
                tok[slot] = (req.rid * 1000 + req.next_pos
                             + int(plan.n_new[slot])) % 97
            b.commit(plan, tok)
        raise AssertionError("did not drain")

    base = run()
    faulted = run(rebuild_at=4)
    assert ({r.rid: r.generated for r in faulted.finished}
            == {r.rid: r.generated for r in base.finished})
    led = faulted.ledger()
    assert led["accounted"] and led["replays"] > 0
    assert faulted.dp == 1 and faulted.batch == 2
    # the tick clock spans the fault: latency accounting never reset
    assert faulted.tick > base.tick
    replayed = [r for r in faulted.finished if r.replays]
    assert replayed and all(("replay", 4) in r.events for r in replayed)


def test_rebuild_world_resets_allocators_without_leak():
    b = ContinuousBatcher(dp=2, slots_local=2, nb_local=9, block_size=4,
                          max_blocks=4, chunk=4)
    for i in range(4):
        b.submit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=6))
    b.commit(b.plan_step(), np.zeros(b.batch, np.int64))
    assert any(a.free_blocks < 8 for a in b.allocators)
    b.rebuild_world(dp=2)
    assert all(a.free_blocks == 8 for a in b.allocators)   # full pools
    _drive(b)
    assert len(b.finished) == 4


# ---------------------------------------------------------------------------
# FaultPlan CLI spec
# ---------------------------------------------------------------------------

def test_fault_plan_parse():
    plan = FaultPlan.parse("preempt@20x4,grow@40x4,crash@60")
    kinds = [(e.kind, e.at_step, e.devices) for e in plan.events]
    assert kinds == [("preempt", 20, 4), ("grow", 40, 4), ("crash", 60, 0)]
    assert not plan.events[0].notice     # bare preempt is the abrupt kill
    assert FaultPlan.parse("notice@5x2").events[0].notice
    ev = FaultPlan.parse("slow@3x2.5").events[0]
    assert ev.kind == "slow" and ev.factor == 2.5 and not ev.evict
    assert FaultPlan.parse("evict@3").events[0].evict
    assert FaultPlan.parse("").events == []


def test_fault_plan_parse_rejects_garbage():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("meteor@5")
    with pytest.raises(ValueError, match="kind@tick"):
        FaultPlan.parse("preempt-5")


def test_crash_event_fires_once():
    plan = FaultPlan.parse("crash@2")
    plan(1)
    with pytest.raises(EngineCrashError):
        plan(2)
    plan(2)                              # one-shot: replay does not re-raise
    assert plan.log and plan.log[0]["kind"] == "crash"


# ---------------------------------------------------------------------------
# chaos properties (subprocess harness, 8 virtual devices)
# ---------------------------------------------------------------------------

CHAOS_CHECKS = ("preempt_replay_bitwise", "grow_back_readmission",
                "straggler_evict", "crash_retry", "shed_under_burst")


@pytest.mark.parametrize("name", CHAOS_CHECKS)
def test_serve_chaos_harness(serve_chaos_results, name):
    assert serve_chaos_results[name]["ok"], serve_chaos_results[name]


def test_chaos_replay_is_bitwise_everywhere(serve_chaos_results):
    summary = serve_chaos_results["summary"]
    assert all(summary["replay_bitwise"].values()), summary
    burst = summary["shed_under_burst"]
    assert burst["accounted"] and burst["shed"] > 0 and burst["completed"] > 0
    assert burst["ladder_engaged"]
