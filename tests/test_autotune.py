"""Autotuner + link-model tests.

Pure units run on any ambient device set (the ranking math never touches
devices — model/topology are duck-typed stubs); the census-match property
tests run through the 8-virtual-device subprocess harness
(tests/autotune_harness.py), comparing the analytical per-stage byte counts
against the measured ``hlo_stats.analyze`` census for every
(topology x wire dtype), plus ``policy="auto"`` end to end.
"""

import dataclasses
import json
import pathlib

import pytest

from harness_util import run_harness
from repro.core.autotune import (
    Plan, compare_census, enumerate_candidates, gather_stages,
    predict_traffic, rank_policies, resolve_config,
)
from repro.core.comm import CommEngine, GatherPolicy, SyncPolicy
from repro.core.linkmodel import (
    EFA_100G, PROFILES, V5E, custom_profile, gbps, get_profile,
)
from repro.core.mics import MiCSConfig

HARNESS = pathlib.Path(__file__).parent / "autotune_harness.py"


# ---------------------------------------------------------------------------
# device-free stubs: the tuner only reads sizes and names
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StubTopo:
    axes: dict
    partition_axes: tuple
    replication_axes: tuple

    def axis_size(self, name):
        return self.axes[name]

    @property
    def partition_size(self):
        out = 1
        for a in self.partition_axes:
            out *= self.axes[a]
        return out

    @property
    def replication_degree(self):
        out = 1
        for a in self.replication_axes:
            out *= self.axes[a]
        return out


@dataclasses.dataclass(frozen=True)
class StubPool:
    name: str


class StubModel:
    """Three pools shaped like a small LM: embed + scanned stack + head."""

    def __init__(self, stack=8, flat_len=65536):
        self.pools = (StubPool("layers"),)
        self._shapes = {
            "embed": (1, 1, 16384),
            "layers": (stack, 1, flat_len),
            "head": (1, 1, 20480),
        }

    def all_pools(self):
        return (StubPool("embed"), StubPool("layers"), StubPool("head"))

    def global_flat_shapes(self):
        return dict(self._shapes)


def topo_single(p=16, repl=2):
    return StubTopo({"shard": p, "repl": repl},
                    ("shard",), ("repl",))


def topo_multi(pods=2, shard=8):
    return StubTopo({"pod": pods, "shard": shard, "repl": 1},
                    ("pod", "shard"), ("repl",))


# ---------------------------------------------------------------------------
# linkmodel units
# ---------------------------------------------------------------------------

def test_named_profiles_and_lookup():
    for name in ("v5e", "efa-100g", "efa-400g"):
        p = get_profile(name)
        assert p.name == name
        assert p.intra.bandwidth > 0 and p.inter.bandwidth > 0
        assert p.node_size > 1
    # the heterogeneous-link profiles the paper's argument rests on
    assert V5E.intra.bandwidth > V5E.inter.bandwidth
    assert EFA_100G.intra.bandwidth > EFA_100G.inter.bandwidth
    assert get_profile(V5E) is V5E
    with pytest.raises(KeyError):
        get_profile("nvlink-9000")


def test_gbps_and_custom_constructor():
    assert gbps(100) == 12.5e9          # 100 Gbps EFA = 12.5 GB/s
    assert EFA_100G.inter.bandwidth == gbps(100)
    prof = custom_profile("test-table", intra_bw=100e9, inter_bw=1e9,
                          node_size=4, register=True)
    assert PROFILES["test-table"] is prof
    assert get_profile("test-table").node_size == 4


def test_ring_time_alpha_beta():
    p = custom_profile("rt", intra_bw=10e9, inter_bw=1e9, node_size=4,
                       alpha_intra=1e-6, alpha_inter=10e-6)
    # 8 participants, 7 hops, 7 MB on the wire at 1 GB/s + 7 * 10us
    t = p.ring_time("inter", 8, 7e6)
    assert t == pytest.approx(7 * 10e-6 + 7e6 / 1e9)
    assert p.ring_time("intra", 1, 1e9) == 0.0
    assert p.group_tier(range(4)) == "intra"
    assert p.group_tier([0, 4]) == "inter"


# ---------------------------------------------------------------------------
# stage algebra
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,inner", [(4, 2), (8, 2), (8, 4), (16, 4)])
def test_staged_bytes_equal_flat_bytes(p, inner):
    """Hierarchical staging moves bytes between tiers, never saves them:
    sum over stages of per-participant wire fractions == (p-1)/p."""
    topo = StubTopo({"shard": p, "repl": 1}, ("shard",), ("repl",))
    for topology in ("flat", "inner_first", "outer_first"):
        stages = gather_stages(topology, topo, inner)
        total = sum(st.wire_frac for st in stages)
        assert total == pytest.approx((p - 1) / p), (topology, stages)


def test_outer_first_minimizes_slow_tier_bytes():
    """Only M(o-1)/p of an outer-first gather crosses the slow tier vs
    M(o-1)/o for inner-first — the paper's §3.3 argument in one assert."""
    topo = StubTopo({"shard": 16, "repl": 1}, ("shard",), ("repl",))
    by = {
        t: {st.label: st.wire_frac for st in gather_stages(t, topo, 4)}
        for t in ("inner_first", "outer_first")
    }
    assert by["outer_first"]["outer"] < by["inner_first"]["outer"]
    assert by["outer_first"]["outer"] == pytest.approx(3 / 16)
    assert by["inner_first"]["outer"] == pytest.approx(3 / 4)


def test_predict_traffic_stage_structure():
    model, topo = StubModel(), topo_single(p=16, repl=2)
    pred = predict_traffic(model, topo,
                           GatherPolicy("inner_first", "bf16", 4, False),
                           SyncPolicy(), micro_steps=2)
    stages = pred["by_stage"]
    assert set(stages) == {"param_gather.inner", "param_gather.outer",
                           "grad_rs.inner", "grad_rs.outer", "hop2"}
    # hop-2 bf16 compression halves exactly the hop2 stage
    pred_c = predict_traffic(model, topo,
                             GatherPolicy("inner_first", "bf16", 4, False),
                             SyncPolicy("2hop", "bf16"), micro_steps=2)
    assert pred_c["by_stage"]["hop2"]["wire_bytes"] == \
        pytest.approx(stages["hop2"]["wire_bytes"] / 2)
    for k in ("param_gather.inner", "grad_rs.outer"):
        assert pred_c["by_stage"][k]["wire_bytes"] == \
            pytest.approx(stages[k]["wire_bytes"])


def test_compare_census_filters_to_engine_stages():
    got = compare_census(
        {"param_gather.flat": {"wire_bytes": 10.0}},
        {"param_gather.flat": {"wire_bytes": 10.0},
         "model_gather": {"wire_bytes": 99.0},
         "tp_allreduce": {"wire_bytes": 99.0}},
    )
    assert set(got) == {"param_gather.flat"}
    assert got["param_gather.flat"]["ratio"] == 1.0


# ---------------------------------------------------------------------------
# ranking regressions
# ---------------------------------------------------------------------------

def test_auto_picks_outer_first_on_slow_inter_pod():
    """The ISSUE regression: when the profile's inter-pod bandwidth is far
    below intra-pod, the paper-faithful 3-stage outer-first gather must win
    (it is the only topology that sends just M(o-1)/p over the slow tier)."""
    prof = custom_profile("slow-pod", intra_bw=100e9, inter_bw=1e9,
                          node_size=8)
    plan = rank_policies(StubModel(), topo_multi(pods=2, shard=8), prof,
                         micro_steps=4, prefetch=False)
    assert plan.chosen.gather.topology == "outer_first"
    # and the winner's slow-tier bytes are the minimum among candidates of
    # the same (lossless) numerics — the lossy int8/qgZ rows move even
    # fewer bytes but are not eligible without opt-in
    lossless = [c for c in plan.candidates
                if not (c.lossy_wire or c.lossy_hop2 or c.lossy_hop1)]
    assert plan.chosen.inter_wire_bytes == pytest.approx(
        min(c.inter_wire_bytes for c in lossless))


def test_uniform_links_never_pick_outer_first():
    """With a homogeneous network the reorder stage is pure cost — the
    3-stage schedule must not win."""
    prof = custom_profile("uniform", intra_bw=50e9, inter_bw=50e9,
                          node_size=16, alpha_inter=1e-6)
    plan = rank_policies(StubModel(), topo_single(p=16), prof,
                         micro_steps=4, prefetch=False)
    assert plan.chosen.gather.topology != "outer_first"


def test_lossy_candidates_ranked_but_not_chosen():
    prof = custom_profile("lossy-test", intra_bw=100e9, inter_bw=1e9,
                          node_size=8)
    plan = rank_policies(StubModel(), topo_single(p=16, repl=2), prof,
                         micro_steps=2, prefetch=False)
    assert any(c.lossy_wire for c in plan.candidates)      # int8 in table
    assert not plan.chosen.lossy_wire                      # but not chosen
    assert not plan.chosen.lossy_hop2
    plan_h = rank_policies(StubModel(), topo_single(p=16, repl=2), prof,
                           micro_steps=2, prefetch=False,
                           allow_bf16_hop2=True)
    # hop-2 compression strictly reduces hop2 bytes: opted in, it wins
    assert plan_h.chosen.sync.hop2_wire_dtype == "bf16"
    # int8 wire halves gather bytes but its straight-through adjoint
    # reduce-scatters in fp32 (2x bf16), so in *training* it does not pay;
    # in serve mode (no gradients) it is the clear winner once allowed
    plan_s = rank_policies(StubModel(), topo_single(p=16, repl=2), prof,
                           mode="serve", prefetch=True, allow_int8=True)
    assert plan_s.chosen.gather.wire_dtype == "int8"


def test_candidate_grid_shape():
    cands = enumerate_candidates(topo_single(p=8, repl=2), prefetch=False)
    gathers = {(g.topology, g.wire_dtype, g.inner) for g, _ in cands}
    # flat + {inner,outer}x{2,4} per wire dtype, hop2 in {fp32, bf16,
    # int8}, hop1 in {fp32, int8} (the qgZ axis)
    assert len(gathers) == 3 * (1 + 2 * 2)
    assert {s.hop1_wire_dtype for _, s in cands} == {"fp32", "int8"}
    assert {s.hop2_wire_dtype for _, s in cands} == {"fp32", "bf16", "int8"}
    assert len(cands) == 3 * 2 * len(gathers)
    # p=2 degenerates to flat only
    flat_only = enumerate_candidates(
        StubTopo({"shard": 2, "repl": 1}, ("shard",), ("repl",)),
        prefetch=False)
    assert {g.topology for g, _ in flat_only} == {"flat"}
    # serving has no gradients: the hop-1 axis collapses
    serve = enumerate_candidates(topo_single(p=8, repl=2), prefetch=True,
                                 mode="serve")
    assert {s.hop1_wire_dtype for _, s in serve} == {"fp32"}


def test_plan_table_and_describe_serializable():
    plan = rank_policies(StubModel(), topo_single(p=8), "v5e",
                         micro_steps=2, prefetch=True)
    assert isinstance(plan, Plan)
    txt = plan.table()
    assert "autotune[v5e]" in txt and "*" in txt
    json.dumps(plan.describe())


def test_clip_and_carry_axes_ranked():
    """The approx-clip and host-carry axes join the ranked grid: approx
    rows on every bucketed train candidate (repl > 1), remat/host carry
    rows whenever a budget prices the grid — and neither outranks the
    reference numerics without its opt-in."""
    prof = custom_profile("axes-slow", intra_bw=100e9, inter_bw=1e9,
                          node_size=8)
    kw = dict(micro_steps=2, prefetch=True, hbm_budget_gb=64.0)
    plan = rank_policies(StubModel(), topo_single(p=8, repl=2), prof, **kw)
    assert {c.clip_mode for c in plan.candidates} == {"exact", "approx"}
    assert all(c.boundary == "bucketed" for c in plan.candidates
               if c.clip_mode == "approx")
    carries = {(c.gather.prefetch_carry, c.gather.carry_offload)
               for c in plan.candidates}
    assert {("stored", "none"), ("remat", "none"),
            ("stored", "host")} <= carries
    # pairing each bucketed candidate with its approx twin: pipelining
    # AdamW under hop-2 can only shrink the exposed time, and does shrink
    # it somewhere in the grid
    by_key = {}
    for c in plan.candidates:
        key = (c.gather, c.sync, c.boundary, c.hop2_bucket_mb)
        by_key.setdefault(key, {})[c.clip_mode] = c
    paired = [v for v in by_key.values() if len(v) == 2]
    assert paired
    for v in paired:
        assert v["approx"].t_hop2_exposed_s \
            <= v["exact"].t_hop2_exposed_s + 1e-18
    assert any(v["approx"].t_hop2_exposed_s < v["exact"].t_hop2_exposed_s
               for v in paired)
    # approx changes numerics: ranked, but chosen only under the opt-in
    assert plan.chosen.clip_mode == "exact"
    assert not plan.chosen.gather.carry_offload == "host"
    # both axes are visible columns in the ranked table
    txt = plan.table(top=None)
    head = txt.splitlines()[1]
    assert "clip" in head and "carry" in head and "off" in head
    assert "approx" in txt and "host" in txt and "remat" in txt


def test_resolve_roundtrips_clip_and_offload():
    """clip_mode='approx' on an auto config is the approximation opt-in;
    the resolved config carries the chosen clip/carry/offload fields and
    revalidates (approx only rides the bucket pipeline)."""
    prof = custom_profile("rt-axes", intra_bw=100e9, inter_bw=1e9,
                          node_size=8)
    mcfg = MiCSConfig(micro_steps=2, policy="auto", link_profile=prof,
                      clip_mode="approx", boundary_schedule="bucketed",
                      hbm_budget_gb=64.0)
    resolved, plan = resolve_config(mcfg, StubModel(),
                                    topo_single(p=8, repl=2))
    assert resolved.policy == "manual"
    assert resolved.clip_mode == plan.chosen.clip_mode
    assert resolved.carry_offload == plan.chosen.gather.carry_offload
    assert resolved.prefetch_carry == plan.chosen.gather.prefetch_carry
    assert resolved.boundary_schedule == plan.chosen.boundary
    if resolved.clip_mode == "approx":
        assert resolved.boundary_schedule == "bucketed"
    # an exact-clip config through the same grid never resolves to approx
    mcfg_e = dataclasses.replace(mcfg, clip_mode="exact")
    resolved_e, _ = resolve_config(mcfg_e, StubModel(),
                                   topo_single(p=8, repl=2))
    assert resolved_e.clip_mode == "exact"


# ---------------------------------------------------------------------------
# config resolution
# ---------------------------------------------------------------------------

def test_policy_field_validated():
    with pytest.raises(ValueError):
        MiCSConfig(policy="autotune")


def test_manual_config_passes_through():
    mcfg = MiCSConfig()
    resolved, plan = resolve_config(mcfg, StubModel(), topo_single())
    assert resolved is mcfg and plan is None


def test_resolve_roundtrips_through_from_config(topo1):
    """The resolved legacy fields must reconstruct exactly the chosen
    GatherPolicy/SyncPolicy when CommEngine.from_config interprets them."""
    prof = custom_profile("rt-slow", intra_bw=100e9, inter_bw=1e9,
                          node_size=8)
    mcfg = MiCSConfig(micro_steps=2, policy="auto", link_profile=prof,
                      prefetch=False)
    resolved, plan = resolve_config(mcfg, StubModel(),
                                    topo_single(p=16, repl=2))
    assert resolved.policy == "manual"
    eng = CommEngine.from_config(topo1, resolved)
    chosen = plan.chosen
    assert eng.gather_policy.topology == chosen.gather.topology
    assert eng.gather_policy.wire_dtype == chosen.gather.wire_dtype
    assert eng.gather_policy.inner == chosen.gather.inner
    assert eng.sync_policy == chosen.sync


# ---------------------------------------------------------------------------
# multi-device harness: analytical census == measured census
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def harness_results():
    return run_harness(HARNESS)


CHECKS = [
    "census_match_single", "census_match_prefetch", "census_match_multi",
    "census_match_qgz", "auto_plan_census",
]


@pytest.mark.parametrize("name", CHECKS)
def test_autotune_check(harness_results, name):
    res = harness_results.get(name)
    assert res is not None, f"harness did not run {name}"
    assert res["ok"], f"{name}: {res.get('err')}\n{res.get('tb', '')}"


def test_census_matrix_covered(harness_results):
    detail = harness_results.get("census_match_single_detail")
    assert detail is not None
    combos = {f"{t}/{w}" for t in ("flat", "inner_first", "outer_first")
              for w in ("fp32", "bf16", "int8")}
    assert combos <= set(detail)
    for combo, stages in detail.items():
        for stage, row in stages.items():
            assert abs(row["ratio"] - 1.0) <= 0.02, (combo, stage, row)
