"""Checkpoint roundtrip, crash-safe atomicity, fault-tolerant train loop with
injected failures, and data-pipeline determinism/seekability."""

import dataclasses

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, smoke_variant
from repro.core.mics import MiCSConfig, init_state
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import LoopConfig, train


def test_checkpoint_roundtrip(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, seed=5)
    ck = Checkpointer(tmp_path)
    ck.save(state, step=7, topo=topo1, data_cursor=123)

    restored, meta = ck.restore(model, topo1)
    assert meta["step"] == 7 and meta["data_cursor"] == 123
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)


def test_checkpoint_latest_and_atomicity(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1)
    ck = Checkpointer(tmp_path)
    ck.save(state, step=1, topo=topo1)
    ck.save(state, step=2, topo=topo1)
    # a stale .tmp dir (simulated crash) must be ignored
    (tmp_path / "step_00000099.tmp").mkdir()
    assert ck.latest_step() == 2


def test_train_loop_recovers_from_injected_fault(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    mcfg = MiCSConfig(micro_steps=2)
    oc = OptConfig(total_steps=8, warmup_steps=0, lr_max=1e-3)
    dc = DataConfig(vocab=cfg.vocab, seq=32, global_batch=4, micro_steps=2)
    lc = LoopConfig(total_steps=8, checkpoint_every=2, log_every=0,
                    checkpoint_dir=str(tmp_path))

    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    stats = train(model, topo1, mcfg, oc, dc, lc, fault_injector=injector)
    assert stats.restarts == 1
    assert len(stats.losses) >= 8
    assert np.isfinite(stats.losses[-1])
    ck = Checkpointer(tmp_path)
    assert ck.latest_step() == 8


def test_train_loop_resume_continues_data_cursor(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    mcfg = MiCSConfig(micro_steps=2)
    dc = DataConfig(vocab=cfg.vocab, seq=32, global_batch=4, micro_steps=2)

    lc1 = LoopConfig(total_steps=4, checkpoint_every=2, log_every=0,
                     checkpoint_dir=str(tmp_path))
    train(model, topo1, mcfg, OptConfig(total_steps=8, warmup_steps=0),
          dc, lc1)
    lc2 = dataclasses.replace(lc1, total_steps=6)
    stats = train(model, topo1, mcfg,
                  OptConfig(total_steps=8, warmup_steps=0), dc, lc2)
    assert len(stats.losses) == 2  # resumed at 4, ran to 6


def test_data_pipeline_deterministic_and_seekable():
    dc = DataConfig(vocab=128, seq=16, global_batch=8, micro_steps=2)
    src1, src2 = SyntheticLM(dc), SyntheticLM(dc)
    b1 = src1.global_step_batch(3)
    b2 = src2.global_step_batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host sharding partitions the global batch exactly
    h0 = src1.host_step_batch(3, 0, 2)
    h1 = src1.host_step_batch(3, 1, 2)
    merged = np.concatenate([h0["tokens"], h1["tokens"]], axis=1)
    np.testing.assert_array_equal(merged, b1["tokens"])
    # targets are inputs shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, :, 1:],
                                  b1["targets"][:, :, :-1])
