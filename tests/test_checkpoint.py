"""Checkpoint roundtrip, crash-safe atomicity (incl. the injected
crash-mid-save writer kill), fault-tolerant train loop with injected
failures, data-pipeline determinism/seekability and exact restart-boundary
continuity, and the offload_opt cross-topology host-stash reset contract.
The multi-device save->restore->save reshard roundtrip (p=2 -> p=4 -> p=2)
is pinned from the elastic harness run (tests/elastic_harness.py)."""

import dataclasses
import hashlib
import json
import logging

import jax
import numpy as np
import pytest

import repro.runtime.train_loop as TL
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, smoke_variant
from repro.core.faults import CrashDuringSaveError, FaultPlan
from repro.core.hostoffload import CKPT_NAMESPACE, export_stash, stash_clear
from repro.core.mics import MiCSConfig, init_state
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import LoopConfig, train


def test_checkpoint_roundtrip(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, seed=5)
    ck = Checkpointer(tmp_path)
    ck.save(state, step=7, topo=topo1, data_cursor=123)

    restored, meta = ck.restore(model, topo1)
    assert meta["step"] == 7 and meta["data_cursor"] == 123
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)


def test_checkpoint_latest_and_atomicity(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1)
    ck = Checkpointer(tmp_path)
    ck.save(state, step=1, topo=topo1)
    ck.save(state, step=2, topo=topo1)
    # a stale .tmp dir (simulated crash) must be ignored
    (tmp_path / "step_00000099.tmp").mkdir()
    assert ck.latest_step() == 2


def test_latest_step_skips_malformed_and_incomplete_dirs(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1)
    ck = Checkpointer(tmp_path)
    ck.save(state, step=3, topo=topo1)
    # a stray non-numeric step_* name (e.g. a hand-made step_old backup)
    # must not crash the scan, let alone win it
    (tmp_path / "step_old").mkdir()
    (tmp_path / "step_12xy").mkdir()
    # a numeric dir missing its state blob (writer died before the state)
    (tmp_path / "step_00000007").mkdir()
    # a numeric dir with a truncated manifest (writer died mid-manifest)
    crashed = tmp_path / "step_00000009"
    crashed.mkdir()
    np.savez(crashed / "state.npz", leaf_0000=np.zeros(3))
    (crashed / "manifest.json").write_text('{"step": 9, "data_c')
    assert ck.latest_step() == 3
    # restore() follows the same completeness rule
    _, meta = ck.restore(model, topo1)
    assert meta["step"] == 3
    with pytest.raises(FileNotFoundError, match="incomplete"):
        ck.restore(model, topo1, step=9)


def test_crash_mid_save_leaves_tmp_and_restores_newest_complete(
        tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, seed=2)
    ck = Checkpointer(tmp_path)
    plan = FaultPlan().crash_during_save(2).bind(ck)
    ck.save(state, step=1, topo=topo1, data_cursor=1)
    with pytest.raises(CrashDuringSaveError):
        ck.save(state, step=2, topo=topo1, data_cursor=2)   # blocking: raises
    # the kill window leaves the .tmp corpse: state blob + truncated manifest
    corpse = tmp_path / "step_00000002.tmp"
    assert corpse.exists() and (corpse / "state.npz").exists()
    with pytest.raises(ValueError):
        json.loads((corpse / "manifest.json").read_text())
    assert not (tmp_path / "step_00000002").exists()
    # restore picks the newest COMPLETE step
    assert ck.latest_step() == 1
    restored, meta = ck.restore(model, topo1)
    assert meta["step"] == 1
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)
    # a later save recovers the cadence (the fired event is one-shot)
    ck.save(state, step=2, topo=topo1, data_cursor=2)
    assert ck.latest_step() == 2 and not corpse.exists()
    assert [e["kind"] for e in plan.log] == ["crash_during_save"]


def test_async_save_failure_surfaces_at_wait(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1)
    ck = Checkpointer(tmp_path)
    FaultPlan().crash_during_save(4).bind(ck)
    ck.save(state, step=4, topo=topo1, blocking=False)   # crash held...
    with pytest.raises(CrashDuringSaveError):
        ck.wait()                                        # ...surfaced here
    ck.wait()   # the failure is re-raised once, not forever


def test_offload_opt_cross_topology_restore_resets_stash_explicitly(
        tmp_path, topo1, caplog):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, seed=4, offload_opt=True)
    stash_clear()
    ck = Checkpointer(tmp_path)
    # fabricate one offloaded-moment shard (tag=TAG_M, slot 0, device 0)
    ck.save(state, step=5, topo=topo1, data_cursor=5,
            host_stash={(0, 1, 0, 0): np.arange(4.0)})

    # same topology: the stash comes back under the checkpoint namespace
    restored, meta = ck.restore(model, topo1, offload_opt=True)
    assert meta["host_stash"] == {
        "present": True, "restored": True, "reset": None}
    stash = export_stash(CKPT_NAMESPACE)
    assert list(stash) == [(CKPT_NAMESPACE, 1, 0, 0)]

    # tamper the manifest into a different source topology: the restore
    # must WARN, surface the reset in meta, and purge the stale entries
    mpath = tmp_path / "step_00000005" / "manifest.json"
    m = json.loads(mpath.read_text())
    m["mesh_axes"]["shard"] = 2
    mpath.write_text(json.dumps(m))
    with caplog.at_level(logging.WARNING, logger="repro.checkpoint"):
        restored, meta = ck.restore(model, topo1, offload_opt=True)
    assert meta["host_stash"] == {
        "present": True, "restored": False, "reset": "cross-topology"}
    assert any("do not reshard" in r.message for r in caplog.records)
    assert export_stash(CKPT_NAMESPACE) == {}   # stale entries purged
    # params/step still restore exactly either way
    assert meta["step"] == 5
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, restored)


def test_offload_opt_restore_without_stash_blob_warns(tmp_path, topo1, caplog):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, offload_opt=True)
    ck = Checkpointer(tmp_path)
    ck.save(state, step=1, topo=topo1)   # no host_stash passed
    with caplog.at_level(logging.WARNING, logger="repro.checkpoint"):
        _, meta = ck.restore(model, topo1, offload_opt=True)
    assert meta["host_stash"] == {
        "present": False, "restored": False, "reset": "missing"}
    assert any("no host stash" in r.message for r in caplog.records)


def test_train_loop_recovers_from_injected_fault(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    mcfg = MiCSConfig(micro_steps=2)
    oc = OptConfig(total_steps=8, warmup_steps=0, lr_max=1e-3)
    dc = DataConfig(vocab=cfg.vocab, seq=32, global_batch=4, micro_steps=2)
    lc = LoopConfig(total_steps=8, checkpoint_every=2, log_every=0,
                    checkpoint_dir=str(tmp_path))

    crashed = {"done": False}

    def injector(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected node failure")

    stats = train(model, topo1, mcfg, oc, dc, lc, fault_injector=injector)
    assert stats.restarts == 1
    assert len(stats.losses) >= 8
    assert np.isfinite(stats.losses[-1])
    ck = Checkpointer(tmp_path)
    assert ck.latest_step() == 8


def test_train_loop_resume_continues_data_cursor(tmp_path, topo1):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    mcfg = MiCSConfig(micro_steps=2)
    dc = DataConfig(vocab=cfg.vocab, seq=32, global_batch=4, micro_steps=2)

    lc1 = LoopConfig(total_steps=4, checkpoint_every=2, log_every=0,
                     checkpoint_dir=str(tmp_path))
    train(model, topo1, mcfg, OptConfig(total_steps=8, warmup_steps=0),
          dc, lc1)
    lc2 = dataclasses.replace(lc1, total_steps=6)
    stats = train(model, topo1, mcfg,
                  OptConfig(total_steps=8, warmup_steps=0), dc, lc2)
    assert len(stats.losses) == 2  # resumed at 4, ran to 6


def test_restart_boundary_replays_and_skips_no_batch(tmp_path, topo1,
                                                     monkeypatch):
    """Satellite (d): the resumed ``data_cursor`` continues the stream
    exactly — batch fingerprints across the restart boundary show neither a
    replayed nor a skipped batch."""
    served = []

    class RecordingLM(SyntheticLM):
        def global_step_batch(self, step):
            b = super().global_step_batch(step)
            served.append(
                (int(step), hashlib.sha1(b["tokens"].tobytes()).hexdigest()))
            return b

    monkeypatch.setattr(TL, "SyntheticLM", RecordingLM)
    cfg = smoke_variant(get_config("llama3.2-1b"))
    model = build_model(cfg, tp=1)
    mcfg = MiCSConfig(micro_steps=2)
    dc = DataConfig(vocab=cfg.vocab, seq=32, global_batch=4, micro_steps=2)
    oc = OptConfig(total_steps=8, warmup_steps=0)
    lc1 = LoopConfig(total_steps=4, checkpoint_every=2, log_every=0,
                     checkpoint_dir=str(tmp_path))
    train(model, topo1, mcfg, oc, dc, lc1)
    boundary = len(served)
    train(model, topo1, mcfg, oc, dc,
          dataclasses.replace(lc1, total_steps=8))

    cursors = [c for c, _ in served]
    assert cursors[:boundary] == [0, 1, 2, 3]
    assert cursors[boundary:] == [4, 5, 6, 7]   # no replay, no skip
    # the fingerprints are the stream's, not an artifact of the restart:
    # a fresh loader reproduces every one, and they are pairwise distinct
    fresh = SyntheticLM(dc)
    for c, h in served:
        want = hashlib.sha1(
            fresh.global_step_batch(c)["tokens"].tobytes()).hexdigest()
        assert h == want, f"batch {c} changed across the restart boundary"
    assert len({h for _, h in served}) == len(served)


def test_reshard_roundtrip_across_topologies(elastic_results):
    """Satellite (c), multi-device half: save -> restore -> save across
    p=2 -> p=4 -> p=2 is bitwise lossless (run in the elastic harness)."""
    res = elastic_results["reshard_roundtrip"]
    assert res["ok"], f"{res.get('err')}\n{res.get('tb', '')}"


def test_data_pipeline_deterministic_and_seekable():
    dc = DataConfig(vocab=128, seq=16, global_batch=8, micro_steps=2)
    src1, src2 = SyntheticLM(dc), SyntheticLM(dc)
    b1 = src1.global_step_batch(3)
    b2 = src2.global_step_batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host sharding partitions the global batch exactly
    h0 = src1.host_step_batch(3, 0, 2)
    h1 = src1.host_step_batch(3, 1, 2)
    merged = np.concatenate([h0["tokens"], h1["tokens"]], axis=1)
    np.testing.assert_array_equal(merged, b1["tokens"])
    # targets are inputs shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, :, 1:],
                                  b1["targets"][:, :, :-1])
