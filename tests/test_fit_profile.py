"""Unit tests for the measured-profile calibration tool
(tools/fit_profile.py): the per-tier (α, β) least-squares fit recovers a
known synthetic link table from policy-step observations, handles
unconstrained tiers via the fallback profile, and emits a runnable
``custom_profile()`` snippet."""

import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "tools"))

from fit_profile import (  # noqa: E402
    Observation, emit_snippet, fit_tiers, observations_from_bench,
)

# ground truth for the synthetic ledgers
ALPHA = {"intra": 2e-6, "inter": 20e-6, "host": 5e-6}
BW = {"intra": 40e9, "inter": 5e9, "host": 12e9}
T0 = 3e-3


def _obs(label, stages):
    t = T0
    for s in stages.values():
        t += s["alpha_events"] * ALPHA[s["tier"]] \
            + s["wire_bytes"] / BW[s["tier"]]
    return Observation(label=label, t_measured_s=t, stages=stages)


def _synthetic(n=6, tiers=("intra", "inter")):
    rng = np.random.default_rng(0)
    out = []
    for i in range(n):
        stages = {}
        for j, tier in enumerate(tiers):
            stages[f"stage{j}.{i}"] = {
                "tier": tier,
                "alpha_events": float(rng.integers(4, 200)),
                "wire_bytes": float(rng.integers(1, 2000)) * 1e6,
            }
        out.append(_obs(f"policy{i}", stages))
    return out


def test_fit_recovers_known_profile():
    fit = fit_tiers(_synthetic(8))
    for tier in ("intra", "inter"):
        tf = fit.tiers[tier]
        assert tf.constrained and not tf.clamped
        assert tf.alpha == pytest.approx(ALPHA[tier], rel=1e-4)
        assert tf.bandwidth == pytest.approx(BW[tier], rel=1e-4)
    assert fit.t0 == pytest.approx(T0, rel=1e-4)
    assert fit.residual_rms_s < 1e-9


def test_fit_unconstrained_tier_flagged():
    fit = fit_tiers(_synthetic(6, tiers=("intra",)))
    assert fit.tiers["intra"].constrained
    assert not fit.tiers["inter"].constrained
    assert fit.tiers["intra"].alpha == pytest.approx(ALPHA["intra"], rel=1e-4)


def test_fit_needs_two_observations():
    with pytest.raises(ValueError):
        fit_tiers(_synthetic(1))


def test_clamped_fit_stays_physical():
    """A compute-dominated ledger (comm terms swamped by noisy times) must
    not emit negative alphas or bandwidths."""
    rng = np.random.default_rng(3)
    obs = []
    for i in range(6):
        stages = {"s": {"tier": "intra", "alpha_events": 10.0 + 3 * i,
                        "wire_bytes": 1e6 * (1 + i % 3)}}
        obs.append(Observation(f"p{i}", 5e-3 + float(rng.normal(0, 1e-3)),
                               stages))
    fit = fit_tiers(obs)
    assert fit.tiers["intra"].alpha >= 0.0
    assert fit.tiers["intra"].bandwidth > 0.0


def test_underdetermined_fit_rejected():
    """Fewer independent observations than exercised coefficients must
    raise instead of emitting an arbitrary min-norm profile."""
    with pytest.raises(ValueError, match="underdetermined"):
        fit_tiers(_synthetic(4))           # 4 obs, 5 coefficients
    # collinear designs are rejected even with enough rows
    obs = [Observation(f"p{i}", 1e-3 * (1 + i % 2),
                       {"s": {"tier": "intra", "alpha_events": 10.0,
                              "wire_bytes": 1e6}})
           for i in range(6)]
    with pytest.raises(ValueError, match="underdetermined"):
        fit_tiers(obs)


def test_snippet_is_runnable_and_registers():
    fit = fit_tiers(_synthetic(8))
    code = emit_snippet(fit, name="fitted-test-table", node_size=4)
    ns: dict = {}
    exec(code, ns)  # the docs/CLI contract: ready-to-paste
    prof = ns["profile"]
    assert prof.intra.bandwidth == pytest.approx(BW["intra"], rel=1e-4)
    assert prof.inter.bandwidth == pytest.approx(BW["inter"], rel=1e-4)
    assert prof.node_size == 4
    from repro.core.linkmodel import get_profile

    assert get_profile("fitted-test-table") is prof


def test_snippet_fallback_for_unconstrained_tier():
    from repro.core.linkmodel import get_profile

    fit = fit_tiers(_synthetic(6, tiers=("intra",)))
    code = emit_snippet(fit, name="fitted-intra-only", node_size=8,
                        fallback="v5e")
    assert "unconstrained" in code
    ns: dict = {}
    exec(code, ns)
    assert ns["profile"].inter.bandwidth == get_profile("v5e").inter.bandwidth


def test_fit_recovers_host_tier():
    """A ledger whose policies exercise carry_offload='host' stages (the
    ``tier='host'`` fit rows benchmarks/comm_bench.py emits) constrains the
    device<->host (α, β) alongside the network tiers."""
    fit = fit_tiers(_synthetic(10, tiers=("intra", "inter", "host")))
    tf = fit.tiers["host"]
    assert tf.constrained and not tf.clamped
    assert tf.alpha == pytest.approx(ALPHA["host"], rel=1e-4)
    assert tf.bandwidth == pytest.approx(BW["host"], rel=1e-4)
    assert fit.residual_rms_s < 1e-9


def test_snippet_emits_host_tier_only_when_constrained():
    fit = fit_tiers(_synthetic(10, tiers=("intra", "inter", "host")))
    code = emit_snippet(fit, name="fitted-host-table", node_size=4)
    assert "host_bw" in code and "alpha_host" in code
    ns: dict = {}
    exec(code, ns)
    prof = ns["profile"]
    assert prof.link("host").bandwidth == pytest.approx(BW["host"], rel=1e-4)
    assert prof.link("host").alpha == pytest.approx(ALPHA["host"], rel=1e-4)
    # no host stages in the ledger -> the kwargs are omitted and the
    # profile falls back to DEFAULT_HOST_LINK
    from repro.core.linkmodel import DEFAULT_HOST_LINK

    code2 = emit_snippet(fit_tiers(_synthetic(8)), name="fitted-no-host",
                         node_size=4)
    assert "host_bw" not in code2
    ns2: dict = {}
    exec(code2, ns2)
    assert ns2["profile"].link("host") is DEFAULT_HOST_LINK


def test_observations_from_bench_shape():
    bench = {"policies": {
        "flat@bf16": {"fit_inputs": {
            "t_measured_s": 1e-3,
            "stages": {"param_gather.flat": {
                "tier": "intra", "alpha_events": 6.0, "wire_bytes": 1e6}},
        }},
        "no-ledger": {},
    }}
    obs = observations_from_bench(bench)
    assert len(obs) == 1 and obs[0].label == "flat@bf16"
    assert obs[0].stages["param_gather.flat"]["tier"] == "intra"
