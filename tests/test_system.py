"""End-to-end behaviour: every assigned architecture trains (reduced config)
on CPU — one forward/backward/optimizer step with finite loss and the exact
state structure, plus a serve (prefill+decode) smoke for each family."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, smoke_variant
from repro.core.mics import MiCSConfig, build_train_step, init_state
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.serving import build_serve_steps

ARCH_NAMES = [c.name for c in ASSIGNED]


def _batch(cfg, s=2, b=2, t=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (s, b, t)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (s, b, t)), jnp.int32),
        "mask": jnp.ones((s, b, t), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(s, b, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.family == "encdec":
        batch["audio"] = jnp.asarray(
            rng.normal(size=(s, b, cfg.n_audio_frames, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step(arch, topo1):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1)
    step = build_train_step(
        model, topo1, MiCSConfig(micro_steps=2),
        OptConfig(total_steps=10, warmup_steps=0, lr_max=1e-3))
    batch = _batch(cfg)

    before = {k: np.asarray(v) for k, v in state["params"].items()}
    state2, metrics = step(state, batch)

    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, loss
    assert np.isfinite(float(metrics["grad_norm"]))
    # params moved, structure/shape preserved, no NaNs
    for name, arr in state2["params"].items():
        a = np.asarray(arr)
        assert a.shape == before[name].shape
        assert np.all(np.isfinite(a)), name
        assert not np.array_equal(a, before[name]), f"{name} did not update"
    assert int(np.asarray(state2["step"])) == 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_decode(arch, topo1):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg, tp=1)
    state = init_state(model, topo1, seed=1)
    prefill_fn, decode_fn = build_serve_steps(
        model, topo1, MiCSConfig(), cache_len=24)
    rng = np.random.default_rng(2)
    b, t0 = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t0)),
                                   jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_vision_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["audio"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)), jnp.bfloat16)

    logits, caches = prefill_fn(state["params"], batch)
    assert logits.shape[:2] == (b, 1)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    tok = jnp.argmax(jnp.asarray(logits[:, -1:]), axis=-1).astype(jnp.int32)
    for i in range(3):
        logits, tok, caches = decode_fn(
            state["params"], caches, tok.astype(jnp.int32), jnp.int32(t0 + i))
        arr = np.asarray(logits, np.float32)
        assert arr.shape[:2] == (b, 1)
        assert np.all(np.isfinite(arr))
        ids = np.asarray(tok)
        assert ids.min() >= 0 and ids.max() < cfg.vocab
