"""Boundary-scheduler correctness harness, run in a subprocess with 8
virtual CPU devices (same pattern as comm_harness.py).  Prints one JSON
object with named check results; tests/test_schedule.py asserts on them.
Checks:

  bucket_plan          partition_buckets/plan_boundary cover every element
                       exactly once, respect the byte cap, and degenerate
                       correctly (one bucket, bucket > total bytes)
  bitwise_bucket_sizes bucketed boundary == serial boundary, bitwise on
                       params/m/v and metrics, across bucket sizes
                       including the one-bucket and bucket>total-bytes
                       degenerate cases (hop 2 live: repl=2)
  bitwise_topologies   the same equivalence under all three gather
                       topologies and the bf16/int8 wire dtypes (the
                       boundary must be schedule-invariant whatever the
                       hop-1 policy feeding it)
  bitwise_compress     ... and under bf16-compressed hop-2 wire
  census_interleave    the compiled bucketed step's HLO shows hop-2 at
                       bucket granularity (hop2_ops == plan buckets,
                       max payload <= bucket bytes) interleaved with
                       norm/optimizer compute; the serial reference keeps
                       pool-granular hop-2 ops
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.flat_param import partition_buckets
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.schedule import GRAD_ITEMSIZE, plan_boundary
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

RESULTS = {}

STEPS = 2
MICRO = 2
TINY_MB = 0.02          # forces several buckets per pool on the smoke model
HUGE_MB = 1e6           # bucket > total bytes: one bucket per pool


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {
                "ok": False,
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc()[-2000:],
            }
        return fn
    return deco


def _setup():
    """repl=2 so hop 2 is a live collective; p=2, tp=2."""
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 2, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    rng = np.random.default_rng(7)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }
    return cfg, topo, model, batch


CFG, TOPO, MODEL, BATCH = _setup()


def _run(mcfg, steps=STEPS, seed=1):
    state = init_state(MODEL, TOPO, seed=seed)
    step = build_train_step(MODEL, TOPO, mcfg,
                            OptConfig(total_steps=50, warmup_steps=0,
                                      lr_max=3e-3))
    metrics = []
    for _ in range(steps):
        state, m = step(state, BATCH)
        metrics.append((float(m["loss"]), float(m["grad_norm"])))
    return metrics, jax.tree.map(np.asarray, state)


def _assert_bitwise(mcfg_kw, tag):
    serial, s_state = _run(MiCSConfig(boundary_schedule="serial", **mcfg_kw))
    bucketed, b_state = _run(
        MiCSConfig(boundary_schedule="bucketed", **mcfg_kw))
    assert all(np.isfinite(v) for row in serial for v in row), serial
    assert serial == bucketed, \
        f"{tag}: metrics diverged {serial} vs {bucketed}"
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(s_state)[0],
            jax.tree_util.tree_flatten_with_path(b_state)[0]):
        assert np.array_equal(a, b), f"{tag}: state leaf {path} diverged"


# ---------------------------------------------------------------------------
@check("bucket_plan")
def _bucket_plan():
    # helper-level: exact cover, byte cap, degenerate cases
    per = partition_buckets(10_000, 0.01, 4)      # 2500-elem buckets
    assert per[0] == (0, 2500) and per[-1][1] == 10_000
    assert all(hi - lo <= 2500 for lo, hi in per)
    covered = [e for lo, hi in per for e in range(lo, hi)]
    assert covered == list(range(10_000))
    assert partition_buckets(100, 1e6, 4) == ((0, 100),)   # bucket > total
    assert partition_buckets(0, 1.0, 4) == ()
    # plan-level: canonical order, counts, shard sizing
    tiny = plan_boundary(MODEL, TOPO, mode="bucketed", bucket_mb=TINY_MB)
    huge = plan_boundary(MODEL, TOPO, mode="bucketed", bucket_mb=HUGE_MB)
    n_pools = len(MODEL.all_pools())
    assert huge.n_buckets == n_pools, huge.describe()
    assert tiny.n_buckets > n_pools, tiny.describe()
    cap = int(TINY_MB * 1e6)
    assert all(b.elems * GRAD_ITEMSIZE <= cap for b in tiny.buckets)
    p = TOPO.partition_size
    for pool in MODEL.all_pools():
        stack, _, flat_len = MODEL.global_flat_shapes()[pool.name]
        pool_bkts = tiny.pool_buckets(pool.name)
        assert pool_bkts[0].lo == 0
        assert pool_bkts[-1].hi == stack * flat_len // p
    RESULTS["bucket_plan_detail"] = {
        "tiny": tiny.describe(), "huge": huge.describe()}


# ---------------------------------------------------------------------------
@check("bitwise_bucket_sizes")
def _bitwise_bucket_sizes():
    for mb in (TINY_MB, 0.2, HUGE_MB):   # several / few / one bucket per pool
        _assert_bitwise(dict(micro_steps=MICRO, hop2_bucket_mb=mb),
                        tag=f"bucket_mb={mb}")


# ---------------------------------------------------------------------------
@check("bitwise_topologies")
def _bitwise_topologies():
    combos = [
        dict(hierarchical=False),                              # flat
        dict(gather_order="outer_first"),                      # 3-stage
        dict(quant_gather=True),                               # int8 wire
        dict(gather_dtype=jnp.float32),                        # fp32 wire
    ]
    for kw in combos:
        _assert_bitwise(
            dict(micro_steps=MICRO, hop2_bucket_mb=TINY_MB, **kw),
            tag=f"combo={kw}")


# ---------------------------------------------------------------------------
@check("bitwise_compress")
def _bitwise_compress():
    _assert_bitwise(
        dict(micro_steps=MICRO, hop2_bucket_mb=TINY_MB, compress_hop2=True),
        tag="compress_hop2")


# ---------------------------------------------------------------------------
@check("census_interleave")
def _census_interleave():
    mesh_shape = dict(zip(TOPO.mesh.axis_names, TOPO.mesh.devices.shape))
    plans = {
        "serial": plan_boundary(MODEL, TOPO, mode="serial",
                                bucket_mb=TINY_MB),
        "bucketed": plan_boundary(MODEL, TOPO, mode="bucketed",
                                  bucket_mb=TINY_MB),
    }
    census = {}
    for label in ("serial", "bucketed"):
        step = build_train_step(
            MODEL, TOPO,
            MiCSConfig(micro_steps=MICRO, boundary_schedule=label,
                       hop2_bucket_mb=TINY_MB),
            OptConfig(total_steps=10))
        stats = analyze(
            step.lower(init_state_shapes(MODEL),
                       make_batch_shapes(MODEL, MICRO * 8, 32, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=TOPO.partition_axes,
            replication_axes=TOPO.replication_axes)
        census[label] = stats["boundary"]
        # hop-2 wire bytes are schedule-invariant (same reduction, resliced)
        census[label]["hop2_wire_bytes"] = \
            stats["by_stage"]["hop2"]["wire_bytes"]

    n_pools = len(MODEL.all_pools())
    ser, bkt = census["serial"], census["bucketed"]
    assert ser["hop2_ops"] == n_pools, census
    assert bkt["hop2_ops"] == plans["bucketed"].n_buckets > n_pools, census
    assert bkt["hop2_max_operand_bytes"] <= int(TINY_MB * 1e6), census
    # the pipeline's signature: compute issued between hop-2 collectives
    assert bkt["interleaved"] and bkt["compute_between_hop2"] > 0, census
    assert bkt["hop2_wire_bytes"] == ser["hop2_wire_bytes"], census
    RESULTS["census_interleave_detail"] = census


print(json.dumps(RESULTS, indent=1, default=str))
