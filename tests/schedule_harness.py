"""Boundary-scheduler correctness harness, run in a subprocess with 8
virtual CPU devices (same pattern as comm_harness.py).  Prints one JSON
object with named check results; tests/test_schedule.py asserts on them.
Checks:

  bucket_plan          partition_buckets/plan_boundary cover every element
                       exactly once, respect the byte cap, and degenerate
                       correctly (one bucket, bucket > total bytes)
  bitwise_bucket_sizes bucketed boundary == serial boundary, bitwise on
                       params/m/v and metrics, across bucket sizes
                       including the one-bucket and bucket>total-bytes
                       degenerate cases (hop 2 live: repl=2)
  bitwise_topologies   the same equivalence under all three gather
                       topologies and the bf16/int8 wire dtypes (the
                       boundary must be schedule-invariant whatever the
                       hop-1 policy feeding it)
  bitwise_compress     ... and under bf16-compressed hop-2 wire
  census_interleave    the compiled bucketed step's HLO shows hop-2 at
                       bucket granularity (hop2_ops == plan buckets,
                       max payload <= bucket bytes) interleaved with
                       norm/optimizer compute; the serial reference keeps
                       pool-granular hop-2 ops
  approx_clip_inactive clip_mode='approx' with the clip never engaging:
                       loss/grad_norm trajectories bitwise identical to
                       exact across bucket sizes (incl. the one-bucket-
                       per-pool degenerate case); params agree to the
                       final ulp (identical update arithmetic, different
                       XLA fusion of the elementwise AdamW chain)
  approx_zero_grad     all-zero grads (zero loss mask): gnorm 0, guarded
                       clip division — approx == exact bitwise, finite
  approx_clip_active_bounded
                       clip engaged: approx's one-bucket-stale factor may
                       drift, bounded by APPROX_CLIP_LOSS_RTOL on the
                       final loss of a short convergence run (loss must
                       also actually decrease under both clips)
  approx_int8_hop2     approx clip composes with the int8-compressed
                       hop-2 wire (finite metrics over 2 steps)
  approx_census_interleave
                       the compiled approx step still shows bucket-granular
                       hop-2, and strictly MORE compute between hop-2 ops
                       than the exact bucketed step — the AdamW updates
                       pipelined into the gaps
  offload_host_bitwise carry_offload='host' (and + offload_opt) leaves the
                       training numerics bitwise identical to the in-HBM
                       bucketed run; the carry stash drains every step and
                       the moment stash persists exactly 2 entries per
                       pool per device
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.flat_param import partition_buckets
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.hostoffload import stash_clear, stash_size
from repro.core.schedule import (
    APPROX_CLIP_LOSS_RTOL, GRAD_ITEMSIZE, plan_boundary,
)
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

RESULTS = {}

STEPS = 2
MICRO = 2
TINY_MB = 0.02          # forces several buckets per pool on the smoke model
HUGE_MB = 1e6           # bucket > total bytes: one bucket per pool


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {
                "ok": False,
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc()[-2000:],
            }
        return fn
    return deco


def _setup():
    """repl=2 so hop 2 is a live collective; p=2, tp=2."""
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 2, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    rng = np.random.default_rng(7)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }
    return cfg, topo, model, batch


CFG, TOPO, MODEL, BATCH = _setup()


def _run(mcfg, steps=STEPS, seed=1, oc=None, batch=None):
    batch = BATCH if batch is None else batch
    state = init_state(MODEL, TOPO, seed=seed, offload_opt=mcfg.offload_opt)
    step = build_train_step(MODEL, TOPO, mcfg,
                            oc or OptConfig(total_steps=50, warmup_steps=0,
                                            lr_max=3e-3))
    metrics = []
    for _ in range(steps):
        state, m = step(state, batch)
        metrics.append((float(m["loss"]), float(m["grad_norm"])))
    return metrics, jax.tree.map(np.asarray, state)


def _assert_state_equal(a_state, b_state, tag):
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(a_state)[0],
            jax.tree_util.tree_flatten_with_path(b_state)[0]):
        assert np.array_equal(a, b), f"{tag}: state leaf {path} diverged"


def _assert_bitwise(mcfg_kw, tag):
    serial, s_state = _run(MiCSConfig(boundary_schedule="serial", **mcfg_kw))
    bucketed, b_state = _run(
        MiCSConfig(boundary_schedule="bucketed", **mcfg_kw))
    assert all(np.isfinite(v) for row in serial for v in row), serial
    assert serial == bucketed, \
        f"{tag}: metrics diverged {serial} vs {bucketed}"
    _assert_state_equal(s_state, b_state, tag)


# ---------------------------------------------------------------------------
@check("bucket_plan")
def _bucket_plan():
    # helper-level: exact cover, byte cap, degenerate cases
    per = partition_buckets(10_000, 0.01, 4)      # 2500-elem buckets
    assert per[0] == (0, 2500) and per[-1][1] == 10_000
    assert all(hi - lo <= 2500 for lo, hi in per)
    covered = [e for lo, hi in per for e in range(lo, hi)]
    assert covered == list(range(10_000))
    assert partition_buckets(100, 1e6, 4) == ((0, 100),)   # bucket > total
    assert partition_buckets(0, 1.0, 4) == ()
    # plan-level: canonical order, counts, shard sizing
    tiny = plan_boundary(MODEL, TOPO, mode="bucketed", bucket_mb=TINY_MB)
    huge = plan_boundary(MODEL, TOPO, mode="bucketed", bucket_mb=HUGE_MB)
    n_pools = len(MODEL.all_pools())
    assert huge.n_buckets == n_pools, huge.describe()
    assert tiny.n_buckets > n_pools, tiny.describe()
    cap = int(TINY_MB * 1e6)
    assert all(b.elems * GRAD_ITEMSIZE <= cap for b in tiny.buckets)
    p = TOPO.partition_size
    for pool in MODEL.all_pools():
        stack, _, flat_len = MODEL.global_flat_shapes()[pool.name]
        pool_bkts = tiny.pool_buckets(pool.name)
        assert pool_bkts[0].lo == 0
        assert pool_bkts[-1].hi == stack * flat_len // p
    RESULTS["bucket_plan_detail"] = {
        "tiny": tiny.describe(), "huge": huge.describe()}


# ---------------------------------------------------------------------------
@check("bitwise_bucket_sizes")
def _bitwise_bucket_sizes():
    for mb in (TINY_MB, 0.2, HUGE_MB):   # several / few / one bucket per pool
        _assert_bitwise(dict(micro_steps=MICRO, hop2_bucket_mb=mb),
                        tag=f"bucket_mb={mb}")


# ---------------------------------------------------------------------------
@check("bitwise_topologies")
def _bitwise_topologies():
    combos = [
        dict(hierarchical=False),                              # flat
        dict(gather_order="outer_first"),                      # 3-stage
        dict(quant_gather=True),                               # int8 wire
        dict(gather_dtype=jnp.float32),                        # fp32 wire
    ]
    for kw in combos:
        _assert_bitwise(
            dict(micro_steps=MICRO, hop2_bucket_mb=TINY_MB, **kw),
            tag=f"combo={kw}")


# ---------------------------------------------------------------------------
@check("bitwise_compress")
def _bitwise_compress():
    _assert_bitwise(
        dict(micro_steps=MICRO, hop2_bucket_mb=TINY_MB, compress_hop2=True),
        tag="compress_hop2")


# ---------------------------------------------------------------------------
@check("census_interleave")
def _census_interleave():
    mesh_shape = dict(zip(TOPO.mesh.axis_names, TOPO.mesh.devices.shape))
    plans = {
        "serial": plan_boundary(MODEL, TOPO, mode="serial",
                                bucket_mb=TINY_MB),
        "bucketed": plan_boundary(MODEL, TOPO, mode="bucketed",
                                  bucket_mb=TINY_MB),
    }
    census = {}
    for label in ("serial", "bucketed"):
        step = build_train_step(
            MODEL, TOPO,
            MiCSConfig(micro_steps=MICRO, boundary_schedule=label,
                       hop2_bucket_mb=TINY_MB),
            OptConfig(total_steps=10))
        stats = analyze(
            step.lower(init_state_shapes(MODEL),
                       make_batch_shapes(MODEL, MICRO * 8, 32, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=TOPO.partition_axes,
            replication_axes=TOPO.replication_axes)
        census[label] = stats["boundary"]
        # hop-2 wire bytes are schedule-invariant (same reduction, resliced)
        census[label]["hop2_wire_bytes"] = \
            stats["by_stage"]["hop2"]["wire_bytes"]

    n_pools = len(MODEL.all_pools())
    ser, bkt = census["serial"], census["bucketed"]
    assert ser["hop2_ops"] == n_pools, census
    assert bkt["hop2_ops"] == plans["bucketed"].n_buckets > n_pools, census
    assert bkt["hop2_max_operand_bytes"] <= int(TINY_MB * 1e6), census
    # the pipeline's signature: compute issued between hop-2 collectives
    assert bkt["interleaved"] and bkt["compute_between_hop2"] > 0, census
    assert bkt["hop2_wire_bytes"] == ser["hop2_wire_bytes"], census
    RESULTS["census_interleave_detail"] = census


# ---------------------------------------------------------------------------
def _bucketed(clip="exact", mb=TINY_MB, **kw):
    return MiCSConfig(boundary_schedule="bucketed", micro_steps=MICRO,
                      hop2_bucket_mb=mb, clip_mode=clip, **kw)


@check("approx_clip_inactive")
def _approx_clip_inactive():
    # with the clip never engaging, the stale factor and the exact factor
    # are the same 1.0 — metrics must be bitwise identical at any bucket
    # count (incl. one bucket per pool: HUGE_MB) and params must agree to
    # the final ulp (same arithmetic, different XLA fusion)
    oc = OptConfig(total_steps=50, warmup_steps=0, lr_max=3e-3,
                   clip_norm=1e9)
    for mb in (TINY_MB, 0.2, HUGE_MB):
        exact, e_state = _run(_bucketed(mb=mb), oc=oc)
        approx, a_state = _run(_bucketed("approx", mb=mb), oc=oc)
        assert all(np.isfinite(v) for row in exact for v in row), exact
        assert exact == approx, f"mb={mb}: {exact} vs {approx}"
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(e_state)[0],
                jax.tree_util.tree_flatten_with_path(a_state)[0]):
            assert np.allclose(a, b, rtol=0, atol=1e-6), \
                f"mb={mb}: state leaf {path} off by " \
                f"{np.max(np.abs(np.float64(a) - np.float64(b)))}"


@check("approx_zero_grad")
def _approx_zero_grad():
    # all-zero loss mask: zero grads in every bucket, gnorm 0, and the
    # clip factor hits its guarded 0-norm branch in both modes
    zb = dict(BATCH, mask=jnp.zeros_like(BATCH["mask"]))
    exact, e_state = _run(_bucketed(), batch=zb)
    approx, a_state = _run(_bucketed("approx"), batch=zb)
    assert all(np.isfinite(v) for row in approx for v in row), approx
    assert all(g == 0.0 for _, g in exact), exact
    assert exact == approx, f"{exact} vs {approx}"
    _assert_state_equal(e_state, a_state, "zero_grad")


@check("approx_clip_active_bounded")
def _approx_clip_active():
    # convergence smoke with the clip engaged (smoke-model gnorm ~15 >>
    # clip_norm=1.0): the stale factor drifts the trajectory, but the
    # final loss stays within APPROX_CLIP_LOSS_RTOL of exact and both
    # runs actually learn
    steps = 12
    oc = OptConfig(total_steps=steps, warmup_steps=0, lr_max=3e-3,
                   clip_norm=1.0)
    exact, _ = _run(_bucketed(), steps=steps, oc=oc)
    approx, _ = _run(_bucketed("approx"), steps=steps, oc=oc)
    assert all(np.isfinite(v) for row in approx for v in row), approx
    # identical params at step 0 => identical first loss, drift after
    assert approx[0][0] == exact[0][0], (approx[0], exact[0])
    assert exact[-1][0] < exact[0][0], exact
    assert approx[-1][0] < approx[0][0], approx
    rtol = abs(approx[-1][0] - exact[-1][0]) / abs(exact[-1][0])
    assert rtol <= APPROX_CLIP_LOSS_RTOL, (rtol, exact[-1], approx[-1])
    RESULTS["approx_convergence_detail"] = {
        "steps": steps, "final_exact": exact[-1][0],
        "final_approx": approx[-1][0], "rtol": rtol}


@check("approx_int8_hop2")
def _approx_int8_hop2():
    # the approx pipeline folds per-bucket psums from the *dequantized*
    # int8 hop-2 wire — composition must stay finite
    approx, _ = _run(_bucketed("approx", compress_hop2="int8"))
    assert all(np.isfinite(v) for row in approx for v in row), approx


@check("approx_census_interleave")
def _approx_census_interleave():
    mesh_shape = dict(zip(TOPO.mesh.axis_names, TOPO.mesh.devices.shape))
    plan = plan_boundary(MODEL, TOPO, mode="bucketed", bucket_mb=TINY_MB)
    census = {}
    for clip in ("exact", "approx"):
        step = build_train_step(MODEL, TOPO, _bucketed(clip),
                                OptConfig(total_steps=10))
        stats = analyze(
            step.lower(init_state_shapes(MODEL),
                       make_batch_shapes(MODEL, MICRO * 8, 32, MICRO))
                .compile().as_text(),
            mesh_shape,
            partition_axes=TOPO.partition_axes,
            replication_axes=TOPO.replication_axes)
        census[clip] = stats["boundary"]
    for clip in ("exact", "approx"):
        assert census[clip]["hop2_ops"] == plan.n_buckets, census
        assert census[clip]["interleaved"], census
    # the pipeline's signature: the AdamW updates land in the gaps
    # between hop-2 collectives, so the approx step has strictly more
    # compute there than the exact bucketed step (whose optimizer runs
    # after the last hop-2)
    assert census["approx"]["compute_between_hop2"] \
        > census["exact"]["compute_between_hop2"], census
    RESULTS["approx_census_detail"] = census


@check("offload_host_bitwise")
def _offload_host_bitwise():
    stash_clear()
    ref, ref_state = _run(_bucketed())
    carry, c_state = _run(_bucketed(carry_offload="host"))
    assert ref == carry, f"carry: {ref} vs {carry}"
    _assert_state_equal(ref_state, c_state, "carry_offload")
    assert stash_size() == 0, "carry stash must drain every step"
    both, b_state = _run(_bucketed(carry_offload="host", offload_opt=True))
    assert ref == both, f"offload_opt: {ref} vs {both}"
    # the moment leaves now live in the host stash: compare what remains
    ref_leaves = {
        "/".join(str(getattr(p, "key", p)) for p in path): a
        for path, a in jax.tree_util.tree_flatten_with_path(ref_state)[0]}
    for path, a in jax.tree_util.tree_flatten_with_path(b_state)[0]:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        assert np.array_equal(a, ref_leaves[key]), \
            f"offload_opt: state leaf {key} diverged"
    # m + v per pool per device persist across steps
    expected = 2 * len(MODEL.all_pools()) * len(jax.devices())
    assert stash_size() == expected, (stash_size(), expected)
    stash_clear()
    RESULTS["offload_detail"] = {"stash_entries": expected}


print(json.dumps(RESULTS, indent=1, default=str))
