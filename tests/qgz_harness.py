"""qgZ correctness harness (int8 hop-1 / hop-2 gradient wires), run in a
subprocess with 8 virtual CPU devices (same pattern as comm_harness.py).
Prints one JSON object with named check results; tests/test_qgz.py asserts
on them.  Checks:

  quant_rs_routing       quantized_reduce_scatter routes/reorders chunks
                         exactly like psum_scatter (single- and multi-axis
                         partition groups, all three topologies): with one
                         contributor and grid-exact data the quantizer is
                         lossless, so any mismatch is a routing bug
  quant_rs_accuracy      dense multi-contributor reduce-scatter stays
                         within the blockwise quantization error bound
  step_seed_dither       the threaded step seed (ISSUE 5 satellite) is
                         bitwise reproducible per seed, draws distinct
                         dither across seeds on the same payload, and
                         stays within the error bound
  hop1_bf16_bitwise      hop1_wire_dtype='bf16' under the bf16 gather wire
                         is bitwise the default path (the cast is identity)
  int8_hop1_convergence  tiny-LM training with the int8 qgZ hop-1 tracks
                         the fp32 reference (finite, decreasing, final
                         loss within tolerance), for the bf16 gather wire
                         and for the full int8 qwZ+qgZ combination
  int8_hop2_boundary     compress_hop2='int8' trains under both boundary
                         schedules; serial and bucketed agree to
                         quantization error (not bitwise — blocks follow
                         the payload), and the compiled bucketed step's
                         census shows one int8 hop-2 leg per bucket
                         interleaved with boundary compute
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import traceback

import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs import get_config, smoke_variant
from repro.core import collectives as C
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.quant import BLOCK
from repro.core.schedule import plan_boundary
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

RESULTS = {}
STEPS = 6
MICRO = 2


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {
                "ok": False,
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc()[-2000:],
            }
        return fn
    return deco


def _grid_exact_data(n):
    """Integers with per-block absmax pinned to 127 -> scale == 1 exactly,
    so quantization (nearest or stochastic) is lossless."""
    rng = np.random.default_rng(3)
    ints = jnp.asarray(rng.integers(-127, 128, size=(n,)), jnp.float32)
    return ints.at[::BLOCK].set(127.0)


# ---------------------------------------------------------------------------
@check("quant_rs_routing")
def _quant_rs_routing():
    single = MiCSTopology(make_host_mesh(1, 2, 4, 1),
                          partition_axes=("shard",),
                          replication_axes=("pod", "repl"))
    multi = MiCSTopology(make_host_mesh(2, 1, 4, 1),
                         partition_axes=("pod", "shard"),
                         replication_axes=("repl",))
    data = _grid_exact_data(4 * 4096)
    for label, topo in (("single", single), ("multi", multi)):
        axes = topo.partition_axes

        def coord():
            idx = 0
            for a in axes:
                idx = idx * topo.axis_size(a) + lax.axis_index(a)
            return idx

        for topology in ("flat", "inner_first", "outer_first"):
            def body(g):
                g = jnp.where(coord() == 0, g, 0.0)  # single contributor
                got = C.quantized_reduce_scatter(g, topo, topology=topology)
                want = lax.psum_scatter(g, axes, scatter_dimension=0,
                                        tiled=True)
                return got, want

            got, want = shard_map(
                body, mesh=topo.mesh, in_specs=P(None),
                out_specs=(P(axes), P(axes)), check_vma=False)(data)
            assert np.array_equal(np.asarray(got), np.asarray(want)), \
                f"{label}/{topology}: quantized RS misroutes chunks"


# ---------------------------------------------------------------------------
@check("quant_rs_accuracy")
def _quant_rs_accuracy():
    topo = MiCSTopology(make_host_mesh(1, 2, 4, 1),
                        partition_axes=("shard",),
                        replication_axes=("pod", "repl"))
    x = jnp.asarray(np.random.default_rng(5).normal(size=(4 * 4096,)),
                    jnp.float32)

    def body(g):
        g = g * (1.0 + 0.1 * lax.axis_index("shard").astype(jnp.float32))
        got = C.quantized_reduce_scatter(g, topo, topology="inner_first")
        want = lax.psum_scatter(g, ("shard",), scatter_dimension=0,
                                tiled=True)
        return got, want

    got, want = shard_map(body, mesh=topo.mesh, in_specs=P(None),
                          out_specs=(P(("shard",)), P(("shard",))),
                          check_vma=False)(x)
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    scale = np.abs(np.asarray(want)).max()
    assert err / scale < 0.05, (err, scale)
    RESULTS["quant_rs_accuracy_detail"] = {"rel_err": float(err / scale)}


# ---------------------------------------------------------------------------
@check("step_seed_dither")
def _step_seed_dither():
    """The threaded step seed replaces the payload-fingerprint dither
    component: distinct seeds draw distinct stochastic rounding on the SAME
    payload (value-independent decorrelation across steps), the same seed
    is bitwise reproducible, and every seed stays within the quantization
    error bound."""
    topo = MiCSTopology(make_host_mesh(1, 2, 4, 1),
                        partition_axes=("shard",),
                        replication_axes=("pod", "repl"))
    x = jnp.asarray(np.random.default_rng(11).normal(size=(4 * 4096,)),
                    jnp.float32)

    def body(g, seed):
        got = C.quantized_reduce_scatter(g, topo, topology="inner_first",
                                         seed=seed)
        want = lax.psum_scatter(g, ("shard",), scatter_dimension=0,
                                tiled=True)
        return got, want

    run = shard_map(body, mesh=topo.mesh, in_specs=(P(None), P()),
                    out_specs=(P(("shard",)), P(("shard",))),
                    check_vma=False)
    got0, want = run(x, jnp.int32(0))
    got0b, _ = run(x, jnp.int32(0))
    got1, _ = run(x, jnp.int32(1))
    assert np.array_equal(np.asarray(got0), np.asarray(got0b)), \
        "same step seed must be bitwise reproducible"
    assert not np.array_equal(np.asarray(got0), np.asarray(got1)), \
        "distinct step seeds must draw distinct dither"
    scale = np.abs(np.asarray(want)).max()
    for got in (got0, got1):
        err = np.abs(np.asarray(got) - np.asarray(want)).max()
        assert err / scale < 0.05, (err, scale)


# ---------------------------------------------------------------------------
def _train_losses(mcfg, steps=STEPS, repl=False):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 2, 2, 2) if repl else make_host_mesh(1, 1, 4, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    state = init_state(model, topo, seed=9)
    step = build_train_step(
        model, topo, mcfg,
        OptConfig(total_steps=50, warmup_steps=0, lr_max=3e-3))
    rng = np.random.default_rng(7)
    b, t = 8, 32
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }
    losses = []
    for _ in range(steps):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return losses


@check("hop1_bf16_bitwise")
def _hop1_bf16_bitwise():
    """Under the bf16 gather wire the cotangent is already bf16, so the
    explicit bf16 hop-1 cast is an identity — bitwise the default path."""
    ref = _train_losses(MiCSConfig(micro_steps=MICRO), steps=3)
    bf16 = _train_losses(
        MiCSConfig(micro_steps=MICRO, hop1_wire_dtype="bf16"), steps=3)
    assert ref == bf16, f"bf16 hop-1 diverged from default: {ref} vs {bf16}"


@check("int8_hop1_convergence")
def _int8_hop1_convergence():
    ref = _train_losses(MiCSConfig(micro_steps=MICRO))
    TOL = 0.05  # relative final-loss tolerance vs the fp32 reference
    combos = {
        "qgZ": MiCSConfig(micro_steps=MICRO, hop1_wire_dtype="int8"),
        "qwZ+qgZ": MiCSConfig(micro_steps=MICRO, hop1_wire_dtype="int8",
                              quant_gather=True),
    }
    detail = {"fp32": ref, "tolerance": TOL}
    for label, mcfg in combos.items():
        got = _train_losses(mcfg)
        detail[label] = got
        assert all(np.isfinite(got)), (label, got)
        assert got[-1] < got[0], (label, "loss did not decrease", got)
        rel = abs(got[-1] - ref[-1]) / abs(ref[-1])
        detail[f"{label}_rel_final"] = rel
        assert rel < TOL, (label, rel, got, ref)
    RESULTS["int8_hop1_convergence_detail"] = detail


# ---------------------------------------------------------------------------
@check("int8_hop2_boundary")
def _int8_hop2_boundary():
    """The int8 decompress leg of the boundary scheduler: both schedules
    train, agree to quantization error, and the bucketed census shows
    bucket-granular int8 hop-2 legs interleaved with compute."""
    BUCKET_MB = 0.02
    kw = dict(micro_steps=MICRO, compress_hop2="int8",
              hop2_bucket_mb=BUCKET_MB)
    serial = _train_losses(
        MiCSConfig(boundary_schedule="serial", **kw), steps=4, repl=True)
    bucketed = _train_losses(
        MiCSConfig(boundary_schedule="bucketed", **kw), steps=4, repl=True)
    assert all(np.isfinite(serial)) and all(np.isfinite(bucketed))
    assert serial[-1] < serial[0] and bucketed[-1] < bucketed[0]
    # quantization blocks follow the payload -> close, not bitwise
    rel = abs(serial[-1] - bucketed[-1]) / abs(serial[-1])
    assert rel < 0.05, (serial, bucketed)

    cfg = smoke_variant(get_config("llama3.2-1b"))
    mesh = make_host_mesh(1, 2, 2, 2)
    topo = MiCSTopology(mesh)
    model = build_model(cfg, tp=2)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    plan = plan_boundary(model, topo, mode="bucketed", bucket_mb=BUCKET_MB)
    step = build_train_step(
        model, topo, MiCSConfig(boundary_schedule="bucketed", **kw),
        OptConfig(total_steps=10))
    stats = analyze(
        step.lower(init_state_shapes(model),
                   make_batch_shapes(model, MICRO * 8, 32, MICRO))
            .compile().as_text(),
        mesh_shape,
        partition_axes=topo.partition_axes,
        replication_axes=topo.replication_axes)
    census = stats["boundary"]
    assert census["hop2_ops"] == plan.n_buckets, (census, plan.describe())
    assert census["interleaved"], census
    # the int8 q payload is ~1/4 the fp32 bucket bytes
    assert census["hop2_max_operand_bytes"] <= int(BUCKET_MB * 1e6) / 4 * 1.1
    RESULTS["int8_hop2_boundary_detail"] = {
        "serial": serial, "bucketed": bucketed, "rel_final": rel,
        "census": census, "n_buckets": plan.n_buckets,
    }


print(json.dumps(RESULTS, indent=1, default=str))
