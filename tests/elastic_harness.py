"""Elastic preemption-survival harness: the kill-a-device test matrix.

Runs in a subprocess with 8 virtual CPU devices (the main pytest process
keeps its ambient device set).  Prints one JSON object with named check
results; tests/test_elastic.py and tests/test_checkpoint.py assert on them,
and ``--check`` mode is the CI bench smoke gate (artifact
BENCH_elastic_smoke.json: restart counts + resume-bitwise flags).

Checks:

  kill_pod_resume_bitwise   train on pod=2/p=2/tp=2 (8 devices) under an
                            ``hbm_budget_gb`` picked so §3.1 forces p=2;
                            abruptly preempt one pod (4 devices, no notice)
                            mid-run.  The loop rolls back to the newest
                            complete checkpoint, re-runs resolve_scale for
                            the 4-device world, rebuilds the mesh and
                            resumes — with a loss trajectory and final
                            params BITWISE identical to a cold
                            ``elastic_restart`` of the same checkpoint on
                            the same surviving topology.
  grow_back_resume_bitwise  the preempted capacity returns (grow 4 -> 8
                            with notice): emergency save at the fire step,
                            zero steps lost, resumed trajectory bitwise vs
                            a cold restore on the regrown topology.
  repick_keep_rule_bitwise  no-budget world change (8 -> 2 devices, tp=1):
                            the keep rule shrinks p 4 -> 2 (largest
                            dividing group), notice path loses zero steps,
                            bitwise vs cold restore.
  resolve_scale_repick      the ledger's partition size equals a direct
                            resolve_scale call for the degraded/regrown
                            extents, and the budget really separates p=1
                            from p=2 (no hardcoded answers).
  data_continuity           recorded per-batch fingerprints across both
                            restart boundaries: cursors replay exactly the
                            rolled-back span (abrupt kill) or nothing at
                            all (with notice), and never skip a batch.
  straggler_flagged         an injected slow step trips the EWMA detector;
                            an injected eviction rides rollback-and-retry.
  crash_mid_save            the checkpoint writer dies mid-write (truncated
                            manifest in a ``.tmp`` dir): the loop's next
                            rollback restores the older *complete* step,
                            and the retried save restores the cadence.
  reshard_roundtrip         save -> restore -> save across p=2 -> p=4 ->
                            p=2 topologies is bitwise lossless.
  offload_cross_topology    ``offload_opt=True`` restore onto a different
                            topology resets the host-stashed moments
                            EXPLICITLY (meta["host_stash"], warning) and
                            training continues; same-topology restore
                            re-imports them.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import dataclasses
import hashlib
import json
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.runtime.train_loop as TL
from repro.bench import measure as MS
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, smoke_variant
from repro.core import memplan as M
from repro.core.autotune import resolve_scale
from repro.core.comm import policies_from_config
from repro.core.faults import FaultPlan
from repro.core.hostoffload import export_stash, stash_clear, stash_size
from repro.core.linkmodel import GIB
from repro.core.mics import MiCSConfig, build_train_step, init_state
from repro.core.topology import MiCSTopology, elastic_host_topology, make_host_mesh
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.runtime.train_loop import (
    ElasticConfig, LoopConfig, elastic_restart, resize_for_world, train,
)

RESULTS = {}
CTX = {}      # cross-check shared state (ledgers, recorded batches)

check = MS.make_check(RESULTS)


class RecordingLM(SyntheticLM):
    """SyntheticLM that fingerprints every batch the train loop consumes —
    the replay/skip evidence of the data-continuity check."""

    served: list = []

    def global_step_batch(self, step):
        b = super().global_step_batch(step)
        RecordingLM.served.append(
            (int(step), hashlib.sha1(b["tokens"].tobytes()).hexdigest()))
        return b


TL.SyntheticLM = RecordingLM   # train() instantiates via its module global

CFG = smoke_variant(get_config("llama3.2-1b"))
OC = OptConfig(total_steps=40, warmup_steps=0, lr_max=1e-3)
DC = DataConfig(vocab=CFG.vocab, seq=32, global_batch=8, micro_steps=2)
COLD_DATA = SyntheticLM(DC)    # un-recorded source for cold reference runs


def _run_cold(step_fn, state, cursors, data=COLD_DATA):
    losses = []
    for c in cursors:
        batch = jax.tree.map(jnp.asarray, data.global_step_batch(c))
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


def _tree_equal(a, b, msg=""):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=msg), a, b)


# ---------------------------------------------------------------------------
# budget: picked so the §3.1 rule has a real decision to make — p=2 with the
# stored carry fits in BOTH worlds (8 and 4 devices at tp=2), while p=1
# overflows under every carry mitigation.  Computed from the footprint
# model, never hardcoded.
# ---------------------------------------------------------------------------

def _pick_budget(model, mcfg, extents):
    gp, sp = policies_from_config(mcfg)
    carries = ("stored", "remat", "host") if gp.prefetch else ("stored",)

    def fp(p, extent, carry):
        if carry == "host":
            g2 = dataclasses.replace(
                gp, prefetch_carry="stored", carry_offload="host")
        else:
            g2 = dataclasses.replace(
                gp, prefetch_carry=carry, carry_offload="none")
        grid = M.DeviceGrid(partition_size=p, replication_degree=extent // p)
        return M.predict_footprint(
            model, grid, g2, sp, micro_steps=mcfg.micro_steps,
            boundary=mcfg.boundary_schedule,
            hop2_bucket_mb=mcfg.hop2_bucket_mb,
            offload_opt=mcfg.offload_opt).total_bytes

    need = max(fp(2, e, "stored") for e in extents)          # p=2 must fit
    cap = min(fp(1, e, c) for e in extents for c in carries)  # p=1 must not
    assert need < cap, f"no separating budget: p2={need} p1={cap}"
    return (need + cap) / 2 / GIB, need / GIB, cap / GIB


MODEL2 = build_model(CFG, tp=2)
BUDGET_GB, FP_P2_GIB, FP_P1_GIB = _pick_budget(
    MODEL2, MiCSConfig(micro_steps=2), extents=(4, 2))
MCFG_B = MiCSConfig(micro_steps=2, hbm_budget_gb=BUDGET_GB)

KILL_DIR = tempfile.mkdtemp(prefix="elastic_kill_")


# ---------------------------------------------------------------------------
@check("kill_pod_resume_bitwise")
def _kill_pod():
    topo8 = MiCSTopology(make_host_mesh(2, 1, 2, 2))   # pod=2, p=2, tp=2
    lc = LoopConfig(total_steps=10, checkpoint_every=3, log_every=0,
                    checkpoint_dir=KILL_DIR, seed=0)
    plan = FaultPlan().preempt(5, devices=4, notice=False)  # abrupt pod loss
    RecordingLM.served = []
    stats = train(MODEL2, topo8, MCFG_B, OC, DC, lc,
                  fault_injector=plan, elastic=ElasticConfig())
    CTX["kill_stats"] = stats
    CTX["kill_served"] = list(RecordingLM.served)

    assert stats.restarts == 1 and len(stats.world_changes) == 1, vars(stats)
    wc = stats.world_changes[0]
    assert wc["kind"] == "preempt" and wc["lost"] == 4 and not wc["notice"]
    assert wc["at_step"] == 5 and wc["world"] == 4
    assert wc["resumed_step"] == 3        # newest complete ckpt (every=3)
    assert wc["rule"] == "resolve_scale" and wc["partition_size"] == 2, wc
    # 5 losses on 8 devices (steps 0-4) + 7 on the survivors (steps 3-9)
    assert len(stats.losses) == 12, len(stats.losses)

    # cold reference: the same checkpoint, the same surviving topology,
    # through the same resize_for_world the loop used
    topo4, mcfg4, info4 = resize_for_world(
        MODEL2, MCFG_B, 4, tp=2, partition_size=topo8.partition_size)
    assert info4["partition_size"] == wc["partition_size"]
    _, cold_state, cold_step, meta = elastic_restart(
        KILL_DIR, CFG, topo4, mcfg4, OC, step=3)
    assert meta["data_cursor"] == 3
    cold_state, cold_losses = _run_cold(cold_step, cold_state, range(3, 10))

    np.testing.assert_array_equal(
        np.float64(stats.losses[5:]), np.float64(cold_losses),
        err_msg="post-preemption trajectory is not bitwise-identical to the "
                "cold restore on the surviving topology")
    final, _ = Checkpointer(KILL_DIR).restore(MODEL2, topo4, step=10)
    _tree_equal(final, cold_state, "final params diverge from cold restore")
    RESULTS["kill_pod_detail"] = {
        "losses": len(stats.losses), "restarts": stats.restarts,
        "ledger": wc, "resume_bitwise": True,
        "budget_gb": BUDGET_GB, "fp_p2_gib": FP_P2_GIB,
        "fp_p1_gib": FP_P1_GIB,
    }


# ---------------------------------------------------------------------------
@check("grow_back_resume_bitwise")
def _grow_back():
    # continue in the same checkpoint dir: the 4-device survivors regrow to 8
    topo4 = elastic_host_topology(4, 2, tp=2)
    lc = LoopConfig(total_steps=16, checkpoint_every=4, log_every=0,
                    checkpoint_dir=KILL_DIR, seed=0)
    plan = FaultPlan().grow(12, devices=4)
    RecordingLM.served = []
    stats = train(MODEL2, topo4, MCFG_B, OC, DC, lc,
                  fault_injector=plan, elastic=ElasticConfig())
    CTX["grow_stats"] = stats
    CTX["grow_served"] = list(RecordingLM.served)

    assert len(stats.world_changes) == 1, stats.world_changes
    wc = stats.world_changes[0]
    assert wc["kind"] == "grow" and wc["gained"] == 4 and wc["world"] == 8
    # grow announcements come with notice: emergency save, zero lost steps
    assert stats.emergency_saves == 1
    assert wc["resumed_step"] == wc["at_step"] == 12, wc
    assert wc["partition_size"] == 2, wc
    assert len(stats.losses) == 6      # 10,11 on 4 devices + 12-15 on 8

    topo8, mcfg8, _ = resize_for_world(MODEL2, MCFG_B, 8, tp=2,
                                       partition_size=2)
    _, cold_state, cold_step, meta = elastic_restart(
        KILL_DIR, CFG, topo8, mcfg8, OC, step=12)
    assert meta["data_cursor"] == 12 and meta["emergency"] is True
    cold_state, cold_losses = _run_cold(cold_step, cold_state, range(12, 16))
    np.testing.assert_array_equal(
        np.float64(stats.losses[2:]), np.float64(cold_losses),
        err_msg="post-growback trajectory diverges from cold restore")
    final, _ = Checkpointer(KILL_DIR).restore(MODEL2, topo8, step=16)
    _tree_equal(final, cold_state, "final params diverge after grow-back")
    RESULTS["grow_back_detail"] = {
        "ledger": wc, "emergency_saves": stats.emergency_saves,
        "resume_bitwise": True,
    }


# ---------------------------------------------------------------------------
@check("resolve_scale_repick")
def _repick():
    # the ledger's p is a *property* of §3.1, not a hardcoded expectation:
    # a direct resolve_scale call for each world must agree with the loop
    for extent, wc in ((2, CTX["kill_stats"].world_changes[0]),
                       (4, CTX["grow_stats"].world_changes[0])):
        p, carry, plan = resolve_scale(MODEL2, MCFG_B, data_extent=extent)
        assert p == wc["partition_size"], (extent, p, wc)
        assert carry == wc["carry"], (extent, carry, wc)
        assert plan.total_bytes <= BUDGET_GB * GIB
    # and the budget genuinely separates the candidates
    assert FP_P2_GIB < BUDGET_GB < FP_P1_GIB


# ---------------------------------------------------------------------------
@check("data_continuity")
def _continuity():
    # abrupt kill: batch 5 was fetched when the preemption hit, the loop
    # rolled back to step 3 — cursors replay exactly [3,4,5] and then run
    # on; nothing is skipped
    cursors = [c for c, _ in CTX["kill_served"]]
    assert cursors == list(range(6)) + list(range(3, 10)), cursors
    # with notice (grow): batch 12 was fetched, the emergency save kept it
    # current — it is re-fetched once after the rebuild, nothing replays
    cursors = [c for c, _ in CTX["grow_served"]]
    assert cursors == [10, 11, 12] + list(range(12, 16)), cursors
    # fingerprints: the same cursor always serves the same bytes (across
    # the restart boundary AND across loader instances)
    for served in (CTX["kill_served"], CTX["grow_served"]):
        by_cursor = {}
        for c, h in served:
            assert by_cursor.setdefault(c, h) == h, f"cursor {c} replayed " \
                "with different data"
    fresh = hashlib.sha1(
        SyntheticLM(DC).global_step_batch(3)["tokens"].tobytes()).hexdigest()
    assert dict(CTX["kill_served"])[3] == fresh


# ---------------------------------------------------------------------------
@check("repick_keep_rule_bitwise")
def _keep_rule():
    # no budget: the keep rule shrinks p to the largest dividing group.
    # 8 devices at p=4/tp=1 lose 6 with notice -> 2 devices, p 4 -> 2,
    # emergency save, zero steps lost, bitwise vs cold restore.
    d = tempfile.mkdtemp(prefix="elastic_keep_")
    model = build_model(CFG, tp=1)
    topo = elastic_host_topology(8, 4, tp=1)
    mcfg = MiCSConfig(micro_steps=2)
    dc = DataConfig(vocab=CFG.vocab, seq=32, global_batch=16, micro_steps=2)
    lc = LoopConfig(total_steps=6, checkpoint_every=10, log_every=0,
                    checkpoint_dir=d, seed=0)
    plan = FaultPlan().preempt(3, devices=6, notice=True)
    stats = train(model, topo, mcfg, OC, dc, lc,
                  fault_injector=plan, elastic=ElasticConfig())
    wc = stats.world_changes[0]
    assert wc["rule"] == "keep" and wc["partition_size"] == 2, wc
    assert wc["resumed_step"] == wc["at_step"] == 3   # notice: zero lost
    assert stats.emergency_saves == 1 and len(stats.losses) == 6

    topo2, mcfg2, info = resize_for_world(model, mcfg, 2, tp=1,
                                          partition_size=4)
    assert info["partition_size"] == 2
    _, cold_state, cold_step, meta = elastic_restart(
        d, CFG, topo2, mcfg2, OC, step=3)
    cold_state, cold_losses = _run_cold(cold_step, cold_state, range(3, 6),
                                        data=SyntheticLM(dc))
    np.testing.assert_array_equal(
        np.float64(stats.losses[3:]), np.float64(cold_losses))
    final, _ = Checkpointer(d).restore(model, topo2, step=6)
    _tree_equal(final, cold_state)
    RESULTS["keep_rule_detail"] = {"ledger": wc, "resume_bitwise": True}


# ---------------------------------------------------------------------------
@check("straggler_flagged")
def _straggler():
    d = tempfile.mkdtemp(prefix="elastic_slow_")
    model = build_model(CFG, tp=1)
    topo = elastic_host_topology(2, 2, tp=1)
    lc = LoopConfig(total_steps=10, checkpoint_every=3, log_every=0,
                    checkpoint_dir=d, seed=0)
    # one 6s stall (flag only) + one evicted straggler (rollback path)
    plan = (FaultPlan(slow_base_s=0.5)
            .slow(6, factor=13)
            .slow(8, factor=2, evict=True))
    stats = train(model, topo, MiCSConfig(micro_steps=2), OC, DC, lc,
                  fault_injector=plan, elastic=ElasticConfig())
    assert 6 in stats.straggler_steps, stats.straggler_steps
    assert stats.restarts == 1          # the eviction rode rollback
    # rollback to step-6 ckpt replays 6,7: 8 + 4 losses
    assert len(stats.losses) == 12, len(stats.losses)
    assert all(np.isfinite(stats.losses))
    RESULTS["straggler_detail"] = {
        "straggler_steps": stats.straggler_steps, "restarts": stats.restarts,
        "fired": plan.log,
    }


# ---------------------------------------------------------------------------
@check("crash_mid_save")
def _crash_mid_save():
    d = tempfile.mkdtemp(prefix="elastic_crash_")
    model = build_model(CFG, tp=1)
    topo = elastic_host_topology(2, 2, tp=1)
    lc = LoopConfig(total_steps=8, checkpoint_every=2, log_every=0,
                    checkpoint_dir=d, seed=0)
    # the async step-4 save dies mid-write (truncated manifest in the .tmp
    # dir); the eviction at step 5 then forces a rollback, which must land
    # on step 2 — the newest COMPLETE checkpoint — not the corpse of 4
    plan = (FaultPlan()
            .crash_during_save(4)
            .slow(5, factor=2, evict=True))
    stats = train(model, topo, MiCSConfig(micro_steps=2), OC, DC, lc,
                  fault_injector=plan, elastic=ElasticConfig())
    # 5 losses (0-4) + 6 replayed from step 2 (2-7): rollback skipped the
    # crashed step-4 checkpoint (9 losses would mean it restored from it)
    assert len(stats.losses) == 11, len(stats.losses)
    assert stats.save_failures == 1     # held writer crash surfaced+retried
    ck = Checkpointer(d)
    assert ck.latest_step() == 8        # cadence recovered after the retry
    RESULTS["crash_mid_save_detail"] = {
        "losses": len(stats.losses), "save_failures": stats.save_failures,
        "fired": plan.log,
    }


# ---------------------------------------------------------------------------
@check("reshard_roundtrip")
def _reshard_roundtrip():
    # save -> restore -> save across p=2 -> p=4 -> p=2 is bitwise lossless
    d = tempfile.mkdtemp(prefix="elastic_reshard_")
    model = build_model(CFG, tp=1)
    topo_p2 = elastic_host_topology(4, 2, tp=1)
    topo_p4 = elastic_host_topology(4, 4, tp=1)
    state0 = init_state(model, topo_p2, seed=11)
    ck = Checkpointer(d)
    ck.save(state0, 1, topo=topo_p2, data_cursor=1)
    state_p4, meta = ck.restore(model, topo_p4)
    assert meta["mesh_axes"]["shard"] == 2      # provenance: saved at p=2
    ck.save(state_p4, 2, topo=topo_p4, data_cursor=2)
    state_back, meta2 = ck.restore(model, topo_p2, step=2)
    assert meta2["mesh_axes"]["shard"] == 4
    _tree_equal(state0, state_back,
                "p=2 -> p=4 -> p=2 roundtrip is not bitwise lossless")


# ---------------------------------------------------------------------------
@check("offload_cross_topology")
def _offload_cross_topology():
    d = tempfile.mkdtemp(prefix="elastic_offload_")
    model = build_model(CFG, tp=1)
    topo_p2 = elastic_host_topology(4, 2, tp=1)
    mcfg = MiCSConfig(micro_steps=2, offload_opt=True)
    stash_clear()
    state = init_state(model, topo_p2, seed=3, offload_opt=True)
    step_fn = build_train_step(model, topo_p2, mcfg, OC)
    state, _ = _run_cold(step_fn, state, range(2))   # populate m/v stash
    assert stash_size() > 0
    ck = Checkpointer(d)
    ck.save(state, 2, topo=topo_p2, data_cursor=2, host_stash=export_stash())

    # same topology: the offloaded moments come back
    stash_clear()
    _, meta = ck.restore(model, topo_p2, offload_opt=True)
    assert meta["host_stash"] == {
        "present": True, "restored": True, "reset": None}, meta["host_stash"]
    assert stash_size() > 0

    # different topology: EXPLICIT reset — surfaced in meta, training runs on
    stash_clear()
    topo_p4 = elastic_host_topology(4, 4, tp=1)
    state4, meta4 = ck.restore(model, topo_p4, offload_opt=True)
    hs = meta4["host_stash"]
    assert hs["present"] and not hs["restored"], hs
    assert hs["reset"] == "cross-topology", hs
    step4 = build_train_step(model, topo_p4, mcfg, OC)
    state4, losses = _run_cold(step4, state4, range(2, 4))
    assert all(np.isfinite(losses)), losses
    RESULTS["offload_detail"] = {"same_topo": meta["host_stash"],
                                 "cross_topo": hs}


# ---------------------------------------------------------------------------
# summary ledger for the CI bench artifact (BENCH_elastic_smoke.json)
ks, gs = CTX.get("kill_stats"), CTX.get("grow_stats")
RESULTS["summary"] = {
    "restarts": (ks.restarts if ks else None),
    "world_changes": ((len(ks.world_changes) if ks else 0)
                      + (len(gs.world_changes) if gs else 0)),
    "emergency_saves": (gs.emergency_saves if gs else None),
    "resume_bitwise": {
        name: RESULTS.get(name, {}).get("ok", False)
        for name in ("kill_pod_resume_bitwise", "grow_back_resume_bitwise",
                     "repick_keep_rule_bitwise")
    },
    "budget_gb": BUDGET_GB,
}

# the elastic suite's matrix cells (one contract cell per named check)
RESULTS["cells"] = MS.contract_cells(
    "elastic", RESULTS,
    dict(model="llama3.2-1b-smoke", budget_gb=BUDGET_GB))
print(json.dumps(RESULTS, indent=1, default=str))
if "--check" in sys.argv:
    MS.exit_check(RESULTS, "elastic smoke gate")
