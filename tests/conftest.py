"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests must see the real single CPU device; multi-device checks run via the
subprocess harness (tests/dist_harness.py)."""

import jax
import pytest

from repro.core.topology import MiCSTopology, make_host_mesh


@pytest.fixture(scope="session")
def topo1():
    """Single-device 4-axis MiCS topology (all axes size 1)."""
    return MiCSTopology(make_host_mesh(1, 1, 1, 1))
