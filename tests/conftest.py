"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here — smoke
tests run against the ambient device set; multi-device checks run via the
subprocess harnesses (tests/dist_harness.py, tests/comm_harness.py), which
set their own device count.  Locally the ambient set is one CPU device; CI
exports --xla_force_host_platform_device_count=8, and the suite is verified
to pass under both (no test may assume an exact ambient device count)."""

import pathlib

import pytest

from repro.core.topology import MiCSTopology, make_host_mesh


@pytest.fixture(scope="session")
def topo1():
    """Single-device 4-axis MiCS topology (all axes size 1)."""
    return MiCSTopology(make_host_mesh(1, 1, 1, 1))


@pytest.fixture(scope="session")
def serve_results():
    """Parsed JSON of the serving-engine harness, run once per session
    (tests/test_paged.py asserts every check: paged-vs-contiguous bitwise
    equivalence, chunked prefill, int8 KV error, sampler, serve memplan)."""
    from harness_util import run_harness

    return run_harness(pathlib.Path(__file__).parent / "serve_harness.py")


@pytest.fixture(scope="session")
def serve_chaos_results():
    """Parsed JSON of the serving chaos harness, run once per session
    (tests/test_batching_faults.py asserts every check: bitwise replay
    across preempt/grow-back/straggler/crash plus deterministic typed
    shedding under a burst)."""
    from harness_util import run_harness

    return run_harness(pathlib.Path(__file__).parent
                       / "serve_chaos_harness.py")


@pytest.fixture(scope="session")
def elastic_results():
    """Parsed JSON of the elastic preemption harness, run once per session
    (tests/test_elastic.py asserts every check; tests/test_checkpoint.py
    pins the cross-topology save/restore satellites from the same run)."""
    from harness_util import run_harness

    return run_harness(pathlib.Path(__file__).parent / "elastic_harness.py")
