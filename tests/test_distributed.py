"""Runs the 8-virtual-device correctness harness in a subprocess (keeps this
process at 1 device) and asserts every named check passed.  Covers:
hierarchical gather correctness+gradients, MiCS==single-device fidelity
(paper Fig 16), ZeRO-3 equivalence, the Fig-14 alternative schedule,
hierarchical-training equivalence, compressed hop-2, decode consistency."""

import pathlib

import pytest

from harness_util import run_harness

HARNESS = pathlib.Path(__file__).parent / "dist_harness.py"


@pytest.fixture(scope="module")
def harness_results():
    return run_harness(HARNESS)


CHECKS = [
    "hier_gather", "mics_fidelity", "zero3_equiv", "alt_sync_equiv",
    "hier_train_equiv", "compress_hop2", "moe_tp_equiv",
    "griffin_partition_equiv", "mlstm_chunk_train_equiv",
    "decode_consistency",
]


@pytest.mark.parametrize("name", CHECKS)
def test_distributed_check(harness_results, name):
    res = harness_results.get(name)
    assert res is not None, f"harness did not run {name}"
    assert res["ok"], f"{name}: {res.get('err')}\n{res.get('tb', '')}"
