"""Unit tests of the numeric layers: chunked attention == direct softmax,
local windows, GQA grouping, vocab-parallel CE == plain CE, rotary, MoE
dispatch == dense-expert reference."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

RNG = np.random.default_rng(3)


def _qkv(b=2, t=256, hkv=2, g=2, dh=32, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(b, t, hkv, g, dh)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, t, hkv, dh)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, hkv, dh)), dtype)
    return q, k, v


def _direct(q, k, v, causal=True, window=0):
    """Dense per-head reference."""
    b, t, hkv, g, dh = q.shape
    s = np.einsum("bqhgd,bkhd->bhgqk", np.asarray(q, np.float64),
                  np.asarray(k, np.float64)) / np.sqrt(dh)
    qp = np.arange(t)[:, None]
    kp = np.arange(t)[None, :]
    mask = np.ones((t, t), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bhgqk,bkhd->bqhgd", p, np.asarray(v, np.float64))
    return out


@pytest.mark.parametrize("window", [0, 64])
def test_chunked_attention_matches_direct(window):
    q, k, v = _qkv()
    got = L.attention(q, k, v, causal=True, window=window, chunk_q=64)
    want = _direct(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_attention_decode_path_matches_prefix():
    """Decode (q_len=1, kv_valid_len) == last row of the full computation."""
    q, k, v = _qkv(t=64)
    full = L.attention(q, k, v, causal=True)
    last = L.attention(q[:, -1:], k, v, causal=False, kv_valid_len=64)
    np.testing.assert_allclose(
        np.asarray(last)[:, 0], np.asarray(full)[:, -1], rtol=2e-5, atol=2e-5)


def test_tp_cross_entropy_matches_dense(topo1):
    """tp=1 vocab-parallel CE == plain logsumexp CE, incl. vocab padding."""
    b, t, v_real, v_pad = 2, 8, 50, 64
    logits = jnp.asarray(RNG.normal(size=(b, t, v_pad)), jnp.float32)
    targets = jnp.asarray(RNG.integers(0, v_real, (b, t)), jnp.int32)
    mask = jnp.ones((b, t), jnp.float32)
    ctx = L.Ctx(tp=1)
    got = L.tp_cross_entropy(logits, targets, mask,
                             vocab_real=v_real, vocab_padded=v_pad, ctx=ctx)
    lg = np.asarray(logits)[:, :, :v_real]
    lse = np.log(np.exp(lg - lg.max(-1, keepdims=True)).sum(-1)) \
        + lg.max(-1)
    nll = lse - np.take_along_axis(lg, np.asarray(targets)[..., None],
                                   -1)[..., 0]
    np.testing.assert_allclose(float(got), nll.mean(), rtol=1e-5)


def test_rotary_preserves_norm_and_relative_phase():
    x = jnp.asarray(RNG.normal(size=(1, 16, 2, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16), (1, 16))
    y = L.rotary(x, pos, theta=10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(k)k'> depends only on p-k
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, 32)), jnp.float32)

    def score(pq, pk):
        rq = L.rotary(q, jnp.full((1, 1), pq), 10_000.0)
        rk = L.rotary(k, jnp.full((1, 1), pk), 10_000.0)
        return float(jnp.sum(rq * rk))

    np.testing.assert_allclose(score(5, 3), score(12, 10), rtol=1e-4)


def test_moe_dispatch_matches_dense_reference(topo1):
    """Capacity-dispatch MoE (no drops) == explicit per-token expert mix."""
    import dataclasses

    from repro.configs import get_config, smoke_variant
    from repro.models.blocks import _moe_dispatch_tokens

    cfg = smoke_variant(get_config("deepseek-moe-16b"))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    d, e, k = 16, cfg.n_experts, cfg.top_k
    n = 32
    t = {
        "router.w": jnp.asarray(RNG.normal(size=(d, e)) * 0.3, jnp.float32),
        "moe.wg": jnp.asarray(RNG.normal(size=(e, d, 8)) * 0.3, jnp.float32),
        "moe.wu": jnp.asarray(RNG.normal(size=(e, d, 8)) * 0.3, jnp.float32),
        "moe.wd": jnp.asarray(RNG.normal(size=(e, 8, d)) * 0.3, jnp.float32),
    }
    x = jnp.asarray(RNG.normal(size=(n, d)), jnp.float32)
    ctx = L.Ctx(tp=1)
    got, _aux = _moe_dispatch_tokens(x, t, cfg, ctx)

    # dense reference
    logits = np.asarray(x) @ np.asarray(t["router.w"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    topk = np.argsort(-probs, axis=-1)[:, :k]
    want = np.zeros((n, d), np.float32)
    for i in range(n):
        gates = probs[i, topk[i]]
        gates = gates / gates.sum()
        for j, eid in enumerate(topk[i]):
            h = np.asarray(x)[i] @ np.asarray(t["moe.wg"])[eid]
            u = np.asarray(x)[i] @ np.asarray(t["moe.wu"])[eid]
            act = h / (1 + np.exp(-h)) * u
            want[i] += gates[j] * (act @ np.asarray(t["moe.wd"])[eid])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
