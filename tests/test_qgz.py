"""qgZ tests: the int8 block-quantized gradient wires (hop-1 per-stage
reduce-scatter, hop-2 decompress leg).

Single-device units cover policy validation, config mapping, the cost
model's gradient-wire pricing, and the ISSUE acceptance ranking (an
int8-hop-1 candidate above the pure-fp32 baseline on ``efa-100g``); the
8-virtual-device harness (tests/qgz_harness.py) covers collective routing
exactness, convergence of the tiny LM under the quantized wires, and the
bucket-granular int8 hop-2 census."""

import pathlib

import pytest

from harness_util import run_harness
from repro.core.comm import CommEngine, GatherPolicy, SyncPolicy
from repro.core.mics import MiCSConfig

HARNESS = pathlib.Path(__file__).parent / "qgz_harness.py"


# ---------------------------------------------------------------------------
# policy / config units (single device)
# ---------------------------------------------------------------------------

def test_sync_policy_validation():
    SyncPolicy(hop1_wire_dtype="int8")
    SyncPolicy(hop2_wire_dtype="int8")
    with pytest.raises(ValueError):
        SyncPolicy(hop1_wire_dtype="fp8")
    with pytest.raises(ValueError):
        SyncPolicy(grad_rounding="truncate")
    with pytest.raises(ValueError):
        # the ablation has no staged hop-1 to compress
        SyncPolicy(mode="allreduce_slice", hop1_wire_dtype="int8")
    assert SyncPolicy().stochastic
    assert not SyncPolicy(grad_rounding="nearest").stochastic


def test_mics_config_validation():
    MiCSConfig(hop1_wire_dtype="int8", compress_hop2="int8")
    with pytest.raises(ValueError):
        MiCSConfig(hop1_wire_dtype="fp8")
    with pytest.raises(ValueError):
        MiCSConfig(grad_rounding="up")
    with pytest.raises(ValueError):
        MiCSConfig(compress_hop2="fp8")


@pytest.mark.parametrize("mcfg,hop1,hop2", [
    (MiCSConfig(), "fp32", "fp32"),
    (MiCSConfig(hop1_wire_dtype="int8"), "int8", "fp32"),
    (MiCSConfig(compress_hop2=True), "fp32", "bf16"),
    (MiCSConfig(compress_hop2="bf16"), "fp32", "bf16"),
    (MiCSConfig(compress_hop2="int8", hop1_wire_dtype="bf16"),
     "bf16", "int8"),
])
def test_from_config_grad_wires(topo1, mcfg, hop1, hop2):
    eng = CommEngine.from_config(topo1, mcfg)
    assert eng.sync_policy.hop1_wire_dtype == hop1
    assert eng.sync_policy.hop2_wire_dtype == hop2
    assert eng.sync_policy.stochastic


def test_hop1_noop_at_p1(topo1):
    """partition_size == 1: the int8 hop-1 adjoint is the identity."""
    import jax.numpy as jnp
    import numpy as np

    eng = CommEngine.from_config(
        topo1, MiCSConfig(hop1_wire_dtype="int8"))
    ct = jnp.arange(16.0, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(eng._adjoint(ct)),
                                  np.asarray(ct))


# ---------------------------------------------------------------------------
# cost-model pricing + ranking (device-free)
# ---------------------------------------------------------------------------

def test_grad_wire_bytes_pricing():
    from repro.core.autotune import INT8_WIRE_BYTES, grad_wire_bytes
    from repro.core.quant import BLOCK

    assert INT8_WIRE_BYTES == pytest.approx(1.0 + 4.0 / BLOCK)
    # hop-1 fp32 keeps the legacy gather-wire-follows rule
    assert grad_wire_bytes("fp32", "fp32") == 4.0
    assert grad_wire_bytes("bf16", "fp32") == 2.0
    assert grad_wire_bytes("int8", "fp32") == 4.0   # straight-through
    # compressed hop-1 decouples from the gather wire
    for gw in ("fp32", "bf16", "int8"):
        assert grad_wire_bytes(gw, "bf16") == 2.0
        assert grad_wire_bytes(gw, "int8") == pytest.approx(INT8_WIRE_BYTES)


def test_predict_traffic_int8_hop1_stages():
    from test_autotune import StubModel, topo_single

    from repro.core.autotune import INT8_WIRE_BYTES, predict_traffic

    model, topo = StubModel(), topo_single(p=16, repl=2)
    gp = GatherPolicy("inner_first", "bf16", 4, False)
    base = predict_traffic(model, topo, gp, SyncPolicy(), micro_steps=2)
    qgz = predict_traffic(model, topo, gp,
                          SyncPolicy(hop1_wire_dtype="int8"), micro_steps=2)
    for stage in ("grad_rs.inner", "grad_rs.outer"):
        b, q = base["by_stage"][stage], qgz["by_stage"][stage]
        # bf16 adjoint (2 B) -> int8+scales wire (~1.03 B)
        assert q["wire_bytes"] == pytest.approx(
            b["wire_bytes"] * INT8_WIRE_BYTES / 2.0)
        assert q["count"] == 2 * b["count"]     # q + scales per stage
        assert q["events"] == b["events"]
    for stage in ("param_gather.inner", "param_gather.outer", "hop2"):
        assert qgz["by_stage"][stage]["wire_bytes"] == pytest.approx(
            base["by_stage"][stage]["wire_bytes"])
    # int8 hop-2: the decomposed quantized all-reduce, 4 legs per payload
    q2 = predict_traffic(model, topo, gp,
                         SyncPolicy(hop2_wire_dtype="int8"), micro_steps=2)
    assert q2["by_stage"]["hop2"]["wire_bytes"] == pytest.approx(
        base["by_stage"]["hop2"]["wire_bytes"] * INT8_WIRE_BYTES / 4.0)
    assert q2["by_stage"]["hop2"]["count"] == \
        4 * base["by_stage"]["hop2"]["count"]


def test_int8_hop1_ranked_above_fp32_baseline():
    """ISSUE acceptance: on efa-100g an int8-hop-1 candidate outranks the
    pure-fp32 baseline — the gradient wire is byte-dominated there."""
    from test_autotune import StubModel, topo_single

    from repro.core.autotune import rank_policies

    plan = rank_policies(StubModel(), topo_single(p=16, repl=2), "efa-100g",
                         micro_steps=2, prefetch=False)
    cands = plan.candidates
    best_int8_hop1 = min(i for i, c in enumerate(cands)
                         if c.sync.hop1_wire_dtype == "int8")
    pure_fp32 = min(i for i, c in enumerate(cands)
                    if c.gather.wire_dtype == "fp32"
                    and c.sync.hop1_wire_dtype == "fp32"
                    and c.sync.hop2_wire_dtype == "fp32")
    assert best_int8_hop1 < pure_fp32
    # and qgZ flips the weight-gather ranking: with the int8 hop-1 the
    # int8 *gather* no longer pays the fp32 straight-through adjoint
    with_qgz_int8g = min(i for i, c in enumerate(cands)
                         if c.gather.wire_dtype == "int8"
                         and c.sync.hop1_wire_dtype == "int8")
    with_qgz_bf16g = min(i for i, c in enumerate(cands)
                         if c.gather.wire_dtype == "bf16"
                         and c.sync.hop1_wire_dtype == "int8")
    assert with_qgz_int8g < with_qgz_bf16g
    no_qgz_int8g = min(i for i, c in enumerate(cands)
                       if c.gather.wire_dtype == "int8"
                       and c.sync.hop1_wire_dtype == "fp32")
    no_qgz_bf16g = min(i for i, c in enumerate(cands)
                       if c.gather.wire_dtype == "bf16"
                       and c.sync.hop1_wire_dtype == "fp32")
    assert no_qgz_bf16g < no_qgz_int8g      # the PR 2 observation, intact


def test_int8_hop1_permission_gating():
    """The tuner ranks qgZ rows always but selects them only under the
    explicit hop1_wire_dtype='int8' opt-in — quant_gather (the int8
    *weight* wire, whose adjoint stays exact) must NOT permit the lossy
    gradient wire on its own."""
    from test_autotune import StubModel, topo_single

    from repro.core.autotune import rank_policies, resolve_config

    topo = topo_single(p=16, repl=2)
    plan = rank_policies(StubModel(), topo, "efa-100g", micro_steps=2,
                         prefetch=False)
    assert any(c.lossy_hop1 for c in plan.candidates)
    assert plan.chosen.sync.hop1_wire_dtype == "fp32"
    opted = rank_policies(StubModel(), topo, "efa-100g", micro_steps=2,
                          prefetch=False, allow_int8_hop1=True)
    assert opted.chosen.sync.hop1_wire_dtype == "int8"

    mcfg = MiCSConfig(policy="auto", link_profile="efa-100g", micro_steps=2,
                      hop1_wire_dtype="int8", prefetch=False)
    resolved, plan = resolve_config(mcfg, StubModel(), topo)
    assert resolved.hop1_wire_dtype == plan.chosen.sync.hop1_wire_dtype \
        == "int8"
    # a pre-qgZ auto config (quant_gather only) keeps exact gradients
    legacy = MiCSConfig(policy="auto", link_profile="efa-100g",
                        micro_steps=2, quant_gather=True, prefetch=False)
    resolved_l, _ = resolve_config(legacy, StubModel(), topo)
    assert resolved_l.hop1_wire_dtype == "fp32"


def test_int8_hop2_ranked_and_gated():
    """compress_hop2='int8' under policy='auto' is honored: the grid ranks
    the int8 hop-2 wire and the opt-in selects it (it is the cheapest
    hop-2 candidate) instead of silently rewriting to bf16/fp32."""
    from test_autotune import StubModel, topo_single

    from repro.core.autotune import rank_policies, resolve_config

    topo = topo_single(p=16, repl=2)
    plan = rank_policies(StubModel(), topo, "efa-100g", micro_steps=2,
                         prefetch=False)
    assert any(c.sync.hop2_wire_dtype == "int8" for c in plan.candidates)
    assert plan.chosen.sync.hop2_wire_dtype == "fp32"
    # bf16 opt-in does not unlock int8 hop-2
    bf16 = rank_policies(StubModel(), topo, "efa-100g", micro_steps=2,
                         prefetch=False, allow_bf16_hop2=True)
    assert bf16.chosen.sync.hop2_wire_dtype == "bf16"
    mcfg = MiCSConfig(policy="auto", link_profile="efa-100g", micro_steps=2,
                      compress_hop2="int8", prefetch=False)
    resolved, plan = resolve_config(mcfg, StubModel(), topo)
    assert plan.chosen.sync.hop2_wire_dtype == "int8"
    assert resolved.compress_hop2 == "int8"


def test_resolve_roundtrips_hop1_through_from_config(topo1):
    from test_autotune import StubModel, topo_single

    from repro.core.autotune import resolve_config

    mcfg = MiCSConfig(micro_steps=2, policy="auto", link_profile="efa-100g",
                      quant_gather=True, compress_hop2=True, prefetch=False)
    resolved, plan = resolve_config(mcfg, StubModel(),
                                    topo_single(p=16, repl=2))
    eng = CommEngine.from_config(topo1, resolved)
    assert eng.sync_policy == plan.chosen.sync
    assert eng.gather_policy.wire_dtype == plan.chosen.gather.wire_dtype


def test_qgz_compute_priced():
    """int8 hop-1 stage times include the quant/dequant HBM term, so the
    qgZ row is not modeled as free compression."""
    from test_autotune import StubModel, topo_single

    from repro.core.autotune import cost_candidate
    from repro.core.linkmodel import get_profile

    model, topo = StubModel(), topo_single(p=16, repl=2)
    prof = get_profile("efa-100g")
    gp = GatherPolicy("inner_first", "bf16", 4, False)
    qgz = cost_candidate(model, topo, prof, gp,
                         SyncPolicy(hop1_wire_dtype="int8"), micro_steps=2)
    assert qgz.lossy_hop1 and not qgz.lossy_wire
    # stage time exceeds the pure wire+alpha time by the HBM term
    for stage in ("grad_rs.inner", "grad_rs.outer"):
        e = qgz.bytes_by_stage[stage]
        link = prof.link(e["tier"])
        wire_only = e["events"] * (e["group_size"] - 1) * link.alpha \
            + e["wire_bytes"] / link.bandwidth
        assert qgz.t_by_stage[stage] > wire_only


# ---------------------------------------------------------------------------
# multi-device harness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def harness_results():
    return run_harness(HARNESS)


CHECKS = [
    "quant_rs_routing", "quant_rs_accuracy", "step_seed_dither",
    "hop1_bf16_bitwise", "int8_hop1_convergence", "int8_hop2_boundary",
]


@pytest.mark.parametrize("name", CHECKS)
def test_qgz_check(harness_results, name):
    res = harness_results.get(name)
    assert res is not None, f"harness did not run {name}"
    assert res["ok"], f"{name}: {res.get('err')}\n{res.get('tb', '')}"


def test_convergence_within_tolerance(harness_results):
    detail = harness_results.get("int8_hop1_convergence_detail")
    assert detail is not None
    tol = detail["tolerance"]
    assert detail["qgZ_rel_final"] < tol
    assert detail["qwZ+qgZ_rel_final"] < tol


def test_int8_hop2_bucket_granularity(harness_results):
    detail = harness_results.get("int8_hop2_boundary_detail")
    assert detail is not None
    assert detail["census"]["hop2_ops"] == detail["n_buckets"]
    assert detail["census"]["interleaved"]
