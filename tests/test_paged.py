"""Paged-KV serving-engine tests.

Device-free units exercise the block allocator and the continuous-batching
scheduler (FIFO admission under the free-block budget, chunked-prefill
interleaving, eviction + front-of-queue requeue determinism, and the
``reserve="full"`` no-eviction watermark); the engine-level properties —
paged-vs-contiguous bitwise equivalence across KV dtypes and block sizes,
chunk-boundary invariance, int8 KV error bounds, the seeded sampler and the
serve-mode memplan contract — run through the 8-virtual-device subprocess
harness (tests/serve_harness.py).
"""

import numpy as np
import pytest

from repro.runtime.batching import ContinuousBatcher, Request
from repro.runtime.paged import PagedKVAllocator, blocks_for


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_blocks_for():
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2
    assert blocks_for(0, 8) == 0


def test_allocator_reserves_garbage_block():
    a = PagedKVAllocator(8, 4)
    assert a.free_blocks == 7          # block 0 is the engine's drop target
    got = a.alloc(7)
    assert got is not None and 0 not in got
    assert a.alloc(1) is None          # exhausted -> None, not a partial
    a.free(got)
    assert a.free_blocks == 7


def test_allocator_free_then_realloc_roundtrip():
    a = PagedKVAllocator(6, 4)
    x = a.alloc(3)
    y = a.alloc(2)
    a.free(x)
    z = a.alloc(3)
    assert sorted(z) == sorted(x)      # recycled, no leak
    assert a.free_blocks == 0 and y is not None


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _requests(n, plen=4, max_new=6, seed0=100):
    return [Request(rid=i, prompt=list(range(1, plen + 1)),
                    max_new_tokens=max_new, seed=seed0 + i)
            for i in range(n)]


def _drive(batcher, reqs, sample=None, max_ticks=500):
    """Run the scheduler loop with a fake engine; the sampled token is
    keyed by (rid, next_pos) so evicted-and-replayed requests regenerate
    the same stream (mirroring the seeded per-(seed, position) sampler)."""
    sample = sample or (lambda req: (req.rid * 1000 + req.next_pos) % 97)
    for r in reqs:
        batcher.submit(r)
    for _ in range(max_ticks):
        if batcher.idle:
            break
        plan = batcher.plan_step()
        tok = np.zeros(batcher.batch, np.int64)
        for slot, req in plan.requests.items():
            n = int(plan.n_new[slot])
            tok[slot] = sample_after(req, n, sample)
        batcher.commit(plan, tok)
    assert batcher.idle, "scheduler did not drain"
    return batcher


def sample_after(req, n, sample):
    """The engine samples from the last consumed token's position."""
    class _V:                          # next_pos as the engine will see it
        rid = req.rid
        next_pos = req.next_pos + n
    return sample(_V)


def test_fifo_admission_and_drain():
    b = ContinuousBatcher(dp=2, slots_local=2, nb_local=9, block_size=4,
                          max_blocks=4, chunk=4)
    reqs = _requests(8)
    _drive(b, reqs)
    st = b.stats()
    assert st["finished"] == 8 and st["evictions"] == 0
    # FIFO: earlier rids were admitted no later than later ones
    admits = {r.rid: r.admit_tick for r in b.finished}
    assert all(admits[i] <= admits[i + 1] for i in range(7))
    # every block returned to its rank's pool
    assert all(a.free_blocks == 8 for a in b.allocators)
    assert all(len(r.generated) == 6 for r in b.finished)


def test_chunked_prefill_plan_shapes():
    b = ContinuousBatcher(dp=1, slots_local=1, nb_local=9, block_size=4,
                          max_blocks=4, chunk=3)
    b.submit(Request(rid=0, prompt=list(range(1, 8)), max_new_tokens=2))
    p1 = b.plan_step()                 # first prompt chunk: 3 tokens
    assert p1.n_new[0] == 3 and list(p1.tokens[0]) == [1, 2, 3]
    b.commit(p1, np.zeros(1, np.int64))
    p2 = b.plan_step()
    assert p2.n_new[0] == 3 and p2.pos[0] == 3
    b.commit(p2, np.zeros(1, np.int64))
    p3 = b.plan_step()                 # ragged tail of the prompt
    assert p3.n_new[0] == 1 and p3.pos[0] == 6 and p3.tokens[0, 0] == 7
    b.commit(p3, np.full(1, 42, np.int64))
    assert b.resident[0].generated == [42]       # first sampled token
    p4 = b.plan_step()                 # decode: feed the sampled token back
    assert p4.n_new[0] == 1 and p4.tokens[0, 0] == 42


def _overload(reserve):
    # 2 slots but only 5 usable blocks: two 12-position requests need
    # 3 blocks each *eventually*, yet admission under "min" lets both in
    b = ContinuousBatcher(dp=1, slots_local=2, nb_local=6, block_size=4,
                          max_blocks=4, chunk=4, reserve=reserve)
    reqs = [Request(rid=i, prompt=[1, 2, 3, 4], max_new_tokens=9)
            for i in range(3)]
    _drive(b, reqs)
    return b


def test_reserve_full_never_evicts():
    b = _overload("full")
    assert b.stats()["finished"] == 3
    assert b.stats()["evictions"] == 0


def test_eviction_requeue_is_deterministic():
    b = _overload("min")
    assert b.stats()["finished"] == 3
    # lazy growth over-admitted, so somebody was evicted and replayed...
    assert b.stats()["evictions"] > 0
    # ...yet every request's stream matches the eviction-free schedule,
    # because the fake engine (like the real sampler) is keyed by
    # (request, position) — replay regenerates the same tokens
    want = {r.rid: r.generated for r in _overload("full").finished}
    assert {r.rid: r.generated for r in b.finished} == want


def test_admission_respects_block_budget():
    b = ContinuousBatcher(dp=1, slots_local=2, nb_local=3, block_size=4,
                          max_blocks=4, chunk=4)
    b.submit(Request(rid=0, prompt=list(range(1, 6)), max_new_tokens=2))
    plan = b.plan_step()               # needs blocks_for(6)=2 of 2 free: ok
    assert plan.active_rows == 1
    b2 = ContinuousBatcher(dp=1, slots_local=2, nb_local=2, block_size=4,
                           max_blocks=4, chunk=4)
    b2.submit(Request(rid=0, prompt=list(range(1, 6)), max_new_tokens=2))
    assert b2.plan_step().active_rows == 0       # 1 free block < budget 2


def test_submit_validates():
    b = ContinuousBatcher(dp=1, slots_local=1, nb_local=9, block_size=4,
                          max_blocks=2, chunk=1)
    with pytest.raises(ValueError):
        b.submit(Request(rid=0, prompt=[1], max_new_tokens=99))
    with pytest.raises(ValueError):
        b.submit(Request(rid=1, prompt=[], max_new_tokens=1))
    with pytest.raises(ValueError):
        ContinuousBatcher(dp=1, slots_local=1, nb_local=2, block_size=4,
                          max_blocks=2, chunk=1, reserve="lazy")


# ---------------------------------------------------------------------------
# engine properties (subprocess harness, 8 virtual devices)
# ---------------------------------------------------------------------------

HARNESS_CHECKS = ("paged_bitwise", "chunked_prefill", "int8_kv_error",
                  "sampler", "memplan_serve_footprint")


@pytest.mark.parametrize("name", HARNESS_CHECKS)
def test_serve_harness(serve_results, name):
    assert serve_results[name]["ok"], serve_results[name]


def test_paged_bitwise_covers_dtypes_and_block_sizes(serve_results):
    detail = serve_results["paged_bitwise_detail"]
    cells = {(d["kv_dtype"], d["block_size"]) for d in detail.values()}
    assert {("fp32", 4), ("fp32", 8), ("bf16", 4), ("bf16", 8)} <= cells
    assert all(d["tokens_bitwise"] and d["logits_bitwise"]
               for d in detail.values())


def test_serve_memplan_residency_ranks_kv_dtypes(serve_results):
    res = serve_results["memplan_serve_footprint_detail"][
        "max_resident_requests"]
    assert 0 < res["fp32"] < res["bf16"] <= res["int8"]
