"""Serving-engine verification harness, run in a subprocess with 8 virtual
CPU devices (same pattern as memplan_harness.py).  Prints one JSON object
with named check results; tests/test_paged.py asserts on them, and the CI
``bench`` job runs ``benchmarks/serve_bench.py --smoke --check`` as the
closed-loop smoke gate.

The property under test is the tentpole contract of runtime/paged.py: a
request decoded through the paged block-pool engine is BITWISE identical
to the contiguous vector-position reference — for every KV dtype that
round-trips exactly (fp32, bf16), across block sizes, and independently of
how its prompt was chunked.  The memplan check extends the training
planner's predicted-vs-compiled discipline (args exact, transients within
``MEM_RTOL``) to the serve-mode footprint with the donated KV pool.

Checks:

  paged_bitwise           {fp32, bf16} KV x block sizes {4, 8} on the GQA
                          mesh (tp=4 > n_kv_heads) with block-straddling
                          prompts and mixed greedy/sampled rows: tokens and
                          logits bitwise-equal to the contiguous reference
  chunked_prefill         chunk-boundary placement at a fixed chunk width
                          is bitwise-irrelevant (same executable, same
                          key-axis length); across widths (chunk=4 vs
                          token-by-token) greedy tokens agree and pools /
                          logits match to last-ulp tolerance
  int8_kv_error           quantize/dequantize round-trip error is within
                          the documented absmax/254 per-element bound, and
                          the int8-KV engine's decode logits stay close to
                          the fp32 reference
  sampler                 temperature 0 equals the greedy argmax; decoding
                          is deterministic per (seed, position); different
                          seeds decorrelate the sampled stream
  memplan_serve_footprint predict_footprint(mode="serve") vs the compiled
                          paged step's memory_analysis(): argument bytes
                          (param shards + KV pool + plan rows) EXACT for
                          bf16 and int8 pools, transients within MEM_RTOL;
                          max_resident_requests grows as the KV dtype
                          shrinks (fp32 < bf16 < int8)
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import memplan as M
from repro.core import quant as Q
from repro.core.comm import policies_from_config
from repro.core.mics import MiCSConfig, init_state
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.runtime import paged as PG
from repro.runtime.serving import build_serve_steps, global_cache_shapes

RESULTS = {}

CAP = 16                      # contiguous reference cache positions
PLENS = [3, 7, 5, 9]          # 7 and 9 straddle both swept block sizes
B = 4
STEPS = 4
_SHARED = {}


def check(name):
    def deco(fn):
        try:
            fn()
            RESULTS[name] = {"ok": True}
        except Exception as e:  # noqa: BLE001
            RESULTS[name] = {
                "ok": False,
                "err": f"{type(e).__name__}: {e}",
                "tb": traceback.format_exc()[-2000:],
            }
        return fn
    return deco


def _shared():
    """Model/params on the GQA mesh (dp=2, tp=4 > n_kv_heads), built once."""
    if not _SHARED:
        cfg = smoke_variant(get_config("llama3.2-1b"))
        topo = MiCSTopology(make_host_mesh(1, 1, 2, 4))
        model = build_model(cfg, tp=topo.model_size)
        state = init_state(model, topo, seed=7)
        _SHARED.update(model=model, topo=topo, params=state["params"])
    return _SHARED["model"], _SHARED["topo"], _SHARED["params"]


def _mixed_rows():
    """Per-request sampling knobs: greedy and sampled rows side by side."""
    seeds = jnp.asarray(np.arange(B, dtype=np.int32) * 101)
    temps = jnp.asarray(np.array([0.0, 0.7, 0.0, 0.9], np.float32))
    return seeds, temps


def _copy(tree):
    return jax.tree.map(lambda x: jnp.array(x, copy=True), tree)


def _prefill_ref(kv_dtype):
    """Contiguous reference caches holding the PLENS prompts, row by row
    (each row prefilled at its own length — no cross-row padding)."""
    model, topo, params = _shared()
    jdt = {"fp32": jnp.float32, "bf16": jnp.bfloat16}[kv_dtype]
    mcfg = MiCSConfig(gather_dtype=jnp.float32, kv_dtype=kv_dtype)
    prefill_fn, _ = build_serve_steps(model, topo, mcfg, CAP)
    tmpl, _specs = global_cache_shapes(model, topo, B, CAP)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, jdt), tmpl)
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, model.cfg.vocab, (B, max(PLENS)))
    last = np.zeros((B, model.vocab_padded), np.float32)
    for b in range(B):
        n = PLENS[b]
        row = {"tokens": jnp.asarray(
            np.broadcast_to(prompts[b:b + 1, :n], (B, n)).astype(np.int32))}
        logits, caches_b = prefill_fn(params, row)

        def put(dst, src):
            return dst.at[:, b].set(
                jnp.asarray(np.asarray(src)[:, b]).astype(dst.dtype))
        caches = jax.tree.map(put, caches, caches_b)
        last[b] = np.asarray(logits)[b, -1]
    tok0 = np.argmax(last[:, :model.cfg.vocab], -1).astype(np.int32)
    return caches, tok0, prompts


def _reference_trace(kv_dtype):
    """STEPS of the contiguous step; returns the per-step (tok, logits)
    record plus the post-prefill caches for seeding paged pools."""
    key = ("ref", kv_dtype)
    if key in _SHARED:
        return _SHARED[key]
    model, topo, params = _shared()
    mcfg = MiCSConfig(gather_dtype=jnp.float32, kv_dtype=kv_dtype)
    caches0, tok0, prompts = _prefill_ref(kv_dtype)
    step = PG.build_contiguous_step(model, topo, mcfg, CAP)
    seeds, temps = _mixed_rows()
    caches = _copy(caches0)
    tok = jnp.asarray(tok0[:, None])
    pos = np.asarray(PLENS, np.int32)
    rec = []
    for s in range(STEPS):
        tr, lr, caches = step(params, caches, tok, jnp.asarray(pos + s),
                              seeds, temps)
        rec.append((np.asarray(tr), np.asarray(lr)))
        tok = tr[:, None].astype(jnp.int32)
    _SHARED[key] = (rec, caches0, tok0, prompts)
    return _SHARED[key]


def _paged_pool_from_ref(caches0, block_size, max_blocks, kv_dtype,
                         extra_pos=STEPS):
    """A block pool seeded with the reference prompts + its tables."""
    model, topo, _params = _shared()
    dp = topo.data_parallel_size
    nbl = sum(PG.blocks_for(n + extra_pos, block_size)
              for n in PLENS) + 1
    allocs = [PG.PagedKVAllocator(nbl, block_size) for _ in range(dp)]
    tables = np.zeros((B, max_blocks), np.int32)
    for b in range(B):
        blocks = allocs[b // (B // dp)].alloc(
            PG.blocks_for(PLENS[b] + extra_pos, block_size))
        tables[b, :len(blocks)] = blocks
    pool, _ = PG.init_paged_caches(model, topo, nbl, block_size, kv_dtype)
    pool = PG.pages_from_contiguous(
        model, topo, caches0, pool, tables, PLENS,
        block_size=block_size, kv_dtype=kv_dtype)
    return pool, tables


def _paged_cell(kv_dtype, block_size):
    """One paged-vs-contiguous bitwise cell; returns its ledger row."""
    model, topo, params = _shared()
    max_blocks = -(-(max(PLENS) + STEPS) // block_size)
    rec, caches0, tok0, _prompts = _reference_trace(kv_dtype)
    mcfg = MiCSConfig(gather_dtype=jnp.float32, kv_dtype=kv_dtype,
                      kv_block_size=block_size)
    step = PG.build_paged_step(model, topo, mcfg, max_blocks=max_blocks,
                               block_size=block_size, chunk=1,
                               kv_dtype=kv_dtype)
    pool, tables = _paged_pool_from_ref(caches0, block_size, max_blocks,
                                        kv_dtype)
    seeds, temps = _mixed_rows()
    tok = jnp.asarray(tok0[:, None])
    pos = np.asarray(PLENS, np.int32)
    ok_tok = ok_log = True
    for s in range(STEPS):
        tp_, lp, pool = step(params, pool, tok, jnp.asarray(pos + s),
                             jnp.ones(B, jnp.int32), jnp.asarray(tables),
                             seeds, temps)
        tr, lr = rec[s]
        ok_tok &= bool(np.array_equal(tr, np.asarray(tp_)))
        ok_log &= bool(np.array_equal(
            lr.view(np.uint32), np.asarray(lp).view(np.uint32)))
        tok = tp_[:, None].astype(jnp.int32)
    row = {"kv_dtype": kv_dtype, "block_size": block_size,
           "tokens_bitwise": ok_tok, "logits_bitwise": ok_log}
    assert ok_tok and ok_log, row
    return row


# ---------------------------------------------------------------------------
@check("paged_bitwise")
def _paged_bitwise():
    detail = {}
    for kv in ("fp32", "bf16"):
        for bs in (4, 8):
            detail[f"{kv}/bs{bs}"] = _paged_cell(kv, bs)
    RESULTS["paged_bitwise_detail"] = detail


# ---------------------------------------------------------------------------
@check("chunked_prefill")
def _chunked_prefill():
    """Chunk-boundary placement is bitwise-irrelevant for a fixed chunk
    width (one compiled executable, key axis always max_blocks * bs);
    across widths (chunk=4 vs token-by-token) the kernels tile the token
    matmuls differently, so the sampled tokens must agree and the pool /
    logits must match to last-ulp tolerance."""
    model, topo, params = _shared()
    bs, mb, chunk = 4, 4, 4
    mcfg = MiCSConfig(gather_dtype=jnp.float32, kv_dtype="fp32",
                      kv_block_size=bs)
    step_c = PG.build_paged_step(model, topo, mcfg, max_blocks=mb,
                                 block_size=bs, chunk=chunk, kv_dtype="fp32")
    step_1 = PG.build_paged_step(model, topo, mcfg, max_blocks=mb,
                                 block_size=bs, chunk=1, kv_dtype="fp32")
    _rec, _c0, _t0, prompts = _reference_trace("fp32")
    plens = np.asarray(PLENS)
    seeds, _ = _mixed_rows()
    temps = jnp.zeros(B, jnp.float32)      # greedy: cross-width tokens
    nbl = 16
    tables = np.zeros((B, mb), np.int32)
    allocs = [PG.PagedKVAllocator(nbl, bs)
              for _ in range(topo.data_parallel_size)]
    for b in range(B):
        blk = allocs[b // (B // topo.data_parallel_size)].alloc(
            PG.blocks_for(int(plens[b]) + STEPS, bs))
        tables[b, :len(blk)] = blk
    tbl = jnp.asarray(tables)

    def prefill(step_fn, width, first_n):
        """Stream the prompts through ``step_fn``; per-row first chunk of
        ``first_n`` tokens, then greedy ``width``-sized chunks.  Returns
        (pool arrays, last (tok, logits) per row)."""
        pool, _ = PG.init_paged_caches(model, topo, nbl, bs, "fp32")
        done = np.zeros(B, np.int64)
        nxt = np.minimum(plens, first_n)
        last = None
        while (done < plens).any():
            n_new = nxt.astype(np.int32)
            toks = np.zeros((B, width), np.int32)
            for b in range(B):
                toks[b, :n_new[b]] = prompts[b, done[b]:done[b] + n_new[b]]
            t, lg, pool = step_fn(
                params, pool, jnp.asarray(toks),
                jnp.asarray(done.astype(np.int32)), jnp.asarray(n_new),
                tbl, seeds, temps)
            t, lg = np.asarray(t), np.asarray(lg)
            if last is None:
                last = (t.copy(), lg.copy())
            fin = (n_new > 0) & (done + n_new == plens)
            last[0][fin] = t[fin]
            last[1][fin] = lg[fin]
            done += n_new
            nxt = np.minimum(plens - done, width)
        return jax.tree.map(np.asarray, pool), last

    def tail(step_fn, pool_np, tok0_):
        pool = jax.tree.map(jnp.asarray, pool_np)
        tok = jnp.asarray(tok0_[:, None].astype(np.int32))
        out = []
        for s in range(STEPS):
            t, lg, pool = step_fn(
                params, pool, tok, jnp.asarray((plens + s).astype(np.int32)),
                jnp.ones(B, jnp.int32), tbl, seeds, temps)
            out.append((np.asarray(t), np.asarray(lg)))
            tok = t[:, None].astype(jnp.int32)
        return out

    # fixed width, two boundary patterns: bitwise-equal pools and tokens
    pool_a, last_a = prefill(step_c, chunk, np.full(B, chunk))
    pool_a2, last_a2 = prefill(step_c, chunk,
                               1 + np.arange(B) % chunk)   # staggered
    ok_fixed = bool(np.array_equal(last_a[0], last_a2[0])) and bool(
        np.array_equal(last_a[1].view(np.uint32),
                       last_a2[1].view(np.uint32)))
    for name in pool_a:
        for part in pool_a[name]:
            ok_fixed &= bool(np.array_equal(pool_a[name][part],
                                            pool_a2[name][part]))

    # across widths: same greedy tokens, last-ulp pools/logits
    pool_b, last_b = prefill(step_1, 1, np.ones(B, np.int64))
    ok_tok = bool(np.array_equal(last_a[0], last_b[0]))
    logit_err = float(np.max(np.abs(last_a[1] - last_b[1])))
    pool_err = max(
        float(np.max(np.abs(pool_a[name][part].astype(np.float64)
                            - pool_b[name][part].astype(np.float64))))
        for name in pool_a for part in pool_a[name])
    tail_a = tail(step_1, pool_a, last_a[0])
    tail_b = tail(step_1, pool_b, last_b[0])
    ok_tail = all(np.array_equal(a[0], b_[0]) for a, b_ in zip(tail_a,
                                                               tail_b))
    RESULTS["chunked_prefill_detail"] = {
        "fixed_width_bitwise": ok_fixed, "cross_width_tokens_equal": ok_tok,
        "cross_width_tail_tokens_equal": ok_tail,
        "cross_width_logit_err": logit_err,
        "cross_width_pool_err": pool_err, "chunk": chunk}
    assert ok_fixed and ok_tok and ok_tail
    assert pool_err < 1e-4 and logit_err < 1e-3, (pool_err, logit_err)


# ---------------------------------------------------------------------------
@check("int8_kv_error")
def _int8_kv_error():
    # (a) the documented round-trip bound: per-element error <= absmax/254
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(8, 4 * Q.BLOCK)).astype(np.float32))
    q, s = Q.quantize_flat(x)
    xd = Q.dequantize_flat(q, s, dtype=jnp.float32)
    err = np.abs(np.asarray(xd) - np.asarray(x))
    absmax = np.max(np.abs(np.asarray(x).reshape(8, 4, Q.BLOCK)), -1)
    bound = np.repeat(absmax / 254.0, Q.BLOCK, axis=-1) + 1e-7
    assert (err <= bound).all(), float((err - bound).max())

    # (b) the int8 engine stays close to the fp32 reference logits
    model, topo, params = _shared()
    bs, mb = 8, -(-(max(PLENS) + STEPS) // 8)
    rec, caches0, tok0, _p = _reference_trace("fp32")
    mcfg = MiCSConfig(gather_dtype=jnp.float32, kv_dtype="int8",
                      kv_block_size=bs)
    step = PG.build_paged_step(model, topo, mcfg, max_blocks=mb,
                               block_size=bs, chunk=1, kv_dtype="int8")
    pool, tables = _paged_pool_from_ref(caches0, bs, mb, "int8")
    seeds, temps = _mixed_rows()
    temps = temps * 0.0          # greedy: isolate the KV quantization error
    tok = jnp.asarray(tok0[:, None])
    pos = np.asarray(PLENS, np.int32)
    rel = 0.0
    for s in range(STEPS):
        _t8, l8, pool = step(params, pool, tok, jnp.asarray(pos + s),
                             jnp.ones(B, jnp.int32), jnp.asarray(tables),
                             seeds, temps)
        tr, lr = rec[s]
        rel = max(rel, float(np.max(np.abs(np.asarray(l8) - lr))
                             / np.max(np.abs(lr))))
        # teacher-force the reference stream: the smoke model's random-init
        # logits are nearly flat, so comparing free-running trajectories
        # would measure argmax flips, not the KV quantization error
        tok = jnp.asarray(tr[:, None].astype(np.int32))
    RESULTS["int8_kv_error_detail"] = {
        "roundtrip_max_err": float(err.max()),
        "logits_rel_err": rel}
    assert np.isfinite(rel) and rel < 0.1, rel


# ---------------------------------------------------------------------------
@check("sampler")
def _sampler():
    model, topo, params = _shared()
    mcfg = MiCSConfig(gather_dtype=jnp.float32, kv_dtype="fp32")
    step = PG.build_contiguous_step(model, topo, mcfg, CAP)
    _rec, caches0, tok0, _p = _reference_trace("fp32")
    pos = jnp.asarray(np.asarray(PLENS, np.int32))
    tok = jnp.asarray(tok0[:, None])
    zs = jnp.zeros(B, jnp.int32)

    def one(seeds, temps):
        t, lg, _c = step(params, _copy(caches0), tok, pos,
                         jnp.asarray(seeds), jnp.asarray(temps))
        return np.asarray(t), np.asarray(lg)

    # temperature 0 == the greedy argmax over the real vocab
    t0, lg = one(zs, np.zeros(B, np.float32))
    assert np.array_equal(t0, np.argmax(lg[:, :model.cfg.vocab], -1)), t0

    # deterministic per (seed, position): same inputs, same stream
    seeds = np.arange(B, dtype=np.int32) * 7 + 1
    hot = np.full(B, 1.2, np.float32)
    ta, _ = one(seeds, hot)
    tb, _ = one(seeds, hot)
    assert np.array_equal(ta, tb), (ta, tb)

    # different seeds decorrelate the stream
    tc, _ = one(seeds + 1, hot)
    assert not np.array_equal(ta, tc), ta
    RESULTS["sampler_detail"] = {
        "greedy": t0.tolist(), "sampled": ta.tolist(),
        "resampled_other_seed": tc.tolist()}


# ---------------------------------------------------------------------------
@check("memplan_serve_footprint")
def _memplan_serve_footprint():
    model, topo, params = _shared()
    bs, mb, slots = 8, 4, 4
    nbl = 17
    dp = topo.data_parallel_size
    Bp = dp * slots
    detail = {}
    for kv in ("bf16", "int8"):
        mcfg = MiCSConfig(kv_dtype=kv, kv_block_size=bs)
        step = PG.build_paged_step(model, topo, mcfg, max_blocks=mb,
                                   block_size=bs, chunk=1, kv_dtype=kv)
        pool, _ = PG.init_paged_caches(model, topo, nbl, bs, kv)
        z = jnp.zeros
        ma = step.lower(
            params, pool, z((Bp, 1), jnp.int32), z(Bp, jnp.int32),
            z(Bp, jnp.int32), z((Bp, mb), jnp.int32), z(Bp, jnp.int32),
            z(Bp, jnp.float32)).compile().memory_analysis()
        gp, sp = policies_from_config(mcfg)
        plan = M.predict_footprint(
            model, topo, gp, sp, mode="serve",
            kv_pages_tokens=nbl * bs, kv_dtype=kv,
            decode_batch=slots, decode_ctx=mb * bs,
            decode_chunk=1, kv_max_blocks=mb)
        row = {
            "predicted_args_bytes": plan.args_bytes,
            "measured_args_bytes": ma.argument_size_in_bytes,
            "predicted_temp_bytes": plan.temp_bytes,
            "measured_temp_bytes": ma.temp_size_in_bytes,
            "components": dict(plan.components),
        }
        detail[kv] = row
        assert plan.args_bytes == ma.argument_size_in_bytes, (kv, row)
        assert abs(plan.temp_bytes - ma.temp_size_in_bytes) \
            <= M.MEM_RTOL * ma.temp_size_in_bytes, (kv, row)

    # residency planning: shrinking the KV dtype admits more requests
    gp, sp = policies_from_config(MiCSConfig())
    res = {kv: M.max_resident_requests(
        model, topo, gp, sp, hbm_bytes=16 * 2**30, ctx_len=1024,
        kv_block_size=16, kv_dtype=kv) for kv in ("fp32", "bf16", "int8")}
    detail["max_resident_requests"] = res
    assert 0 < res["fp32"] < res["bf16"] <= res["int8"], res
    RESULTS["memplan_serve_footprint_detail"] = detail


print(json.dumps(RESULTS, indent=1, default=str))
if "--check" in sys.argv:
    bad = [k for k, v in RESULTS.items()
           if isinstance(v, dict) and v.get("ok") is False]
    if bad:
        print(f"serve smoke gate FAILED: {bad}", file=sys.stderr)
        sys.exit(1)
