"""Topology invariants: group tables, heuristics, mesh refactoring, and the
HLO analyzer cross-checked against XLA's own cost analysis on a loop-free
program."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, strategies as st
from repro.compat import cost_analysis
from repro.core.topology import (
    HBM_BYTES_PER_CHIP, MiCSTopology, choose_partition_size, make_host_mesh,
)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def test_partition_and_replication_groups_cover_world():
    topo = MiCSTopology(make_host_mesh(1, 1, 1, 1))
    assert topo.partition_groups() == [[0]]
    assert topo.world_size == 1
    assert topo.data_parallel_size == 1


@given(st.integers(28, 36), st.integers(0, 3))
def test_choose_partition_size_monotone(log2_params, reserve_step):
    params = 2 ** log2_params
    reserve = 0.2 + 0.05 * reserve_step
    p = choose_partition_size(params, reserve_fraction=reserve)
    assert p in (1, 2, 4, 8, 16)
    # p is minimal: p/2 must NOT fit (when p > 1)
    budget = HBM_BYTES_PER_CHIP * (1 - reserve)
    per_dev = params * 16 / 16
    if p > 1:
        assert per_dev / (p // 2) > budget
    assert per_dev / p <= budget


def test_choose_partition_size_known_models():
    from repro.configs import get_config
    from repro.models.build import exact_param_count

    p_qwen = choose_partition_size(exact_param_count(get_config("qwen1.5-110b")))
    p_1b = choose_partition_size(exact_param_count(get_config("llama3.2-1b")))
    assert p_qwen == 16
    assert p_1b == 1


def test_too_large_model_raises():
    with pytest.raises(ValueError):
        choose_partition_size(10_000_000_000_000)


def test_hlo_analyzer_matches_xla_on_loop_free_program():
    """Without loops the trip-weighted analyzer must agree with XLA's own
    cost analysis on matmul FLOPs."""
    from repro.roofline.hlo_stats import analyze

    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 256), jnp.float32)
    comp = jax.jit(lambda a, b: (a @ b) @ (a @ b).T).lower(a, b).compile()
    got = analyze(comp.as_text(), {"d": 1})
    ca = cost_analysis(comp)
    np.testing.assert_allclose(got["dot_flops"], ca["flops"], rtol=1e-6)


def test_hlo_analyzer_weights_scan_trip_counts():
    from repro.roofline.hlo_stats import analyze

    def f(xs):
        def body(c, x):
            return c + jnp.sum(x @ x), None
        out, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
        return out

    xs = jnp.ones((7, 32, 32), jnp.float32)
    comp = jax.jit(f).lower(xs).compile()
    got = analyze(comp.as_text(), {"d": 1})
    ca = cost_analysis(comp)
    # XLA counts the body once; the analyzer must count it 7 times.
    assert got["dot_flops"] == pytest.approx(7 * 2 * 32 * 32 * 32, rel=1e-6)
    assert ca["flops"] < got["dot_flops"]
