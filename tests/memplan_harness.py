"""Memory-planner verification harness, run in a subprocess with 8 virtual
CPU devices (same pattern as autotune_harness.py).  Prints one JSON object
with named check results; tests/test_memplan.py asserts on them, and the CI
``bench`` job runs it with ``--check`` as the memplan smoke gate (the JSON
is the uploaded ledger artifact).

The property under test is the tentpole contract of core/memplan.py: the
*analytical* per-device HBM footprint (``predict_footprint``) matches XLA's
own compiled ``memory_analysis()`` of the actually-built train step — the
same predicted-vs-compiled discipline the autotuner applies to wire bytes.
Argument bytes (the donated fp32 state + batch) must match EXACTLY;
transient bytes within the documented ``memplan.MEM_RTOL``.

Checks:

  footprint_match       3 gather topologies x {stored, remat} prefetch
                        carries + the serial schedule + the qgZ hop-1 wire
                        on the p=4/repl=2 topology: args exact, temp within
                        tolerance
  footprint_degenerate  partition group == world (p=8, no replication → no
                        hop-2 staging) and a single-device mesh (p=1,
                        nothing on the wire): same contract
  remat_lowers_peak     prefetch_carry='remat' measurably lowers the
                        COMPILED temp bytes vs 'stored' while 3-step
                        loss/grad-norm trajectories stay bitwise equal
  census_match_remat    the remat schedule's collective event counts
                        (2·s·stack+1 gathers, s·stack adjoints) are
                        instruction-exact against the measured census
  carried_buffer_census the carried-gather bytes are visible to
                        hlo_stats.prefetch_census under BOTH carries —
                        remat keeps the double-buffered forward (the
                        residual it drops is what remat_lowers_peak
                        measures)
  offload_lowers_peak   carry_offload='host' lowers BOTH the predicted and
                        the XLA-compiled temp bytes vs the stored carry,
                        and offload_opt=True additionally shrinks the
                        donated argument bytes (the m/v shards leave HBM)
                        — with the predicted-vs-compiled contract (args
                        exact, temp within MEM_RTOL) holding on every
                        offload cell
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import json
import sys

import jax.numpy as jnp
import numpy as np

from repro.bench import measure as MS
from repro.configs import get_config, smoke_variant
from repro.core import memplan as M
from repro.core.autotune import compare_census, predict_traffic
from repro.core.comm import policies_from_config
from repro.core.mics import (
    MiCSConfig, build_train_step, init_state, init_state_shapes,
    make_batch_shapes,
)
from repro.core.topology import MiCSTopology, make_host_mesh
from repro.models.build import build_model
from repro.optim.adamw import OptConfig
from repro.roofline.hlo_stats import analyze

RESULTS = {}
MICRO = 2
GLOBAL_BATCH = 16
SEQ = 16

check = MS.make_check(RESULTS)


def _build(mesh_dims, part, repl, **mcfg_kw):
    cfg = smoke_variant(get_config("llama3.2-1b"))
    topo = MiCSTopology(make_host_mesh(*mesh_dims),
                        partition_axes=part, replication_axes=repl)
    model = build_model(cfg, tp=1)
    mcfg = MiCSConfig(micro_steps=MICRO, **mcfg_kw)
    step = build_train_step(model, topo, mcfg, OptConfig(
        total_steps=100, warmup_steps=0, lr_max=3e-3))
    return model, topo, mcfg, step


def _compile(model, step, offload_opt=False):
    return step.lower(
        init_state_shapes(model, offload_opt=offload_opt),
        make_batch_shapes(model, GLOBAL_BATCH, SEQ, MICRO),
    ).compile()


def _footprint_cell(tag, mesh_dims, part, repl, **mcfg_kw):
    """One predicted-vs-compiled cell; returns its ledger row."""
    model, topo, mcfg, step = _build(mesh_dims, part, repl, **mcfg_kw)
    compiled = _compile(model, step, offload_opt=mcfg.offload_opt)
    ma = compiled.memory_analysis()
    gp, sp = policies_from_config(mcfg)
    n_dev = int(np.prod(mesh_dims))
    local_batch = (GLOBAL_BATCH // MICRO) // n_dev  # tp=1: all devices data
    plan = M.predict_footprint(
        model, topo, gp, sp, micro_steps=MICRO, mode="train",
        local_batch=local_batch, seq=SEQ, boundary=mcfg.boundary_schedule,
        hop2_bucket_mb=mcfg.hop2_bucket_mb, offload_opt=mcfg.offload_opt)
    args_m = ma.argument_size_in_bytes
    temp_m = ma.temp_size_in_bytes
    row = {
        "predicted_args_bytes": plan.args_bytes,
        "measured_args_bytes": args_m,
        "predicted_temp_bytes": plan.temp_bytes,
        "measured_temp_bytes": temp_m,
        "temp_ratio": plan.temp_bytes / temp_m,
        "components": dict(plan.components),
    }
    assert plan.args_bytes == args_m, \
        f"{tag}: predicted args {plan.args_bytes} != measured {args_m}"
    assert abs(plan.temp_bytes - temp_m) <= M.MEM_RTOL * temp_m, \
        f"{tag}: temp predicted {plan.temp_bytes} vs measured {temp_m} " \
        f"outside rtol {M.MEM_RTOL}"
    return row


BASE = ((1, 2, 4, 1), ("shard",), ("pod", "repl"))


# ---------------------------------------------------------------------------
@check("footprint_match")
def _footprint_match():
    detail = {}
    for topology, kw in (
        ("flat", dict(hierarchical=False)),
        ("inner_first", dict()),
        ("outer_first", dict(gather_order="outer_first")),
    ):
        for carry in ("stored", "remat"):
            tag = f"{topology}/{carry}"
            detail[tag] = _footprint_cell(
                tag, *BASE, prefetch_carry=carry, **kw)
    detail["inner_first/serial"] = _footprint_cell(
        "inner_first/serial", *BASE, prefetch=False)
    detail["inner_first/qgz"] = _footprint_cell(
        "inner_first/qgz", *BASE, hop1_wire_dtype="int8")
    RESULTS["footprint_match_detail"] = detail


# ---------------------------------------------------------------------------
@check("footprint_degenerate")
def _footprint_degenerate():
    detail = {
        # partition group == world: no replication, hop 2 vanishes
        "world_partition": _footprint_cell(
            "world_partition", (1, 1, 8, 1), ("shard",), ("repl",)),
        # single-device mesh: p = 1, nothing on the wire
        "single_device": _footprint_cell(
            "single_device", (1, 1, 1, 1), ("shard",), ("repl",)),
    }
    assert "hop2_staging" not in detail["world_partition"]["components"]
    RESULTS["footprint_degenerate_detail"] = detail


# ---------------------------------------------------------------------------
@check("remat_lowers_peak")
def _remat_lowers_peak():
    rng = np.random.default_rng(3)
    cfg = smoke_variant(get_config("llama3.2-1b"))
    b, t = 8, 16
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                            jnp.int32),
        "targets": jnp.array(rng.integers(0, cfg.vocab, (MICRO, b, t)),
                             jnp.int32),
        "mask": jnp.ones((MICRO, b, t), jnp.float32),
    }
    temp = {}
    traj = {}
    for carry in ("stored", "remat"):
        model, topo, _mcfg, step = _build(*BASE, prefetch_carry=carry)
        temp[carry] = _compile(model, step).memory_analysis() \
            .temp_size_in_bytes
        state = init_state(model, topo, seed=7)
        rows = []
        for _ in range(3):
            state, m = step(state, batch)
            rows.append((float(m["loss"]), float(m["grad_norm"])))
        traj[carry] = rows
    assert traj["stored"] == traj["remat"], \
        f"remat changed the numerics: {traj}"
    assert temp["remat"] < temp["stored"], temp
    RESULTS["remat_lowers_peak_detail"] = {
        "temp_bytes": temp,
        "saving_bytes": temp["stored"] - temp["remat"],
        "trajectory_bitwise_equal": True,
    }


# ---------------------------------------------------------------------------
@check("census_match_remat")
def _census_match_remat():
    model, topo, mcfg, step = _build(*BASE, prefetch_carry="remat")
    text = _compile(model, step).as_text()
    mesh_shape = dict(zip(topo.mesh.axis_names, topo.mesh.devices.shape))
    measured = analyze(text, mesh_shape,
                       partition_axes=topo.partition_axes,
                       replication_axes=topo.replication_axes)["by_stage"]
    gp, sp = policies_from_config(mcfg)
    pred = predict_traffic(model, topo, gp, sp, micro_steps=MICRO,
                           upcast_float_collectives=True)["by_stage"]
    cmp = compare_census(pred, measured)
    detail = {}
    for stage, row in cmp.items():
        p_, m_ = row["predicted_wire_bytes"], row["measured_wire_bytes"]
        assert p_ > 0 and m_ > 0, (stage, row)
        assert abs(m_ - p_) <= 0.02 * p_, (stage, row)
        pc, mc = pred[stage]["count"], measured[stage]["count"]
        assert pc == mc, f"{stage}: count predicted {pc} != measured {mc}"
        detail[stage] = {"bytes": m_, "count": mc}
    RESULTS["census_match_remat_detail"] = detail


# ---------------------------------------------------------------------------
@check("carried_buffer_census")
def _carried_buffer_census():
    by_carry = {}
    for carry in ("stored", "remat"):
        model, topo, _mcfg, step = _build(*BASE, prefetch_carry=carry)
        text = _compile(model, step).as_text()
        mesh_shape = dict(zip(topo.mesh.axis_names, topo.mesh.devices.shape))
        by_carry[carry] = analyze(text, mesh_shape)["prefetch"]
    # the stored carry is visible: >0 carried gathers with real payloads
    assert by_carry["stored"]["carried_all_gathers"] > 0
    assert by_carry["stored"]["carried_buffer_bytes"] > 0
    # remat keeps the double-buffered FORWARD (the lookahead gather still
    # flows into the scan carry) — what it drops is the backward residual,
    # which remat_lowers_peak measures via the compiled temp bytes.
    assert by_carry["remat"]["carried_all_gathers"] > 0
    RESULTS["carried_buffer_census_detail"] = by_carry


# ---------------------------------------------------------------------------
@check("offload_lowers_peak")
def _offload_lowers_peak():
    rows = {
        "stored": _footprint_cell("offload/stored", *BASE),
        "host_carry": _footprint_cell(
            "offload/host_carry", *BASE, carry_offload="host"),
        "host_carry_opt": _footprint_cell(
            "offload/host_carry_opt", *BASE, carry_offload="host",
            offload_opt=True),
    }
    s, hc, ho = rows["stored"], rows["host_carry"], rows["host_carry_opt"]
    # the freed carry residual: predicted AND compiled temp bytes drop
    assert hc["predicted_temp_bytes"] < s["predicted_temp_bytes"], rows
    assert hc["measured_temp_bytes"] < s["measured_temp_bytes"], rows
    # offloaded moments leave the donated args (8 bytes/shard element);
    # _footprint_cell already asserted predicted args == compiled args
    assert ho["predicted_args_bytes"] < s["predicted_args_bytes"], rows
    assert ho["measured_args_bytes"] < s["measured_args_bytes"], rows
    # and the end-to-end peak (args + temps) shrinks on both ledgers
    for r in (hc, ho):
        assert r["predicted_args_bytes"] + r["predicted_temp_bytes"] \
            < s["predicted_args_bytes"] + s["predicted_temp_bytes"], rows
        assert r["measured_args_bytes"] + r["measured_temp_bytes"] \
            < s["measured_args_bytes"] + s["measured_temp_bytes"], rows
    RESULTS["offload_lowers_peak_detail"] = rows


# the memplan suite's matrix cells (one contract cell per named check)
RESULTS["cells"] = MS.contract_cells(
    "memplan", RESULTS,
    dict(model="llama3.2-1b-smoke", micro_steps=MICRO,
         global_batch=GLOBAL_BATCH, seq=SEQ))
print(json.dumps(RESULTS, indent=1, default=str))
if "--check" in sys.argv:
    MS.exit_check(RESULTS, "memplan smoke gate")
