"""Boundary-scheduler tests: plan/config units on one device, plus the
8-virtual-device harness (tests/schedule_harness.py) asserting bitwise
serial==bucketed equivalence across bucket sizes (incl. one-bucket and
bucket>total-bytes degenerate cases), gather topologies and wire dtypes,
the HLO-census evidence that hop-2 runs at bucket granularity interleaved
with boundary compute, the approximate-clip pipeline's degenerate/bounded
-divergence guarantees (clip-inactive equivalence, zero-grad, int8 hop-2
composition, convergence within APPROX_CLIP_LOSS_RTOL, AdamW census
interleave), and the host-offload cells' bitwise equivalence."""

import pathlib

import pytest

from harness_util import run_harness
from repro.core.flat_param import bucket_elems, partition_buckets
from repro.core.mics import MiCSConfig
from repro.core.schedule import BoundaryPlan, BucketRef, plan_boundary

HARNESS = pathlib.Path(__file__).parent / "schedule_harness.py"


# ---------------------------------------------------------------------------
# plan / config units (single device)
# ---------------------------------------------------------------------------

def test_bucket_helpers_validate():
    with pytest.raises(ValueError):
        bucket_elems(0.0)
    with pytest.raises(ValueError):
        partition_buckets(100, -1.0)
    assert bucket_elems(1e-9) == 1            # floor at one element
    assert partition_buckets(3, 1e-9) == ((0, 1), (1, 2), (2, 3))


def test_boundary_config_validated():
    with pytest.raises(ValueError):
        MiCSConfig(boundary_schedule="pipelined")
    with pytest.raises(ValueError):
        MiCSConfig(hop2_bucket_mb=0.0)
    with pytest.raises(ValueError):
        BoundaryPlan(mode="eager", bucket_mb=1.0, shard_elems={}, buckets=())


def test_clip_offload_config_validated():
    with pytest.raises(ValueError):
        MiCSConfig(clip_mode="running")
    with pytest.raises(ValueError):   # approx needs the bucket pipeline
        MiCSConfig(clip_mode="approx", boundary_schedule="serial")
    MiCSConfig(clip_mode="approx", boundary_schedule="bucketed")
    with pytest.raises(ValueError):
        MiCSConfig(carry_offload="nvme")
    with pytest.raises(ValueError):   # host carry offloads the stored carry
        MiCSConfig(carry_offload="host", prefetch=False)
    with pytest.raises(ValueError):
        MiCSConfig(carry_offload="host", prefetch_carry="remat")
    MiCSConfig(carry_offload="host", prefetch=True, prefetch_carry="stored")
    with pytest.raises(ValueError):
        BoundaryPlan(mode="bucketed", bucket_mb=1.0, shard_elems={},
                     buckets=(), clip_mode="stale")
    with pytest.raises(ValueError):   # serial has no pipeline to hide under
        BoundaryPlan(mode="serial", bucket_mb=1.0, shard_elems={},
                     buckets=(), clip_mode="approx")


def test_plan_boundary_static_structure(topo1):
    from repro.configs import get_config, smoke_variant
    from repro.models.build import build_model

    model = build_model(smoke_variant(get_config("llama3.2-1b")), tp=1)
    huge = plan_boundary(model, topo1, mode="bucketed", bucket_mb=1e6)
    assert huge.n_buckets == len(model.all_pools())
    tiny = plan_boundary(model, topo1, mode="bucketed", bucket_mb=0.01)
    assert tiny.n_buckets > huge.n_buckets
    # canonical order: pools in all_pools() order, offsets ascending
    names = [p.name for p in model.all_pools()]
    seen = [b.pool for b in tiny.buckets]
    assert seen == sorted(seen, key=names.index)
    for name in names:
        offs = [b.lo for b in tiny.pool_buckets(name)]
        assert offs == sorted(offs)
    d = tiny.describe()
    assert d["n_buckets"] == tiny.n_buckets
    assert d["max_bucket_bytes"] <= int(0.01 * 1e6)
    assert BucketRef("x", 3, 10).elems == 7


def test_autotune_ranks_bucket_axis():
    """policy='auto' must carry the boundary schedule into the config."""
    import dataclasses

    from repro.core.autotune import (
        HOP2_BUCKET_MB_CANDIDATES, enumerate_hop2_schedules, resolve_config,
    )
    from test_autotune import StubModel, topo_single

    topo = topo_single(p=16, repl=2)
    axis = enumerate_hop2_schedules(topo)
    assert ("serial", 32.0) in axis
    assert {mb for b, mb in axis if b == "bucketed"} \
        == set(HOP2_BUCKET_MB_CANDIDATES)
    mcfg = MiCSConfig(policy="auto", link_profile="efa-100g", micro_steps=4)
    resolved, plan = resolve_config(mcfg, StubModel(), topo)
    assert resolved.boundary_schedule in ("serial", "bucketed")
    assert resolved.hop2_bucket_mb == plan.chosen.hop2_bucket_mb
    assert {c.boundary for c in plan.candidates} == {"serial", "bucketed"}
    # exposed <= total for every candidate, strict for some bucketed one
    for c in plan.candidates:
        assert c.t_hop2_exposed_s <= c.t_hop2_total_s + 1e-18
    assert any(c.boundary == "bucketed"
               and c.t_hop2_exposed_s < c.t_hop2_total_s
               for c in plan.candidates)
    d = plan.chosen.describe()
    assert {"boundary", "hop2_bucket_mb", "t_hop2_exposed_s"} <= set(d)
    assert dataclasses.asdict(resolved)["hop2_bucket_mb"] > 0


# ---------------------------------------------------------------------------
# multi-device harness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def harness_results():
    return run_harness(HARNESS)


CHECKS = [
    "bucket_plan", "bitwise_bucket_sizes", "bitwise_topologies",
    "bitwise_compress", "census_interleave",
    "approx_clip_inactive", "approx_zero_grad",
    "approx_clip_active_bounded", "approx_int8_hop2",
    "approx_census_interleave", "offload_host_bitwise",
]


@pytest.mark.parametrize("name", CHECKS)
def test_schedule_check(harness_results, name):
    res = harness_results.get(name)
    assert res is not None, f"harness did not run {name}"
    assert res["ok"], f"{name}: {res.get('err')}\n{res.get('tb', '')}"


def test_census_interleave_counts(harness_results):
    detail = harness_results.get("census_interleave_detail")
    assert detail is not None
    assert detail["bucketed"]["hop2_ops"] > detail["serial"]["hop2_ops"]
    assert detail["bucketed"]["interleaved"]
    assert detail["bucketed"]["hop2_wire_bytes"] \
        == detail["serial"]["hop2_wire_bytes"]


def test_approx_census_counts(harness_results):
    """The approx pipeline's census signature: same bucket-granular hop-2,
    strictly more compute between the hop-2 ops (the pipelined AdamW)."""
    detail = harness_results.get("approx_census_detail")
    assert detail is not None
    assert detail["approx"]["hop2_ops"] == detail["exact"]["hop2_ops"]
    assert detail["approx"]["compute_between_hop2"] \
        > detail["exact"]["compute_between_hop2"]


def test_approx_convergence_bound(harness_results):
    from repro.core.schedule import APPROX_CLIP_LOSS_RTOL

    detail = harness_results.get("approx_convergence_detail")
    assert detail is not None
    assert detail["rtol"] <= APPROX_CLIP_LOSS_RTOL
    assert detail["final_approx"] < 6.0  # it actually learned


def test_offload_stash_accounting(harness_results):
    detail = harness_results.get("offload_detail")
    assert detail is not None
    assert detail["stash_entries"] > 0
    assert detail["stash_entries"] % 2 == 0  # an m and a v per slot
