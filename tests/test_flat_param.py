"""Property-based tests of the flat parameter pool invariants (hypothesis)."""

import jax
import numpy as np

from hypothesis_compat import given, settings, strategies as st
from repro.core.flat_param import PAD_MULTIPLE, LayoutBuilder

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


shapes = st.lists(
    st.tuples(st.integers(1, 8), st.integers(1, 16)), min_size=1, max_size=8)


def _layout(dims):
    b = LayoutBuilder()
    for i, (a, c) in enumerate(dims):
        b.add(f"t{i}", (a, c), decay=(i % 2 == 0),
              init=["normal", "zeros", "ones"][i % 3])
    return b.build()


@given(shapes)
def test_roundtrip(dims):
    layout = _layout(dims)
    key = jax.random.key(0)
    flat = layout.init_flat(key)
    assert flat.shape == (layout.flat_len,)
    assert layout.flat_len % PAD_MULTIPLE == 0
    tensors = layout.unflatten(flat)
    flat2 = layout.flatten(tensors)
    np.testing.assert_array_equal(flat, flat2)
    # segments are contiguous and ordered
    cursor = 0
    for s in layout.segments:
        assert s.offset == cursor
        cursor += s.size
    assert cursor == layout.raw_len <= layout.flat_len


@given(shapes, st.integers(1, 8))
def test_shard_masks_tile_to_full(dims, nshards_pow):
    layout = _layout(dims)
    p = 2 ** (nshards_pow % 4)
    assert layout.flat_len % p == 0
    shard_len = layout.flat_len // p
    full_decay = np.concatenate([
        np.asarray(layout.decay_mask_for_shard(i * shard_len, shard_len))
        for i in range(p)
    ])
    full_pad = np.concatenate([
        np.asarray(layout.padding_mask_for_shard(i * shard_len, shard_len))
        for i in range(p)
    ])
    # padding tail masked out
    assert np.all(full_pad[layout.raw_len:] == 0)
    assert np.all(full_pad[: layout.raw_len] == 1)
    # decay mask honors per-segment decay flags
    for s in layout.segments:
        want = 1.0 if s.decay else 0.0
        assert np.all(full_decay[s.offset:s.end] == want), s.name
    assert np.all(full_decay[layout.raw_len:] == 0)


@given(shapes)
def test_init_kinds(dims):
    layout = _layout(dims)
    flat = layout.init_flat(jax.random.key(1))
    tensors = layout.unflatten(flat)
    for i, s in enumerate(layout.segments):
        t = np.asarray(tensors[s.name])
        if s.init == "zeros":
            assert np.all(t == 0)
        elif s.init == "ones":
            assert np.all(t == 1)
        else:
            assert np.std(t) > 0 or t.size < 4
